"""L2 — JAX model: byte-level SwiGLU transformer with GLASS FFN variants.

This is the build-time half of the three-layer stack: every function here is
lowered once by ``aot.py`` to HLO text and executed from the Rust runtime
(L3). Nothing in this module runs on the request path.

The FFN follows the paper's gated structure (Eq. 1):

    h = (x @ W_up) * silu(x @ W_gate)        # a_u ⊙ a_g, phi_u = id
    y = h @ W_down

GLASS sparsification masks/gathers the hidden units ``h`` (Eq. 2-3). Three
FFN variants exist:

  * dense   — mask of ones (baseline)
  * masked  — multiplicative 0/1 mask input  (used by all quality evals;
              any density with one executable)
  * topk    — gathered computation over a static-k index set, implemented
              by the L1 Pallas kernel (``kernels.sparse_ffn``); this is the
              variant that actually removes FLOPs/weight traffic.

Every forward also emits the ℓ2-normalized per-token activation magnitudes
``hhat = |h| / (||h||_2 + eps)`` aggregated per layer — the statistic the
paper uses for local importance A^l (Eq. 4), the NPS global prior A^g, and
the post-hoc oracle sets (App. C.1).
"""

from __future__ import annotations

import dataclasses
import json
from functools import partial

import jax
import jax.numpy as jnp

from .kernels.sparse_ffn import sparse_ffn_pallas

EPS = 1e-6


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (mirrored in artifacts/model.json)."""

    vocab: int = 260  # 256 bytes + BOS(256) + PAD(257) + 2 unused
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    head_dim: int = 32
    ffn_m: int = 512
    max_seq: int = 224  # KV-cache length T
    prefill_len: int = 96  # S for prefill/generate executables
    score_len: int = 224  # S for the teacher-forced scorer
    gen_len: int = 96  # N decode steps inside the fused generator
    rope_base: float = 10000.0
    bos_id: int = 256
    pad_id: int = 257

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2)


# ------------------------------------------------------------------ params


def init_params(cfg: ModelConfig, seed: int = 0):
    """Random init. Stacked per-layer arrays (leading n_layers dim) so the
    forward pass can scan over layers -> compact HLO."""
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 12)
    d, m, L = cfg.d_model, cfg.ffn_m, cfg.n_layers
    sd = d**-0.5
    sm = m**-0.5

    def nrm(key, shape, scale):
        return (jax.random.normal(key, shape) * scale).astype(jnp.float32)

    return {
        "embed": nrm(ks[0], (cfg.vocab, d), 1.0),
        "head": nrm(ks[1], (d, cfg.vocab), sd),
        "ln_f": jnp.ones((d,), jnp.float32),
        "layers": {
            "ln1": jnp.ones((L, d), jnp.float32),
            "ln2": jnp.ones((L, d), jnp.float32),
            "wq": nrm(ks[2], (L, d, d), sd),
            "wk": nrm(ks[3], (L, d, d), sd),
            "wv": nrm(ks[4], (L, d, d), sd),
            "wo": nrm(ks[5], (L, d, d), sd),
            "w_up": nrm(ks[6], (L, d, m), sd),
            "w_gate": nrm(ks[7], (L, d, m), sd),
            "w_down": nrm(ks[8], (L, m, d), sm),
        },
    }


def param_spec(cfg: ModelConfig):
    """Flattened (path, shape) list in jax tree_flatten order — the contract
    with the Rust weight store (artifacts/manifest.json)."""
    params = jax.eval_shape(lambda: init_params(cfg))
    leaves_with_path = jax.tree_util.tree_flatten_with_path(params)[0]
    spec = []
    for path, leaf in leaves_with_path:
        name = "/".join(
            p.key if hasattr(p, "key") else str(p) for p in path
        )
        spec.append((name, tuple(int(s) for s in leaf.shape)))
    return spec


def flatten_params(params):
    return jax.tree_util.tree_leaves(params)


def unflatten_params(cfg: ModelConfig, leaves):
    shape = jax.eval_shape(lambda: init_params(cfg))
    treedef = jax.tree_util.tree_structure(shape)
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ------------------------------------------------------------------- util


def rmsnorm(x, w):
    return x * w * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + EPS)


def _rope_angles(cfg: ModelConfig, pos):
    """pos: [...] int32 -> cos/sin of shape [..., head_dim//2]."""
    half = cfg.head_dim // 2
    freqs = cfg.rope_base ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: [..., head_dim]; cos/sin broadcastable [..., head_dim//2]."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def hhat(h):
    """ℓ2-normalized activation magnitude per token (paper Eq. 4)."""
    return jnp.abs(h) / (jnp.linalg.norm(h, axis=-1, keepdims=True) + EPS)


def _split_heads(cfg, x):
    # [B, S, d] -> [B, H, S, Dh]
    b, s, _ = x.shape
    return x.reshape(b, s, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)


# ------------------------------------------------ full-sequence forward


def _layer_full(cfg: ModelConfig, x, lw, pos, attn_mask, ffn_mask):
    """One transformer layer over a full sequence.

    x: [B,S,d]; lw: per-layer weights (unstacked); pos: [S];
    attn_mask: [B,1,S,S] additive; ffn_mask: [B,m] (0/1 or ones).
    Returns (x', k, v, hh) with k/v: [B,H,S,Dh], hh: [B,S,m] per-token hhat.
    """
    xin = rmsnorm(x, lw["ln1"])
    q = _split_heads(cfg, xin @ lw["wq"])
    k = _split_heads(cfg, xin @ lw["wk"])
    v = _split_heads(cfg, xin @ lw["wv"])
    cos, sin = _rope_angles(cfg, pos)  # [S, Dh/2]
    cos, sin = cos[None, None], sin[None, None]
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * (cfg.head_dim**-0.5)
    scores = scores + attn_mask
    att = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    b, _, s, _ = out.shape
    out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.d_model)
    x = x + out @ lw["wo"]

    xin2 = rmsnorm(x, lw["ln2"])
    h = (xin2 @ lw["w_up"]) * jax.nn.silu(xin2 @ lw["w_gate"])
    h = h * ffn_mask[:, None, :]
    x = x + h @ lw["w_down"]
    return x, k, v, hhat(h)


def forward_full(cfg: ModelConfig, params, tokens, pos, attn_mask, ffn_mask,
                 stats_w):
    """Full-sequence forward shared by prefill/score/generate.

    tokens: [B,S] i32; pos: [S]; attn_mask: [B,1,S,S] additive;
    ffn_mask: [B,L,m]; stats_w: [B,S] aggregation weights for stats.
    Returns (logits[B,S,V], k[L,B,H,S,Dh], v[L,...], stats[B,L,m]).
    """
    x = params["embed"][tokens]

    def body(x, lw_and_mask):
        lw, fmask = lw_and_mask
        x, k, v, hh = _layer_full(cfg, x, lw, pos, attn_mask, fmask)
        stats = jnp.einsum("bs,bsm->bm", stats_w, hh)
        return x, (k, v, stats)

    masks = jnp.swapaxes(ffn_mask, 0, 1)  # [L,B,m]
    x, (k, v, stats) = jax.lax.scan(body, x, (params["layers"], masks))
    x = rmsnorm(x, params["ln_f"])
    logits = x @ params["head"]
    return logits, k, v, jnp.swapaxes(stats, 0, 1)  # stats -> [B,L,m]


# --------------------------------------------------------------- prefill


def causal_mask(cfg, lens, s):
    """[B,1,S,S] additive mask: causal AND key-position < len."""
    i = jnp.arange(s)
    causal = i[None, :, None] >= i[None, None, :]  # [1,S,S] q >= k
    valid = i[None, None, :] < lens[:, None, None]  # [B,1,S]
    ok = causal & valid
    return jnp.where(ok[:, None], 0.0, -1e9).astype(jnp.float32)


def _pad_kv(cfg, k, v, s):
    pad = cfg.max_seq - s
    k = jnp.pad(k, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
    return k, v


def apply_prefill(cfg: ModelConfig, params, tokens, lens):
    """tokens: [B,S] (PAD beyond lens), lens: [B] i32.

    Returns (logits[B,V] at position lens-1,
             k[L,B,H,T,Dh], v[L,B,H,T,Dh]  (zero beyond S),
             stats[B,L,m]  mean hhat over valid prompt tokens  = A^l).
    """
    b, s = tokens.shape
    amask = causal_mask(cfg, lens, s)
    valid = (jnp.arange(s)[None, :] < lens[:, None]).astype(jnp.float32)
    stats_w = valid / jnp.maximum(valid.sum(-1, keepdims=True), 1.0)
    ones = jnp.ones((b, cfg.n_layers, cfg.ffn_m), jnp.float32)
    logits, k, v, stats = forward_full(
        cfg, params, tokens, jnp.arange(s), amask, ones, stats_w
    )
    last = jnp.take_along_axis(logits, (lens - 1)[:, None, None], 1)[:, 0]
    k, v = _pad_kv(cfg, k, v, s)
    return last, k, v, stats


# --------------------------------------------------------- chunked prefill


def _chunk_causal_mask(cfg, pos_q):
    """[B,1,S,T] additive mask over CACHE key positions: key t is
    attendable iff t <= the query's absolute position (earlier chunks'
    rows are all < offset, so they are covered automatically)."""
    tpos = jnp.arange(cfg.max_seq)
    ok = tpos[None, None, None, :] <= pos_q[:, None, :, None]
    return jnp.where(ok, 0.0, -1e9).astype(jnp.float32)


def _layer_prefill_chunk(cfg: ModelConfig, x, lw, kc, vc, pos_q, valid,
                         attn_mask):
    """One layer over a prompt chunk with a carry-in KV cache.

    x: [B,S,d]; kc/vc: [B,H,T,Dh] (cache; this chunk's rows scattered
    in at absolute positions); pos_q: [B,S] absolute positions;
    valid: [B,S] 0/1 chunk-token validity; attn_mask: [B,1,S,T].
    Returns (x', kc', vc', hh[B,S,m]).
    """
    b, s, _ = x.shape
    xin = rmsnorm(x, lw["ln1"])
    q = _split_heads(cfg, xin @ lw["wq"])
    k = _split_heads(cfg, xin @ lw["wk"])
    v = _split_heads(cfg, xin @ lw["wv"])
    cos, sin = _rope_angles(cfg, pos_q)  # [B, S, Dh/2]
    cos, sin = cos[:, None], sin[:, None]  # [B, 1, S, Dh/2]
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    # scatter ONLY the chunk's valid rows into the cache (pad rows are
    # never written — the host contract mirrored by the simulator)
    oh = jax.nn.one_hot(pos_q, cfg.max_seq, dtype=jnp.float32)
    oh = oh * valid[:, :, None]  # [B,S,T]
    written = oh.sum(1)  # [B,T]
    keep = (1.0 - written)[:, None, :, None]
    kc = kc * keep + jnp.einsum("bst,bhsd->bhtd", oh, k)
    vc = vc * keep + jnp.einsum("bst,bhsd->bhtd", oh, v)

    scores = jnp.einsum("bhsd,bhtd->bhst", q, kc) * (cfg.head_dim**-0.5)
    scores = scores + attn_mask
    att = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", att, vc)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.d_model)
    x = x + out @ lw["wo"]

    xin2 = rmsnorm(x, lw["ln2"])
    h = (xin2 @ lw["w_up"]) * jax.nn.silu(xin2 @ lw["w_gate"])
    x = x + h @ lw["w_down"]
    return x, kc, vc, hhat(h)


def apply_prefill_chunk(cfg: ModelConfig, params, tokens, lens, offsets,
                        k, v):
    """One chunk of a chunked prefill (long prompts over the fixed frame).

    tokens: [B,S] (PAD beyond lens), lens: [B] valid tokens in THIS
    chunk (0 = idle slot), offsets: [B] absolute position of the chunk's
    first token, k/v: [L,B,H,T,Dh] carry-in cache holding the previous
    chunks' rows.

    Returns (logits[B,V] at the chunk's last valid position,
             k'/v' with this chunk's rows appended at offset..offset+len,
             stats[B,L,m] mean hhat over THIS chunk's valid tokens —
             the host merges chunks token-count-weighted into the same
             A^l a monolithic prefill would emit).
    """
    b, s = tokens.shape
    pos_q = offsets[:, None] + jnp.arange(s)[None, :]  # [B,S] absolute
    valid = (jnp.arange(s)[None, :] < lens[:, None]).astype(jnp.float32)
    amask = _chunk_causal_mask(cfg, pos_q)
    stats_w = valid / jnp.maximum(
        lens[:, None].astype(jnp.float32), 1.0
    )
    x = params["embed"][tokens]

    def body(x, lw_kv):
        lw, kc, vc = lw_kv
        x, kc, vc, hh = _layer_prefill_chunk(
            cfg, x, lw, kc, vc, pos_q, valid, amask
        )
        stats = jnp.einsum("bs,bsm->bm", stats_w, hh)
        return x, (kc, vc, stats)

    x, (k, v, stats) = jax.lax.scan(body, x, (params["layers"], k, v))
    x = rmsnorm(x, params["ln_f"])
    logits = x @ params["head"]  # [B,S,V]
    last = jnp.take_along_axis(
        logits, jnp.maximum(lens - 1, 0)[:, None, None], 1
    )[:, 0]
    return last, k, v, jnp.swapaxes(stats, 0, 1)  # stats -> [B,L,m]


# ----------------------------------------------------------------- score


def apply_score(cfg: ModelConfig, params, tokens, stats_w, ffn_mask):
    """Teacher-forced scorer: full logits under a static FFN mask.

    tokens: [B,S]; stats_w: [B,S] (arbitrary non-neg aggregation weights —
    select prompt region, generation region, ...); ffn_mask: [B,L,m].
    Returns (logits[B,S,V], stats[B,L,m] = sum_s stats_w * hhat).
    """
    b, s = tokens.shape
    lens = jnp.full((b,), s, jnp.int32)
    amask = causal_mask(cfg, lens, s)
    logits, _, _, stats = forward_full(
        cfg, params, tokens, jnp.arange(s), amask, ffn_mask, stats_w
    )
    return logits, stats


# ---------------------------------------------------------------- decode


def _layer_decode(cfg: ModelConfig, x, lw, kc, vc, pos, ffn_h_fn):
    """Single-token decode for one layer.

    x: [B,d]; kc/vc: [B,H,T,Dh]; pos: [B] i32 (write position);
    ffn_h_fn: fn(xin2[B,d], lw) -> (ffn_out[B,d], stats[B,?]).
    Returns (x', kc', vc', stats).
    """
    b = x.shape[0]
    xin = rmsnorm(x, lw["ln1"])
    q = (xin @ lw["wq"]).reshape(b, cfg.n_heads, cfg.head_dim)
    k = (xin @ lw["wk"]).reshape(b, cfg.n_heads, cfg.head_dim)
    v = (xin @ lw["wv"]).reshape(b, cfg.n_heads, cfg.head_dim)
    cos, sin = _rope_angles(cfg, pos)  # [B, Dh/2]
    q = apply_rope(q, cos[:, None], sin[:, None])
    k = apply_rope(k, cos[:, None], sin[:, None])

    oh = jax.nn.one_hot(pos, cfg.max_seq, dtype=jnp.float32)  # [B,T]
    ohk = oh[:, None, :, None]
    kc = kc * (1.0 - ohk) + k[:, :, None, :] * ohk
    vc = vc * (1.0 - ohk) + v[:, :, None, :] * ohk

    scores = jnp.einsum("bhd,bhtd->bht", q, kc) * (cfg.head_dim**-0.5)
    tpos = jnp.arange(cfg.max_seq)[None, :]
    scores = jnp.where(tpos[:, None] <= pos[:, None, None], scores, -1e9)
    att = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bht,bhtd->bhd", att, vc).reshape(b, cfg.d_model)
    x = x + out @ lw["wo"]

    xin2 = rmsnorm(x, lw["ln2"])
    y, stats = ffn_h_fn(xin2, lw)
    x = x + y
    return x, kc, vc, stats


def _decode_core(cfg, params, token, pos, k, v, ffn_h_fn, extras):
    """extras: [L, ...] per-layer extra FFN input (mask or index set)."""
    x = params["embed"][token]  # [B,d]

    def body(x, lw_kv):
        lw, kc, vc, extra = lw_kv
        x, kc, vc, stats = _layer_decode(
            cfg, x, lw, kc, vc, pos, partial(ffn_h_fn, extra)
        )
        return x, (kc, vc, stats)

    x, (k, v, stats) = jax.lax.scan(body, x, (params["layers"], k, v, extras))
    x = rmsnorm(x, params["ln_f"])
    return x @ params["head"], k, v, stats


def apply_decode(cfg: ModelConfig, params, token, pos, k, v, ffn_mask):
    """One masked decode step.

    token: [B] i32; pos: [B] i32 (per-slot position — continuous batching);
    k/v: [L,B,H,T,Dh]; ffn_mask: [B,L,m].
    Returns (logits[B,V], k', v', stats[B,L,m] = hhat of this token).
    """

    def ffn(mask, xin2, lw):
        h = (xin2 @ lw["w_up"]) * jax.nn.silu(xin2 @ lw["w_gate"])
        h = h * mask
        return h @ lw["w_down"], hhat(h)

    extras = jnp.swapaxes(ffn_mask, 0, 1)  # [L,B,m]
    logits, k, v, stats = _decode_core(cfg, params, token, pos, k, v, ffn,
                                       extras)
    return logits, k, v, jnp.swapaxes(stats, 0, 1)


def apply_decode_topk(cfg: ModelConfig, params, token, pos, k, v, idx):
    """One gathered-sparse decode step (L1 Pallas kernel on the FFN).

    idx: [B,L,K] i32 — per-slot per-layer critical-neuron indices.
    Returns (logits[B,V], k', v', gstats[B,L,K] = hhat over gathered units).
    """

    def ffn(ids, xin2, lw):
        y, habs = sparse_ffn_pallas(
            xin2, ids, lw["w_up"], lw["w_gate"], lw["w_down"]
        )
        return y, habs

    extras = jnp.swapaxes(idx, 0, 1)  # [L,B,K]
    logits, k, v, stats = _decode_core(cfg, params, token, pos, k, v, ffn,
                                       extras)
    return logits, k, v, jnp.swapaxes(stats, 0, 1)


# -------------------------------------------------------- fused generator


def apply_generate(cfg: ModelConfig, params, tokens, lens, ffn_mask):
    """Fused prefill + N-step greedy decode under a static FFN mask.

    The whole decode loop runs inside one XLA program (lax.scan), so the
    KV cache never crosses the host boundary — this is the L2-optimized
    path used for dense-trajectory generation and sparse generation evals.

    tokens: [B,S] prompt (PAD beyond lens); lens: [B]; ffn_mask: [B,L,m].
    Returns (gen_tokens[B,N] i32,
             gen_logits[B,N,V]  next-token logits after each generated tok,
             gen_stats[B,L,m]   mean hhat over the N generated tokens —
                                the paper's post-hoc decoding-time oracle
                                statistic when run dense).
    """
    b, s = tokens.shape
    amask = causal_mask(cfg, lens, s)
    valid = (jnp.arange(s)[None, :] < lens[:, None]).astype(jnp.float32)
    stats_w = valid / jnp.maximum(valid.sum(-1, keepdims=True), 1.0)
    logits0, kc, vc, _ = forward_full(
        cfg, params, tokens, jnp.arange(s), amask, ffn_mask, stats_w
    )
    last = jnp.take_along_axis(logits0, (lens - 1)[:, None, None], 1)[:, 0]
    kc, vc = _pad_kv(cfg, kc, vc, s)

    tok0 = jnp.argmax(last, axis=-1).astype(jnp.int32)
    pos0 = lens.astype(jnp.int32)

    def step(carry, _):
        tok, pos, k, v = carry
        logits, k, v, stats = apply_decode(cfg, params, tok, pos, k, v,
                                           ffn_mask)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return (nxt, pos + 1, k, v), (tok, logits, stats)

    # step i consumes generated token t_i and emits the distribution over
    # t_{i+1}: gen_tokens[:, i] = t_i, gen_logits[:, i] = p(. | ..., t_i).
    _, (toks, glogits, gstats) = jax.lax.scan(
        step, (tok0, pos0, kc, vc), None, length=cfg.gen_len
    )
    gen_tokens = jnp.swapaxes(toks, 0, 1)  # [B,N]
    gen_logits = jnp.swapaxes(glogits, 0, 1)  # [B,N,V]
    gen_stats = jnp.mean(gstats, axis=0)  # [B,L,m]
    return gen_tokens, gen_logits, gen_stats


# ----------------------------------------------- loss / impact (I^g) path


def loss_with_h_probe(cfg: ModelConfig, params, probe, tokens, labels, wmask):
    """Cross-entropy with an additive zero 'probe' on every FFN hidden
    vector h — grad w.r.t. the probe equals dL/dh, giving the paper's
    I^g = E|h_j * dL/dh_j| (Eq. 5-6) without a hand-written backward pass.

    probe: [L,B,S,m] (zeros); tokens/labels: [B,S]; wmask: [B,S] valid
    next-token positions. Returns (scalar loss, h values [L,B,S,m]).
    """
    b, s = tokens.shape
    lens = jnp.full((b,), s, jnp.int32)
    amask = causal_mask(cfg, lens, s)
    pos = jnp.arange(s)
    x = params["embed"][tokens]

    def body(x, lw_probe):
        lw, pr = lw_probe
        xin = rmsnorm(x, lw["ln1"])
        q = _split_heads(cfg, xin @ lw["wq"])
        k = _split_heads(cfg, xin @ lw["wk"])
        v = _split_heads(cfg, xin @ lw["wv"])
        cos, sin = _rope_angles(cfg, pos)
        q = apply_rope(q, cos[None, None], sin[None, None])
        k = apply_rope(k, cos[None, None], sin[None, None])
        sc = jnp.einsum("bhqd,bhkd->bhqk", q, k) * (cfg.head_dim**-0.5)
        att = jax.nn.softmax(sc + amask, axis=-1)
        out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
        out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.d_model)
        x = x + out @ lw["wo"]
        xin2 = rmsnorm(x, lw["ln2"])
        h = (xin2 @ lw["w_up"]) * jax.nn.silu(xin2 @ lw["w_gate"])
        h = h + pr
        x = x + h @ lw["w_down"]
        return x, h

    x, hs = jax.lax.scan(body, x, (params["layers"], probe))
    x = rmsnorm(x, params["ln_f"])
    logits = x @ params["head"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], -1)[..., 0]
    loss = (nll * wmask).sum() / jnp.maximum(wmask.sum(), 1.0)
    return loss, hs


def impact_and_activation(cfg: ModelConfig, params, tokens, labels, wmask):
    """Per-layer I^g and A^g contributions for one batch of sequences.

    Returns (i_stats[L,m] = sum over valid tokens |h * dL/dh|,
             a_stats[L,m] = sum over valid tokens hhat,
             n_tokens scalar).
    """
    b, s = tokens.shape
    probe = jnp.zeros((cfg.n_layers, b, s, cfg.ffn_m), jnp.float32)
    grads, hs = jax.grad(
        lambda pr: loss_with_h_probe(cfg, params, pr, tokens, labels, wmask),
        has_aux=True,
    )(probe)
    w = wmask[None, :, :, None]
    i_stats = jnp.sum(jnp.abs(hs * grads) * w, axis=(1, 2))
    a_stats = jnp.sum(hhat(hs) * w, axis=(1, 2))
    return i_stats, a_stats, wmask.sum()


# ------------------------------------------------------------- LM training


def lm_loss(cfg: ModelConfig, params, tokens, labels, wmask):
    """Plain next-token CE used by train.py (no probe, no stats)."""
    b, s = tokens.shape
    lens = jnp.full((b,), s, jnp.int32)
    amask = causal_mask(cfg, lens, s)
    ones = jnp.ones((b, cfg.n_layers, cfg.ffn_m), jnp.float32)
    stats_w = jnp.zeros((b, s), jnp.float32)
    logits, _, _, _ = forward_full(
        cfg, params, tokens, jnp.arange(s), amask, ones, stats_w
    )
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], -1)[..., 0]
    return (nll * wmask).sum() / jnp.maximum(wmask.sum(), 1.0)
