"""Deterministic grammar-world corpus generator.

Substitute for the paper's natural-language corpora (WikiText for priors,
Alpaca for the LG benchmark). A small templated grammar over a fixed
"world" of entities/attributes/relations produces English-like text with
enough structure for a ~1M-param byte-level LM to learn non-trivial
next-token statistics — which is all GLASS's activation-statistics
machinery needs. Everything is seeded and reproducible.

Splits (disjoint by construction, via seed domains):
  train   — LM training text
  prior   — "corpus prior" estimation text (WikiText substitute, Tab. 3)
  oracle  — held-out text for the oracle-overlap analysis (Tab. 5 / Fig. 1)
  eval    — source of LG/classification/short-gen benchmark items
"""

from __future__ import annotations

import random
from dataclasses import dataclass

# ---------------------------------------------------------------- world ---

ANIMALS = [
    "fox", "dog", "cat", "owl", "wolf", "bear", "hare", "crow", "deer",
    "frog", "mouse", "horse", "lynx", "otter", "raven", "swan",
]
COLORS = [
    "red", "blue", "green", "grey", "black", "white", "brown", "golden",
    "silver", "amber",
]
TRAITS = [
    "quick", "lazy", "clever", "quiet", "brave", "gentle", "hungry",
    "sleepy", "curious", "careful", "proud", "shy",
]
PLACES = [
    "river", "forest", "meadow", "hill", "lake", "valley", "garden",
    "bridge", "cave", "shore",
]
WEATHERS = ["sunny", "rainy", "windy", "cloudy", "snowy", "foggy", "clear"]
TIMES = ["morning", "noon", "evening", "night", "dawn", "dusk"]
VERBS = [
    "runs", "jumps", "sleeps", "hunts", "sings", "swims", "hides",
    "watches", "waits", "plays", "rests", "drinks",
]
NUMBER_WORDS = [
    "zero", "one", "two", "three", "four", "five", "six", "seven",
    "eight", "nine", "ten", "eleven", "twelve",
]


def number_word(n: int) -> str:
    return NUMBER_WORDS[n]


# ---------------------------------------------------------- sentence fns ---


def _s_scene(rng: random.Random) -> str:
    a = rng.choice(ANIMALS)
    c = rng.choice(COLORS)
    t = rng.choice(TRAITS)
    v = rng.choice(VERBS)
    p = rng.choice(PLACES)
    return f"the {c} {a} is {t} and {v} near the {p}."


def _s_weather(rng: random.Random) -> str:
    w = rng.choice(WEATHERS)
    tm = rng.choice(TIMES)
    return f"in the {tm} the weather is {w}."


def _s_relation(rng: random.Random) -> str:
    a1, a2 = rng.sample(ANIMALS, 2)
    v = rng.choice(VERBS)
    p = rng.choice(PLACES)
    return f"the {a1} {v} beside the {a2} at the {p}."


def _s_arith(rng: random.Random) -> str:
    x = rng.randint(0, 6)
    y = rng.randint(0, 6)
    return f"{number_word(x)} plus {number_word(y)} is {number_word(x + y)}."


def _s_count(rng: random.Random) -> str:
    n = rng.randint(2, 9)
    a = rng.choice(ANIMALS)
    p = rng.choice(PLACES)
    return f"{number_word(n)} {a}s live by the {p}."


def _s_qa_color(rng: random.Random) -> str:
    # context-bound QA: answer is derivable from the context sentence, so
    # the LM learns to copy from context (CoQA/QASPER substitute skill).
    a = rng.choice(ANIMALS)
    c = rng.choice(COLORS)
    v = rng.choice(VERBS)
    p = rng.choice(PLACES)
    return (f"the {c} {a} {v} near the {p}. "
            f"Q: what color is the {a}? A: {c}.")


def _s_qa_place(rng: random.Random) -> str:
    a = rng.choice(ANIMALS)
    c = rng.choice(COLORS)
    p = rng.choice(PLACES)
    v = rng.choice(VERBS)
    return (f"the {c} {a} {v} near the {p}. "
            f"Q: where is the {a}? A: near the {p}.")


def _s_bool(rng: random.Random) -> str:
    # BoolQ substitute: yes/no grounded in the context sentence.
    a = rng.choice(ANIMALS)
    c = rng.choice(COLORS)
    if rng.random() < 0.5:
        c2, ans = c, "yes"
    else:
        c2 = rng.choice([x for x in COLORS if x != c])
        ans = "no"
    return f"the {a} is {c}. Q: is the {a} {c2}? A: {ans}."


def _s_summary(rng: random.Random) -> str:
    # XSum/CNN-DM substitute: short passage followed by a one-line summary
    # in a fixed format the LM can learn to produce. Kept under ~80 bytes
    # so eval prompts fit the prefill window.
    a = rng.choice(ANIMALS)
    c = rng.choice(COLORS)
    t = rng.choice(TRAITS)
    p = rng.choice(PLACES)
    tm = rng.choice(TIMES)
    v1 = rng.choice(VERBS)
    passage = f"the {c} {a} who was very {t} {v1} near the {p} every {tm}."
    return f"{passage} summary: the {t} {c} {a} stayed near the {p}."


def _s_story(rng: random.Random) -> str:
    a = rng.choice(ANIMALS)
    c = rng.choice(COLORS)
    t = rng.choice(TRAITS)
    p = rng.choice(PLACES)
    w = rng.choice(WEATHERS)
    tm = rng.choice(TIMES)
    v1, v2 = rng.sample(VERBS, 2)
    return (
        f"once there was a {c} {a} who was very {t}. "
        f"every {tm} the {a} {v1} near the {p}. "
        f"when the weather turned {w}, the {a} {v2} until the next {tm}."
    )


SENTENCE_FNS = [
    (_s_scene, 4),
    (_s_weather, 2),
    (_s_relation, 3),
    (_s_arith, 2),
    (_s_count, 2),
    (_s_qa_color, 2),
    (_s_qa_place, 2),
    (_s_bool, 2),
    (_s_summary, 2),
    (_s_story, 3),
]

_FNS = [f for f, w in SENTENCE_FNS for _ in range(w)]


@dataclass
class CorpusConfig:
    seed: int = 0
    n_chars: int = 400_000


SPLIT_SEEDS = {"train": 1000, "prior": 2000, "oracle": 3000, "eval": 4000}


def generate_text(split: str, n_chars: int, seed: int = 0) -> str:
    """Generate `split` text of at least n_chars characters."""
    if split not in SPLIT_SEEDS:
        raise ValueError(f"unknown split {split!r}")
    rng = random.Random(SPLIT_SEEDS[split] + seed * 17)
    parts: list[str] = []
    total = 0
    while total < n_chars:
        s = rng.choice(_FNS)(rng)
        parts.append(s)
        total += len(s) + 1
    return " ".join(parts)


def story_prompt(rng: random.Random) -> str:
    """Short LG-benchmark prompt (Alpaca substitute): <=32 bytes-ish."""
    a = rng.choice(ANIMALS)
    c = rng.choice(COLORS)
    return f"once there was a {c} {a}"


if __name__ == "__main__":
    for split in SPLIT_SEEDS:
        t = generate_text(split, 2000)
        print(split, len(t), repr(t[:120]))
