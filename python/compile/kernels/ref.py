"""Pure-jnp oracle implementations for the L1 kernels.

Everything here is straight-line jnp with no Pallas, no tiling, no
accumulation tricks — the reference semantics the kernels must match.
pytest/hypothesis sweep shapes and dtypes against these (python/tests/).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gated_ffn_ref(x, w_up, w_gate, w_down):
    """Dense gated FFN (paper Eq. 1 with phi_u = id, phi_g = silu)."""
    h = (x @ w_up) * jax.nn.silu(x @ w_gate)
    return h @ w_down, h


def masked_ffn_ref(x, mask, w_up, w_gate, w_down):
    """Multiplicative-mask FFN: h_j zeroed where mask_j == 0 (Eq. 2-3)."""
    h = (x @ w_up) * jax.nn.silu(x @ w_gate) * mask
    return h @ w_down


def sparse_ffn_ref(x, idx, w_up, w_gate, w_down):
    """Gathered FFN over index set idx: [B, K].

    Returns (y [B, d], habs [B, K] = ℓ2-normalized |h| of gathered units).
    Semantically equal to masked_ffn_ref with a 0/1 mask built from idx
    (when idx has no duplicates).
    """
    wu = jnp.take(w_up, idx, axis=1)  # [d, B, K] -> move batch out
    wu = jnp.moveaxis(wu, 1, 0)  # [B, d, K]
    wg = jnp.moveaxis(jnp.take(w_gate, idx, axis=1), 1, 0)
    wd = jnp.take(w_down, idx, axis=0)  # [B, K, d]
    zu = jnp.einsum("bd,bdk->bk", x, wu)
    zg = jnp.einsum("bd,bdk->bk", x, wg)
    h = zu * jax.nn.silu(zg)
    y = jnp.einsum("bk,bkd->bd", h, wd)
    habs = jnp.abs(h) / (jnp.linalg.norm(h, axis=-1, keepdims=True) + 1e-6)
    return y, habs


def mask_from_idx(idx, m):
    """0/1 mask [B, m] from index set [B, K] (assumes unique ids)."""
    b, _ = idx.shape
    mask = jnp.zeros((b, m), jnp.float32)
    return mask.at[jnp.arange(b)[:, None], idx].set(1.0)
