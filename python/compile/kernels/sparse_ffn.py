"""L1 — Pallas kernel: gathered gated FFN over a static top-k neuron set.

This is the compute hot-spot of GLASS's decode phase. Given the per-request
critical-neuron index set ``idx`` (built by the L3 rank-aggregation step),
the kernel computes only the k selected hidden units:

    h_k = (x @ W_up[:, idx]) * silu(x @ W_gate[:, idx])
    y   = h_k @ W_down[idx, :]

so FLOPs and FFN weight traffic scale with k instead of m — the paper's
"compact FFN subset" realized as computation.

Hardware adaptation (DESIGN.md §4): the paper's on-device numbers come from
a phone runtime; on TPU the natural shape is k-tiled panels staged
HBM→VMEM and fed to the MXU as dense [d, k_tile] matmuls. The grid below
is (batch, k/block_k): each step gathers one k-panel of the three weight
matrices and accumulates the down-projection. With ``interpret=True``
(mandatory on this CPU-only image — real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute) the same schedule runs as
plain XLA ops; VMEM/MXU behaviour is estimated analytically in DESIGN.md §8.

Correctness is pinned to ``ref.sparse_ffn_ref`` by pytest + hypothesis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_K = 128


def sparse_ffn_pallas(x, idx, w_up, w_gate, w_down, *, block_k=DEFAULT_BLOCK_K):
    """Gathered gated FFN.

    x:      [B, d]   f32 input activations
    idx:    [B, K]   i32 neuron ids (any order; need not be sorted)
    w_up:   [d, m]   f32
    w_gate: [d, m]   f32
    w_down: [m, d]   f32
    Returns (y [B, d], habs [B, K]) where habs are the ℓ2-normalized
    magnitudes of the gathered hidden units (stats for drift monitoring).
    """
    b, d = x.shape
    k = idx.shape[1]
    if k % block_k != 0:
        block_k = k  # tiny shapes (tests): single panel
    nk = k // block_k

    def kernel(x_ref, idx_ref, wu_ref, wg_ref, wd_ref, y_ref, h_ref):
        # one (batch row, k-panel) step
        xv = x_ref[...]  # [1, d]
        ids = idx_ref[...][0]  # [block_k]
        wu = wu_ref[...][:, ids]  # gather panel [d, block_k]
        wg = wg_ref[...][:, ids]
        wd = wd_ref[...][ids, :]  # [block_k, d]
        zu = xv @ wu
        zg = xv @ wg
        h = zu * jax.nn.sigmoid(zg) * zg  # silu(zg) = zg*sigmoid(zg)
        h_ref[...] = h
        contrib = h @ wd

        @pl.when(pl.program_id(1) == 0)
        def _init():
            y_ref[...] = jnp.zeros_like(y_ref)

        y_ref[...] += contrib

    grid = (b, nk)
    y, h = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_k), lambda i, j: (i, j)),
            pl.BlockSpec((d, w_up.shape[1]), lambda i, j: (0, 0)),
            pl.BlockSpec((d, w_gate.shape[1]), lambda i, j: (0, 0)),
            pl.BlockSpec((w_down.shape[0], d), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_k), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, d), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.float32),
        ],
        interpret=True,
    )(x, idx, w_up, w_gate, w_down)
    # h currently holds raw gathered h; normalize magnitudes per token.
    habs = jnp.abs(h) / (jnp.linalg.norm(h, axis=-1, keepdims=True) + 1e-6)
    return y, habs


def masked_ffn_pallas(x, mask, w_up, w_gate, w_down, *, block_m=128):
    """Masked (multiplicative) gated FFN as a Pallas kernel.

    Kept for kernel-level parity tests and TPU schedule experiments; the
    production masked path uses the fused XLA version in model.py (faster
    under interpret-mode lowering).

    x: [B, d]; mask: [B, m]; returns y [B, d].
    """
    b, d = x.shape
    m = mask.shape[1]
    if m % block_m != 0:
        block_m = m
    nm = m // block_m

    def kernel(x_ref, mask_ref, wu_ref, wg_ref, wd_ref, y_ref):
        xv = x_ref[...]  # [1, d]
        mk = mask_ref[...]  # [1, block_m]
        wu = wu_ref[...]  # [d, block_m]
        wg = wg_ref[...]
        wd = wd_ref[...]  # [block_m, d]
        zu = xv @ wu
        zg = xv @ wg
        h = zu * jax.nn.sigmoid(zg) * zg * mk

        @pl.when(pl.program_id(1) == 0)
        def _init():
            y_ref[...] = jnp.zeros_like(y_ref)

        y_ref[...] += h @ wd

    y = pl.pallas_call(
        kernel,
        grid=(b, nm),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_m), lambda i, j: (i, j)),
            pl.BlockSpec((d, block_m), lambda i, j: (0, j)),
            pl.BlockSpec((d, block_m), lambda i, j: (0, j)),
            pl.BlockSpec((block_m, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=True,
    )(x, mask, w_up, w_gate, w_down)
    return y
