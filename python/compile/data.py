"""Benchmark-set generators (evaluation-data substitutes, DESIGN.md §3).

Emits artifacts/data/*.json consumed by the Rust eval harness:

  lg.json   — Long-Generation benchmark (Alpaca substitute): short story
              prompts; the dense model's greedy continuation defines the
              reference trajectory (PPL/KLD protocol of Sec. 4 / App. B.2).
  cls.json  — six MCQ families mapped to the paper's classification
              benchmarks (HellaSwag/PIQA/COPA/ARC-E/ARC-C/BoolQ):
              0-shot unnormalized logprob scoring.
  sg.json   — short-form generation (XSum/CNN-DM/CoQA/QASPER substitutes):
              summarization (ROUGE-1/2/L) and extractive QA (F1/EM).

All items are drawn from the *eval* seed domain — disjoint from the
training, prior, and oracle splits.
"""

from __future__ import annotations

import json
import os
import random

from .corpus import (
    ANIMALS,
    COLORS,
    NUMBER_WORDS,
    PLACES,
    TIMES,
    TRAITS,
    VERBS,
    number_word,
)

EVAL_SEED = 991


# ----------------------------------------------------------------- LG ----


def gen_lg(n: int, rng: random.Random):
    """Short prompts (<=48 bytes) for long-form generation.

    Deliberately spans ALL the grammar's task families (stories, QA,
    arithmetic, yes/no, summaries, weather, counting) — mirroring why the
    paper picked Alpaca: prompt diversity is what makes the local signal
    informative and a static global mask unreliable (App. C.1's variance
    observation).
    """
    prompts = []
    seen = set()
    while len(prompts) < n:
        a = rng.choice(ANIMALS)
        c = rng.choice(COLORS)
        t = rng.choice(TRAITS)
        p_ = rng.choice(PLACES)
        tm = rng.choice(TIMES)
        v = rng.choice(VERBS)
        style = rng.randrange(8)
        if style == 0:
            p = f"once there was a {c} {a}"
        elif style == 1:
            p = f"the {c} {a} is"
        elif style == 2:
            p = f"every {tm} the {a}"
        elif style == 3:
            p = f"the {c} {a} {v} near the {p_}. Q:"
        elif style == 4:
            x, y = rng.randrange(7), rng.randrange(7)
            p = (f"{number_word(x)} plus {number_word(y)} is "
                 f"{number_word(x + y)}. {number_word(rng.randrange(7))}")
        elif style == 5:
            p = f"the {a} is {c}. Q: is the {a}"
        elif style == 6:
            p = f"the {c} {a} who was very {t}"
        else:
            p = f"in the {tm} the weather is"
        if p in seen or len(p) > 60:
            continue
        seen.add(p)
        prompts.append(p)
    return {"name": "lg_alpaca_sub", "prompts": prompts}


# -------------------------------------------------------- classification --


def _cls_hellaswag(rng):
    """Continuation plausibility: pick the in-grammar ending."""
    a, c, t, v, p = (rng.choice(ANIMALS), rng.choice(COLORS),
                     rng.choice(TRAITS), rng.choice(VERBS),
                     rng.choice(PLACES))
    ctx = f"the {c} {a} is {t} and"
    good = f" {v} near the {p}."
    bads = [
        f" the {rng.choice(PLACES)} {rng.choice(COLORS)} plus.",
        f" {number_word(rng.randrange(9))} weather {rng.choice(ANIMALS)}.",
        f" is is near {rng.choice(TRAITS)} the.",
    ]
    opts = [good] + bads
    order = list(range(4))
    rng.shuffle(order)
    return {"family": "hellaswag", "context": ctx,
            "options": [opts[i] for i in order],
            "answer": order.index(0)}


def _cls_piqa(rng):
    """Physical plausibility: animals drink at water places."""
    a = rng.choice(ANIMALS)
    water = rng.choice(["river", "lake", "shore"])
    dry = rng.choice(["hill", "cave", "bridge"])
    ctx = f"the {a} is hungry and drinks at the"
    opts = [f" {water}.", f" {dry}."]
    order = [0, 1] if rng.random() < 0.5 else [1, 0]
    return {"family": "piqa", "context": ctx,
            "options": [opts[i] for i in order],
            "answer": order.index(0)}


def _cls_copa(rng):
    """Cause/effect: grammar-consistent consequence."""
    a = rng.choice(ANIMALS)
    tm = rng.choice(TIMES)
    ctx = f"in the {tm} the weather is rainy. the {a}"
    good = f" hides near the {rng.choice(PLACES)}."
    bad = f" {number_word(rng.randrange(9))} plus the {rng.choice(COLORS)}."
    order = [0, 1] if rng.random() < 0.5 else [1, 0]
    opts = [good, bad]
    return {"family": "copa", "context": ctx,
            "options": [opts[i] for i in order],
            "answer": order.index(0)}


def _cls_arc_e(rng):
    """Arithmetic (easy: distant distractor)."""
    x, y = rng.randrange(5), rng.randrange(5)
    s = x + y
    wrong = (s + rng.randrange(3, 6)) % 13
    ctx = f"{number_word(x)} plus {number_word(y)} is"
    opts = [f" {number_word(s)}.", f" {number_word(wrong)}."]
    order = [0, 1] if rng.random() < 0.5 else [1, 0]
    return {"family": "arc_e", "context": ctx,
            "options": [opts[i] for i in order],
            "answer": order.index(0)}


def _cls_arc_c(rng):
    """Arithmetic (challenge: 4 close distractors)."""
    # keep s >= 3 so that {s-2..s+2}\{s} always has >= 3 distinct values
    x, y = rng.randrange(1, 6), rng.randrange(2, 6)
    s = x + y
    cands = {s}
    while len(cands) < 4:
        cands.add(max(0, min(12, s + rng.choice([-2, -1, 1, 2]))))
    cands = list(cands)
    rng.shuffle(cands)
    ctx = f"{number_word(x)} plus {number_word(y)} is"
    return {"family": "arc_c", "context": ctx,
            "options": [f" {number_word(c)}." for c in cands],
            "answer": cands.index(s)}


def _cls_boolq(rng):
    a = rng.choice(ANIMALS)
    c = rng.choice(COLORS)
    if rng.random() < 0.5:
        c2, ans = c, 0
    else:
        c2, ans = rng.choice([x for x in COLORS if x != c]), 1
    ctx = f"the {a} is {c}. Q: is the {a} {c2}? A:"
    return {"family": "boolq", "context": ctx,
            "options": [" yes.", " no."], "answer": ans}


CLS_FAMILIES = {
    "hellaswag": _cls_hellaswag,
    "piqa": _cls_piqa,
    "copa": _cls_copa,
    "arc_e": _cls_arc_e,
    "arc_c": _cls_arc_c,
    "boolq": _cls_boolq,
}


def gen_cls(n_per_family: int, rng: random.Random):
    items = []
    for fam, fn in CLS_FAMILIES.items():
        for _ in range(n_per_family):
            items.append(fn(rng))
    return {"name": "cls_sub", "items": items}


# ------------------------------------------------------------------ SG ----


def _sg_sum(rng, family):
    a, c, t, p, tm = (rng.choice(ANIMALS), rng.choice(COLORS),
                      rng.choice(TRAITS), rng.choice(PLACES),
                      rng.choice(TIMES))
    v1 = rng.choice(VERBS)
    # short passage (fits the prefill window incl. BOS; mirrors the
    # corpus _s_summary pattern so the LM knows the format)
    passage = f"the {c} {a} who was very {t} {v1} near the {p} every {tm}."
    prompt = f"{passage} summary:"
    ref = f"the {t} {c} {a} stayed near the {p}."
    return {"family": family, "prompt": prompt, "reference": ref,
            "metric": "rouge"}


def _sg_qa_color(rng, family):
    a, c, v, p = (rng.choice(ANIMALS), rng.choice(COLORS),
                  rng.choice(VERBS), rng.choice(PLACES))
    prompt = (f"the {c} {a} {v} near the {p}. "
              f"Q: what color is the {a}? A:")
    return {"family": family, "prompt": prompt, "reference": c,
            "metric": "qa"}


def _sg_qa_place(rng, family):
    a, c, v, p = (rng.choice(ANIMALS), rng.choice(COLORS),
                  rng.choice(VERBS), rng.choice(PLACES))
    prompt = (f"the {c} {a} {v} near the {p}. "
              f"Q: where is the {a}? A:")
    return {"family": family, "prompt": prompt, "reference": f"near the {p}",
            "metric": "qa"}


def gen_sg(n_per_family: int, rng: random.Random):
    items = []
    for _ in range(n_per_family):
        items.append(_sg_sum(rng, "xsum"))
    for _ in range(n_per_family):
        items.append(_sg_sum(rng, "cnndm"))
    for _ in range(n_per_family):
        items.append(_sg_qa_color(rng, "coqa"))
    for _ in range(n_per_family):
        items.append(_sg_qa_place(rng, "qasper"))
    return {"name": "sg_sub", "items": items}


# ---------------------------------------------------------------- driver --


def write_datasets(art_dir: str, n_lg=256, n_cls=40, n_sg=32):
    ddir = os.path.join(art_dir, "data")
    os.makedirs(ddir, exist_ok=True)
    rng = random.Random(EVAL_SEED)
    sets = {
        "lg.json": gen_lg(n_lg, rng),
        "cls.json": gen_cls(n_cls, rng),
        "sg.json": gen_sg(n_sg, rng),
    }
    for fname, obj in sets.items():
        with open(os.path.join(ddir, fname), "w") as f:
            json.dump(obj, f, indent=1)
        print(f"[data] wrote {fname}")
    return sets
