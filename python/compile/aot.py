"""AOT export: lower every L2 graph to HLO text + write the artifact bundle.

Python runs ONCE (`make artifacts`); the Rust binary is self-contained
afterwards. Interchange is HLO *text* — the image's xla_extension 0.5.1
rejects jax>=0.5 serialized protos (64-bit instruction ids); the text
parser reassigns ids (see /opt/xla-example/README.md).

Artifact bundle (artifacts/):
  manifest.json        — executable I/O contracts + param layout + paths
  model.json           — ModelConfig
  params.bin           — f32 raw little-endian, leaves in flatten order
  *.hlo.txt            — one per executable variant
  priors/*.bin         — A^g / I^g global priors (NPS + corpus), [L, m] f32
  data/*.json          — benchmark sets
  train_log.json       — build-time training curve
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as data_mod
from . import nps as nps_mod
from . import train as train_mod
from .model import (
    ModelConfig,
    apply_decode,
    apply_decode_topk,
    apply_generate,
    apply_prefill,
    apply_prefill_chunk,
    apply_score,
    flatten_params,
    param_spec,
)

BATCH_SIZES = (1, 4)
TOPK_K = 256  # 50% of ffn_m — the paper's headline operating point


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _shape_of(x):
    return [int(s) for s in x.shape]


def build_executables(cfg: ModelConfig):
    """Return {name: (fn, operand_specs, operand_names, output_names)}.

    Every fn takes (params, *operands); lowering flattens params into the
    leading HLO parameters (flatten order == manifest param order).
    """
    L, m, T = cfg.n_layers, cfg.ffn_m, cfg.max_seq
    H, Dh, V = cfg.n_heads, cfg.head_dim, cfg.vocab
    S, SS, K = cfg.prefill_len, cfg.score_len, TOPK_K
    exes = {}

    for b in BATCH_SIZES:
        kv = _spec((L, b, H, T, Dh))
        exes[f"prefill_b{b}"] = (
            lambda p, t, ln: apply_prefill(cfg, p, t, ln),
            [_spec((b, S), jnp.int32), _spec((b,), jnp.int32)],
            ["tokens", "lens"],
            ["logits", "k", "v", "stats"],
        )
        exes[f"prefill_chunk_b{b}"] = (
            lambda p, t, ln, off, k, v: apply_prefill_chunk(cfg, p, t, ln,
                                                            off, k, v),
            [_spec((b, S), jnp.int32), _spec((b,), jnp.int32),
             _spec((b,), jnp.int32), kv, kv],
            ["tokens", "lens", "offsets", "k", "v"],
            ["logits", "k", "v", "stats"],
        )
        exes[f"decode_b{b}"] = (
            lambda p, t, pos, k, v, msk: apply_decode(cfg, p, t, pos, k, v,
                                                      msk),
            [_spec((b,), jnp.int32), _spec((b,), jnp.int32), kv, kv,
             _spec((b, L, m))],
            ["token", "pos", "k", "v", "mask"],
            ["logits", "k", "v", "stats"],
        )
        exes[f"decode_topk_b{b}"] = (
            lambda p, t, pos, k, v, idx: apply_decode_topk(cfg, p, t, pos,
                                                           k, v, idx),
            [_spec((b,), jnp.int32), _spec((b,), jnp.int32), kv, kv,
             _spec((b, L, K), jnp.int32)],
            ["token", "pos", "k", "v", "idx"],
            ["logits", "k", "v", "gstats"],
        )
        exes[f"score_b{b}"] = (
            lambda p, t, w, msk: apply_score(cfg, p, t, w, msk),
            [_spec((b, SS), jnp.int32), _spec((b, SS)), _spec((b, L, m))],
            ["tokens", "stats_w", "mask"],
            ["logits", "stats"],
        )
        exes[f"generate_b{b}"] = (
            lambda p, t, ln, msk: apply_generate(cfg, p, t, ln, msk),
            [_spec((b, S), jnp.int32), _spec((b,), jnp.int32),
             _spec((b, L, m))],
            ["tokens", "lens", "mask"],
            ["gen_tokens", "gen_logits", "gen_stats"],
        )
    return exes


def lower_all(cfg: ModelConfig, art_dir: str, only=None):
    from .model import init_params

    spec = param_spec(cfg)
    pspecs = [_spec(s) for _, s in spec]
    treedef = jax.tree_util.tree_structure(
        jax.eval_shape(lambda: init_params(cfg))
    )
    params_tree = jax.tree_util.tree_unflatten(treedef, pspecs)
    manifest_exes = {}
    for name, (fn, ospecs, onames, outnames) in build_executables(cfg).items():
        if only and name not in only:
            continue
        path = os.path.join(art_dir, f"{name}.hlo.txt")
        print(f"[aot] lowering {name} ...", flush=True)
        lowered = jax.jit(fn).lower(params_tree, *ospecs)
        outs = jax.eval_shape(fn, params_tree, *ospecs)
        outs_flat = jax.tree_util.tree_leaves(outs)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        manifest_exes[name] = {
            "file": f"{name}.hlo.txt",
            "inputs": (
                [{"name": n, "shape": list(s), "dtype": "f32"}
                 for n, s in spec]
                + [{"name": n, "shape": _shape_of(o),
                    "dtype": "i32" if o.dtype == jnp.int32 else "f32"}
                   for n, o in zip(onames, ospecs)]
            ),
            "n_params": len(spec),
            "outputs": [
                {"name": n, "shape": _shape_of(o),
                 "dtype": "i32" if o.dtype == jnp.int32 else "f32"}
                for n, o in zip(outnames, outs_flat)
            ],
        }
        print(f"[aot]   wrote {path} ({len(text)} chars)")
    return manifest_exes, spec


def write_params_bin(art_dir, params, spec):
    leaves = flatten_params(params)
    assert len(leaves) == len(spec)
    offsets = []
    off = 0
    with open(os.path.join(art_dir, "params.bin"), "wb") as f:
        for (name, shape), leaf in zip(spec, leaves):
            arr = np.asarray(leaf, dtype="<f4")
            assert tuple(arr.shape) == tuple(shape), (name, arr.shape, shape)
            offsets.append({"name": name, "shape": list(shape),
                            "offset": off, "numel": int(arr.size)})
            f.write(arr.tobytes())
            off += arr.size * 4
    return offsets


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="manifest output path (default artifacts/manifest.json)")
    ap.add_argument("--art-dir", default=None)
    ap.add_argument("--train-steps", type=int, default=300)
    ap.add_argument("--only", nargs="*", default=None,
                    help="lower only these executables")
    args = ap.parse_args()

    root = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))
    art_dir = args.art_dir or os.path.join(root, "artifacts")
    os.makedirs(art_dir, exist_ok=True)

    cfg = ModelConfig()
    with open(os.path.join(art_dir, "model.json"), "w") as f:
        f.write(cfg.to_json())

    params = train_mod.ensure_trained(cfg, art_dir, steps=args.train_steps)
    priors = nps_mod.compute_priors(cfg, params, art_dir)
    del priors
    data_mod.write_datasets(art_dir)

    exes, spec = lower_all(cfg, art_dir, only=args.only)
    param_layout = write_params_bin(art_dir, params, spec)

    manifest = {
        "version": 1,
        "model": dataclasses.asdict(cfg),
        "topk_k": TOPK_K,
        "params_file": "params.bin",
        "params": param_layout,
        "executables": exes,
        "priors": {
            n: f"priors/{n}.bin"
            for n in ["a_nps", "i_nps", "a_corpus", "i_corpus"]
        },
        "data": {"lg": "data/lg.json", "cls": "data/cls.json",
                 "sg": "data/sg.json"},
    }
    out = args.out or os.path.join(art_dir, "manifest.json")
    with open(out, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {out}")


if __name__ == "__main__":
    main()
