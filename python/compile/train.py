"""Build-time LM training on the grammar-world corpus.

Produces the base model every experiment runs on (substitute for the
paper's pretrained 7B checkpoints — see DESIGN.md §3). Runs once; the
result is cached at artifacts/params.npz and reused until deleted.

Plain Adam + cosine schedule, next-byte objective, seq 128 / batch 16.
The loss curve is logged to artifacts/train_log.json and summarized in
EXPERIMENTS.md (end-to-end training evidence).
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus
from .model import ModelConfig, flatten_params, init_params, lm_loss


def encode_bytes(text: str) -> np.ndarray:
    return np.frombuffer(text.encode("utf-8"), dtype=np.uint8).astype(np.int32)


def make_batches(data: np.ndarray, seq: int, batch: int, steps: int, seed=0):
    rng = np.random.default_rng(seed)
    n = len(data) - seq - 1
    for _ in range(steps):
        starts = rng.integers(0, n, size=batch)
        toks = np.stack([data[s : s + seq] for s in starts])
        labs = np.stack([data[s + 1 : s + seq + 1] for s in starts])
        yield toks, labs


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.99, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(
        lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(
        lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree_util.tree_map(
        lambda m: m / (1 - b1 ** t.astype(jnp.float32)), m)
    vh = jax.tree_util.tree_map(
        lambda v: v / (1 - b2 ** t.astype(jnp.float32)), v)
    params = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mh, vh)
    return params, {"m": m, "v": v, "t": t}


def train(
    cfg: ModelConfig,
    steps: int = 300,
    batch: int = 16,
    seq: int = 128,
    base_lr: float = 3e-3,
    corpus_chars: int = 400_000,
    seed: int = 0,
    log_every: int = 25,
):
    text = corpus.generate_text("train", corpus_chars, seed)
    data = encode_bytes(text)
    params = init_params(cfg, seed)
    opt = adam_init(params)

    @jax.jit
    def step_fn(params, opt, toks, labs, lr):
        wmask = jnp.ones_like(labs, jnp.float32)
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(cfg, p, toks, labs, wmask)
        )(params)
        params, opt = adam_update(params, grads, opt, lr)
        return params, opt, loss

    log = []
    t0 = time.time()
    for i, (toks, labs) in enumerate(
        make_batches(data, seq, batch, steps, seed)
    ):
        lr = base_lr * 0.5 * (1 + np.cos(np.pi * i / steps))
        params, opt, loss = step_fn(
            params, opt, jnp.asarray(toks), jnp.asarray(labs),
            jnp.float32(lr),
        )
        if i % log_every == 0 or i == steps - 1:
            lv = float(loss)
            log.append({"step": i, "loss": lv, "lr": float(lr),
                        "elapsed_s": round(time.time() - t0, 1)})
            print(f"[train] step {i:4d} loss {lv:.4f} lr {lr:.2e} "
                  f"({time.time() - t0:.0f}s)")
    return params, log


def heldout_loss(cfg, params, n_chars=20_000, seq=128, seed=0):
    text = corpus.generate_text("eval", n_chars, seed)
    data = encode_bytes(text)
    nb = min(8, (len(data) - seq - 1) // seq)
    toks = np.stack([data[i * seq : i * seq + seq] for i in range(nb)])
    labs = np.stack([data[i * seq + 1 : i * seq + seq + 1] for i in range(nb)])
    wmask = jnp.ones_like(jnp.asarray(labs), jnp.float32)
    return float(lm_loss(cfg, params, jnp.asarray(toks), jnp.asarray(labs),
                         wmask))


def save_params(path: str, params):
    leaves = flatten_params(params)
    np.savez(path, *[np.asarray(p) for p in leaves])


def load_params(cfg: ModelConfig, path: str):
    from .model import unflatten_params

    z = np.load(path)
    leaves = [jnp.asarray(z[f"arr_{i}"]) for i in range(len(z.files))]
    return unflatten_params(cfg, leaves)


def ensure_trained(cfg: ModelConfig, art_dir: str, steps: int = 300):
    """Train-or-load: the `make artifacts` entry point."""
    path = os.path.join(art_dir, "params.npz")
    if os.path.exists(path):
        print(f"[train] cached params at {path}")
        return load_params(cfg, path)
    params, log = train(cfg, steps=steps)
    hl = heldout_loss(cfg, params)
    print(f"[train] heldout loss {hl:.4f}")
    os.makedirs(art_dir, exist_ok=True)
    save_params(path, params)
    with open(os.path.join(art_dir, "train_log.json"), "w") as f:
        json.dump({"log": log, "heldout_loss": hl,
                   "steps": steps}, f, indent=2)
    return params


if __name__ == "__main__":
    cfg = ModelConfig()
    ensure_trained(cfg, os.path.join(os.path.dirname(__file__), "..", "..",
                                     "artifacts"))
