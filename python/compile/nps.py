"""Null-Prompt Stimulation (NPS) and global-prior computation (Sec. 3.1-3.3).

Computes the four global priors used by the experiments:

  a_nps    — A^g from NPS self-generated text            (A-GLASS, NPS)
  i_nps    — I^g from NPS + teacher-forced replay        (I-GLASS, NPS)
  a_corpus — A^g from a held-out external corpus slice   (Tab. 3 "Wiki")
  i_corpus — I^g from the same corpus slice              (Tab. 3 "Wiki")

NPS sampling schedule follows App. B.3, scaled to model size (Tab. 4
substitution in DESIGN.md): first 10 tokens at temperature 1.5 with a
bigram repetition penalty, then temperature 1.0 without penalty; top-k=20
throughout. Each self-generated sequence is replayed with teacher forcing
and its own next tokens as pseudo-labels to obtain gradients for I^g.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus as corpus_mod
from .model import (
    ModelConfig,
    apply_decode,
    apply_prefill,
    impact_and_activation,
)
from .train import encode_bytes

NPS_TEMP_HOT = 1.5
NPS_TEMP = 1.0
NPS_HOT_TOKENS = 10
NPS_TOP_K = 20
NPS_BIGRAM_PENALTY = 2.5  # divisor on logits of seen bigram continuations


def nps_generate(
    cfg: ModelConfig,
    params,
    n_seqs: int = 64,
    seq_len: int = 160,
    batch: int = 16,
    seed: int = 0,
):
    """Sample sequences from the model given only BOS ("null prompt").

    Returns (tokens [n_seqs, seq_len] int32 — generated ids only,
             a_stats [L, m] — mean hhat over all generated tokens).
    Sampling runs host-side (numpy) on jitted single-step logits; this is
    build-time code, not the request path.
    """
    L, m = cfg.n_layers, cfg.ffn_m
    decode = jax.jit(
        lambda p, t, pos, k, v, mask: apply_decode(cfg, p, t, pos, k, v, mask)
    )
    prefill = jax.jit(lambda p, t, l: apply_prefill(cfg, p, t, l))

    rng = np.random.default_rng(seed)
    all_tokens = []
    a_sum = np.zeros((L, m), np.float64)
    n_tok = 0

    for b0 in range(0, n_seqs, batch):
        bs = min(batch, n_seqs - b0)
        prompt = np.full((bs, cfg.prefill_len), cfg.pad_id, np.int32)
        prompt[:, 0] = cfg.bos_id
        lens = np.ones((bs,), np.int32)
        logits, k, v, _ = prefill(params, jnp.asarray(prompt),
                                  jnp.asarray(lens))
        logits = np.asarray(logits)
        mask = jnp.ones((bs, L, m), jnp.float32)

        toks = np.zeros((bs, seq_len), np.int32)
        last = np.zeros((bs,), np.int32)
        seen_bigrams = [set() for _ in range(bs)]
        pos = np.ones((bs,), np.int32)  # BOS at 0; first gen token at 1

        for t in range(seq_len):
            hot = t < NPS_HOT_TOKENS
            temp = NPS_TEMP_HOT if hot else NPS_TEMP
            step_logits = logits / temp
            for i in range(bs):
                if hot and t > 0:
                    for nxt in range(cfg.vocab):
                        if (last[i], nxt) in seen_bigrams[i]:
                            step_logits[i, nxt] /= NPS_BIGRAM_PENALTY
            # top-k sampling
            chosen = np.zeros((bs,), np.int32)
            for i in range(bs):
                row = step_logits[i]
                topk = np.argpartition(row, -NPS_TOP_K)[-NPS_TOP_K:]
                p = np.exp(row[topk] - row[topk].max())
                p /= p.sum()
                chosen[i] = topk[rng.choice(NPS_TOP_K, p=p)]
                if t > 0:
                    seen_bigrams[i].add((last[i], int(chosen[i])))
            toks[:, t] = chosen
            last = chosen

            lg, k, v, stats = decode(
                params, jnp.asarray(chosen), jnp.asarray(pos), k, v, mask
            )
            logits = np.asarray(lg)
            a_sum += np.asarray(stats).sum(axis=0)  # [L,m] over batch
            n_tok += bs
            pos += 1
        all_tokens.append(toks)

    a_stats = (a_sum / max(n_tok, 1)).astype(np.float32)
    return np.concatenate(all_tokens, axis=0), a_stats


def replay_impact(cfg: ModelConfig, params, sequences, batch=8,
                  prepend_bos=True):
    """Teacher-forced replay: I^g and A^g over token sequences [N, S].

    Each sequence's own next token is the pseudo-label (App. B.3).
    Returns (i_stats [L,m], a_stats [L,m]) — token-mean statistics.
    """
    imp = jax.jit(
        lambda p, t, l, w: impact_and_activation(cfg, p, t, l, w)
    )
    L, m = cfg.n_layers, cfg.ffn_m
    i_sum = np.zeros((L, m), np.float64)
    a_sum = np.zeros((L, m), np.float64)
    n_tok = 0.0
    n, s = sequences.shape
    for b0 in range(0, n, batch):
        seqs = sequences[b0 : b0 + batch]
        if prepend_bos:
            bos = np.full((len(seqs), 1), 256, np.int32)
            seqs = np.concatenate([bos, seqs], axis=1)
        toks = seqs[:, :-1]
        labs = seqs[:, 1:]
        wmask = np.ones_like(labs, np.float32)
        i_s, a_s, nt = imp(
            params, jnp.asarray(toks), jnp.asarray(labs), jnp.asarray(wmask)
        )
        i_sum += np.asarray(i_s)
        a_sum += np.asarray(a_s)
        n_tok += float(nt)
    return (
        (i_sum / max(n_tok, 1)).astype(np.float32),
        (a_sum / max(n_tok, 1)).astype(np.float32),
    )


def corpus_sequences(cfg: ModelConfig, n_seqs=64, seq_len=160, seed=0,
                     split="prior"):
    """Fixed-length byte sequences from a corpus split (WikiText stand-in)."""
    text = corpus_mod.generate_text(split, n_seqs * seq_len + seq_len, seed)
    data = encode_bytes(text)
    return np.stack(
        [data[i * seq_len : (i + 1) * seq_len] for i in range(n_seqs)]
    ).astype(np.int32)


def compute_priors(cfg: ModelConfig, params, art_dir: str,
                   n_seqs=64, seq_len=160, seed=0):
    """Compute-or-load all four priors; saves artifacts/priors.npz and raw
    .bin files (f32, row-major [L, m]) for the Rust loader."""
    path = os.path.join(art_dir, "priors.npz")
    if os.path.exists(path):
        print(f"[nps] cached priors at {path}")
        return dict(np.load(path))

    print("[nps] generating null-prompt stimulation set ...")
    nps_toks, a_nps_gen = nps_generate(cfg, params, n_seqs, seq_len,
                                       seed=seed)
    print("[nps] replaying NPS sequences for I^g ...")
    i_nps, a_nps = replay_impact(cfg, params, nps_toks)
    # Use the replay-based A^g (same token weighting as I^g); the
    # generation-time accumulation is kept as a cross-check.
    print("[nps] corpus (WikiText stand-in) priors ...")
    corp = corpus_sequences(cfg, n_seqs, seq_len, seed)
    i_corpus, a_corpus = replay_impact(cfg, params, corp)

    priors = {
        "a_nps": a_nps,
        "i_nps": i_nps,
        "a_corpus": a_corpus,
        "i_corpus": i_corpus,
        "a_nps_gen": a_nps_gen,
    }
    os.makedirs(art_dir, exist_ok=True)
    np.savez(path, **priors)
    pdir = os.path.join(art_dir, "priors")
    os.makedirs(pdir, exist_ok=True)
    for name in ["a_nps", "i_nps", "a_corpus", "i_corpus"]:
        priors[name].astype("<f4").tofile(os.path.join(pdir, f"{name}.bin"))
    np.save(os.path.join(art_dir, "nps_tokens.npy"), nps_toks)
    return priors
