"""Corpus generator + benchmark-set generator properties."""

import json
import random

import pytest

from compile import corpus, data


def test_splits_deterministic():
    a = corpus.generate_text("train", 5000)
    b = corpus.generate_text("train", 5000)
    assert a == b


def test_splits_differ():
    texts = {s: corpus.generate_text(s, 5000) for s in corpus.SPLIT_SEEDS}
    vals = list(texts.values())
    for i in range(len(vals)):
        for j in range(i + 1, len(vals)):
            assert vals[i][:2000] != vals[j][:2000]


def test_corpus_is_ascii_lowercase_ish():
    t = corpus.generate_text("train", 10_000)
    assert all(ord(c) < 128 for c in t)
    assert len(t) >= 10_000


def test_corpus_contains_all_pattern_kinds():
    t = corpus.generate_text("train", 60_000)
    for marker in ["Q: what color", "Q: is the", "summary:", "plus",
                   "once there was", "the weather is"]:
        assert marker in t, marker


def test_story_prompt_short():
    rng = random.Random(0)
    for _ in range(50):
        assert len(corpus.story_prompt(rng)) <= 40


def test_lg_items_unique_and_short():
    rng = random.Random(0)
    lg = data.gen_lg(64, rng)
    assert len(set(lg["prompts"])) == 64
    assert all(len(p) <= 60 for p in lg["prompts"])


def test_cls_items_well_formed():
    rng = random.Random(1)
    cls = data.gen_cls(10, rng)
    fams = {}
    for it in cls["items"]:
        assert 0 <= it["answer"] < len(it["options"])
        assert all(o and o[0] == " " for o in it["options"])
        assert len(set(it["options"])) == len(it["options"])
        fams[it["family"]] = fams.get(it["family"], 0) + 1
    assert set(fams) == set(data.CLS_FAMILIES)
    assert all(v == 10 for v in fams.values())


def test_cls_answers_not_positionally_biased():
    rng = random.Random(2)
    cls = data.gen_cls(60, rng)
    two_opt = [it for it in cls["items"] if len(it["options"]) == 2]
    frac0 = sum(1 for it in two_opt if it["answer"] == 0) / len(two_opt)
    assert 0.3 < frac0 < 0.7


def test_sg_items_well_formed():
    rng = random.Random(3)
    sg = data.gen_sg(8, rng)
    for it in sg["items"]:
        assert it["prompt"]
        assert it["reference"]
        assert it["metric"] in ("rouge", "qa")
        if it["family"] in ("xsum", "cnndm"):
            assert it["prompt"].endswith("summary:")
            # +BOS must fit the prefill window (ModelConfig.prefill_len)
            assert len(it["prompt"]) < 95


def test_write_datasets(tmp_path):
    sets = data.write_datasets(str(tmp_path), n_lg=8, n_cls=2, n_sg=2)
    for fname in ["lg.json", "cls.json", "sg.json"]:
        with open(tmp_path / "data" / fname) as f:
            obj = json.load(f)
        assert obj["name"]
