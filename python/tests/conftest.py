import os
import sys

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__),
                                                "..")))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import ModelConfig, init_params


@pytest.fixture(scope="session")
def tiny_cfg():
    """Small config: fast to trace, exercises every code path."""
    return ModelConfig(n_layers=2, d_model=64, n_heads=2, head_dim=32,
                       ffn_m=128, max_seq=32, prefill_len=16, score_len=32,
                       gen_len=6)


@pytest.fixture(scope="session")
def tiny_params(tiny_cfg):
    return init_params(tiny_cfg, seed=1)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def rand_tokens(cfg, b, s, rng, lo=0, hi=256):
    return jnp.asarray(rng.integers(lo, hi, size=(b, s)), jnp.int32)
