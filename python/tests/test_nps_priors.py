"""NPS generation + global-prior properties (Sec. 3.1-3.3)."""

import numpy as np
import pytest

from compile import nps
from compile.model import init_params


def test_corpus_sequences_shape(tiny_cfg):
    seqs = nps.corpus_sequences(tiny_cfg, n_seqs=4, seq_len=24)
    assert seqs.shape == (4, 24)
    assert seqs.dtype == np.int32
    assert seqs.max() < 128  # ascii corpus


def test_replay_impact_shapes_and_positivity(tiny_cfg, tiny_params):
    seqs = nps.corpus_sequences(tiny_cfg, n_seqs=4, seq_len=24)
    i_s, a_s = nps.replay_impact(tiny_cfg, tiny_params, seqs, batch=2,
                                 prepend_bos=False)
    L, m = tiny_cfg.n_layers, tiny_cfg.ffn_m
    assert i_s.shape == (L, m) and a_s.shape == (L, m)
    assert np.all(i_s >= 0) and np.all(a_s >= 0)
    assert i_s.sum() > 0 and a_s.sum() > 0
    assert np.all(np.isfinite(i_s)) and np.all(np.isfinite(a_s))


def test_replay_impact_deterministic(tiny_cfg, tiny_params):
    seqs = nps.corpus_sequences(tiny_cfg, n_seqs=2, seq_len=16)
    r1 = nps.replay_impact(tiny_cfg, tiny_params, seqs, batch=2,
                           prepend_bos=False)
    r2 = nps.replay_impact(tiny_cfg, tiny_params, seqs, batch=2,
                           prepend_bos=False)
    np.testing.assert_allclose(r1[0], r2[0], atol=1e-6)


def test_nps_generate_runs_and_tokens_valid(tiny_cfg, tiny_params):
    toks, a = nps.nps_generate(tiny_cfg, tiny_params, n_seqs=2,
                               seq_len=10, batch=2, seed=0)
    assert toks.shape == (2, 10)
    assert toks.min() >= 0 and toks.max() < tiny_cfg.vocab
    assert a.shape == (tiny_cfg.n_layers, tiny_cfg.ffn_m)
    assert np.all(a >= 0) and a.sum() > 0


def test_nps_generate_seed_determinism(tiny_cfg, tiny_params):
    t1, _ = nps.nps_generate(tiny_cfg, tiny_params, n_seqs=2, seq_len=8,
                             batch=2, seed=7)
    t2, _ = nps.nps_generate(tiny_cfg, tiny_params, n_seqs=2, seq_len=8,
                             batch=2, seed=7)
    np.testing.assert_array_equal(t1, t2)


def test_nps_priors_differ_from_corpus_priors(tiny_cfg, tiny_params):
    """The two stimulation distributions must yield distinct rankings —
    otherwise Tab. 3's NPS-vs-Wiki contrast is vacuous."""
    toks, _ = nps.nps_generate(tiny_cfg, tiny_params, n_seqs=2, seq_len=16,
                               batch=2, seed=0)
    i_nps, a_nps = nps.replay_impact(tiny_cfg, tiny_params, toks)
    seqs = nps.corpus_sequences(tiny_cfg, n_seqs=2, seq_len=16)
    i_c, a_c = nps.replay_impact(tiny_cfg, tiny_params, seqs)
    assert not np.allclose(a_nps, a_c)
    assert not np.allclose(i_nps, i_c)


def test_compute_priors_caches(tiny_cfg, tiny_params, tmp_path):
    import compile.nps as nps_mod

    p1 = nps_mod.compute_priors(tiny_cfg, tiny_params, str(tmp_path),
                                n_seqs=2, seq_len=8)
    p2 = nps_mod.compute_priors(tiny_cfg, tiny_params, str(tmp_path),
                                n_seqs=2, seq_len=8)
    np.testing.assert_allclose(p1["a_nps"], p2["a_nps"])
    for name in ["a_nps", "i_nps", "a_corpus", "i_corpus"]:
        f = tmp_path / "priors" / f"{name}.bin"
        assert f.exists()
        raw = np.fromfile(f, "<f4")
        assert raw.size == tiny_cfg.n_layers * tiny_cfg.ffn_m
