"""AOT export: manifest contract, params.bin layout, HLO text validity."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import (
    _spec,
    build_executables,
    to_hlo_text,
    write_params_bin,
)
from compile.model import flatten_params, init_params, param_spec


def test_param_spec_matches_flatten_order(tiny_cfg, tiny_params):
    spec = param_spec(tiny_cfg)
    leaves = flatten_params(tiny_params)
    assert len(spec) == len(leaves)
    for (name, shape), leaf in zip(spec, leaves):
        assert tuple(shape) == tuple(leaf.shape), name


def test_params_bin_roundtrip(tiny_cfg, tiny_params, tmp_path):
    spec = param_spec(tiny_cfg)
    layout = write_params_bin(str(tmp_path), tiny_params, spec)
    raw = np.fromfile(tmp_path / "params.bin", "<f4")
    total = sum(e["numel"] for e in layout)
    assert raw.size == total
    # spot-check: first leaf content round-trips
    leaf0 = np.asarray(flatten_params(tiny_params)[0]).ravel()
    np.testing.assert_allclose(raw[: leaf0.size], leaf0, atol=0)
    # offsets are contiguous
    off = 0
    for e in layout:
        assert e["offset"] == off
        off += e["numel"] * 4


def test_build_executables_cover_contract(tiny_cfg):
    exes = build_executables(tiny_cfg)
    for b in (1, 4):
        for kind in ["prefill", "prefill_chunk", "decode", "decode_topk",
                     "score", "generate"]:
            assert f"{kind}_b{b}" in exes


def test_lower_one_executable_to_hlo_text(tiny_cfg):
    """Full lowering path on the tiny config — the HLO text must contain
    an ENTRY computation and one parameter per input."""
    spec = param_spec(tiny_cfg)
    pspecs = [_spec(s) for _, s in spec]
    treedef = jax.tree_util.tree_structure(
        jax.eval_shape(lambda: init_params(tiny_cfg)))
    ptree = jax.tree_util.tree_unflatten(treedef, pspecs)
    fn, ospecs, _, _ = build_executables(tiny_cfg)["decode_b1"]
    lowered = jax.jit(fn).lower(ptree, *ospecs)
    text = to_hlo_text(lowered)
    assert "ENTRY" in text
    # ENTRY must expose one parameter per model leaf + operand (nested
    # computations add their own parameters, so count the entry layout).
    entry_layout = text.split("entry_computation_layout={(")[1]
    entry_layout = entry_layout.split(")->")[0]
    n_entry_params = entry_layout.count("f32[") + entry_layout.count("s32[")
    assert n_entry_params == len(spec) + len(ospecs)


def test_lowered_decode_numerics_match_eager(tiny_cfg, tiny_params, rng):
    """Compile the lowered stablehlo back through jax and compare one step
    against the eager function — guards the whole AOT interchange."""
    from compile.model import apply_decode

    cfg, params = tiny_cfg, tiny_params
    b = 1
    toks = jnp.asarray(rng.integers(0, 200, (b,)), jnp.int32)
    pos = jnp.zeros((b,), jnp.int32)
    kv = jnp.zeros((cfg.n_layers, b, cfg.n_heads, cfg.max_seq,
                    cfg.head_dim), jnp.float32)
    mask = jnp.ones((b, cfg.n_layers, cfg.ffn_m), jnp.float32)
    eager = apply_decode(cfg, params, toks, pos, kv, kv, mask)
    jitted = jax.jit(
        lambda p, t, ps, k, v, m: apply_decode(cfg, p, t, ps, k, v, m)
    )(params, toks, pos, kv, kv, mask)
    for e, j in zip(jax.tree_util.tree_leaves(eager),
                    jax.tree_util.tree_leaves(jitted)):
        np.testing.assert_allclose(np.asarray(e), np.asarray(j), atol=2e-5)
