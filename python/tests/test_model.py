"""L2 model invariants: prefill/decode/score consistency, masks, stats."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.model import (
    apply_decode,
    apply_decode_topk,
    apply_generate,
    apply_prefill,
    apply_prefill_chunk,
    apply_score,
    causal_mask,
    hhat,
    impact_and_activation,
    lm_loss,
)
from .conftest import rand_tokens

ATOL = 5e-4  # logits-level tolerance across distinct computation paths


def _ones_mask(cfg, b):
    return jnp.ones((b, cfg.n_layers, cfg.ffn_m), jnp.float32)


def test_prefill_shapes(tiny_cfg, tiny_params, rng):
    b, s = 2, tiny_cfg.prefill_len
    toks = rand_tokens(tiny_cfg, b, s, rng)
    lens = jnp.array([4, s], jnp.int32)
    logits, k, v, stats = apply_prefill(tiny_cfg, tiny_params, toks, lens)
    assert logits.shape == (b, tiny_cfg.vocab)
    assert k.shape == (tiny_cfg.n_layers, b, tiny_cfg.n_heads,
                       tiny_cfg.max_seq, tiny_cfg.head_dim)
    assert stats.shape == (b, tiny_cfg.n_layers, tiny_cfg.ffn_m)
    assert np.all(np.isfinite(np.asarray(logits)))
    assert np.all(np.asarray(stats) >= 0)


def test_prefill_ignores_padding(tiny_cfg, tiny_params, rng):
    """Tokens beyond lens must not affect logits, KV (valid part), stats."""
    b, s = 2, tiny_cfg.prefill_len
    toks = rand_tokens(tiny_cfg, b, s, rng)
    lens = jnp.array([5, 7], jnp.int32)
    out1 = apply_prefill(tiny_cfg, tiny_params, toks, lens)
    toks2 = np.asarray(toks).copy()
    toks2[0, 5:] = 3
    toks2[1, 7:] = 9
    out2 = apply_prefill(tiny_cfg, tiny_params, jnp.asarray(toks2), lens)
    np.testing.assert_allclose(out1[0], out2[0], atol=ATOL)
    np.testing.assert_allclose(out1[3], out2[3], atol=ATOL)


def test_prefill_then_decode_matches_longer_prefill(tiny_cfg, tiny_params,
                                                    rng):
    """THE consistency test: prefill(n) + decode(token) == prefill(n+1).

    Validates RoPE positions, KV write position, causal masking, and the
    decode-time attention over the cache — the whole L3 hot path contract.
    """
    cfg, params = tiny_cfg, tiny_params
    b, s = 2, cfg.prefill_len
    toks = rand_tokens(cfg, b, s, rng)
    n = 6
    lens = jnp.full((b,), n, jnp.int32)
    _, k, v, _ = apply_prefill(cfg, params, toks, lens)
    nxt = toks[:, n]
    logits_step, _, _, _ = apply_decode(cfg, params, nxt, lens, k, v,
                                        _ones_mask(cfg, b))
    logits_full, _, _, _ = apply_prefill(cfg, params, toks,
                                         jnp.full((b,), n + 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits_step),
                               np.asarray(logits_full), atol=ATOL)


def test_prefill_chunk_matches_monolithic(tiny_cfg, tiny_params, rng):
    """Chunked prefill contract: feeding a prompt in two chunks with
    carry-in KV must reproduce the monolithic prefill — same valid KV
    rows, same final logits, and token-count-weighted chunk statistics
    that merge into the monolithic A^l."""
    cfg, params = tiny_cfg, tiny_params
    b, s = 2, cfg.prefill_len
    n, split = 14, 8
    toks = rand_tokens(cfg, b, s, rng)
    lens = jnp.full((b,), n, jnp.int32)
    logits_m, k_m, v_m, stats_m = apply_prefill(cfg, params, toks, lens)

    kv_shape = (cfg.n_layers, b, cfg.n_heads, cfg.max_seq, cfg.head_dim)
    k = jnp.zeros(kv_shape, jnp.float32)
    v = jnp.zeros(kv_shape, jnp.float32)
    frame1 = np.full((b, s), cfg.pad_id, np.int32)
    frame1[:, :split] = np.asarray(toks)[:, :split]
    _, k, v, stats1 = apply_prefill_chunk(
        cfg, params, jnp.asarray(frame1),
        jnp.full((b,), split, jnp.int32),
        jnp.zeros((b,), jnp.int32), k, v)
    frame2 = np.full((b, s), cfg.pad_id, np.int32)
    frame2[:, :n - split] = np.asarray(toks)[:, split:n]
    logits_c, k, v, stats2 = apply_prefill_chunk(
        cfg, params, jnp.asarray(frame2),
        jnp.full((b,), n - split, jnp.int32),
        jnp.full((b,), split, jnp.int32), k, v)

    np.testing.assert_allclose(np.asarray(logits_c),
                               np.asarray(logits_m), atol=ATOL)
    merged = (split * np.asarray(stats1)
              + (n - split) * np.asarray(stats2)) / n
    np.testing.assert_allclose(merged, np.asarray(stats_m), atol=ATOL)
    np.testing.assert_allclose(np.asarray(k)[:, :, :, :n],
                               np.asarray(k_m)[:, :, :, :n], atol=ATOL)
    np.testing.assert_allclose(np.asarray(v)[:, :, :, :n],
                               np.asarray(v_m)[:, :, :, :n], atol=ATOL)


def test_prefill_chunk_then_decode_continues_the_sequence(tiny_cfg,
                                                          tiny_params, rng):
    """After a chunked prefill, a decode step at the prompt end must match
    the logits of a longer monolithic prefill — KV offsets line up."""
    cfg, params = tiny_cfg, tiny_params
    b, s = 2, cfg.prefill_len
    n, split = 10, 6
    toks = rand_tokens(cfg, b, s, rng)
    kv_shape = (cfg.n_layers, b, cfg.n_heads, cfg.max_seq, cfg.head_dim)
    k = jnp.zeros(kv_shape, jnp.float32)
    v = jnp.zeros(kv_shape, jnp.float32)
    frame1 = np.full((b, s), cfg.pad_id, np.int32)
    frame1[:, :split] = np.asarray(toks)[:, :split]
    _, k, v, _ = apply_prefill_chunk(
        cfg, params, jnp.asarray(frame1),
        jnp.full((b,), split, jnp.int32),
        jnp.zeros((b,), jnp.int32), k, v)
    frame2 = np.full((b, s), cfg.pad_id, np.int32)
    frame2[:, :n - split] = np.asarray(toks)[:, split:n]
    _, k, v, _ = apply_prefill_chunk(
        cfg, params, jnp.asarray(frame2),
        jnp.full((b,), n - split, jnp.int32),
        jnp.full((b,), split, jnp.int32), k, v)
    lens = jnp.full((b,), n, jnp.int32)
    logits_step, _, _, _ = apply_decode(cfg, params, toks[:, n], lens, k, v,
                                        _ones_mask(cfg, b))
    logits_full, _, _, _ = apply_prefill(cfg, params, toks,
                                         jnp.full((b,), n + 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits_step),
                               np.asarray(logits_full), atol=ATOL)


def test_score_matches_prefill_logits(tiny_cfg, tiny_params, rng):
    """Teacher-forced scorer logits at position i == prefill last-logits
    with lens=i+1 (same tokens)."""
    cfg, params = tiny_cfg, tiny_params
    b = 2
    s = cfg.prefill_len
    toks = rand_tokens(cfg, b, s, rng)
    pad = cfg.score_len - s
    toks_s = jnp.pad(toks, ((0, 0), (0, pad)), constant_values=cfg.pad_id)
    w = jnp.zeros((b, cfg.score_len))
    logits_all, _ = apply_score(cfg, params, toks_s, w, _ones_mask(cfg, b))
    for n in [1, 3, s]:
        lg, _, _, _ = apply_prefill(cfg, params, toks,
                                    jnp.full((b,), n, jnp.int32))
        np.testing.assert_allclose(np.asarray(logits_all[:, n - 1]),
                                   np.asarray(lg), atol=ATOL)


def test_decode_mask_zero_vs_dense_differs(tiny_cfg, tiny_params, rng):
    cfg, params = tiny_cfg, tiny_params
    b = 2
    toks = rand_tokens(cfg, b, cfg.prefill_len, rng)
    lens = jnp.full((b,), 4, jnp.int32)
    _, k, v, _ = apply_prefill(cfg, params, toks, lens)
    tok = jnp.array([10, 20], jnp.int32)
    lg1, _, _, _ = apply_decode(cfg, params, tok, lens, k, v,
                                _ones_mask(cfg, b))
    lg0, _, _, _ = apply_decode(cfg, params, tok, lens, k, v,
                                _ones_mask(cfg, b) * 0.0)
    assert float(jnp.abs(lg1 - lg0).max()) > 1e-3


def test_decode_topk_matches_masked_decode(tiny_cfg, tiny_params, rng):
    """Gathered (Pallas) decode == masked decode with the equivalent 0/1
    mask — the L1/L2 cross-variant contract."""
    cfg, params = tiny_cfg, tiny_params
    b, kk = 2, cfg.ffn_m // 2
    toks = rand_tokens(cfg, b, cfg.prefill_len, rng)
    lens = jnp.full((b,), 5, jnp.int32)
    _, k, v, _ = apply_prefill(cfg, params, toks, lens)
    tok = jnp.array([7, 8], jnp.int32)
    idx = jnp.asarray(
        np.stack([np.stack([np.random.default_rng(i * 10 + l)
                            .permutation(cfg.ffn_m)[:kk]
                            for l in range(cfg.n_layers)])
                  for i in range(b)]), jnp.int32)
    mask = np.zeros((b, cfg.n_layers, cfg.ffn_m), np.float32)
    for i in range(b):
        for l in range(cfg.n_layers):
            mask[i, l, np.asarray(idx)[i, l]] = 1.0
    lg_topk, k1, v1, _ = apply_decode_topk(cfg, params, tok, lens, k, v, idx)
    lg_mask, k2, v2, _ = apply_decode(cfg, params, tok, lens, k, v,
                                      jnp.asarray(mask))
    np.testing.assert_allclose(np.asarray(lg_topk), np.asarray(lg_mask),
                               atol=ATOL)
    np.testing.assert_allclose(np.asarray(k1), np.asarray(k2), atol=ATOL)


def test_generate_matches_manual_loop(tiny_cfg, tiny_params, rng):
    """Fused scan generator == prefill + explicit greedy decode loop."""
    cfg, params = tiny_cfg, tiny_params
    b = 2
    toks = rand_tokens(cfg, b, cfg.prefill_len, rng)
    lens = jnp.array([3, 5], jnp.int32)
    mask = _ones_mask(cfg, b)
    gt, gl, _ = apply_generate(cfg, params, toks, lens, mask)

    logits, k, v, _ = apply_prefill(cfg, params, toks, lens)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = lens
    for i in range(cfg.gen_len):
        np.testing.assert_array_equal(np.asarray(gt[:, i]), np.asarray(tok))
        logits, k, v, _ = apply_decode(cfg, params, tok, pos, k, v, mask)
        np.testing.assert_allclose(np.asarray(gl[:, i]), np.asarray(logits),
                                   atol=ATOL)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        pos = pos + 1


def test_generate_sparse_mask_changes_output(tiny_cfg, tiny_params, rng):
    cfg, params = tiny_cfg, tiny_params
    b = 1
    toks = rand_tokens(cfg, b, cfg.prefill_len, rng)
    lens = jnp.array([4], jnp.int32)
    _, gl1, _ = apply_generate(cfg, params, toks, lens, _ones_mask(cfg, b))
    half = np.ones((b, cfg.n_layers, cfg.ffn_m), np.float32)
    half[:, :, ::2] = 0.0
    _, gl2, _ = apply_generate(cfg, params, toks, lens, jnp.asarray(half))
    assert float(jnp.abs(gl1 - gl2).max()) > 1e-3


def test_hhat_is_l2_normalized():
    h = jax.random.normal(jax.random.PRNGKey(0), (4, 7, 64)) * 5
    hh = hhat(h)
    np.testing.assert_allclose(np.asarray((hh**2).sum(-1)),
                               np.ones((4, 7)), atol=1e-3)
    assert np.all(np.asarray(hh) >= 0)


def test_causal_mask_blocks_future():
    from compile.model import ModelConfig

    cfg = ModelConfig()
    m = causal_mask(cfg, jnp.array([3, 5], jnp.int32), 6)
    m = np.asarray(m)
    assert m.shape == (2, 1, 6, 6)
    assert m[0, 0, 0, 1] < -1e8  # future blocked
    assert m[0, 0, 1, 0] == 0.0  # past visible
    assert m[0, 0, 4, 4] < -1e8  # beyond len blocked even on diagonal? no:
    # diagonal at position >= len is padding-query; it may attend nothing
    # valid — key validity is what matters:
    assert m[0, 0, 5, 3] < -1e8  # key at 3 >= len(3) blocked
    assert m[1, 0, 5, 4] == 0.0  # len 5: key 4 visible


def test_impact_first_order_agrees_with_ablation(tiny_cfg, tiny_params, rng):
    """|h_j * dL/dh_j| must approximate the true loss change from ablating
    neuron j (Eq. 5): check rank correlation > 0.5 on a sample of neurons."""
    cfg, params = tiny_cfg, tiny_params
    b, s = 2, 12
    toks = rand_tokens(cfg, b, s, rng)
    labs = rand_tokens(cfg, b, s, rng)
    w = jnp.ones((b, s))
    i_stats, a_stats, nt = impact_and_activation(cfg, params, toks, labs, w)
    assert float(nt) == b * s
    i0 = np.asarray(i_stats)[0] / (b * s)

    # true ablation deltas for a handful of neurons in layer 0
    def loss_with_unit_masked(j):
        mask = np.ones((b, cfg.n_layers, cfg.ffn_m), np.float32)
        mask[:, 0, j] = 0.0
        pad = cfg.score_len - s
        toks_s = jnp.pad(toks, ((0, 0), (0, pad)),
                         constant_values=cfg.pad_id)
        logits, _ = apply_score(cfg, params, toks_s, jnp.zeros(
            (b, cfg.score_len)), jnp.asarray(mask))
        logits = logits[:, :s]
        logp = jax.nn.log_softmax(logits, -1)
        nll = -jnp.take_along_axis(logp, labs[..., None], -1)[..., 0]
        return float(nll.mean())

    base = loss_with_unit_masked(-1)  # -1: masks nothing real? use none:
    mask_none = jnp.ones((b, cfg.n_layers, cfg.ffn_m))
    pad = cfg.score_len - s
    toks_s = jnp.pad(toks, ((0, 0), (0, pad)), constant_values=cfg.pad_id)
    logits, _ = apply_score(cfg, params, toks_s,
                            jnp.zeros((b, cfg.score_len)), mask_none)
    logp = jax.nn.log_softmax(logits[:, :s], -1)
    base = float((-jnp.take_along_axis(logp, labs[..., None], -1)).mean())

    js = list(np.argsort(i0)[-5:]) + list(np.argsort(i0)[:5])
    deltas = np.array([abs(loss_with_unit_masked(int(j)) - base)
                       for j in js])
    scores = i0[js]
    # Spearman-ish: top-impact neurons should have larger ablation deltas
    assert deltas[:5].mean() > deltas[5:].mean()
    assert np.corrcoef(np.argsort(np.argsort(scores)),
                       np.argsort(np.argsort(deltas)))[0, 1] > 0.3


def test_lm_loss_decreases_on_memorizable_batch(tiny_cfg):
    """One-batch sanity: a few Adam steps reduce the loss (training path)."""
    import jax

    from compile.model import init_params
    from compile.train import adam_init, adam_update

    cfg = tiny_cfg
    params = init_params(cfg, 3)
    opt = adam_init(params)
    rng = np.random.default_rng(4)
    toks = jnp.asarray(rng.integers(0, 120, (4, 16)), jnp.int32)
    labs = jnp.asarray(rng.integers(0, 120, (4, 16)), jnp.int32)
    w = jnp.ones((4, 16))

    @jax.jit
    def step(params, opt):
        loss, g = jax.value_and_grad(
            lambda p: lm_loss(cfg, p, toks, labs, w))(params)
        params, opt = adam_update(params, g, opt, 1e-2)
        return params, opt, loss

    losses = []
    for _ in range(8):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    assert losses[-1] < losses[0]
