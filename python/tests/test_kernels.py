"""L1 kernel correctness: Pallas vs pure-jnp oracle (ref.py).

hypothesis sweeps shapes/seeds; every property asserts allclose against the
reference — this is the core correctness signal for the sparse FFN hot path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import (
    gated_ffn_ref,
    mask_from_idx,
    masked_ffn_ref,
    sparse_ffn_ref,
)
from compile.kernels.sparse_ffn import masked_ffn_pallas, sparse_ffn_pallas

ATOL = 2e-5


def _weights(d, m, seed):
    k = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(k, 3)
    return (
        jax.random.normal(k1, (d, m)) * d**-0.5,
        jax.random.normal(k2, (d, m)) * d**-0.5,
        jax.random.normal(k3, (m, d)) * m**-0.5,
    )


def _x(b, d, seed):
    return jax.random.normal(jax.random.PRNGKey(seed + 777), (b, d))


def _idx(b, m, k, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        np.stack([rng.permutation(m)[:k] for _ in range(b)]), jnp.int32
    )


# ------------------------------------------------------------ sparse_ffn


@settings(max_examples=8, deadline=None)
@given(
    b=st.integers(1, 5),
    d=st.sampled_from([8, 32, 128]),
    m=st.sampled_from([64, 256]),
    kfrac=st.sampled_from([0.25, 0.5, 1.0]),
    seed=st.integers(0, 10_000),
)
def test_sparse_ffn_matches_ref(b, d, m, kfrac, seed):
    k = max(1, int(m * kfrac))
    wu, wg, wd = _weights(d, m, seed)
    x = _x(b, d, seed)
    idx = _idx(b, m, k, seed)
    y_ref, h_ref = sparse_ffn_ref(x, idx, wu, wg, wd)
    y_pal, h_pal = sparse_ffn_pallas(x, idx, wu, wg, wd)
    np.testing.assert_allclose(y_pal, y_ref, atol=ATOL, rtol=1e-4)
    np.testing.assert_allclose(h_pal, h_ref, atol=ATOL, rtol=1e-4)


@settings(max_examples=5, deadline=None)
@given(
    b=st.integers(1, 4),
    seed=st.integers(0, 10_000),
    block_k=st.sampled_from([32, 64, 128]),
)
def test_sparse_ffn_block_size_invariance(b, seed, block_k):
    """Result must not depend on the VMEM panel size (pure schedule knob)."""
    d, m, k = 32, 256, 128
    wu, wg, wd = _weights(d, m, seed)
    x = _x(b, d, seed)
    idx = _idx(b, m, k, seed)
    y1, h1 = sparse_ffn_pallas(x, idx, wu, wg, wd, block_k=block_k)
    y2, h2 = sparse_ffn_pallas(x, idx, wu, wg, wd, block_k=k)
    np.testing.assert_allclose(y1, y2, atol=ATOL, rtol=1e-4)
    np.testing.assert_allclose(h1, h2, atol=ATOL, rtol=1e-4)


def test_sparse_equals_masked_when_idx_full():
    """Gathering ALL units must equal the dense FFN."""
    d, m, b = 16, 64, 3
    wu, wg, wd = _weights(d, m, 5)
    x = _x(b, d, 5)
    idx = jnp.tile(jnp.arange(m, dtype=jnp.int32)[None], (b, 1))
    y, _ = sparse_ffn_pallas(x, idx, wu, wg, wd)
    y_dense, _ = gated_ffn_ref(x, wu, wg, wd)
    np.testing.assert_allclose(y, y_dense, atol=ATOL, rtol=1e-4)


def test_sparse_equals_masked_ref():
    """idx-gather semantics == multiplicative 0/1 mask semantics (Eq. 2)."""
    d, m, k, b = 32, 128, 64, 2
    wu, wg, wd = _weights(d, m, 9)
    x = _x(b, d, 9)
    idx = _idx(b, m, k, 9)
    y_sparse, _ = sparse_ffn_ref(x, idx, wu, wg, wd)
    y_masked = masked_ffn_ref(x, mask_from_idx(idx, m), wu, wg, wd)
    np.testing.assert_allclose(y_sparse, y_masked, atol=ATOL, rtol=1e-4)


def test_sparse_ffn_permutation_invariance():
    """Order of the index set must not change the output."""
    d, m, k, b = 16, 64, 32, 2
    wu, wg, wd = _weights(d, m, 3)
    x = _x(b, d, 3)
    idx = _idx(b, m, k, 3)
    perm = np.random.default_rng(1).permutation(k)
    y1, _ = sparse_ffn_pallas(x, idx, wu, wg, wd)
    y2, _ = sparse_ffn_pallas(x, idx[:, perm], wu, wg, wd)
    np.testing.assert_allclose(y1, y2, atol=ATOL, rtol=1e-4)


def test_sparse_habs_normalized():
    """habs rows are ℓ2-normalized |h| — squared norms sum to ~1."""
    d, m, k, b = 32, 128, 64, 3
    wu, wg, wd = _weights(d, m, 11)
    x = _x(b, d, 11) * 3.0
    idx = _idx(b, m, k, 11)
    _, habs = sparse_ffn_pallas(x, idx, wu, wg, wd)
    sq = np.asarray((habs**2).sum(-1))
    assert np.all(habs >= 0)
    np.testing.assert_allclose(sq, np.ones_like(sq), atol=1e-3)


# ------------------------------------------------------------ masked_ffn


@settings(max_examples=6, deadline=None)
@given(
    b=st.integers(1, 4),
    d=st.sampled_from([8, 64]),
    m=st.sampled_from([64, 256]),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 10_000),
)
def test_masked_ffn_matches_ref(b, d, m, density, seed):
    wu, wg, wd = _weights(d, m, seed)
    x = _x(b, d, seed)
    rng = np.random.default_rng(seed)
    mask = jnp.asarray((rng.random((b, m)) < density).astype(np.float32))
    y_ref = masked_ffn_ref(x, mask, wu, wg, wd)
    y_pal = masked_ffn_pallas(x, mask, wu, wg, wd)
    np.testing.assert_allclose(y_pal, y_ref, atol=ATOL, rtol=1e-4)


def test_masked_ffn_zero_mask_is_zero():
    d, m, b = 16, 64, 2
    wu, wg, wd = _weights(d, m, 2)
    x = _x(b, d, 2)
    y = masked_ffn_pallas(x, jnp.zeros((b, m)), wu, wg, wd)
    np.testing.assert_allclose(y, np.zeros((b, d)), atol=1e-7)


def test_kernels_jit_compatible():
    """Kernels must trace under jit (the AOT path requirement)."""
    d, m, k, b = 16, 64, 32, 2
    wu, wg, wd = _weights(d, m, 4)
    x = _x(b, d, 4)
    idx = _idx(b, m, k, 4)
    y1, _ = jax.jit(sparse_ffn_pallas)(x, idx, wu, wg, wd)
    y2, _ = sparse_ffn_pallas(x, idx, wu, wg, wd)
    np.testing.assert_allclose(y1, y2, atol=ATOL, rtol=1e-4)
