//! Line-oriented Rust source scanner.
//!
//! A small character-level state machine splits each source line into
//! a masked **code** channel and a **comment** channel, tracks
//! `#[cfg(test)]` regions and brace depth, and records every string
//! literal together with the code context that precedes it.
//!
//! The masking is what makes the lint rules cheap and robust: string
//! and char literal contents are blanked to spaces (the quotes are
//! kept), comments become a single space in the code channel, and the
//! comment text is collected per line — so a rule can match
//! `thread::sleep` in `code` without tripping on a doc-comment
//! example, and match `SAFETY:` in `comment` without a real parser.

/// One scanned source line.
#[derive(Debug)]
pub struct Line {
    /// Code with literal contents blanked and comments stripped.
    pub code: String,
    /// Comment text on this line (line, block and doc comments).
    pub comment: String,
    /// True when the line sits inside a `#[cfg(test)]` item.
    pub in_test: bool,
    /// Brace depth at the start of the line.
    pub depth_at_start: i32,
}

/// One string literal plus the call-site context before its quote.
#[derive(Debug)]
pub struct StringLit {
    /// 0-based index of the line holding the opening quote.
    pub line: usize,
    /// Literal contents, escapes kept verbatim.
    pub text: String,
    /// The last (up to) 16 non-whitespace code characters emitted
    /// before the opening quote — enough to recognize call sites like
    /// `.set(` across line breaks.
    pub prefix: String,
}

/// A whole scanned file.
#[derive(Debug)]
pub struct Scanned {
    /// Path as handed to [`scan`], used for reports and path scoping.
    pub path: String,
    /// Per-line records, index 0 = line 1.
    pub lines: Vec<Line>,
    /// Every string literal in the file, in source order.
    pub strings: Vec<StringLit>,
}

struct Scanner {
    out: Scanned,
    code: String,
    comment: String,
    depth: i32,
    line_depth: i32,
    recent: Vec<char>,
    pending_test: bool,
    test_depth: Option<i32>,
    line_test: bool,
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

fn tail_matches(buf: &[char], pat: &str) -> bool {
    let count = pat.chars().count();
    buf.len() >= count
        && buf[buf.len() - count..].iter().copied().eq(pat.chars())
}

impl Scanner {
    fn push_line(&mut self) {
        let in_test = self.line_test || self.test_depth.is_some();
        self.out.lines.push(Line {
            code: std::mem::take(&mut self.code),
            comment: std::mem::take(&mut self.comment),
            in_test,
            depth_at_start: self.line_depth,
        });
        self.line_depth = self.depth;
        self.line_test = self.test_depth.is_some();
    }

    /// Emit one code character, maintaining brace depth, the rolling
    /// context buffer, and `#[cfg(test)]` region tracking.
    fn emit(&mut self, c: char) {
        self.code.push(c);
        if !c.is_whitespace() {
            self.recent.push(c);
            if self.recent.len() > 16 {
                self.recent.remove(0);
            }
        }
        if c == '{' {
            self.depth += 1;
            if self.pending_test {
                self.pending_test = false;
                if self.test_depth.is_none() {
                    self.test_depth = Some(self.depth);
                }
            }
        } else if c == '}' {
            self.depth -= 1;
            if let Some(d) = self.test_depth {
                if self.depth < d {
                    self.test_depth = None;
                }
            }
        }
        if tail_matches(&self.recent, "#[cfg(test)]") {
            self.pending_test = true;
        }
    }

    fn push_comment(&mut self, text: &str) {
        self.comment.push_str(text.trim());
        self.comment.push(' ');
    }

    /// Scan a string literal whose opening `"` sits at `open`.
    /// Handles normal, byte, and (byte-)raw strings, escapes, and the
    /// `\` line continuation. Returns the index after the literal.
    fn scan_string(&mut self, chars: &[char], open: usize) -> usize {
        let n = chars.len();
        // Raw/byte prefix: look back over the masked line tail for
        // `r`/`br` plus hashes, with a non-identifier char before it.
        let tail: Vec<char> = self.code.chars().collect();
        let mut t = tail.len();
        let mut hashes = 0usize;
        while t > 0 && tail[t - 1] == '#' {
            hashes += 1;
            t -= 1;
        }
        let mut raw = false;
        if t > 0 && tail[t - 1] == 'r' {
            let mut t2 = t - 1;
            if t2 > 0 && tail[t2 - 1] == 'b' {
                t2 -= 1;
            }
            if t2 == 0 || !is_ident(tail[t2 - 1]) {
                raw = true;
            }
        }
        if !raw {
            hashes = 0;
        }
        let prefix: String = self.recent.iter().collect();
        let line = self.out.lines.len();
        let mut content = String::new();
        self.code.push('"');
        let mut j = open + 1;
        while j < n {
            let cj = chars[j];
            if cj == '\n' {
                self.push_line();
                j += 1;
                continue;
            }
            if !raw && cj == '\\' {
                self.code.push(' ');
                let nxt = chars.get(j + 1).copied();
                if nxt == Some('\n') {
                    self.push_line();
                } else {
                    self.code.push(' ');
                    content.push(cj);
                    if let Some(x) = nxt {
                        content.push(x);
                    }
                }
                j += 2;
                continue;
            }
            if cj == '"' {
                if raw {
                    let mut have = 0;
                    while chars.get(j + 1 + have) == Some(&'#') {
                        have += 1;
                    }
                    if have >= hashes {
                        self.code.push('"');
                        for _ in 0..hashes {
                            self.code.push('#');
                        }
                        j += 1 + hashes;
                        break;
                    }
                    self.code.push(' ');
                    content.push(cj);
                    j += 1;
                    continue;
                }
                self.code.push('"');
                j += 1;
                break;
            }
            self.code.push(' ');
            content.push(cj);
            j += 1;
        }
        self.out.strings.push(StringLit {
            line,
            text: content,
            prefix,
        });
        j
    }
}

/// Scan `text` into per-line code/comment records plus string
/// literals. `path` is carried through verbatim for reporting.
pub fn scan(path: &str, text: &str) -> Scanned {
    let chars: Vec<char> = text.chars().collect();
    let n = chars.len();
    let mut s = Scanner {
        out: Scanned {
            path: path.to_string(),
            lines: Vec::new(),
            strings: Vec::new(),
        },
        code: String::new(),
        comment: String::new(),
        depth: 0,
        line_depth: 0,
        recent: Vec::new(),
        pending_test: false,
        test_depth: None,
        line_test: false,
    };
    let mut i = 0;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            s.push_line();
            i += 1;
            continue;
        }
        if c == '/' && chars.get(i + 1) == Some(&'/') {
            // Line comment (incl. `///` and `//!` doc comments).
            let mut j = i + 2;
            while matches!(chars.get(j), Some('/') | Some('!')) {
                j += 1;
            }
            let mut k = j;
            while k < n && chars[k] != '\n' {
                k += 1;
            }
            let text: String = chars[j..k].iter().collect();
            s.push_comment(&text);
            s.code.push(' ');
            i = k;
            continue;
        }
        if c == '/' && chars.get(i + 1) == Some(&'*') {
            // Block comment, nesting-aware.
            s.code.push(' ');
            let mut bd = 1;
            let mut j = i + 2;
            let mut buf = String::new();
            while j < n && bd > 0 {
                if chars[j] == '/' && chars.get(j + 1) == Some(&'*') {
                    bd += 1;
                    j += 2;
                } else if chars[j] == '*'
                    && chars.get(j + 1) == Some(&'/')
                {
                    bd -= 1;
                    j += 2;
                } else if chars[j] == '\n' {
                    let t = std::mem::take(&mut buf);
                    s.push_comment(&t);
                    s.push_line();
                    j += 1;
                } else {
                    buf.push(chars[j]);
                    j += 1;
                }
            }
            s.push_comment(&buf);
            i = j;
            continue;
        }
        if c == '\'' {
            // Char literal vs lifetime: `'\...'` and `'x'` are
            // literals (contents blanked), anything else is a
            // lifetime tick emitted as plain code.
            if chars.get(i + 1) == Some(&'\\') {
                s.code.push('\'');
                let mut j = i + 1;
                while j < n {
                    if chars[j] == '\\' {
                        s.code.push_str("  ");
                        j += 2;
                    } else if chars[j] == '\'' {
                        s.code.push('\'');
                        j += 1;
                        break;
                    } else {
                        s.code.push(' ');
                        j += 1;
                    }
                }
                i = j;
                continue;
            }
            if chars.get(i + 2) == Some(&'\'') {
                s.code.push_str("' '");
                i += 3;
                continue;
            }
            s.emit(c);
            i += 1;
            continue;
        }
        if c == '"' {
            i = s.scan_string(&chars, i);
            continue;
        }
        s.emit(c);
        i += 1;
    }
    if !s.code.is_empty() || !s.comment.is_empty() {
        s.push_line();
    }
    s.out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_masked_quotes_kept() {
        let sc = scan("t.rs", "let x = \"thread::sleep\";\n");
        assert_eq!(sc.lines.len(), 1);
        assert!(!sc.lines[0].code.contains("thread::sleep"));
        assert!(sc.lines[0].code.contains('"'));
        assert_eq!(sc.strings.len(), 1);
        assert_eq!(sc.strings[0].text, "thread::sleep");
    }

    #[test]
    fn comments_split_from_code() {
        let sc = scan("t.rs", "foo(); // SAFETY: checked above\n");
        assert!(sc.lines[0].code.contains("foo()"));
        assert!(!sc.lines[0].code.contains("SAFETY"));
        assert!(sc.lines[0].comment.contains("SAFETY: checked above"));
    }

    #[test]
    fn block_comments_keep_line_numbers() {
        let sc = scan("t.rs", "a();\n/* x\n y */\nb();\n");
        assert_eq!(sc.lines.len(), 4);
        assert!(sc.lines[3].code.contains("b()"));
        assert!(sc.lines[1].comment.contains('x'));
    }

    #[test]
    fn cfg_test_region_tracked() {
        let src = "fn live() {}\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   fn t() { x.unwrap(); }\n\
                   }\n\
                   fn live2() {}\n";
        let sc = scan("t.rs", src);
        let flags: Vec<bool> =
            sc.lines.iter().map(|l| l.in_test).collect();
        assert_eq!(
            flags,
            [false, false, true, true, true, false]
        );
    }

    #[test]
    fn char_literal_vs_lifetime() {
        let sc = scan("t.rs", "fn f<'a>(x: &'a str) -> char { '{' }\n");
        // the brace inside the char literal must not affect depth
        let sc2 = scan("t.rs", "fn g() {}\n");
        assert_eq!(
            sc.lines[0].depth_at_start,
            sc2.lines[0].depth_at_start
        );
        assert!(sc.lines[0].code.contains("'a"));
        let sc3 = scan("t.rs", "let c = '\\n'; foo();\n");
        assert!(sc3.lines[0].code.contains("foo()"));
    }

    #[test]
    fn raw_strings_consume_hashes() {
        let sc =
            scan("t.rs", "let x = r#\"a \"quoted\" b\"#; foo();\n");
        assert_eq!(sc.strings.len(), 1);
        assert_eq!(sc.strings[0].text, "a \"quoted\" b");
        assert!(sc.lines[0].code.contains("foo()"));
    }

    #[test]
    fn backslash_continuation_keeps_line_numbers() {
        let src = "let s = \"first \\\n    second\";\nafter();\n";
        let sc = scan("t.rs", src);
        assert_eq!(sc.lines.len(), 3);
        assert!(sc.lines[2].code.contains("after()"));
    }

    #[test]
    fn string_prefix_captures_multiline_call_site() {
        let src = "o.set(\n    \"warm_hits\",\n    v,\n);\n";
        let sc = scan("t.rs", src);
        assert_eq!(sc.strings[0].text, "warm_hits");
        assert!(sc.strings[0].prefix.ends_with(".set("));
    }

    #[test]
    fn second_string_in_call_is_not_key_prefixed() {
        let sc = scan("t.rs", "o.set(\"k\", Json::Str(\"v\".into()));\n");
        assert!(sc.strings[0].prefix.ends_with(".set("));
        assert!(!sc.strings[1].prefix.ends_with(".set("));
    }
}
