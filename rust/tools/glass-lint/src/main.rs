//! `glass-lint` CLI.
//!
//! ```text
//! glass-lint [--check] [--telemetry] [paths...]
//! ```
//!
//! Lints every `.rs` file under the given paths (default:
//! `rust/src`, i.e. run it from the repository root). Findings go to
//! stdout as `path:line: [rule] message`. With `--check` the exit
//! code is nonzero when any finding survives; with `--telemetry` a
//! one-line JSON summary (rule count, files scanned, per-rule
//! violation counts) is printed last, for CI to record per commit.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut check = false;
    let mut telemetry = false;
    let mut paths: Vec<PathBuf> = Vec::new();
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--check" => check = true,
            "--telemetry" => telemetry = true,
            "--help" | "-h" => {
                println!(
                    "usage: glass-lint [--check] [--telemetry] \
                     [paths...] (default path: rust/src)"
                );
                return ExitCode::SUCCESS;
            }
            other if other.starts_with("--") => {
                eprintln!("glass-lint: unknown flag `{other}`");
                return ExitCode::from(2);
            }
            other => paths.push(PathBuf::from(other)),
        }
    }
    if paths.is_empty() {
        paths.push(PathBuf::from("rust/src"));
    }
    let report = match glass_lint::lint_paths(&paths) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("glass-lint: {e}");
            return ExitCode::from(2);
        }
    };
    for v in &report.violations {
        println!("{v}");
    }
    if telemetry {
        println!("{}", telemetry_json(&report));
    }
    if check && !report.violations.is_empty() {
        eprintln!(
            "glass-lint: {} violation(s)",
            report.violations.len()
        );
        return ExitCode::FAILURE;
    }
    ExitCode::SUCCESS
}

/// One-line JSON summary of a lint run; every rule is listed even at
/// zero violations so the enforcement surface is visible per commit.
fn telemetry_json(report: &glass_lint::Report) -> String {
    let mut s = String::from("{\"glass_lint_rules\": ");
    s.push_str(&glass_lint::RULES.len().to_string());
    s.push_str(", \"files_scanned\": ");
    s.push_str(&report.files_scanned.to_string());
    s.push_str(", \"violations\": {");
    for (i, rule) in glass_lint::RULES.iter().enumerate() {
        if i > 0 {
            s.push_str(", ");
        }
        s.push('"');
        s.push_str(rule);
        s.push_str("\": ");
        s.push_str(&report.count(rule).to_string());
    }
    s.push_str("}}");
    s
}
