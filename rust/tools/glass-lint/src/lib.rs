//! **glass-lint** — dependency-free, project-invariant static
//! analysis for the GLASS serving stack.
//!
//! The serving layer (continuous batcher, per-shard reactor,
//! lock-free gauges, radix prefix cache) rests on concurrency and
//! wire-protocol invariants that module docs describe but `clippy`
//! cannot check. This crate scans the `glass` crate sources with a
//! small line-oriented tokenizer ([`scan`]) and enforces those
//! invariants as lint rules ([`rules`]):
//!
//! * `no-unwrap-on-serving-paths` — a panic in a batcher or reactor
//!   thread kills a whole shard, not one request.
//! * `justified-atomics` — every non-SeqCst ordering must say why it
//!   is sound (the packed `ShardGauges` word is the archetype).
//! * `no-sleep-outside-reactor` — a stray sleep on the engine loop
//!   stalls every slot in a shard.
//! * `no-lock-across-blocking-call` — a MutexGuard held across
//!   socket I/O or a sleep serializes the reactor.
//! * `safety-comment` — every `unsafe` carries a `// SAFETY:` note.
//! * `protocol-key-drift` — wire keys must agree between
//!   `server/protocol.rs`, `server/client.rs`, and the protocol
//!   module's wire-key registry docs.
//! * `lint-annotation` — suppressions themselves stay auditable.
//!
//! Findings are suppressed per site with
//! `// lint: allow(<rule>) -- <reason>` (see [`rules`]).

#![forbid(unsafe_code)]
#![warn(missing_docs)]

pub mod rules;
pub mod scan;

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

pub use rules::{Violation, RULES};
pub use scan::Scanned;

/// Result of linting a set of paths.
#[derive(Debug)]
pub struct Report {
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
    /// All findings, in file-walk order (cross-file checks last).
    pub violations: Vec<Violation>,
}

impl Report {
    /// Violation count for one rule.
    pub fn count(&self, rule: &str) -> usize {
        self.violations.iter().filter(|v| v.rule == rule).count()
    }
}

/// Lint one in-memory source file (single-file rules only).
pub fn lint_source(path: &str, text: &str) -> Vec<Violation> {
    let sc = scan::scan(path, text);
    let allows = rules::parse_allows(&sc);
    let mut out = Vec::new();
    rules::lint_file(&sc, &allows, &mut out);
    rules::lint_annotations(&sc, &allows, &mut out);
    out
}

/// Walk `paths` (files or directories, `vendor/` and `target/`
/// skipped), lint every `.rs` file, then cross-check each
/// `server/protocol.rs` + `server/client.rs` sibling pair.
pub fn lint_paths(paths: &[PathBuf]) -> io::Result<Report> {
    let mut files = Vec::new();
    for p in paths {
        collect(p, &mut files)?;
    }
    let mut scanned = Vec::new();
    let mut violations = Vec::new();
    for f in &files {
        let text = fs::read_to_string(f)?;
        let path = f.to_string_lossy().replace('\\', "/");
        let sc = scan::scan(&path, &text);
        let allows = rules::parse_allows(&sc);
        rules::lint_file(&sc, &allows, &mut violations);
        rules::lint_annotations(&sc, &allows, &mut violations);
        scanned.push(sc);
    }
    rules::lint_protocol_pairs(&scanned, &mut violations);
    Ok(Report {
        files_scanned: scanned.len(),
        violations,
    })
}

fn collect(p: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    if p.is_file() {
        out.push(p.to_path_buf());
        return Ok(());
    }
    let mut entries: Vec<PathBuf> = fs::read_dir(p)?
        .map(|e| e.map(|e| e.path()))
        .collect::<io::Result<_>>()?;
    entries.sort();
    for e in entries {
        if e.is_dir() {
            let name =
                e.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if name == "target" || name == "vendor" {
                continue;
            }
            collect(&e, out)?;
        } else if e.extension().is_some_and(|x| x == "rs") {
            out.push(e);
        }
    }
    Ok(())
}
