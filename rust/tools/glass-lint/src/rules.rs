//! The glass-lint rule set: project-specific invariants of the GLASS
//! serving stack that `clippy` cannot see, each grounded in a real
//! hazard this codebase has hit (see the "Invariants & enforcement"
//! section of `rust/src/server/mod.rs` for the rationale per rule).
//!
//! A finding is suppressed by an allowlist annotation in a comment on
//! the same line or up to two lines above:
//!
//! ```text
//! // lint: allow(no-sleep-outside-reactor) -- reactor idle tick
//! ```
//!
//! The reason after `--` is mandatory; an annotation with a missing
//! reason or an unknown rule name is itself reported (rule
//! `lint-annotation`), so suppressions stay auditable.

use std::collections::{BTreeMap, HashSet};

use crate::scan::Scanned;

/// `.unwrap()` / `.expect(` forbidden in non-test serving code.
pub const NO_UNWRAP: &str = "no-unwrap-on-serving-paths";
/// Relaxed/Acquire/Release orderings need a justification comment.
pub const JUSTIFIED_ATOMICS: &str = "justified-atomics";
/// `thread::sleep` only at explicitly allowlisted sites.
pub const NO_SLEEP: &str = "no-sleep-outside-reactor";
/// A MutexGuard binding may not live across a blocking call.
pub const NO_LOCK_ACROSS_BLOCKING: &str = "no-lock-across-blocking-call";
/// Every `unsafe` needs an adjacent `// SAFETY:` comment.
pub const SAFETY_COMMENT: &str = "safety-comment";
/// Wire keys must match between protocol.rs, client.rs and the docs.
pub const PROTOCOL_KEY_DRIFT: &str = "protocol-key-drift";
/// Malformed or unknown allowlist annotations.
pub const LINT_ANNOTATION: &str = "lint-annotation";

/// Every rule glass-lint enforces, in reporting order.
pub const RULES: [&str; 7] = [
    NO_UNWRAP,
    JUSTIFIED_ATOMICS,
    NO_SLEEP,
    NO_LOCK_ACROSS_BLOCKING,
    SAFETY_COMMENT,
    PROTOCOL_KEY_DRIFT,
    LINT_ANNOTATION,
];

/// Atomic memory orderings that demand justification. `SeqCst` is the
/// conservative default and exempt; `std::cmp::Ordering` variants
/// (Less/Equal/Greater) never match these names.
const ATOMIC_ORDERINGS: [&str; 4] = [
    "Ordering::Relaxed",
    "Ordering::Acquire",
    "Ordering::Release",
    "Ordering::AcqRel",
];

/// Statements that bind a MutexGuard when nothing else is chained.
const GUARD_MARKERS: [&str; 3] = [".lock()", ".locked()", "lock_conns("];

/// Chained calls that still yield a guard binding (poison recovery);
/// any other chained call means the guard is a dropped temporary.
const GUARD_CHAIN_OK: [&str; 4] =
    ["unwrap", "expect", "unwrap_or_else", "into_inner"];

/// Calls that can block a thread for an unbounded or scheduled time.
/// `Condvar::wait` is deliberately absent — it releases the lock.
const BLOCKING_MARKERS: [&str; 9] = [
    "thread::sleep",
    ".write_all(",
    ".flush(",
    ".read(",
    ".read_exact(",
    ".read_to_end(",
    ".read_line(",
    ".accept(",
    "::connect(",
];

/// Call-site suffixes that mark a string literal as a wire key.
const KEY_PREFIXES: [&str; 3] = [".set(", ".get(", ".req("];

/// One finding.
#[derive(Debug, Clone)]
pub struct Violation {
    /// File the finding is in (as passed to the scanner).
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    /// Rule name, one of [`RULES`].
    pub rule: &'static str,
    /// Human-readable explanation.
    pub msg: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.path, self.line, self.rule, self.msg
        )
    }
}

/// Allowlist annotations per 0-based line: `(rule name, has reason)`.
pub type Allows = BTreeMap<usize, Vec<(String, bool)>>;

/// Collect `lint: allow(<rule>) -- <reason>` annotations per line.
pub fn parse_allows(sc: &Scanned) -> Allows {
    let mut out = Allows::new();
    for (idx, ln) in sc.lines.iter().enumerate() {
        let c = ln.comment.as_str();
        let mut from = 0;
        while let Some(p) = c[from..].find("lint:") {
            from += p + 5;
            let rest = c[from..].trim_start();
            let Some(r2) = rest.strip_prefix("allow(") else {
                continue;
            };
            let Some(close) = r2.find(')') else { continue };
            let name = r2[..close].trim().to_string();
            let after = r2[close + 1..].trim_start();
            let has_reason = after
                .strip_prefix("--")
                .is_some_and(|r| !r.trim_start().is_empty());
            out.entry(idx).or_default().push((name, has_reason));
        }
    }
    out
}

/// Is `rule` allowlisted at `idx` (same line or two lines above)?
fn allowed(allows: &Allows, idx: usize, rule: &str) -> bool {
    (0..3).any(|back| {
        idx.checked_sub(back).is_some_and(|j| {
            allows.get(&j).is_some_and(|entries| {
                entries
                    .iter()
                    .any(|(name, reason)| name == rule && *reason)
            })
        })
    })
}

/// Does the normalized path sit under one of `segs` directories?
fn on_path(sc: &Scanned, segs: &[&str]) -> bool {
    let p = sc.path.replace('\\', "/");
    segs.iter().any(|s| {
        p.contains(&format!("/{s}/")) || p.starts_with(&format!("{s}/"))
    })
}

/// Does `code` contain `word` with non-identifier chars around it?
fn has_word(code: &str, word: &str) -> bool {
    let bytes = code.as_bytes();
    let mut from = 0;
    while let Some(p) = code[from..].find(word) {
        let start = from + p;
        let end = start + word.len();
        let ok_before = start == 0
            || !(bytes[start - 1].is_ascii_alphanumeric()
                || bytes[start - 1] == b'_');
        let ok_after = end == bytes.len()
            || !(bytes[end].is_ascii_alphanumeric()
                || bytes[end] == b'_');
        if ok_before && ok_after {
            return true;
        }
        from = end;
    }
    false
}

/// Any non-empty comment on `idx` or the `back` lines above it?
fn comment_near(sc: &Scanned, idx: usize, back: usize) -> bool {
    (0..=back).any(|b| {
        idx.checked_sub(b)
            .and_then(|j| sc.lines.get(j))
            .is_some_and(|l| !l.comment.trim().is_empty())
    })
}

/// A `SAFETY:` comment on `idx` or the `back` lines above it?
fn safety_near(sc: &Scanned, idx: usize, back: usize) -> bool {
    (0..=back).any(|b| {
        idx.checked_sub(b)
            .and_then(|j| sc.lines.get(j))
            .is_some_and(|l| l.comment.contains("SAFETY:"))
    })
}

/// Run every single-file rule over `sc`, appending findings to `out`.
pub fn lint_file(sc: &Scanned, allows: &Allows, out: &mut Vec<Violation>) {
    let serving = on_path(sc, &["server", "engine"]);
    for (idx, ln) in sc.lines.iter().enumerate() {
        if ln.in_test {
            continue;
        }
        let code = ln.code.as_str();
        let lineno = idx + 1;
        if serving
            && (code.contains(".unwrap()") || code.contains(".expect("))
            && !allowed(allows, idx, NO_UNWRAP)
        {
            out.push(Violation {
                path: sc.path.clone(),
                line: lineno,
                rule: NO_UNWRAP,
                msg: "`.unwrap()`/`.expect(` on a serving path; \
                      return an error or annotate why it cannot fail"
                    .to_string(),
            });
        }
        if ATOMIC_ORDERINGS.iter().any(|o| code.contains(o))
            && !comment_near(sc, idx, 4)
            && !allowed(allows, idx, JUSTIFIED_ATOMICS)
        {
            out.push(Violation {
                path: sc.path.clone(),
                line: lineno,
                rule: JUSTIFIED_ATOMICS,
                msg: "atomic memory ordering without a nearby \
                      justification comment"
                    .to_string(),
            });
        }
        if code.contains("thread::sleep")
            && !allowed(allows, idx, NO_SLEEP)
        {
            out.push(Violation {
                path: sc.path.clone(),
                line: lineno,
                rule: NO_SLEEP,
                msg: "thread::sleep outside an allowlisted site can \
                      stall a whole shard"
                    .to_string(),
            });
        }
        if has_word(code, "unsafe")
            && !safety_near(sc, idx, 3)
            && !allowed(allows, idx, SAFETY_COMMENT)
        {
            out.push(Violation {
                path: sc.path.clone(),
                line: lineno,
                rule: SAFETY_COMMENT,
                msg: "`unsafe` without an adjacent `// SAFETY:` \
                      comment"
                    .to_string(),
            });
        }
    }
    if serving {
        lint_guards(sc, allows, out);
    }
}

/// First identifier bound by a `let` statement on this line.
fn let_binding(code: &str) -> Option<String> {
    let bytes = code.as_bytes();
    let mut from = 0;
    while let Some(p) = code[from..].find("let ") {
        let abs = from + p;
        from = abs + 4;
        let boundary = abs == 0
            || !(bytes[abs - 1].is_ascii_alphanumeric()
                || bytes[abs - 1] == b'_');
        if !boundary {
            continue;
        }
        let rest = code[abs + 4..].trim_start();
        let rest = rest.strip_prefix("mut ").unwrap_or(rest);
        let name: String = rest
            .trim_start()
            .chars()
            .take_while(|c| c.is_alphanumeric() || *c == '_')
            .collect();
        if !name.is_empty() {
            return Some(name);
        }
    }
    None
}

/// Is every chained `.method(` after the guard marker one that still
/// yields a guard (poison recovery)? Any other call means the lock is
/// a temporary dropped at the end of the statement.
fn chain_is_clean(suffix: &str) -> bool {
    let b: Vec<char> = suffix.chars().collect();
    let mut i = 0;
    while i < b.len() {
        if b[i] != '.' {
            i += 1;
            continue;
        }
        let mut j = i + 1;
        while j < b.len() && b[j].is_whitespace() {
            j += 1;
        }
        let start = j;
        while j < b.len() && (b[j].is_alphanumeric() || b[j] == '_') {
            j += 1;
        }
        let named = j > start && (b[start].is_alphabetic()
            || b[start] == '_');
        if named {
            let mut k = j;
            while k < b.len() && b[k].is_whitespace() {
                k += 1;
            }
            if k < b.len() && b[k] == '(' {
                let name: String = b[start..j].iter().collect();
                if !GUARD_CHAIN_OK.contains(&name.as_str()) {
                    return false;
                }
            }
        }
        i = j.max(i + 1);
    }
    true
}

/// The `no-lock-across-blocking-call` heuristic: find `let` bindings
/// that hold a MutexGuard, then walk the rest of their block looking
/// for a blocking call before the guard is dropped.
fn lint_guards(sc: &Scanned, allows: &Allows, out: &mut Vec<Violation>) {
    for idx in 0..sc.lines.len() {
        let ln = &sc.lines[idx];
        if ln.in_test {
            continue;
        }
        let code = ln.code.as_str();
        let Some(marker) =
            GUARD_MARKERS.iter().find(|m| code.contains(*m))
        else {
            continue;
        };
        if !code.contains("let ") {
            continue;
        }
        let Some(pos) = code.find(marker) else { continue };
        if !chain_is_clean(&code[pos + marker.len()..]) {
            continue;
        }
        let Some(name) = let_binding(code) else { continue };
        let base = ln.depth_at_start;
        let drop_pat = format!("drop({name})");
        let mut j = idx + 1;
        while j < sc.lines.len() && sc.lines[j].depth_at_start >= base {
            let nxt = &sc.lines[j];
            if nxt.code.contains(&drop_pat) {
                break;
            }
            let hit = BLOCKING_MARKERS
                .iter()
                .find(|b| nxt.code.contains(*b));
            if let Some(hit) = hit {
                if !nxt.in_test {
                    if !allowed(allows, j, NO_LOCK_ACROSS_BLOCKING)
                        && !allowed(allows, idx, NO_LOCK_ACROSS_BLOCKING)
                    {
                        out.push(Violation {
                            path: sc.path.clone(),
                            line: j + 1,
                            rule: NO_LOCK_ACROSS_BLOCKING,
                            msg: format!(
                                "blocking call `{hit}` while \
                                 MutexGuard `{name}` (line {}) is held",
                                idx + 1
                            ),
                        });
                    }
                    break;
                }
            }
            j += 1;
        }
    }
}

/// Report malformed allowlist annotations (unknown rule / no reason).
pub fn lint_annotations(
    sc: &Scanned,
    allows: &Allows,
    out: &mut Vec<Violation>,
) {
    for (idx, entries) in allows {
        for (name, has_reason) in entries {
            if !RULES.contains(&name.as_str()) {
                out.push(Violation {
                    path: sc.path.clone(),
                    line: idx + 1,
                    rule: LINT_ANNOTATION,
                    msg: format!(
                        "allow() names unknown rule \"{name}\""
                    ),
                });
            } else if !has_reason {
                out.push(Violation {
                    path: sc.path.clone(),
                    line: idx + 1,
                    rule: LINT_ANNOTATION,
                    msg: format!(
                        "allow({name}) is missing a \"-- <reason>\""
                    ),
                });
            }
        }
    }
}

/// Is `text` shaped like a wire key (`snake_case` identifier)?
fn is_key(text: &str) -> bool {
    let mut cs = text.chars();
    let head_ok = matches!(cs.next(), Some(c) if c.is_ascii_lowercase() || c == '_');
    head_ok
        && cs.all(|c| {
            c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_'
        })
}

/// Wire keys used in non-test code: string literals at `.set(` /
/// `.get(` / `.req(` call sites. Returns `(line_idx, key)` pairs.
fn key_strings(sc: &Scanned) -> Vec<(usize, &str)> {
    sc.strings
        .iter()
        .filter(|s| {
            sc.lines.get(s.line).is_some_and(|l| !l.in_test)
        })
        .filter(|s| {
            KEY_PREFIXES.iter().any(|p| s.prefix.ends_with(p))
        })
        .filter(|s| is_key(&s.text))
        .map(|s| (s.line, s.text.as_str()))
        .collect()
}

/// Cross-check one `server/protocol.rs` + `server/client.rs` pair:
/// every key the protocol reads or writes must appear backticked in
/// the protocol module's docs (the wire-key registry), and the client
/// may only reference keys the protocol knows.
pub fn lint_protocol_pair(
    proto: &Scanned,
    client: &Scanned,
    out: &mut Vec<Violation>,
) {
    let proto_allows = parse_allows(proto);
    let client_allows = parse_allows(client);
    let proto_keys = key_strings(proto);
    let proto_set: HashSet<&str> =
        proto_keys.iter().map(|(_, k)| *k).collect();
    let docs: String = proto
        .lines
        .iter()
        .map(|l| l.comment.as_str())
        .collect::<Vec<_>>()
        .join(" ");
    let mut seen = HashSet::new();
    for (idx, k) in &proto_keys {
        if !seen.insert(*k) {
            continue;
        }
        if !docs.contains(&format!("`{k}`"))
            && !allowed(&proto_allows, *idx, PROTOCOL_KEY_DRIFT)
        {
            out.push(Violation {
                path: proto.path.clone(),
                line: idx + 1,
                rule: PROTOCOL_KEY_DRIFT,
                msg: format!(
                    "wire key \"{k}\" missing from the module docs' \
                     wire-key registry"
                ),
            });
        }
    }
    let mut seen = HashSet::new();
    for (idx, k) in key_strings(client) {
        if !seen.insert(k) {
            continue;
        }
        if !proto_set.contains(k)
            && !allowed(&client_allows, idx, PROTOCOL_KEY_DRIFT)
        {
            out.push(Violation {
                path: client.path.clone(),
                line: idx + 1,
                rule: PROTOCOL_KEY_DRIFT,
                msg: format!(
                    "wire key \"{k}\" used by the client but never \
                     read or written by protocol.rs"
                ),
            });
        }
    }
}

/// Pair every `server/protocol.rs` with its sibling
/// `server/client.rs` (same parent directory) and cross-check them.
pub fn lint_protocol_pairs(
    scanned: &[Scanned],
    out: &mut Vec<Violation>,
) {
    type Pair<'a> = (Option<&'a Scanned>, Option<&'a Scanned>);
    let mut pairs: BTreeMap<String, Pair<'_>> = BTreeMap::new();
    for sc in scanned {
        let p = sc.path.replace('\\', "/");
        if let Some(dir) = p.strip_suffix("server/protocol.rs") {
            pairs.entry(dir.to_string()).or_default().0 = Some(sc);
        } else if let Some(dir) = p.strip_suffix("server/client.rs") {
            pairs.entry(dir.to_string()).or_default().1 = Some(sc);
        }
    }
    for (proto, client) in pairs.values() {
        if let (Some(p), Some(c)) = (proto, client) {
            lint_protocol_pair(p, c, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::scan;

    fn lint(path: &str, src: &str) -> Vec<Violation> {
        let sc = scan(path, src);
        let allows = parse_allows(&sc);
        let mut out = Vec::new();
        lint_file(&sc, &allows, &mut out);
        lint_annotations(&sc, &allows, &mut out);
        out
    }

    #[test]
    fn unsafe_word_boundary() {
        // `unsafe_op_in_unsafe_fn` is an identifier, not the keyword
        let vs = lint(
            "x/lib.rs",
            "#![deny(unsafe_op_in_unsafe_fn)]\nfn ok() {}\n",
        );
        assert!(vs.is_empty(), "{vs:?}");
        let vs = lint("x/lib.rs", "unsafe impl Send for X {}\n");
        assert_eq!(vs.len(), 1);
        assert_eq!(vs[0].rule, SAFETY_COMMENT);
    }

    #[test]
    fn cmp_ordering_is_not_an_atomic_ordering() {
        let vs = lint(
            "x/a.rs",
            "fn f(a: u32, b: u32) -> bool {\n    \
             matches!(a.cmp(&b), std::cmp::Ordering::Less)\n}\n",
        );
        assert!(vs.is_empty(), "{vs:?}");
    }

    #[test]
    fn annotation_requires_reason_and_known_rule() {
        let src = "fn f() {\n\
                   // lint: allow(no-sleep-outside-reactor)\n\
                   std::thread::sleep(d);\n\
                   // lint: allow(no-naps) -- not a rule\n\
                   std::thread::sleep(d);\n\
                   }\n";
        let vs = lint("x/a.rs", src);
        let ann = vs
            .iter()
            .filter(|v| v.rule == LINT_ANNOTATION)
            .count();
        let sleep = vs.iter().filter(|v| v.rule == NO_SLEEP).count();
        assert_eq!(ann, 2, "{vs:?}");
        assert_eq!(sleep, 2, "reasonless annotations suppress nothing");
    }

    #[test]
    fn guard_temporary_chain_is_not_a_guard() {
        let src = "fn f() {\n    \
                   let tx = conns.lock().unwrap().get(&id).cloned();\n    \
                   s.write_all(b\"x\").ok();\n}\n";
        let sc = scan("x/server/m.rs", src);
        let mut out = Vec::new();
        lint_guards(&sc, &Allows::new(), &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn poison_recovery_still_binds_a_guard() {
        let src = "fn f() {\n    \
                   let g = m.lock().unwrap_or_else(|p| p.into_inner());\n    \
                   s.write_all(b\"x\").ok();\n}\n";
        let sc = scan("x/server/m.rs", src);
        let mut out = Vec::new();
        lint_guards(&sc, &Allows::new(), &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert_eq!(out[0].rule, NO_LOCK_ACROSS_BLOCKING);
        assert_eq!(out[0].line, 3);
    }
}
