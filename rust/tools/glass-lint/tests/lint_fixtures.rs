//! Fixture-driven coverage for every glass-lint rule: one negative
//! (`bad`) and one positive (`good`) fixture per rule, an allowlist
//! round-trip, `--check` exit codes through the real binary, and a
//! self-check asserting the committed tree is clean.

use std::path::{Path, PathBuf};
use std::process::Command;

use glass_lint::{rules, Report};

fn fixture(rel: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(rel)
}

fn lint(rel: &str) -> Report {
    glass_lint::lint_paths(&[fixture(rel)]).expect("lint fixture")
}

fn assert_clean(rel: &str) {
    let report = lint(rel);
    assert!(
        report.violations.is_empty(),
        "{rel} should be clean:\n{}",
        render(&report)
    );
}

fn render(report: &Report) -> String {
    report
        .violations
        .iter()
        .map(|v| v.to_string())
        .collect::<Vec<_>>()
        .join("\n")
}

#[test]
fn no_unwrap_on_serving_paths_fixtures() {
    let bad = lint("serving_unwrap/server/bad.rs");
    assert_eq!(bad.count(rules::NO_UNWRAP), 2, "{}", render(&bad));
    assert_eq!(bad.violations.len(), 2);
    assert_clean("serving_unwrap/server/good.rs");
}

#[test]
fn justified_atomics_fixtures() {
    let bad = lint("atomics/bad.rs");
    assert_eq!(
        bad.count(rules::JUSTIFIED_ATOMICS),
        1,
        "{}",
        render(&bad)
    );
    assert_eq!(bad.violations.len(), 1);
    assert_clean("atomics/good.rs");
}

#[test]
fn no_sleep_outside_reactor_fixtures() {
    let bad = lint("sleep/bad.rs");
    assert_eq!(bad.count(rules::NO_SLEEP), 1, "{}", render(&bad));
    assert_eq!(bad.violations.len(), 1);
    assert_clean("sleep/good.rs");
}

#[test]
fn no_lock_across_blocking_call_fixtures() {
    let bad = lint("lock_across/server/bad.rs");
    assert_eq!(
        bad.count(rules::NO_LOCK_ACROSS_BLOCKING),
        1,
        "{}",
        render(&bad)
    );
    assert_eq!(bad.violations.len(), 1);
    assert_clean("lock_across/server/good.rs");
}

#[test]
fn safety_comment_fixtures() {
    let bad = lint("safety/bad.rs");
    assert_eq!(
        bad.count(rules::SAFETY_COMMENT),
        1,
        "{}",
        render(&bad)
    );
    assert_eq!(bad.violations.len(), 1);
    assert_clean("safety/good.rs");
}

#[test]
fn protocol_key_drift_fixtures() {
    let bad = lint("protocol_drift/bad");
    assert_eq!(
        bad.count(rules::PROTOCOL_KEY_DRIFT),
        2,
        "{}",
        render(&bad)
    );
    assert_eq!(bad.violations.len(), 2);
    let undocumented = bad
        .violations
        .iter()
        .any(|v| v.msg.contains("queue_pos"));
    let drifted =
        bad.violations.iter().any(|v| v.msg.contains("finish"));
    assert!(undocumented && drifted, "{}", render(&bad));
    assert_clean("protocol_drift/good");
}

#[test]
fn allowlist_round_trip() {
    // a well-formed annotation suppresses its violation and is not
    // itself reported...
    assert_clean("allowlist/good.rs");
    // ...while a reasonless or unknown-rule annotation is reported
    // AND suppresses nothing
    let bad = lint("allowlist/bad.rs");
    assert_eq!(
        bad.count(rules::LINT_ANNOTATION),
        2,
        "{}",
        render(&bad)
    );
    assert_eq!(bad.count(rules::NO_SLEEP), 2, "{}", render(&bad));
}

#[test]
fn telemetry_counts_every_rule() {
    let report = lint("sleep/bad.rs");
    for rule in glass_lint::RULES {
        // count() answers for every known rule, found or not
        let n = report.count(rule);
        assert!(n <= report.violations.len());
    }
    assert_eq!(glass_lint::RULES.len(), 7);
}

fn run_check(path: &Path) -> bool {
    Command::new(env!("CARGO_BIN_EXE_glass-lint"))
        .arg("--check")
        .arg(path)
        .output()
        .expect("run glass-lint")
        .status
        .success()
}

#[test]
fn check_mode_exit_codes() {
    let bad = [
        "serving_unwrap/server/bad.rs",
        "atomics/bad.rs",
        "sleep/bad.rs",
        "lock_across/server/bad.rs",
        "safety/bad.rs",
        "protocol_drift/bad",
        "allowlist/bad.rs",
    ];
    for rel in bad {
        assert!(!run_check(&fixture(rel)), "{rel} must fail --check");
    }
    let good = [
        "serving_unwrap/server/good.rs",
        "atomics/good.rs",
        "sleep/good.rs",
        "lock_across/server/good.rs",
        "safety/good.rs",
        "protocol_drift/good",
        "allowlist/good.rs",
    ];
    for rel in good {
        assert!(run_check(&fixture(rel)), "{rel} must pass --check");
    }
}

#[test]
fn real_tree_is_clean() {
    // the committed tree must hold its own invariants: glass-lint
    // --check exits 0 on HEAD (CI runs the binary; this keeps the
    // guarantee inside plain `cargo test` too)
    let src = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("..")
        .join("src");
    let report =
        glass_lint::lint_paths(&[src]).expect("lint rust/src");
    assert!(report.files_scanned > 40, "walk found the real tree");
    assert!(
        report.violations.is_empty(),
        "glass-lint violations on HEAD:\n{}",
        render(&report)
    );
}
