fn spin_wait() {
    std::thread::sleep(std::time::Duration::from_millis(5));
}
