fn idle_tick() {
    // lint: allow(no-sleep-outside-reactor) -- reactor idle tick
    std::thread::sleep(std::time::Duration::from_micros(500));
}

#[cfg(test)]
mod tests {
    #[test]
    fn sleeps_are_fine_in_tests() {
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
}
