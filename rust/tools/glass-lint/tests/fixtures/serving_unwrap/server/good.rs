fn route(v: Option<u32>) -> Option<u32> {
    v
}

fn annotated(v: Option<u32>) -> u32 {
    // lint: allow(no-unwrap-on-serving-paths) -- caller checked is_some
    v.unwrap()
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_is_fine_in_tests() {
        assert_eq!(super::route(Some(2)).unwrap(), 2);
    }
}
