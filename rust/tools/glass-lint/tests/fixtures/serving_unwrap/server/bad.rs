fn route(v: Option<u32>) -> u32 {
    v.unwrap()
}

fn describe(v: Option<u32>) -> u32 {
    v.expect("value must be routed")
}
