use std::sync::atomic::{AtomicU64, Ordering};

fn bump(c: &AtomicU64) {
    // monotonic counter: readers tolerate stale values
    c.fetch_add(1, Ordering::Relaxed);
}

fn strict(c: &AtomicU64) -> u64 {
    c.load(Ordering::SeqCst)
}

fn is_less(a: u32, b: u32) -> bool {
    matches!(a.cmp(&b), std::cmp::Ordering::Less)
}
