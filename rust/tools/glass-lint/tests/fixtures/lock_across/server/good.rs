use std::io::Write;
use std::net::TcpStream;
use std::sync::Mutex;

fn flush(m: &Mutex<Vec<u8>>, s: &mut TcpStream) {
    let msg = {
        let buf = m.lock().unwrap_or_else(|p| p.into_inner());
        buf.clone()
    };
    s.write_all(&msg).ok();
}

fn flush_explicit_drop(m: &Mutex<Vec<u8>>, s: &mut TcpStream) {
    let buf = m.lock().unwrap_or_else(|p| p.into_inner());
    let msg = buf.clone();
    drop(buf);
    s.write_all(&msg).ok();
}
