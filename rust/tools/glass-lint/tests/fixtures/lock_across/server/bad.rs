use std::io::Write;
use std::net::TcpStream;
use std::sync::Mutex;

fn flush(m: &Mutex<Vec<u8>>, s: &mut TcpStream) {
    let buf = m.lock().unwrap_or_else(|p| p.into_inner());
    s.write_all(&buf).ok();
}
