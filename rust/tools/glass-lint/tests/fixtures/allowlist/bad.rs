fn nap_without_reason() {
    // lint: allow(no-sleep-outside-reactor)
    std::thread::sleep(std::time::Duration::from_millis(1));
}

fn nap_with_bogus_rule() {
    // lint: allow(no-naps) -- this rule does not exist
    std::thread::sleep(std::time::Duration::from_millis(1));
}
