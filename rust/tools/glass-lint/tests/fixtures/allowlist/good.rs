fn backoff() {
    // lint: allow(no-sleep-outside-reactor) -- client-side backoff,
    // no server slot or lock is held while waiting
    std::thread::sleep(std::time::Duration::from_millis(1));
}
