struct Wrapper(*mut u8);

// SAFETY: the pointer is only ever dereferenced behind the Mutex
// that owns this wrapper, so cross-thread access is serialized.
unsafe impl Send for Wrapper {}

fn shifted(x: u64) -> u64 {
    // an identifier containing the word is not the keyword
    let unsafe_op_in_unsafe_fn = x;
    unsafe_op_in_unsafe_fn << 1
}
