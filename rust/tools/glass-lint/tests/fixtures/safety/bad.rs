struct Wrapper(*mut u8);

unsafe impl Send for Wrapper {}
