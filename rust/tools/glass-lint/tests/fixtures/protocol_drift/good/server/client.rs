pub fn parse(j: &Json) {
    j.get("id");
    j.req("text");
}
