pub fn parse(j: &Json) {
    j.get("id");
    j.get("finish");
}
