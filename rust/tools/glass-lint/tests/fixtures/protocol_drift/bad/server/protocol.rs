//! Fixture protocol module.
//!
//! # Wire-key registry
//!
//! `id`, `text`.

pub fn to_frame(o: &mut Json) {
    o.set("id", 1);
    o.set("text", "x");
    o.set("queue_pos", 0);
}
