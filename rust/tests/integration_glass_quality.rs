//! Quality-direction integration: on the real trained model, the paper's
//! ordering claims should hold in shape on a small LG sample —
//! fusion helps over random, dense beats everything, KLD grows as
//! density drops.

mod common;

use glass::glass::{GlobalPrior, PriorKind, Strategy};
use glass::harness::lgeval::eval_strategies;

#[test]
fn strategy_quality_ordering_holds() {
    let engine = common::engine();
    let prompts = common::sample_prompts(8);
    let i_nps = GlobalPrior::load(&engine.rt, PriorKind::INps).unwrap();

    let strategies = vec![
        ("glass".to_string(), Strategy::Glass { lambda: 0.5 }, Some(&i_nps)),
        ("griffin".to_string(), Strategy::LocalOnly, None),
        ("random".to_string(), Strategy::Random { seed: 7 }, None),
        ("oracle".to_string(), Strategy::Oracle, None),
    ];
    let results =
        eval_strategies(&engine, &prompts, 4, &strategies, 0.5, 100)
            .unwrap();
    let kld: std::collections::HashMap<&str, f64> = results
        .iter()
        .map(|(n, m, _)| (n.as_str(), m.kld.mean))
        .collect();

    // random is the sanity floor: every informed method beats it
    assert!(
        kld["glass"] < kld["random"],
        "glass {} !< random {}",
        kld["glass"],
        kld["random"]
    );
    assert!(kld["griffin"] < kld["random"]);
    // the oracle (post-hoc decode stats) upper-bounds prompt-only local
    assert!(
        kld["oracle"] < kld["griffin"] * 1.05,
        "oracle {} should be at least as good as griffin {}",
        kld["oracle"],
        kld["griffin"]
    );
    // all KLDs positive and finite at 50% sparsity
    for (n, v) in &kld {
        assert!(*v > 0.0 && v.is_finite(), "{n}: bad kld {v}");
    }
}

#[test]
fn kld_monotone_in_density() {
    let engine = common::engine();
    let prompts = common::sample_prompts(4);
    let i_nps = GlobalPrior::load(&engine.rt, PriorKind::INps).unwrap();
    let mut last = 0.0;
    for density in [0.9, 0.5, 0.2] {
        let results = eval_strategies(
            &engine,
            &prompts,
            4,
            &[(
                "glass".to_string(),
                Strategy::Glass { lambda: 0.5 },
                Some(&i_nps),
            )],
            density,
            100,
        )
        .unwrap();
        let kld = results[0].1.kld.mean;
        assert!(
            kld > last,
            "KLD should grow as density drops: {kld} at {density} vs {last}"
        );
        last = kld;
    }
}

#[test]
fn lambda_endpoints_match_dedicated_strategies() {
    // Glass(λ=0) ≡ LocalOnly and Glass(λ=1) ≡ GlobalOnly — on the real
    // model end to end, not just unit level.
    let engine = common::engine();
    let prompts = common::sample_prompts(4);
    let prior = GlobalPrior::load(&engine.rt, PriorKind::ANps).unwrap();
    let strategies = vec![
        ("g0".to_string(), Strategy::Glass { lambda: 0.0 }, Some(&prior)),
        ("local".to_string(), Strategy::LocalOnly, None),
        ("g1".to_string(), Strategy::Glass { lambda: 1.0 }, Some(&prior)),
        ("global".to_string(), Strategy::GlobalOnly, Some(&prior)),
    ];
    let results =
        eval_strategies(&engine, &prompts, 4, &strategies, 0.5, 100)
            .unwrap();
    let get = |n: &str| {
        results
            .iter()
            .find(|(name, _, _)| name == n)
            .map(|(_, m, _)| m.kld.mean)
            .unwrap()
    };
    assert!((get("g0") - get("local")).abs() < 1e-9);
    assert!((get("g1") - get("global")).abs() < 1e-9);
}
