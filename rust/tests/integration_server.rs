//! Serving-layer integration: the continuous batcher driven directly
//! (deterministic, no timing races) plus real TCP server + client runs.

mod common;

use std::time::{Duration, Instant};

use glass::server::batcher::Batcher;
use glass::server::client::{request, Client};
use glass::server::protocol::{Request, Response};
use glass::server::scheduler::{Pending, Scheduler};
use glass::server::Server;

fn start_server() -> Server {
    let engine = common::engine();
    Server::start(engine, "127.0.0.1:0", 4).expect("start server")
}

fn pending(
    conn_id: u64,
    prompt: &str,
    strategy: &str,
    max_tokens: usize,
    refresh_every: usize,
) -> Pending {
    Pending {
        request: Request {
            id: conn_id,
            prompt: prompt.into(),
            strategy: strategy.into(),
            lambda: 0.5,
            density: 0.5,
            max_tokens,
            refresh_every,
        },
        arrived: Instant::now(),
        conn_id,
    }
}

/// Drive the batcher until `n` responses arrive (bounded step budget).
fn drive(
    batcher: &mut Batcher,
    done: &mut Vec<(u64, Response)>,
    n: usize,
) {
    let mut out = std::mem::take(done);
    for _ in 0..512 {
        if out.len() >= n {
            break;
        }
        batcher
            .step(&mut |c, r| out.push((c, r)))
            .expect("decode step");
    }
    *done = out;
}

// ------------------------------------------------------ TCP-level tests

#[test]
fn serves_all_strategies() {
    let server = start_server();
    let mut client = Client::connect(&server.addr).unwrap();
    for strategy in ["dense", "griffin", "global", "a-glass", "i-glass"] {
        let resp = client
            .call(request("once there was a red fox", strategy, 0.5))
            .unwrap();
        assert!(resp.error.is_none(), "{strategy}: {:?}", resp.error);
        assert!(resp.tokens > 0);
        assert!(!resp.text.is_empty(), "{strategy} returned empty text");
        assert!(!resp.finish.is_empty(), "{strategy} missing finish reason");
        if strategy == "dense" {
            assert!((resp.density - 1.0).abs() < 1e-9);
        } else {
            assert!((resp.density - 0.5).abs() < 0.02, "{strategy}");
        }
    }
    server.stop();
}

#[test]
fn batches_concurrent_requests() {
    let server = start_server();
    let mut client = Client::connect(&server.addr).unwrap();
    let reqs: Vec<Request> = (0..6)
        .map(|i| {
            let mut r = request(
                &format!("the blue owl is number {i}"),
                "i-glass",
                0.5,
            );
            r.max_tokens = 16;
            r
        })
        .collect();
    let out = client.call_many(reqs).unwrap();
    assert_eq!(out.len(), 6);
    for (resp, _latency) in &out {
        assert!(resp.error.is_none());
        assert_eq!(resp.tokens, 16);
        assert_eq!(resp.finish, "length");
    }
    server.stop();
}

#[test]
fn malformed_and_invalid_requests_get_errors() {
    let server = start_server();
    // raw socket: send garbage then a bad strategy
    use std::io::{BufRead, BufReader, Write};
    let mut stream =
        std::net::TcpStream::connect(&server.addr).unwrap();
    writeln!(stream, "this is not json").unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "got: {line}");

    writeln!(
        stream,
        r#"{{"id":9,"prompt":"x","strategy":"nonsense"}}"#
    )
    .unwrap();
    let mut line2 = String::new();
    reader.read_line(&mut line2).unwrap();
    assert!(line2.contains("error"), "got: {line2}");
    server.stop();
}

#[test]
fn dense_and_sparse_agree_on_prefix_sometimes() {
    // not a strict invariant, but dense vs 90%-density glass should agree
    // on the first generated token for a well-learned prompt
    let server = start_server();
    let mut client = Client::connect(&server.addr).unwrap();
    let d = client
        .call(request("the red fox is", "dense", 1.0))
        .unwrap();
    let s = client
        .call(request("the red fox is", "i-glass", 0.9))
        .unwrap();
    assert!(!d.text.is_empty() && !s.text.is_empty());
    assert_eq!(
        d.text.chars().next(),
        s.text.chars().next(),
        "dense={:?} sparse={:?}",
        d.text,
        s.text
    );
    server.stop();
}

// --------------------------------------- continuous-batching semantics
//
// These drive the Batcher synchronously (admit/step), so admission
// ordering, early exit, and refresh behavior are asserted without any
// sleeps or cross-thread timing.

#[test]
fn short_request_overtakes_long_one_mid_flight() {
    let engine = common::engine();
    let mut batcher = Batcher::new(engine, 4).unwrap();
    let mut done: Vec<(u64, Response)> = Vec::new();

    // long request starts decoding alone
    let over = batcher.admit(
        vec![pending(1, "once there was a red fox", "i-glass", 24, 0)],
        &mut |c, r| done.push((c, r)),
    );
    assert!(over.is_empty(), "unexpected admission overflow");
    assert_eq!(batcher.active(), 1);
    for _ in 0..5 {
        batcher.step(&mut |c, r| done.push((c, r))).unwrap();
    }
    assert!(done.is_empty(), "long request must still be decoding");

    // short request admitted mid-flight into a free slot
    let over = batcher.admit(
        vec![pending(2, "the blue owl is", "i-glass", 3, 0)],
        &mut |c, r| done.push((c, r)),
    );
    assert!(over.is_empty(), "unexpected admission overflow");
    assert_eq!(batcher.active(), 2, "admitted while slot 0 in flight");

    drive(&mut batcher, &mut done, 2);
    assert_eq!(done.len(), 2, "both requests must complete");
    // the short request finishes (and its response is delivered) FIRST,
    // while the long one is still decoding — no head-of-line blocking
    assert_eq!(done[0].0, 2, "short request delivered first");
    assert_eq!(done[1].0, 1);
    let short = &done[0].1;
    let long = &done[1].1;
    assert!(short.error.is_none() && long.error.is_none());
    assert_eq!(short.tokens, 3);
    assert_eq!(long.tokens, 24);
    assert_eq!(batcher.active(), 0, "slots freed after completion");
}

#[test]
fn mask_refresh_changes_masks_after_r_steps() {
    let engine = common::engine();
    let mut batcher = Batcher::new(engine, 4).unwrap();
    let mut done: Vec<(u64, Response)> = Vec::new();

    // refresh every 4 decoded tokens; control request with refresh off
    let over = batcher.admit(
        vec![
            pending(1, "the blue owl is", "griffin", 16, 4),
            pending(2, "the blue owl is", "i-glass", 16, 4),
            pending(3, "the blue owl is", "griffin", 16, 0),
        ],
        &mut |c, r| done.push((c, r)),
    );
    assert!(over.is_empty(), "unexpected admission overflow");
    drive(&mut batcher, &mut done, 3);
    assert_eq!(done.len(), 3);

    let by_conn = |c: u64| {
        &done.iter().find(|(cc, _)| *cc == c).unwrap().1
    };
    for c in [1, 2] {
        let r = by_conn(c);
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(
            r.refreshes, 3,
            "16 tokens / R=4 → refreshes at 4, 8, 12"
        );
        assert!(
            r.mask_updates >= 1,
            "conn {c}: decode-time statistics drift must change the \
             mask vs. its prefill-time selection (got {} updates)",
            r.mask_updates
        );
        assert!((r.density - 0.5).abs() < 0.02, "budget preserved");
    }
    let control = by_conn(3);
    assert_eq!(control.refreshes, 0);
    assert_eq!(control.mask_updates, 0, "refresh off → static mask");
}

#[test]
fn unknown_strategy_rejected_by_engine_path() {
    // bypasses protocol validation to hit the serve-path guard that
    // used to silently fall through to i-GLASS
    let engine = common::engine();
    let mut batcher = Batcher::new(engine, 4).unwrap();
    let mut done: Vec<(u64, Response)> = Vec::new();
    let over = batcher.admit(
        vec![
            pending(7, "hello", "not-a-strategy", 8, 0),
            pending(8, "hello", "dense", 2, 0),
        ],
        &mut |c, r| done.push((c, r)),
    );
    assert!(over.is_empty(), "unexpected admission overflow");
    // the invalid request errors immediately, before any decode step
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].0, 7);
    let err = done[0].1.error.as_deref().unwrap_or("");
    assert!(
        err.contains("unknown strategy"),
        "expected strategy rejection, got {err:?}"
    );
    // the valid companion request still serves normally
    drive(&mut batcher, &mut done, 2);
    assert_eq!(done.len(), 2);
    assert!(done[1].1.error.is_none());
    assert_eq!(done[1].1.tokens, 2);
}

#[test]
fn stop_state_and_kv_window_bound_generation() {
    // a request whose budget exactly fills the KV window finishes with
    // reason "length" at the window edge (no position overflow); asking
    // for more than the window can hold is rejected at admission with
    // an explicit error — never silently capped or truncated
    let engine = common::engine();
    let max_seq = engine.spec().max_seq;
    let prompt = "the grey cat is quiet and";
    let n_prompt = prompt.len() + 1;
    // the final token comes from the last in-window logits and needs
    // no KV write, so exact capacity is max_seq - n_prompt + 1
    let capacity = max_seq - n_prompt + 1;
    let mut batcher = Batcher::new(engine, 4).unwrap();
    let mut done: Vec<(u64, Response)> = Vec::new();
    let over = batcher.admit(
        vec![pending(1, prompt, "dense", capacity, 0)],
        &mut |c, r| done.push((c, r)),
    );
    assert!(over.is_empty(), "unexpected admission overflow");
    drive(&mut batcher, &mut done, 1);
    assert_eq!(done.len(), 1, "window-filling request must finish");
    let r = &done[0].1;
    assert!(r.error.is_none(), "{:?}", r.error);
    assert_eq!(r.finish, "length");
    assert!(
        r.tokens <= capacity,
        "{} tokens exceeds KV capacity {capacity}",
        r.tokens
    );

    // one token more than the window holds → explicit admission error
    let over = batcher.admit(
        vec![pending(2, prompt, "dense", capacity + 1, 0)],
        &mut |c, r| done.push((c, r)),
    );
    assert!(over.is_empty(), "unexpected admission overflow");
    assert_eq!(done.len(), 2);
    let err = done[1].1.error.as_deref().unwrap_or("");
    assert!(
        err.contains("prompt too long"),
        "expected explicit window rejection, got {err:?}"
    );
}

// ------------------------------------------- chunked long-prompt admission

#[test]
fn long_prompt_is_served_in_full_without_truncation() {
    let engine = common::engine();
    let spec = engine.spec().clone();
    // ≥ 3× the prefill frame: must stream through ≥ 3 chunks
    let long_prompt = "abcdefghij ".repeat(3 * spec.prefill_len / 11 + 1);
    let n_prompt = long_prompt.len() + 1;
    assert!(n_prompt >= 3 * spec.prefill_len);
    assert!(n_prompt + 8 <= spec.max_seq);

    let mut batcher = Batcher::new(engine, 4).unwrap();
    let mut done: Vec<(u64, Response)> = Vec::new();
    let over = batcher.admit(
        vec![pending(1, &long_prompt, "i-glass", 8, 0)],
        &mut |c, r| done.push((c, r)),
    );
    assert!(over.is_empty(), "unexpected admission overflow");
    assert_eq!(batcher.prefilling(), 1, "long prompt streams in");
    assert_eq!(batcher.active(), 0, "no decoding before the final chunk");
    drive(&mut batcher, &mut done, 1);
    assert_eq!(done.len(), 1);
    let r = &done[0].1;
    assert!(r.error.is_none(), "{:?}", r.error);
    assert_eq!(
        r.prompt_tokens, n_prompt,
        "every prompt token must be consumed (no tail truncation)"
    );
    assert_eq!(r.tokens, 8);
    assert!((r.density - 0.5).abs() < 0.02, "glass mask built post-stream");
    assert!(
        batcher.chunks >= 3,
        "expected a multi-chunk stream, got {} chunks",
        batcher.chunks
    );
}

#[test]
fn in_flight_decode_continues_during_chunked_admission() {
    // the stall this PR removes: admitting a long prompt used to run a
    // monolithic prefill while every in-flight slot waited
    let engine = common::engine();
    let spec = engine.spec().clone();
    let mut batcher = Batcher::new(engine, 4).unwrap();
    let mut done: Vec<(u64, Response)> = Vec::new();

    // a short request decodes alone first
    let over = batcher.admit(
        vec![pending(1, "once there was a red fox", "i-glass", 6, 0)],
        &mut |c, r| done.push((c, r)),
    );
    assert!(over.is_empty(), "unexpected admission overflow");
    assert_eq!(batcher.active(), 1);
    for _ in 0..2 {
        batcher.step(&mut |c, r| done.push((c, r))).unwrap();
    }
    assert!(done.is_empty());

    // a long prompt claims a slot and streams chunk by chunk
    let long_prompt = "abcdefghijklm ".repeat(3 * spec.prefill_len / 14 + 1);
    let n_long = long_prompt.len() + 1;
    assert!(n_long >= 3 * spec.prefill_len && n_long + 8 <= spec.max_seq);
    let over = batcher.admit(
        vec![pending(2, &long_prompt, "griffin", 8, 0)],
        &mut |c, r| done.push((c, r)),
    );
    assert!(over.is_empty(), "unexpected admission overflow");
    assert_eq!(batcher.prefilling(), 1);
    assert_eq!(batcher.active(), 1, "short request still in flight");

    drive(&mut batcher, &mut done, 2);
    assert_eq!(done.len(), 2, "both requests must complete");
    // the short request keeps decoding THROUGH the stream and finishes
    // first — its slot never stalls for the newcomer's prompt
    assert_eq!(done[0].0, 1, "short request delivered first");
    assert_eq!(done[1].0, 2);
    let short = &done[0].1;
    let long = &done[1].1;
    assert!(short.error.is_none() && long.error.is_none());
    assert_eq!(short.tokens, 6);
    assert_eq!(long.tokens, 8);
    assert_eq!(long.prompt_tokens, n_long, "stream consumed in full");
    assert!(
        batcher.overlap_steps > 0,
        "decode steps must overlap prefill streaming (no-stall evidence)"
    );
    assert!(batcher.chunks >= 3, "got {} chunks", batcher.chunks);
}

#[test]
fn burst_wider_than_free_slots_is_requeued_not_failed() {
    // Batcher::admit used to shed overload with "batcher overloaded"
    // errors, losing requests; overflow now flows back to the scheduler
    // queue front and every request is eventually served (FCFS)
    let engine = common::engine();
    let mut batcher = Batcher::new(engine, 4).unwrap();
    // scheduler wider than the batcher, so next_batch can hand admit()
    // more requests than there are decode slots
    let sched = Scheduler::new(10, Duration::from_millis(1));
    for i in 0..10 {
        sched.submit(pending(i, "the blue owl is", "dense", 3, 0));
    }
    sched.close();
    let mut done: Vec<(u64, Response)> = Vec::new();
    batcher.run(&sched, &mut |c, r| done.push((c, r)));
    assert_eq!(done.len(), 10, "every burst request must be served");
    for (c, r) in &done {
        assert!(r.error.is_none(), "conn {c}: {:?}", r.error);
        assert_eq!(r.tokens, 3);
    }
}
