//! Serving-layer integration: real TCP server + client over the engine.

mod common;

use glass::server::client::{request, Client};
use glass::server::protocol::Request;
use glass::server::Server;

fn start_server() -> Server {
    let engine = common::engine();
    Server::start(engine, "127.0.0.1:0", 4).expect("start server")
}

#[test]
fn serves_all_strategies() {
    let server = start_server();
    let mut client = Client::connect(&server.addr).unwrap();
    for strategy in ["dense", "griffin", "global", "a-glass", "i-glass"] {
        let resp = client
            .call(request("once there was a red fox", strategy, 0.5))
            .unwrap();
        assert!(resp.error.is_none(), "{strategy}: {:?}", resp.error);
        assert!(resp.tokens > 0);
        assert!(!resp.text.is_empty(), "{strategy} returned empty text");
        if strategy == "dense" {
            assert!((resp.density - 1.0).abs() < 1e-9);
        } else {
            assert!((resp.density - 0.5).abs() < 0.02, "{strategy}");
        }
    }
    server.stop();
}

#[test]
fn batches_concurrent_requests() {
    let server = start_server();
    let mut client = Client::connect(&server.addr).unwrap();
    let reqs: Vec<Request> = (0..6)
        .map(|i| {
            let mut r = request(
                &format!("the blue owl is number {i}"),
                "i-glass",
                0.5,
            );
            r.max_tokens = 16;
            r
        })
        .collect();
    let out = client.call_many(reqs).unwrap();
    assert_eq!(out.len(), 6);
    for (resp, _latency) in &out {
        assert!(resp.error.is_none());
        assert_eq!(resp.tokens, 16);
    }
    server.stop();
}

#[test]
fn malformed_and_invalid_requests_get_errors() {
    let server = start_server();
    // raw socket: send garbage then a bad strategy
    use std::io::{BufRead, BufReader, Write};
    let mut stream =
        std::net::TcpStream::connect(&server.addr).unwrap();
    writeln!(stream, "this is not json").unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "got: {line}");

    writeln!(
        stream,
        r#"{{"id":9,"prompt":"x","strategy":"nonsense"}}"#
    )
    .unwrap();
    let mut line2 = String::new();
    reader.read_line(&mut line2).unwrap();
    assert!(line2.contains("error"), "got: {line2}");
    server.stop();
}

#[test]
fn dense_and_sparse_agree_on_prefix_sometimes() {
    // not a strict invariant, but dense vs 90%-density glass should agree
    // on the first generated token for a well-learned prompt
    let server = start_server();
    let mut client = Client::connect(&server.addr).unwrap();
    let d = client
        .call(request("the red fox is", "dense", 1.0))
        .unwrap();
    let s = client
        .call(request("the red fox is", "i-glass", 0.9))
        .unwrap();
    assert!(!d.text.is_empty() && !s.text.is_empty());
    assert_eq!(
        d.text.chars().next(),
        s.text.chars().next(),
        "dense={:?} sparse={:?}",
        d.text,
        s.text
    );
    server.stop();
}
