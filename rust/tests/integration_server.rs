//! Serving-layer integration: the continuous batcher driven directly
//! (deterministic, no timing races) plus real TCP server + client runs.
//!
//! Every TCP-level test starts its server with `GLASS_TEST_SHARDS`
//! shards (default 1) — the CI matrix runs the whole suite at 1 and 4
//! shards, so concurrency regressions in the sharded batcher cannot
//! land green. Tests that specifically exercise sharding pin their own
//! shard count with [`start_server_sharded`].

mod common;

use std::collections::HashMap;
use std::time::{Duration, Instant};

use glass::engine::prefix_cache::CacheMode;
use glass::server::batcher::{Batcher, BatcherOptions};
use glass::server::client::{request, Client};
use glass::server::protocol::{Request, Response};
use glass::server::scheduler::{Pending, Scheduler};
use glass::server::{Server, ServerOptions};

/// Shard count for the generic TCP tests (the CI matrix sets this).
fn test_shards() -> usize {
    std::env::var("GLASS_TEST_SHARDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(1)
}

fn start_server() -> Server {
    start_server_sharded(test_shards())
}

fn start_server_sharded(shards: usize) -> Server {
    let engine = common::engine();
    let opts = ServerOptions::new(4).with_shards(shards);
    Server::start_with(engine, "127.0.0.1:0", opts)
        .expect("start server")
}

fn pending(
    conn_id: u64,
    prompt: &str,
    strategy: &str,
    max_tokens: usize,
    refresh_every: usize,
) -> Pending {
    pending_cached(
        conn_id,
        prompt,
        strategy,
        max_tokens,
        refresh_every,
        CacheMode::On,
    )
}

fn pending_cached(
    conn_id: u64,
    prompt: &str,
    strategy: &str,
    max_tokens: usize,
    refresh_every: usize,
    cache: CacheMode,
) -> Pending {
    Pending {
        request: Request {
            id: conn_id,
            prompt: prompt.into(),
            strategy: strategy.into(),
            lambda: 0.5,
            density: 0.5,
            max_tokens,
            refresh_every,
            cache,
        },
        arrived: Instant::now(),
        conn_id,
    }
}

/// Drive the batcher until `n` responses arrive (bounded step budget).
fn drive(
    batcher: &mut Batcher,
    done: &mut Vec<(u64, Response)>,
    n: usize,
) {
    let mut out = std::mem::take(done);
    for _ in 0..512 {
        if out.len() >= n {
            break;
        }
        batcher
            .step(&mut |c, r| out.push((c, r)))
            .expect("decode step");
    }
    *done = out;
}

// ------------------------------------------------------ TCP-level tests

#[test]
fn serves_all_strategies() {
    let server = start_server();
    let mut client = Client::connect(&server.addr).unwrap();
    for strategy in ["dense", "griffin", "global", "a-glass", "i-glass"] {
        let resp = client
            .call(request("once there was a red fox", strategy, 0.5))
            .unwrap();
        assert!(resp.error.is_none(), "{strategy}: {:?}", resp.error);
        assert!(resp.tokens > 0);
        assert!(!resp.text.is_empty(), "{strategy} returned empty text");
        assert!(!resp.finish.is_empty(), "{strategy} missing finish reason");
        if strategy == "dense" {
            assert!((resp.density - 1.0).abs() < 1e-9);
        } else {
            assert!((resp.density - 0.5).abs() < 0.02, "{strategy}");
        }
    }
    server.stop();
}

#[test]
fn batches_concurrent_requests() {
    let server = start_server();
    let mut client = Client::connect(&server.addr).unwrap();
    let reqs: Vec<Request> = (0..6)
        .map(|i| {
            let mut r = request(
                &format!("the blue owl is number {i}"),
                "i-glass",
                0.5,
            );
            r.max_tokens = 16;
            r
        })
        .collect();
    let out = client.call_many(reqs).unwrap();
    assert_eq!(out.len(), 6);
    for (resp, _latency) in &out {
        assert!(resp.error.is_none());
        assert_eq!(resp.tokens, 16);
        assert_eq!(resp.finish, "length");
    }
    server.stop();
}

#[test]
fn malformed_and_invalid_requests_get_errors() {
    let server = start_server();
    // raw socket: send garbage then a bad strategy
    use std::io::{BufRead, BufReader, Write};
    let mut stream =
        std::net::TcpStream::connect(&server.addr).unwrap();
    writeln!(stream, "this is not json").unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "got: {line}");

    writeln!(
        stream,
        r#"{{"id":9,"prompt":"x","strategy":"nonsense"}}"#
    )
    .unwrap();
    let mut line2 = String::new();
    reader.read_line(&mut line2).unwrap();
    assert!(line2.contains("error"), "got: {line2}");
    server.stop();
}

#[test]
fn dense_and_sparse_agree_on_prefix_sometimes() {
    // not a strict invariant, but dense vs 90%-density glass should agree
    // on the first generated token for a well-learned prompt
    let server = start_server();
    let mut client = Client::connect(&server.addr).unwrap();
    let d = client
        .call(request("the red fox is", "dense", 1.0))
        .unwrap();
    let s = client
        .call(request("the red fox is", "i-glass", 0.9))
        .unwrap();
    assert!(!d.text.is_empty() && !s.text.is_empty());
    assert_eq!(
        d.text.chars().next(),
        s.text.chars().next(),
        "dense={:?} sparse={:?}",
        d.text,
        s.text
    );
    server.stop();
}

// --------------------------------------- continuous-batching semantics
//
// These drive the Batcher synchronously (admit/step), so admission
// ordering, early exit, and refresh behavior are asserted without any
// sleeps or cross-thread timing.

#[test]
fn short_request_overtakes_long_one_mid_flight() {
    let engine = common::engine();
    let mut batcher = Batcher::new(engine, 4).unwrap();
    let mut done: Vec<(u64, Response)> = Vec::new();

    // long request starts decoding alone
    let over = batcher.admit(
        vec![pending(1, "once there was a red fox", "i-glass", 24, 0)],
        &mut |c, r| done.push((c, r)),
    );
    assert!(over.is_empty(), "unexpected admission overflow");
    assert_eq!(batcher.active(), 1);
    for _ in 0..5 {
        batcher.step(&mut |c, r| done.push((c, r))).unwrap();
    }
    assert!(done.is_empty(), "long request must still be decoding");

    // short request admitted mid-flight into a free slot
    let over = batcher.admit(
        vec![pending(2, "the blue owl is", "i-glass", 3, 0)],
        &mut |c, r| done.push((c, r)),
    );
    assert!(over.is_empty(), "unexpected admission overflow");
    assert_eq!(batcher.active(), 2, "admitted while slot 0 in flight");

    drive(&mut batcher, &mut done, 2);
    assert_eq!(done.len(), 2, "both requests must complete");
    // the short request finishes (and its response is delivered) FIRST,
    // while the long one is still decoding — no head-of-line blocking
    assert_eq!(done[0].0, 2, "short request delivered first");
    assert_eq!(done[1].0, 1);
    let short = &done[0].1;
    let long = &done[1].1;
    assert!(short.error.is_none() && long.error.is_none());
    assert_eq!(short.tokens, 3);
    assert_eq!(long.tokens, 24);
    assert_eq!(batcher.active(), 0, "slots freed after completion");
}

#[test]
fn mask_refresh_changes_masks_after_r_steps() {
    let engine = common::engine();
    let mut batcher = Batcher::new(engine, 4).unwrap();
    let mut done: Vec<(u64, Response)> = Vec::new();

    // refresh every 4 decoded tokens; control request with refresh off
    let over = batcher.admit(
        vec![
            pending(1, "the blue owl is", "griffin", 16, 4),
            pending(2, "the blue owl is", "i-glass", 16, 4),
            pending(3, "the blue owl is", "griffin", 16, 0),
        ],
        &mut |c, r| done.push((c, r)),
    );
    assert!(over.is_empty(), "unexpected admission overflow");
    drive(&mut batcher, &mut done, 3);
    assert_eq!(done.len(), 3);

    let by_conn = |c: u64| {
        &done.iter().find(|(cc, _)| *cc == c).unwrap().1
    };
    for c in [1, 2] {
        let r = by_conn(c);
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(
            r.refreshes, 3,
            "16 tokens / R=4 → refreshes at 4, 8, 12"
        );
        assert!(
            r.mask_updates >= 1,
            "conn {c}: decode-time statistics drift must change the \
             mask vs. its prefill-time selection (got {} updates)",
            r.mask_updates
        );
        assert!((r.density - 0.5).abs() < 0.02, "budget preserved");
    }
    let control = by_conn(3);
    assert_eq!(control.refreshes, 0);
    assert_eq!(control.mask_updates, 0, "refresh off → static mask");
}

#[test]
fn unknown_strategy_rejected_by_engine_path() {
    // bypasses protocol validation to hit the serve-path guard that
    // used to silently fall through to i-GLASS
    let engine = common::engine();
    let mut batcher = Batcher::new(engine, 4).unwrap();
    let mut done: Vec<(u64, Response)> = Vec::new();
    let over = batcher.admit(
        vec![
            pending(7, "hello", "not-a-strategy", 8, 0),
            pending(8, "hello", "dense", 2, 0),
        ],
        &mut |c, r| done.push((c, r)),
    );
    assert!(over.is_empty(), "unexpected admission overflow");
    // the invalid request errors immediately, before any decode step
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].0, 7);
    let err = done[0].1.error.as_deref().unwrap_or("");
    assert!(
        err.contains("unknown strategy"),
        "expected strategy rejection, got {err:?}"
    );
    // the valid companion request still serves normally
    drive(&mut batcher, &mut done, 2);
    assert_eq!(done.len(), 2);
    assert!(done[1].1.error.is_none());
    assert_eq!(done[1].1.tokens, 2);
}

#[test]
fn stop_state_and_kv_window_bound_generation() {
    // a request whose budget exactly fills the KV window finishes with
    // reason "length" at the window edge (no position overflow); asking
    // for more than the window can hold is rejected at admission with
    // an explicit error — never silently capped or truncated
    let engine = common::engine();
    let max_seq = engine.spec().max_seq;
    let prompt = "the grey cat is quiet and";
    let n_prompt = prompt.len() + 1;
    // the final token comes from the last in-window logits and needs
    // no KV write, so exact capacity is max_seq - n_prompt + 1
    let capacity = max_seq - n_prompt + 1;
    let mut batcher = Batcher::new(engine, 4).unwrap();
    let mut done: Vec<(u64, Response)> = Vec::new();
    let over = batcher.admit(
        vec![pending(1, prompt, "dense", capacity, 0)],
        &mut |c, r| done.push((c, r)),
    );
    assert!(over.is_empty(), "unexpected admission overflow");
    drive(&mut batcher, &mut done, 1);
    assert_eq!(done.len(), 1, "window-filling request must finish");
    let r = &done[0].1;
    assert!(r.error.is_none(), "{:?}", r.error);
    assert_eq!(r.finish, "length");
    assert!(
        r.tokens <= capacity,
        "{} tokens exceeds KV capacity {capacity}",
        r.tokens
    );

    // one token more than the window holds → explicit admission error
    let over = batcher.admit(
        vec![pending(2, prompt, "dense", capacity + 1, 0)],
        &mut |c, r| done.push((c, r)),
    );
    assert!(over.is_empty(), "unexpected admission overflow");
    assert_eq!(done.len(), 2);
    let err = done[1].1.error.as_deref().unwrap_or("");
    assert!(
        err.contains("prompt too long"),
        "expected explicit window rejection, got {err:?}"
    );
}

// ------------------------------------------- chunked long-prompt admission

#[test]
fn long_prompt_is_served_in_full_without_truncation() {
    let engine = common::engine();
    let spec = engine.spec().clone();
    // ≥ 3× the prefill frame: must stream through ≥ 3 chunks
    let long_prompt = "abcdefghij ".repeat(3 * spec.prefill_len / 11 + 1);
    let n_prompt = long_prompt.len() + 1;
    assert!(n_prompt >= 3 * spec.prefill_len);
    assert!(n_prompt + 8 <= spec.max_seq);

    let mut batcher = Batcher::new(engine, 4).unwrap();
    let mut done: Vec<(u64, Response)> = Vec::new();
    let over = batcher.admit(
        vec![pending(1, &long_prompt, "i-glass", 8, 0)],
        &mut |c, r| done.push((c, r)),
    );
    assert!(over.is_empty(), "unexpected admission overflow");
    assert_eq!(batcher.prefilling(), 1, "long prompt streams in");
    assert_eq!(batcher.active(), 0, "no decoding before the final chunk");
    drive(&mut batcher, &mut done, 1);
    assert_eq!(done.len(), 1);
    let r = &done[0].1;
    assert!(r.error.is_none(), "{:?}", r.error);
    assert_eq!(
        r.prompt_tokens, n_prompt,
        "every prompt token must be consumed (no tail truncation)"
    );
    assert_eq!(r.tokens, 8);
    assert!((r.density - 0.5).abs() < 0.02, "glass mask built post-stream");
    assert!(
        batcher.chunks >= 3,
        "expected a multi-chunk stream, got {} chunks",
        batcher.chunks
    );
}

#[test]
fn in_flight_decode_continues_during_chunked_admission() {
    // the stall this PR removes: admitting a long prompt used to run a
    // monolithic prefill while every in-flight slot waited
    let engine = common::engine();
    let spec = engine.spec().clone();
    let mut batcher = Batcher::new(engine, 4).unwrap();
    let mut done: Vec<(u64, Response)> = Vec::new();

    // a short request decodes alone first
    let over = batcher.admit(
        vec![pending(1, "once there was a red fox", "i-glass", 6, 0)],
        &mut |c, r| done.push((c, r)),
    );
    assert!(over.is_empty(), "unexpected admission overflow");
    assert_eq!(batcher.active(), 1);
    for _ in 0..2 {
        batcher.step(&mut |c, r| done.push((c, r))).unwrap();
    }
    assert!(done.is_empty());

    // a long prompt claims a slot and streams chunk by chunk
    let long_prompt = "abcdefghijklm ".repeat(3 * spec.prefill_len / 14 + 1);
    let n_long = long_prompt.len() + 1;
    assert!(n_long >= 3 * spec.prefill_len && n_long + 8 <= spec.max_seq);
    let over = batcher.admit(
        vec![pending(2, &long_prompt, "griffin", 8, 0)],
        &mut |c, r| done.push((c, r)),
    );
    assert!(over.is_empty(), "unexpected admission overflow");
    assert_eq!(batcher.prefilling(), 1);
    assert_eq!(batcher.active(), 1, "short request still in flight");

    drive(&mut batcher, &mut done, 2);
    assert_eq!(done.len(), 2, "both requests must complete");
    // the short request keeps decoding THROUGH the stream and finishes
    // first — its slot never stalls for the newcomer's prompt
    assert_eq!(done[0].0, 1, "short request delivered first");
    assert_eq!(done[1].0, 2);
    let short = &done[0].1;
    let long = &done[1].1;
    assert!(short.error.is_none() && long.error.is_none());
    assert_eq!(short.tokens, 6);
    assert_eq!(long.tokens, 8);
    assert_eq!(long.prompt_tokens, n_long, "stream consumed in full");
    assert!(
        batcher.overlap_steps > 0,
        "decode steps must overlap prefill streaming (no-stall evidence)"
    );
    assert!(batcher.chunks >= 3, "got {} chunks", batcher.chunks);
}

// ------------------------------------------------- shared-prefix cache

/// Drive one request through a batcher to completion.
fn serve_one(batcher: &mut Batcher, p: Pending) -> Response {
    let mut done: Vec<(u64, Response)> = Vec::new();
    let over = batcher.admit(vec![p], &mut |c, r| done.push((c, r)));
    assert!(over.is_empty(), "unexpected admission overflow");
    drive(batcher, &mut done, 1);
    assert_eq!(done.len(), 1, "request must complete");
    done.pop().unwrap().1
}

/// A multi-frame shared system prefix plus a per-request user suffix.
fn shared_prefix_prompts() -> Option<(String, String, String)> {
    let engine = common::engine();
    if engine.rt.manifest.exe("prefill_chunk_b1").is_err() {
        return None;
    }
    let spec = engine.spec().clone();
    let sys =
        "shared system prompt: answer with terse grammar-world prose. "
            .repeat(2 * spec.prefill_len / 61 + 1);
    assert!(sys.len() >= 2 * spec.prefill_len);
    let p1 = format!("{sys} alpha asks about the fox");
    let p2 = format!("{sys} beta asks about the owl");
    // both must fit the serving capacity with an 8-token budget
    if p2.len().max(p1.len()) + 1 + 8 > spec.max_seq + 1 {
        return None;
    }
    Some((sys, p1, p2))
}

#[test]
fn shared_prefix_hit_is_bit_identical_to_cold_and_reports_savings() {
    // THE cache-correctness contract: for a prompt pair sharing a
    // prefix, the second request's generated text (and mask density)
    // must be identical with the cache on vs. off, while its telemetry
    // proves the prefix was spliced, not recomputed.
    let engine = common::engine();
    let Some((sys, p1, p2)) = shared_prefix_prompts() else {
        eprintln!("artifact bundle lacks prefill_chunk — skipping");
        return;
    };
    let spec = engine.spec().clone();

    // cache ON: p1 warms the prefix, p2 splices it
    let mut on = Batcher::new(engine.clone(), 4).unwrap();
    assert!(on.cache_enabled());
    let first = serve_one(&mut on, pending(1, &p1, "i-glass", 8, 0));
    assert!(first.error.is_none(), "{:?}", first.error);
    assert_eq!(first.cached_prompt_tokens, 0, "first request is cold");
    let warm = serve_one(&mut on, pending(2, &p2, "i-glass", 8, 0));

    // cache OFF: p2 served cold by a fresh batcher
    let mut off = Batcher::with_options(
        engine.clone(),
        BatcherOptions::new(4).without_cache(),
    )
    .unwrap();
    assert!(!off.cache_enabled());
    let cold = serve_one(&mut off, pending(3, &p2, "i-glass", 8, 0));

    assert!(warm.error.is_none(), "{:?}", warm.error);
    assert!(cold.error.is_none(), "{:?}", cold.error);
    assert_eq!(
        warm.text, cold.text,
        "cached splice changed the generated tokens"
    );
    assert_eq!(warm.tokens, cold.tokens);
    assert_eq!(
        warm.density, cold.density,
        "cached splice changed the GLASS mask"
    );
    assert_eq!(warm.prompt_tokens, cold.prompt_tokens);
    assert_eq!(warm.prompt_tokens, p2.len() + 1, "full prompt consumed");
    // ...and the splice actually happened
    assert!(
        warm.cached_prompt_tokens >= spec.prefill_len,
        "expected ≥ one cached frame, got {}",
        warm.cached_prompt_tokens
    );
    assert!(
        warm.cached_prompt_tokens <= sys.len() + 2,
        "cached span cannot exceed the shared prefix"
    );
    assert_eq!(warm.cache_hits, 1);
    assert_eq!(cold.cached_prompt_tokens, 0);
    assert_eq!(cold.cache_hits, 0);
    assert!(
        on.prefill_tokens_saved >= spec.prefill_len as u64,
        "batcher-level savings counter must record the splice"
    );
}

#[test]
fn exact_repeat_prompt_skips_prefill_entirely() {
    let engine = common::engine();
    let mut batcher = Batcher::new(engine, 4).unwrap();
    let prompt = "the grey cat is quiet and";
    let a = serve_one(&mut batcher, pending(1, prompt, "i-glass", 6, 0));
    let b = serve_one(&mut batcher, pending(2, prompt, "i-glass", 6, 0));
    assert!(a.error.is_none() && b.error.is_none());
    assert_eq!(a.text, b.text, "same prompt, same greedy output");
    assert_eq!(a.cached_prompt_tokens, 0);
    // the repeat hit the full-prompt entry: every token spliced
    assert_eq!(b.cached_prompt_tokens, prompt.len() + 1);
    assert_eq!(b.cache_hits, 1);
    assert_eq!(b.prompt_tokens, prompt.len() + 1);
    assert_eq!(b.prefill_ms, 0.0, "exact hit makes no prefill call");
}

#[test]
fn cache_off_mode_bypasses_and_readonly_never_inserts() {
    let engine = common::engine();
    let mut batcher = Batcher::new(engine, 4).unwrap();
    let prompt = "every morning the wolf";
    let telemetry = batcher.telemetry();

    // readonly on a cold cache: reads (miss), never publishes
    let r = serve_one(
        &mut batcher,
        pending_cached(1, prompt, "dense", 4, 0, CacheMode::ReadOnly),
    );
    assert!(r.error.is_none());
    let snap = telemetry.snapshot();
    assert_eq!(snap.inserts, 0, "readonly must never insert");
    assert_eq!(snap.misses, 1);

    // a later identical readonly request still misses (nothing stored)
    let r2 = serve_one(
        &mut batcher,
        pending_cached(2, prompt, "dense", 4, 0, CacheMode::ReadOnly),
    );
    assert_eq!(r2.cached_prompt_tokens, 0);
    assert_eq!(telemetry.snapshot().inserts, 0);

    // mode `on` publishes; a following `off` request bypasses entirely
    let r3 = serve_one(
        &mut batcher,
        pending_cached(3, prompt, "dense", 4, 0, CacheMode::On),
    );
    assert!(r3.error.is_none());
    assert!(telemetry.snapshot().inserts >= 1, "on-mode publishes");
    let r4 = serve_one(
        &mut batcher,
        pending_cached(4, prompt, "dense", 4, 0, CacheMode::Off),
    );
    assert_eq!(
        r4.cached_prompt_tokens, 0,
        "off-mode must not read the warm entry"
    );
    assert_eq!(r4.cache_hits, 0);
    assert_eq!(r4.text, r3.text, "bypass serves the same output");

    // ...while an `on` request does hit it
    let r5 = serve_one(
        &mut batcher,
        pending_cached(5, prompt, "dense", 4, 0, CacheMode::On),
    );
    assert_eq!(r5.cached_prompt_tokens, prompt.len() + 1);
}

#[test]
fn same_prefix_burst_pays_the_prefix_miss_once() {
    let engine = common::engine();
    let Some((_sys, p1, p2)) = shared_prefix_prompts() else {
        eprintln!("artifact bundle lacks prefill_chunk — skipping");
        return;
    };
    let spec = engine.spec().clone();
    let mut batcher = Batcher::new(engine, 4).unwrap();
    // both requests submitted in ONE admission burst: the follower is
    // deferred (returned with the overflow) while the leader streams,
    // then splices the published prefix on retry
    let sched = Scheduler::new(4, Duration::from_millis(1));
    sched.submit(pending(1, &p1, "i-glass", 8, 0));
    sched.submit(pending(2, &p2, "i-glass", 8, 0));
    sched.close();
    let mut done: Vec<(u64, Response)> = Vec::new();
    batcher.run(&sched, &mut |c, r| done.push((c, r)));
    assert_eq!(done.len(), 2);
    let by_conn = |c: u64| {
        &done.iter().find(|(cc, _)| *cc == c).unwrap().1
    };
    let (leader, follower) = (by_conn(1), by_conn(2));
    assert!(leader.error.is_none() && follower.error.is_none());
    assert_eq!(leader.cached_prompt_tokens, 0, "leader pays the miss");
    assert!(
        follower.cached_prompt_tokens >= spec.prefill_len,
        "deferred follower must splice the published prefix \
         (got {} cached tokens)",
        follower.cached_prompt_tokens
    );

    // warm re-burst: with every prefix cached, NOBODY defers or pays —
    // both requests splice (the deferral check peeks the cache first)
    let sched = Scheduler::new(4, Duration::from_millis(1));
    sched.submit(pending(3, &p1, "i-glass", 8, 0));
    sched.submit(pending(4, &p2, "i-glass", 8, 0));
    sched.close();
    let mut done: Vec<(u64, Response)> = Vec::new();
    batcher.run(&sched, &mut |c, r| done.push((c, r)));
    assert_eq!(done.len(), 2);
    for (c, r) in &done {
        assert!(r.error.is_none(), "conn {c}: {:?}", r.error);
        assert!(
            r.cached_prompt_tokens >= spec.prefill_len,
            "conn {c}: warm burst must hit (got {} cached tokens)",
            r.cached_prompt_tokens
        );
    }
}

#[test]
fn stats_command_reports_server_cache_counters() {
    let server = start_server();
    let mut client = Client::connect(&server.addr).unwrap();
    // cold stats: all zero
    let s0 = client.stats().unwrap();
    assert_eq!(s0.hits + s0.misses + s0.inserts, 0);
    // one served request (miss + publish), one repeat (hit)
    let prompt = "once there was a red fox";
    for _ in 0..2 {
        let resp =
            client.call(request(prompt, "i-glass", 0.5)).unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
    }
    let s = client.stats().unwrap();
    assert!(s.misses >= 1, "first request misses: {s:?}");
    assert!(s.hits >= 1, "repeat request hits: {s:?}");
    assert!(s.inserts >= 1, "miss publishes: {s:?}");
    assert!(s.bytes_resident > 0, "entries are byte-accounted: {s:?}");
    assert!(s.entries >= 1);
    server.stop();
}

// --------------------------------------------------- sharded serving

/// A fixed mixed request set: every strategy over the short prompts,
/// plus (when the bundle supports chunked prefill) a multi-chunk long
/// prompt and a shared-prefix pair. Ids are distinct and deterministic.
fn fixed_workload() -> Vec<Request> {
    let engine = common::engine();
    let spec = engine.spec().clone();
    let mut reqs = Vec::new();
    let mut id = 0u64;
    for strategy in ["i-glass", "dense", "griffin"] {
        for prompt in [
            "once there was a red fox",
            "the blue owl is",
            "every morning the wolf",
            "the grey cat is quiet and",
        ] {
            id += 1;
            let mut r = request(prompt, strategy, 0.5);
            r.id = id;
            r.max_tokens = 8;
            reqs.push(r);
        }
    }
    if engine.rt.manifest.exe("prefill_chunk_b1").is_ok() {
        let long = "abcdefghij ".repeat(3 * spec.prefill_len / 11 + 1);
        if long.len() + 1 + 8 <= spec.max_seq {
            id += 1;
            let mut r = request(&long, "i-glass", 0.5);
            r.id = id;
            r.max_tokens = 8;
            reqs.push(r);
        }
        if let Some((_sys, p1, p2)) = shared_prefix_prompts() {
            for p in [p1, p2] {
                id += 1;
                let mut r = request(&p, "i-glass", 0.5);
                r.id = id;
                r.max_tokens = 8;
                reqs.push(r);
            }
        }
    }
    reqs
}

/// Per-request observables compared across shard counts: text, tokens,
/// prompt_tokens, mask density, finish reason (timing fields excluded).
type Digest = HashMap<u64, (String, usize, usize, f64, String)>;

#[test]
fn four_shards_serve_bit_identical_outputs_to_one_shard() {
    // THE sharding-correctness contract: splitting the serving stack
    // into per-shard decode loops (separate engines, KV, caches with a
    // split byte budget) must not change a single generated token. The
    // sim backend is deterministic per slot, so any divergence here is
    // a real sharding bug, not noise.
    let digest = |shards: usize| -> Digest {
        let server = start_server_sharded(shards);
        let mut client = Client::connect(&server.addr).unwrap();
        let out = client.call_many(fixed_workload()).unwrap();
        server.stop();
        out.into_iter()
            .map(|(r, _latency)| {
                assert!(r.error.is_none(), "id {}: {:?}", r.id, r.error);
                (
                    r.id,
                    (r.text, r.tokens, r.prompt_tokens, r.density, r.finish),
                )
            })
            .collect()
    };
    let one = digest(1);
    let four = digest(4);
    assert_eq!(one.len(), four.len());
    for (id, resp) in &one {
        assert_eq!(
            four.get(id),
            Some(resp),
            "request {id} diverged between --shards 1 and --shards 4"
        );
    }
}

#[test]
fn same_prefix_burst_across_connections_pays_one_miss_on_shards() {
    // prefix-affinity routing colocates a shared-system-prompt burst
    // on ONE shard even when the requests arrive on different
    // connections — so the whole burst still pays exactly one cold
    // prefill, exactly like the single-shard deferral test above
    let Some((_sys, p1, p2)) = shared_prefix_prompts() else {
        eprintln!("artifact bundle lacks prefill_chunk — skipping");
        return;
    };
    let spec = common::engine().spec().clone();
    let p3 = {
        // third distinct suffix over the same system prefix
        let cut = p2.len() - "beta asks about the owl".len();
        format!("{}gamma asks about the cat", &p2[..cut])
    };
    let server = start_server_sharded(4);
    let mut clients: Vec<Client> = (0..3)
        .map(|_| Client::connect(&server.addr).unwrap())
        .collect();
    // all three submitted before any response is read, from three
    // distinct connections (order of arrival at the shard is whatever
    // the kernel makes of it — the invariant must hold regardless)
    for (i, (c, p)) in
        clients.iter_mut().zip([&p1, &p2, &p3]).enumerate()
    {
        let mut r = request(p, "i-glass", 0.5);
        r.id = (i as u64 + 1) * 11;
        r.max_tokens = 8;
        c.send(r).unwrap();
    }
    let resps: Vec<Response> = clients
        .iter_mut()
        .map(|c| c.recv().unwrap())
        .collect();
    let mut cold = 0usize;
    for r in &resps {
        assert!(r.error.is_none(), "id {}: {:?}", r.id, r.error);
        if r.cached_prompt_tokens == 0 {
            cold += 1;
        } else {
            assert!(
                r.cached_prompt_tokens >= spec.prefill_len,
                "id {}: warm member spliced only {} tokens",
                r.id,
                r.cached_prompt_tokens
            );
        }
    }
    assert_eq!(
        cold, 1,
        "a same-prefix burst must pay exactly one cold prefill \
         (cached_prompt_tokens per response: {:?})",
        resps
            .iter()
            .map(|r| r.cached_prompt_tokens)
            .collect::<Vec<_>>()
    );
    server.stop();
}

#[test]
fn repeat_prompt_across_connections_hits_the_same_shard() {
    // behavioral proof of routing determinism: the second connection's
    // identical prompt must land on the shard that cached it, turning
    // into an exact full-prompt hit with zero prefill
    let server = start_server_sharded(4);
    let prompt = "every morning the wolf";
    let mut a = Client::connect(&server.addr).unwrap();
    let first = a.call(request(prompt, "i-glass", 0.5)).unwrap();
    assert!(first.error.is_none(), "{:?}", first.error);
    assert_eq!(first.cached_prompt_tokens, 0, "first serve is cold");
    let mut b = Client::connect(&server.addr).unwrap();
    let second = b.call(request(prompt, "i-glass", 0.5)).unwrap();
    assert!(second.error.is_none(), "{:?}", second.error);
    assert_eq!(
        second.cached_prompt_tokens,
        prompt.len() + 1,
        "deterministic routing must land the repeat on the warm shard"
    );
    assert_eq!(second.text, first.text);
    server.stop();
}

#[test]
fn stats_reports_per_shard_queue_depth_and_occupancy() {
    let server = start_server_sharded(4);
    let mut client = Client::connect(&server.addr).unwrap();
    // cold: four shards, correct widths, nothing queued or occupied
    let (agg0, shards0) = client.stats_full().unwrap();
    assert_eq!(shards0.len(), 4);
    for (i, sh) in shards0.iter().enumerate() {
        assert_eq!(sh.shard, i as u64);
        assert_eq!(sh.batch_width, 4);
        assert_eq!(sh.queue_depth, 0);
        assert_eq!(sh.slots_active, 0);
        assert_eq!(sh.slots_prefilling, 0);
    }
    assert_eq!(agg0.hits + agg0.misses + agg0.inserts, 0);

    // serve a few requests, then wait for the gauges to drain: the
    // batcher publishes occupancy after the retiring step, so poll
    // briefly instead of racing it
    let out = client
        .call_many(
            (1..=6u64)
                .map(|i| {
                    let mut r = request(
                        &format!("the blue owl is number {i}"),
                        "dense",
                        0.5,
                    );
                    r.id = i;
                    r.max_tokens = 4;
                    r
                })
                .collect(),
        )
        .unwrap();
    assert!(out.iter().all(|(r, _)| r.error.is_none()));
    let deadline = Instant::now() + Duration::from_secs(2);
    loop {
        let (agg, shards) = client.stats_full().unwrap();
        assert_eq!(shards.len(), 4);
        let queued: u64 = shards.iter().map(|s| s.queue_depth).sum();
        let busy: u64 = shards
            .iter()
            .map(|s| s.slots_active + s.slots_prefilling)
            .sum();
        assert_eq!(queued, 0, "queues drain before responses return");
        if busy == 0 {
            // requests were served, caches touched, slots all free
            assert!(agg.misses >= 1, "{agg:?}");
            break;
        }
        assert!(
            Instant::now() < deadline,
            "shard gauges never drained: {shards:?}"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    server.stop();
}

#[test]
fn burst_wider_than_free_slots_is_requeued_not_failed() {
    // Batcher::admit used to shed overload with "batcher overloaded"
    // errors, losing requests; overflow now flows back to the scheduler
    // queue front and every request is eventually served (FCFS)
    let engine = common::engine();
    let mut batcher = Batcher::new(engine, 4).unwrap();
    // scheduler wider than the batcher, so next_batch can hand admit()
    // more requests than there are decode slots
    let sched = Scheduler::new(10, Duration::from_millis(1));
    for i in 0..10 {
        sched.submit(pending(i, "the blue owl is", "dense", 3, 0));
    }
    sched.close();
    let mut done: Vec<(u64, Response)> = Vec::new();
    batcher.run(&sched, &mut |c, r| done.push((c, r)));
    assert_eq!(done.len(), 10, "every burst request must be served");
    for (c, r) in &done {
        assert!(r.error.is_none(), "conn {c}: {:?}", r.error);
        assert_eq!(r.tokens, 3);
    }
}
