//! Serving-layer integration: the continuous batcher driven directly
//! (deterministic, no timing races) plus real TCP server + client runs
//! over the nonblocking reactor.
//!
//! Every TCP-level test starts its server with `GLASS_TEST_SHARDS`
//! shards (default 1) and talks `GLASS_TEST_PROTOCOL` (v1 default, v2
//! for the framed streaming protocol) — the CI matrix crosses both, so
//! neither a sharding nor a protocol regression can land green. Tests
//! that exercise a specific shard count or protocol pin their own.

mod common;

use std::collections::HashMap;
use std::time::{Duration, Instant};

use glass::config::ServerConfig;
use glass::engine::prefix_cache::CacheMode;
use glass::server::batcher::Batcher;
use glass::server::client::{request, Client};
use glass::server::protocol::{Event, Request, Response, Tier};
use glass::server::scheduler::{Control, Pending, Scheduler};
use glass::server::Server;

/// Shard count for the generic TCP tests (the CI matrix sets this).
fn test_shards() -> usize {
    std::env::var("GLASS_TEST_SHARDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(1)
}

/// Should the generic TCP tests speak v2 (the CI matrix sets this)?
fn test_protocol_v2() -> bool {
    std::env::var("GLASS_TEST_PROTOCOL")
        .map(|v| v == "v2")
        .unwrap_or(false)
}

/// Should the generic TCP servers run with the overload governor on
/// (the CI matrix sets this)? Degradation only rewrites knob values
/// under pressure and never below the per-tier floors, so the whole
/// generic suite must stay green either way.
fn test_governor() -> bool {
    std::env::var("GLASS_TEST_GOVERNOR")
        .map(|v| v == "on")
        .unwrap_or(false)
}

/// Protocol-matrix client: v1 or v2 per `GLASS_TEST_PROTOCOL`. The
/// blocking API is identical, so every generic test runs on both.
fn connect(addr: &str) -> Client {
    if test_protocol_v2() {
        Client::connect_v2(addr).unwrap()
    } else {
        Client::connect(addr).unwrap()
    }
}

fn start_server() -> Server {
    start_server_sharded(test_shards())
}

fn start_server_sharded(shards: usize) -> Server {
    let engine = common::engine();
    let cfg = ServerConfig::new(4)
        .with_bind("127.0.0.1:0")
        .with_shards(shards)
        .with_governor(test_governor());
    Server::start_with_config(engine, &cfg).expect("start server")
}

fn pending(
    conn_id: u64,
    prompt: &str,
    strategy: &str,
    max_tokens: usize,
    refresh_every: usize,
) -> Pending {
    pending_cached(
        conn_id,
        prompt,
        strategy,
        max_tokens,
        refresh_every,
        CacheMode::On,
    )
}

fn pending_cached(
    conn_id: u64,
    prompt: &str,
    strategy: &str,
    max_tokens: usize,
    refresh_every: usize,
    cache: CacheMode,
) -> Pending {
    Pending {
        request: Request {
            id: conn_id,
            prompt: prompt.into(),
            strategy: strategy.into(),
            lambda: 0.5,
            density: 0.5,
            max_tokens,
            refresh_every,
            cache,
            tier: Tier::Standard,
        },
        arrived: Instant::now(),
        conn_id,
        // component tests assert delta/refresh event streams
        stream: true,
        resume_from: 0,
        degraded: false,
        reported_floor: usize::MAX,
    }
}

/// Event-sink adapter: collect only terminal responses, exactly what
/// the v1 compatibility shim serializes.
fn respond(
    done: &mut Vec<(u64, Response)>,
) -> impl FnMut(u64, Event) + '_ {
    move |c, ev| {
        if let Some(r) = ev.into_response() {
            done.push((c, r));
        }
    }
}

/// Drive the batcher until `n` responses arrive (bounded step budget).
fn drive(
    batcher: &mut Batcher,
    done: &mut Vec<(u64, Response)>,
    n: usize,
) {
    let mut out = std::mem::take(done);
    for _ in 0..512 {
        if out.len() >= n {
            break;
        }
        batcher.step(&mut respond(&mut out)).expect("decode step");
    }
    *done = out;
}

// ------------------------------------------------------ TCP-level tests

#[test]
fn serves_all_strategies() {
    let server = start_server();
    let mut client = connect(&server.addr);
    for strategy in ["dense", "griffin", "global", "a-glass", "i-glass"] {
        let resp = client
            .call(request("once there was a red fox", strategy, 0.5))
            .unwrap();
        assert!(resp.error.is_none(), "{strategy}: {:?}", resp.error);
        assert!(resp.tokens > 0);
        assert!(!resp.text.is_empty(), "{strategy} returned empty text");
        assert!(!resp.finish.is_empty(), "{strategy} missing finish reason");
        if strategy == "dense" {
            assert!((resp.density - 1.0).abs() < 1e-9);
        } else {
            assert!((resp.density - 0.5).abs() < 0.02, "{strategy}");
        }
    }
    server.stop();
}

#[test]
fn batches_concurrent_requests() {
    let server = start_server();
    let mut client = connect(&server.addr);
    let reqs: Vec<Request> = (0..6)
        .map(|i| {
            let mut r = request(
                &format!("the blue owl is number {i}"),
                "i-glass",
                0.5,
            );
            r.max_tokens = 16;
            r
        })
        .collect();
    let out = client.call_many(reqs).unwrap();
    assert_eq!(out.len(), 6);
    for (resp, _latency) in &out {
        assert!(resp.error.is_none());
        assert_eq!(resp.tokens, 16);
        assert_eq!(resp.finish, "length");
    }
    server.stop();
}

#[test]
fn malformed_and_invalid_requests_get_errors() {
    let server = start_server();
    // raw socket: send garbage then a bad strategy (v1 wire)
    use std::io::{BufRead, BufReader, Write};
    let mut stream =
        std::net::TcpStream::connect(&server.addr).unwrap();
    writeln!(stream, "this is not json").unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "got: {line}");

    writeln!(
        stream,
        r#"{{"id":9,"prompt":"x","strategy":"nonsense"}}"#
    )
    .unwrap();
    let mut line2 = String::new();
    reader.read_line(&mut line2).unwrap();
    assert!(line2.contains("error"), "got: {line2}");
    server.stop();
}

#[test]
fn dense_and_sparse_agree_on_prefix_sometimes() {
    // not a strict invariant, but dense vs 90%-density glass should agree
    // on the first generated token for a well-learned prompt
    let server = start_server();
    let mut client = connect(&server.addr);
    let d = client
        .call(request("the red fox is", "dense", 1.0))
        .unwrap();
    let s = client
        .call(request("the red fox is", "i-glass", 0.9))
        .unwrap();
    assert!(!d.text.is_empty() && !s.text.is_empty());
    assert_eq!(
        d.text.chars().next(),
        s.text.chars().next(),
        "dense={:?} sparse={:?}",
        d.text,
        s.text
    );
    server.stop();
}

// ----------------------------------------------- protocol v2 streaming

/// The ISSUE's acceptance proof: a v2 client streaming a long-form
/// generation receives deltas whose concatenation is bit-identical to
/// the v1 blocking response for the same request against the same
/// server — and the done frame repeats the identical full response.
#[test]
fn v2_stream_deltas_concat_bit_identical_to_v1_blocking() {
    let server = start_server();
    let mk = || {
        let mut r = request("once there was a red fox", "i-glass", 0.5);
        r.max_tokens = 48;
        r.refresh_every = 8;
        r.cache = CacheMode::Off; // strict cold path on both runs
        r
    };

    let mut v1 = Client::connect(&server.addr).unwrap();
    let blocking = v1.call(mk()).unwrap();
    assert!(blocking.error.is_none(), "{:?}", blocking.error);
    assert_eq!(blocking.tokens, 48);

    let mut v2 = Client::connect_v2(&server.addr).unwrap();
    let id = v2.generate_stream(mk()).unwrap();
    let mut concat = String::new();
    let mut next_index = 0u64;
    let mut accepted = false;
    let mut refreshes_seen = 0usize;
    let done = loop {
        match v2.next_event(id).unwrap() {
            Event::Accepted { .. } => {
                assert!(!accepted, "accepted must arrive exactly once");
                assert!(
                    concat.is_empty(),
                    "accepted must precede every delta"
                );
                accepted = true;
            }
            Event::Delta { index, text, .. } => {
                assert_eq!(
                    index, next_index,
                    "delta indices must be contiguous from 0"
                );
                next_index += 1;
                concat.push_str(&text);
            }
            Event::Refresh { .. } => refreshes_seen += 1,
            Event::Queue { .. } => {}
            Event::Done(resp) => break resp,
            Event::Error { error, .. } => panic!("stream failed: {error}"),
        }
    };
    assert!(accepted, "session never got an accepted frame");
    assert!(next_index > 1, "long-form run must stream multiple deltas");
    assert_eq!(
        concat, blocking.text,
        "delta concatenation diverged from the v1 blocking text"
    );
    assert_eq!(done.text, blocking.text, "done frame text diverged");
    assert_eq!(done.tokens, blocking.tokens);
    assert_eq!(done.prompt_tokens, blocking.prompt_tokens);
    assert_eq!(done.density, blocking.density);
    assert_eq!(done.finish, blocking.finish);
    assert_eq!(done.refreshes, blocking.refreshes);
    assert_eq!(
        refreshes_seen, done.refreshes,
        "one refresh frame per applied refresh"
    );
    server.stop();
}

#[test]
fn v2_cancel_mid_stream_stops_and_connection_stays_usable() {
    let server = start_server();
    let mut c = Client::connect_v2(&server.addr).unwrap();
    let mut r = request("the grey cat is quiet and", "i-glass", 0.5);
    r.max_tokens = 160; // long-form: plenty of stream left to cancel
    let id = c.generate_stream(r).unwrap();
    // wait until the stream is demonstrably decoding
    loop {
        match c.next_event(id).unwrap() {
            Event::Delta { .. } => break,
            Event::Done(resp) => {
                panic!("finished before any delta: {resp:?}")
            }
            Event::Error { error, .. } => panic!("{error}"),
            _ => {}
        }
    }
    c.cancel(id).unwrap();
    let done = loop {
        match c.next_event(id).unwrap() {
            Event::Done(resp) => break resp,
            Event::Error { error, .. } => {
                panic!("cancel must terminate via done, got: {error}")
            }
            _ => {}
        }
    };
    assert_eq!(done.finish, "cancel");
    assert!(
        done.tokens < 160,
        "cancel mid-stream must cut generation short (got {})",
        done.tokens
    );

    // cancel of the now-FINISHED id: a no-op error frame...
    c.cancel(id).unwrap();
    match c.next_event(id).unwrap() {
        Event::Error {
            error, retryable, ..
        } => {
            assert!(error.contains("no live session"), "{error}");
            assert!(!retryable);
        }
        other => panic!("expected no-op error frame, got {other:?}"),
    }
    // ...NOT a connection teardown: the same connection keeps serving
    let resp = c
        .call(request("the blue owl is", "dense", 0.5))
        .unwrap();
    assert!(resp.error.is_none(), "{:?}", resp.error);
    assert!(resp.tokens > 0);
    server.stop();
}

#[test]
fn v2_cancel_of_unknown_id_is_noop_error_frame() {
    let server = start_server();
    let mut c = Client::connect_v2(&server.addr).unwrap();
    c.cancel(777).unwrap();
    match c.next_event(777).unwrap() {
        Event::Error {
            error, retryable, ..
        } => {
            assert!(error.contains("no live session"), "{error}");
            assert!(!retryable);
        }
        other => panic!("expected error frame, got {other:?}"),
    }
    // the connection survives and serves
    let resp = c
        .call(request("once there was a red fox", "i-glass", 0.5))
        .unwrap();
    assert!(resp.error.is_none(), "{:?}", resp.error);
    server.stop();
}

#[test]
fn v2_duplicate_live_session_id_is_rejected() {
    let server = start_server();
    let mut c = Client::connect_v2(&server.addr).unwrap();
    let mut a = request("the grey cat is quiet and", "i-glass", 0.5);
    a.id = 42;
    a.max_tokens = 120;
    let id = c.generate_stream(a.clone()).unwrap();
    assert_eq!(id, 42);
    // same id while the first session is live → rejection on the
    // RESERVED connection-level id 0, so it can never read as the
    // original session's terminal; the original stream completes
    c.generate_stream(a).unwrap();
    match c.next_event(0).unwrap() {
        Event::Error { error, .. } => {
            assert!(error.contains("duplicate"), "{error}");
            assert!(error.contains("42"), "{error}");
        }
        other => panic!("expected duplicate rejection, got {other:?}"),
    }
    let done = loop {
        match c.next_event(42).unwrap() {
            Event::Done(resp) => break resp,
            Event::Error { error, .. } => {
                panic!("original session must be unaffected: {error}")
            }
            _ => {}
        }
    };
    assert_eq!(done.tokens, 120, "original session must be unaffected");
    server.stop();
}

#[test]
fn v2_session_id_zero_is_reserved() {
    // id 0 is the correlation id of connection-level errors; a session
    // with id 0 could mistake one for its terminal frame
    use std::io::{BufRead, BufReader, Write};
    let server = start_server();
    let mut stream =
        std::net::TcpStream::connect(&server.addr).unwrap();
    writeln!(
        stream,
        r#"{{"v":2,"cmd":"generate","id":0,"prompt":"hi"}}"#
    )
    .unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(
        line.contains("reserved") && line.contains("\"ev\":\"error\""),
        "got: {line}"
    );
    server.stop();
}

#[test]
fn v2_set_frame_adjusts_refresh_mid_stream() {
    let server = start_server();
    let mut c = Client::connect_v2(&server.addr).unwrap();
    // start with refresh OFF and a long budget, then switch it on
    // mid-stream: the done frame must report refreshes applied
    let mut r = request("the grey cat is quiet and", "i-glass", 0.5);
    r.max_tokens = 150;
    r.refresh_every = 0;
    let id = c.generate_stream(r).unwrap();
    c.set_refresh(id, 2).unwrap();
    let done = loop {
        match c.next_event(id).unwrap() {
            Event::Done(resp) => break resp,
            Event::Error { error, .. } => panic!("{error}"),
            _ => {}
        }
    };
    assert!(done.error.is_none());
    assert_eq!(done.tokens, 150);
    assert!(
        done.refreshes >= 1,
        "set frame must enable refreshes mid-stream (got {})",
        done.refreshes
    );
    server.stop();
}

/// The resume acceptance proof: kill a v2 stream after K deltas, then
/// reconnect and `resume` with the replayed request + delta count — the
/// concatenation of the pre-kill deltas and the resumed stream is
/// byte-identical to an uninterrupted run, with delta indices
/// continuing exactly at K.
#[test]
fn v2_resume_after_dropped_connection_is_byte_identical() {
    let server = start_server();
    let mk = |id: u64| {
        let mut r = request("once there was a red fox", "i-glass", 0.5);
        r.id = id;
        r.max_tokens = 48;
        r.cache = CacheMode::Off; // determinism independent of cache
        r
    };

    // uninterrupted reference stream
    let mut v2 = Client::connect_v2(&server.addr).unwrap();
    let full = v2.call(mk(1)).unwrap();
    assert!(full.error.is_none(), "{:?}", full.error);
    assert_eq!(full.tokens, 48);

    // interrupted stream: consume K deltas, then drop the connection
    let mut doomed = Client::connect_v2(&server.addr).unwrap();
    let id = doomed.generate_stream(mk(2)).unwrap();
    let mut prefix = String::new();
    let mut received = 0u64;
    while received < 3 {
        match doomed.next_event(id).unwrap() {
            Event::Delta { index, text, .. } => {
                assert_eq!(index, received);
                prefix.push_str(&text);
                received += 1;
            }
            Event::Done(r) => panic!("finished before the kill: {r:?}"),
            Event::Error { error, .. } => panic!("{error}"),
            _ => {}
        }
    }
    drop(doomed); // the kill: socket closes mid-stream

    // reconnect and resume: the server re-decodes deterministically and
    // suppresses the deltas the client already holds
    let mut revived = Client::connect_v2(&server.addr).unwrap();
    let rid = revived.resume(mk(3), received).unwrap();
    let mut tail = String::new();
    let mut next_index = received;
    let done = loop {
        match revived.next_event(rid).unwrap() {
            Event::Delta { index, text, .. } => {
                assert_eq!(
                    index, next_index,
                    "resumed deltas must continue at the replayed count"
                );
                next_index += 1;
                tail.push_str(&text);
            }
            Event::Done(r) => break r,
            Event::Error { error, .. } => {
                panic!("resume failed: {error}")
            }
            _ => {}
        }
    };
    assert!(next_index > received, "resume must stream the tail");
    assert_eq!(
        format!("{prefix}{tail}"),
        full.text,
        "kill-and-resume concatenation diverged from the \
         uninterrupted stream"
    );
    assert_eq!(done.text, full.text, "done reports the full generation");
    assert_eq!(done.tokens, full.tokens);
    assert_eq!(done.finish, full.finish);
    server.stop();
}

#[test]
fn call_resuming_completes_a_healthy_stream() {
    // the retryable-error client fix's happy path: with nothing to
    // survive, call_resuming assembles the same bits call() returns
    let server = start_server();
    let mk = |id: u64| {
        let mut r = request("the grey cat is quiet and", "i-glass", 0.5);
        r.id = id;
        r.max_tokens = 24;
        r
    };
    let mut a = Client::connect_v2(&server.addr).unwrap();
    let blocking = a.call(mk(21)).unwrap();
    assert!(blocking.error.is_none(), "{:?}", blocking.error);
    let mut b = Client::connect_v2(&server.addr).unwrap();
    let (text, resp) = b.call_resuming(mk(22), 3).unwrap();
    assert!(resp.error.is_none(), "{:?}", resp.error);
    assert_eq!(text, blocking.text, "assembled deltas diverged");
    assert_eq!(resp.text, blocking.text);
    assert_eq!(resp.tokens, blocking.tokens);
    server.stop();
}

#[test]
fn v2_graceful_shutdown_drains_in_flight_and_fails_queued_retryably() {
    // width-1, single-shard server: the first session occupies the only
    // decode slot, the other two queue behind it. stop() must drain the
    // in-flight session to its natural done and fail the queued ones
    // with RETRYABLE error frames (they were never admitted).
    let engine = common::engine();
    let cfg = ServerConfig::new(1).with_bind("127.0.0.1:0");
    let server = Server::start_with_config(engine, &cfg).unwrap();
    let mut c = Client::connect_v2(&server.addr).unwrap();
    for (id, prompt) in [
        (1u64, "once there was a red fox"),
        (2, "the blue owl is"),
        (3, "every morning the wolf"),
    ] {
        let mut r = request(prompt, "i-glass", 0.5);
        r.id = id;
        r.max_tokens = 160;
        c.generate_stream(r).unwrap();
    }
    // all three accepted (submitted server-side) before we stop
    for id in [1u64, 2, 3] {
        match c.next_event(id).unwrap() {
            Event::Accepted { .. } => {}
            other => panic!("expected accepted for {id}, got {other:?}"),
        }
    }
    // session 1 is demonstrably IN FLIGHT (its prefill-seeded delta
    // arrived), so stop() must drain it to a natural done while 2 and
    // 3 are still waiting on the single busy slot
    loop {
        match c.next_event(1).unwrap() {
            Event::Delta { .. } => break,
            Event::Done(r) => {
                panic!("160-token session finished instantly: {r:?}")
            }
            Event::Error { error, .. } => panic!("{error}"),
            _ => {}
        }
    }
    server.stop();
    let mut dones = 0usize;
    let mut retryable_errors = 0usize;
    for id in [1u64, 2, 3] {
        loop {
            match c.next_event(id).unwrap() {
                Event::Done(resp) => {
                    assert!(
                        resp.finish == "length" || resp.finish == "stop",
                        "in-flight session must drain naturally, got \
                         finish {:?}",
                        resp.finish
                    );
                    dones += 1;
                    break;
                }
                Event::Error {
                    error, retryable, ..
                } => {
                    assert!(
                        retryable,
                        "queued-at-shutdown session {id} must be \
                         retryable: {error}"
                    );
                    retryable_errors += 1;
                    break;
                }
                _ => {}
            }
        }
    }
    assert_eq!(dones + retryable_errors, 3, "every session terminates");
    assert!(
        retryable_errors >= 1,
        "a width-1 server stopping with 3 near-capacity sessions must \
         have queued work to fail retryably"
    );
    assert!(dones >= 1, "the admitted session must drain to done");
}

#[test]
fn oversized_frame_is_rejected_and_connection_closed() {
    // the unbounded-read-buffer bugfix: a gigantic line (or a line that
    // never ends) must die with a protocol error, not grow server
    // memory without limit
    use std::io::{BufRead, BufReader, Write};
    let engine = common::engine();
    let cfg = ServerConfig::new(4)
        .with_bind("127.0.0.1:0")
        .with_max_frame_bytes(1024);
    let server = Server::start_with_config(engine, &cfg).unwrap();

    // case 1: a complete line over the cap
    let mut stream =
        std::net::TcpStream::connect(&server.addr).unwrap();
    let huge = format!(
        r#"{{"id":1,"prompt":"{}"}}"#,
        "x".repeat(4096)
    );
    writeln!(stream, "{huge}").unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(
        line.contains("max_frame_bytes"),
        "expected frame-cap error, got: {line}"
    );
    let mut rest = String::new();
    let n = reader.read_line(&mut rest).unwrap();
    assert_eq!(n, 0, "connection must be closed after the violation");

    // case 2: a line that never ends (no newline at all)
    let mut stream =
        std::net::TcpStream::connect(&server.addr).unwrap();
    stream.write_all(&vec![b'a'; 4096]).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(
        line.contains("max_frame_bytes"),
        "expected frame-cap error, got: {line}"
    );

    // an in-cap request on a fresh connection still serves fine
    let mut c = connect(&server.addr);
    let resp = c
        .call(request("once there was a red fox", "dense", 0.5))
        .unwrap();
    assert!(resp.error.is_none(), "{:?}", resp.error);
    server.stop();
}

#[test]
fn v1_line_on_v2_server_is_served_with_exactly_one_response_line() {
    // version auto-detection: a bare v1 line on a fresh connection gets
    // the classic single response line — same fields as a v2 done frame
    // for the identical request (the compatibility shim), with no event
    // frames leaking in between
    use std::io::{BufRead, BufReader, Write};
    let server = start_server();
    let mut stream =
        std::net::TcpStream::connect(&server.addr).unwrap();
    let mut req = request("every morning the wolf", "i-glass", 0.5);
    req.id = 5;
    req.max_tokens = 12;
    req.cache = CacheMode::Off;
    writeln!(stream, "{}", req.to_line()).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(
        !line.contains("\"ev\""),
        "v1 connection must never see event frames: {line}"
    );
    let v1_resp = Response::parse(line.trim()).unwrap();
    assert!(v1_resp.error.is_none());
    assert_eq!(v1_resp.id, 5);
    assert_eq!(v1_resp.tokens, 12);

    let mut v2 = Client::connect_v2(&server.addr).unwrap();
    req.id = 6;
    let done = v2.call(req).unwrap();
    assert_eq!(done.text, v1_resp.text, "shim must serve the same bits");
    assert_eq!(done.tokens, v1_resp.tokens);
    assert_eq!(done.density, v1_resp.density);
    assert_eq!(done.finish, v1_resp.finish);
    server.stop();
}

// --------------------------------------- continuous-batching semantics
//
// These drive the Batcher synchronously (admit/step), so admission
// ordering, early exit, and refresh behavior are asserted without any
// sleeps or cross-thread timing.

#[test]
fn short_request_overtakes_long_one_mid_flight() {
    let engine = common::engine();
    let mut batcher = Batcher::new(engine, 4).unwrap();
    let mut done: Vec<(u64, Response)> = Vec::new();

    // long request starts decoding alone
    let over = batcher.admit(
        vec![pending(1, "once there was a red fox", "i-glass", 24, 0)],
        &mut respond(&mut done),
    );
    assert!(over.is_empty(), "unexpected admission overflow");
    assert_eq!(batcher.active(), 1);
    for _ in 0..5 {
        batcher.step(&mut respond(&mut done)).unwrap();
    }
    assert!(done.is_empty(), "long request must still be decoding");

    // short request admitted mid-flight into a free slot
    let over = batcher.admit(
        vec![pending(2, "the blue owl is", "i-glass", 3, 0)],
        &mut respond(&mut done),
    );
    assert!(over.is_empty(), "unexpected admission overflow");
    assert_eq!(batcher.active(), 2, "admitted while slot 0 in flight");

    drive(&mut batcher, &mut done, 2);
    assert_eq!(done.len(), 2, "both requests must complete");
    // the short request finishes (and its response is delivered) FIRST,
    // while the long one is still decoding — no head-of-line blocking
    assert_eq!(done[0].0, 2, "short request delivered first");
    assert_eq!(done[1].0, 1);
    let short = &done[0].1;
    let long = &done[1].1;
    assert!(short.error.is_none() && long.error.is_none());
    assert_eq!(short.tokens, 3);
    assert_eq!(long.tokens, 24);
    assert_eq!(batcher.active(), 0, "slots freed after completion");
}

#[test]
fn mask_refresh_changes_masks_after_r_steps() {
    let engine = common::engine();
    let mut batcher = Batcher::new(engine, 4).unwrap();
    let mut done: Vec<(u64, Response)> = Vec::new();

    // refresh every 4 decoded tokens; control request with refresh off
    let over = batcher.admit(
        vec![
            pending(1, "the blue owl is", "griffin", 16, 4),
            pending(2, "the blue owl is", "i-glass", 16, 4),
            pending(3, "the blue owl is", "griffin", 16, 0),
        ],
        &mut respond(&mut done),
    );
    assert!(over.is_empty(), "unexpected admission overflow");
    drive(&mut batcher, &mut done, 3);
    assert_eq!(done.len(), 3);

    let by_conn = |c: u64| {
        &done.iter().find(|(cc, _)| *cc == c).unwrap().1
    };
    for c in [1, 2] {
        let r = by_conn(c);
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(
            r.refreshes, 3,
            "16 tokens / R=4 → refreshes at 4, 8, 12"
        );
        assert!(
            r.mask_updates >= 1,
            "conn {c}: decode-time statistics drift must change the \
             mask vs. its prefill-time selection (got {} updates)",
            r.mask_updates
        );
        assert!((r.density - 0.5).abs() < 0.02, "budget preserved");
    }
    let control = by_conn(3);
    assert_eq!(control.refreshes, 0);
    assert_eq!(control.mask_updates, 0, "refresh off → static mask");
}

#[test]
fn unknown_strategy_rejected_by_engine_path() {
    // bypasses protocol validation to hit the serve-path guard that
    // used to silently fall through to i-GLASS
    let engine = common::engine();
    let mut batcher = Batcher::new(engine, 4).unwrap();
    let mut done: Vec<(u64, Response)> = Vec::new();
    let over = batcher.admit(
        vec![
            pending(7, "hello", "not-a-strategy", 8, 0),
            pending(8, "hello", "dense", 2, 0),
        ],
        &mut respond(&mut done),
    );
    assert!(over.is_empty(), "unexpected admission overflow");
    // the invalid request errors immediately, before any decode step
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].0, 7);
    let err = done[0].1.error.as_deref().unwrap_or("");
    assert!(
        err.contains("unknown strategy"),
        "expected strategy rejection, got {err:?}"
    );
    // the valid companion request still serves normally
    drive(&mut batcher, &mut done, 2);
    assert_eq!(done.len(), 2);
    assert!(done[1].1.error.is_none());
    assert_eq!(done[1].1.tokens, 2);
}

#[test]
fn stop_state_and_kv_window_bound_generation() {
    // a request whose budget exactly fills the KV window finishes with
    // reason "length" at the window edge (no position overflow); asking
    // for more than the window can hold is rejected at admission with
    // an explicit error — never silently capped or truncated
    let engine = common::engine();
    let max_seq = engine.spec().max_seq;
    let prompt = "the grey cat is quiet and";
    let n_prompt = prompt.len() + 1;
    // the final token comes from the last in-window logits and needs
    // no KV write, so exact capacity is max_seq - n_prompt + 1
    let capacity = max_seq - n_prompt + 1;
    let mut batcher = Batcher::new(engine, 4).unwrap();
    let mut done: Vec<(u64, Response)> = Vec::new();
    let over = batcher.admit(
        vec![pending(1, prompt, "dense", capacity, 0)],
        &mut respond(&mut done),
    );
    assert!(over.is_empty(), "unexpected admission overflow");
    drive(&mut batcher, &mut done, 1);
    assert_eq!(done.len(), 1, "window-filling request must finish");
    let r = &done[0].1;
    assert!(r.error.is_none(), "{:?}", r.error);
    assert_eq!(r.finish, "length");
    assert!(
        r.tokens <= capacity,
        "{} tokens exceeds KV capacity {capacity}",
        r.tokens
    );

    // one token more than the window holds → explicit admission error
    let over = batcher.admit(
        vec![pending(2, prompt, "dense", capacity + 1, 0)],
        &mut respond(&mut done),
    );
    assert!(over.is_empty(), "unexpected admission overflow");
    assert_eq!(done.len(), 2);
    let err = done[1].1.error.as_deref().unwrap_or("");
    assert!(
        err.contains("prompt too long"),
        "expected explicit window rejection, got {err:?}"
    );
}

// --------------------------------------- cancellation (deterministic)

#[test]
fn cancel_mid_decode_frees_slot_and_queued_request_takes_it() {
    // THE cancellation contract, driven without timing races: cancel a
    // mid-decode session on a width-1 batcher, and the queued request
    // behind it is admitted into the freed slot on the next iteration
    let engine = common::engine();
    let mut batcher = Batcher::new(engine, 1).unwrap();
    let sched = Scheduler::new(1, Duration::from_millis(1));
    let mut events: Vec<(u64, Event)> = Vec::new();

    let over = batcher.admit(
        vec![pending(1, "once there was a red fox", "i-glass", 64, 0)],
        &mut |c, ev| events.push((c, ev)),
    );
    assert!(over.is_empty());
    assert_eq!(batcher.active(), 1);
    for _ in 0..4 {
        batcher
            .step(&mut |c, ev| events.push((c, ev)))
            .unwrap();
    }
    let deltas_before = events
        .iter()
        .filter(|(_, ev)| matches!(ev, Event::Delta { .. }))
        .count();
    assert!(deltas_before > 0, "session must be demonstrably decoding");

    // the queued request waits for the occupied slot...
    let _ = sched.submit(pending(2, "the blue owl is", "dense", 3, 0));
    sched.control(Control::Cancel { conn_id: 1, id: 1 });
    sched.close();
    batcher.run(&sched, &mut |c, ev| events.push((c, ev)));

    let terminals: Vec<&(u64, Event)> = events
        .iter()
        .filter(|(_, ev)| ev.is_terminal())
        .collect();
    assert_eq!(terminals.len(), 2, "both sessions terminate");
    // the cancel lands FIRST (slot freed before the newcomer decodes)
    let (c1, ev1) = terminals[0];
    assert_eq!(*c1, 1);
    match ev1 {
        Event::Done(resp) => {
            assert_eq!(resp.finish, "cancel");
            assert!(
                resp.tokens > 0 && resp.tokens < 64,
                "cancel mid-decode keeps partial output ({} tokens)",
                resp.tokens
            );
        }
        other => panic!("expected done(cancel), got {other:?}"),
    }
    // the queued request was admitted into the freed slot and served
    let (c2, ev2) = terminals[1];
    assert_eq!(*c2, 2);
    match ev2 {
        Event::Done(resp) => {
            assert!(resp.error.is_none());
            assert_eq!(resp.tokens, 3);
            assert_eq!(resp.finish, "length");
        }
        other => panic!("expected done for the queued request, got {other:?}"),
    }
    assert_eq!(batcher.active(), 0, "all slots freed");
}

#[test]
fn cancel_of_queued_request_plucks_it_without_serving() {
    let engine = common::engine();
    let mut batcher = Batcher::new(engine, 1).unwrap();
    let sched = Scheduler::new(1, Duration::from_millis(1));
    let mut events: Vec<(u64, Event)> = Vec::new();

    // slot occupied; conn 2's request queues; cancel it before admission
    let over = batcher.admit(
        vec![pending(1, "once there was a red fox", "i-glass", 8, 0)],
        &mut |c, ev| events.push((c, ev)),
    );
    assert!(over.is_empty());
    let _ = sched.submit(pending(2, "the blue owl is", "dense", 4, 0));
    sched.control(Control::Cancel { conn_id: 2, id: 2 });
    sched.close();
    batcher.run(&sched, &mut |c, ev| events.push((c, ev)));

    let for_conn2: Vec<&Event> = events
        .iter()
        .filter(|(c, _)| *c == 2)
        .map(|(_, ev)| ev)
        .collect();
    assert_eq!(for_conn2.len(), 1, "exactly one terminal, no deltas");
    match for_conn2[0] {
        Event::Done(resp) => {
            assert_eq!(resp.finish, "cancel");
            assert_eq!(resp.tokens, 0, "never decoded");
        }
        other => panic!("expected done(cancel), got {other:?}"),
    }
    // conn 1 unaffected
    assert!(events.iter().any(|(c, ev)| *c == 1
        && matches!(ev, Event::Done(r) if r.tokens == 8)));
}

#[test]
fn cancel_of_already_finished_session_adds_no_second_terminal() {
    // a control that matches no slot and no queued request means the
    // session terminated while the frame was in flight: the batcher
    // must stay SILENT (its real terminal is already in the channel) —
    // a second terminal would corrupt the per-session frame contract.
    // (Controls for ids the server never saw are rejected by the
    // reactor before they reach the batcher.)
    let engine = common::engine();
    let mut batcher = Batcher::new(engine, 1).unwrap();
    let sched = Scheduler::new(1, Duration::from_millis(1));
    let mut events: Vec<(u64, Event)> = Vec::new();

    // serve a session to completion, then cancel it (the race's
    // batcher-side view)
    let over = batcher.admit(
        vec![pending(3, "the blue owl is", "dense", 2, 0)],
        &mut |c, ev| events.push((c, ev)),
    );
    assert!(over.is_empty());
    for _ in 0..8 {
        batcher.step(&mut |c, ev| events.push((c, ev))).unwrap();
    }
    let before = events.len();
    assert_eq!(
        events.iter().filter(|(_, ev)| ev.is_terminal()).count(),
        1,
        "session finished with exactly one terminal"
    );
    batcher.apply_control(
        Control::Cancel { conn_id: 3, id: 3 },
        &sched,
        &mut |c, ev| events.push((c, ev)),
    );
    batcher.apply_control(
        Control::SetRefresh { conn_id: 3, id: 3, refresh_every: 4 },
        &sched,
        &mut |c, ev| events.push((c, ev)),
    );
    assert_eq!(
        events.len(),
        before,
        "late controls must not emit anything: {:?}",
        &events[before..]
    );
}

#[test]
fn set_refresh_control_applies_to_active_slot() {
    let engine = common::engine();
    let mut batcher = Batcher::new(engine, 1).unwrap();
    let sched = Scheduler::new(1, Duration::from_millis(1));
    let mut done: Vec<(u64, Response)> = Vec::new();

    // admitted with refresh OFF
    let over = batcher.admit(
        vec![pending(1, "the blue owl is", "i-glass", 16, 0)],
        &mut respond(&mut done),
    );
    assert!(over.is_empty());
    for _ in 0..2 {
        batcher.step(&mut respond(&mut done)).unwrap();
    }
    // flip it on mid-stream via the control plane
    batcher.apply_control(
        Control::SetRefresh {
            conn_id: 1,
            id: 1,
            refresh_every: 4,
        },
        &sched,
        &mut respond(&mut done),
    );
    drive(&mut batcher, &mut done, 1);
    assert_eq!(done.len(), 1);
    let r = &done[0].1;
    assert!(r.error.is_none(), "{:?}", r.error);
    assert_eq!(r.tokens, 16);
    assert!(
        r.refreshes >= 1,
        "mid-stream set must enable refreshes (got {})",
        r.refreshes
    );
}

#[test]
fn v2_event_stream_order_and_delta_concat_at_batcher_level() {
    // deterministic (no TCP) ordering proof: per session, deltas are
    // contiguous, exactly one terminal arrives last, and the delta
    // concatenation equals the done text
    let engine = common::engine();
    let mut batcher = Batcher::new(engine, 4).unwrap();
    let mut events: Vec<(u64, Event)> = Vec::new();
    let over = batcher.admit(
        vec![
            pending(1, "once there was a red fox", "i-glass", 12, 4),
            pending(2, "the blue owl is", "dense", 7, 0),
        ],
        &mut |c, ev| events.push((c, ev)),
    );
    assert!(over.is_empty());
    for _ in 0..64 {
        if events.iter().filter(|(_, ev)| ev.is_terminal()).count() == 2 {
            break;
        }
        batcher
            .step(&mut |c, ev| events.push((c, ev)))
            .unwrap();
    }
    for conn in [1u64, 2] {
        let stream: Vec<&Event> = events
            .iter()
            .filter(|(c, _)| *c == conn)
            .map(|(_, ev)| ev)
            .collect();
        let mut concat = String::new();
        let mut next_index = 0u64;
        let mut terminal: Option<&Event> = None;
        for &ev in &stream {
            assert!(
                terminal.is_none(),
                "conn {conn}: event after terminal: {ev:?}"
            );
            match ev {
                Event::Delta { index, text, .. } => {
                    assert_eq!(*index, next_index, "conn {conn}");
                    next_index += 1;
                    concat.push_str(text);
                }
                Event::Refresh { .. } => {}
                t if t.is_terminal() => terminal = Some(t),
                other => panic!("unexpected event {other:?}"),
            }
        }
        match terminal {
            Some(Event::Done(resp)) => {
                assert_eq!(
                    concat, resp.text,
                    "conn {conn}: delta concat != final text"
                );
            }
            other => panic!("conn {conn}: bad terminal {other:?}"),
        }
    }
}

// ------------------------------------------- chunked long-prompt admission

#[test]
fn long_prompt_is_served_in_full_without_truncation() {
    let engine = common::engine();
    let spec = engine.spec().clone();
    // ≥ 3× the prefill frame: must stream through ≥ 3 chunks
    let long_prompt = "abcdefghij ".repeat(3 * spec.prefill_len / 11 + 1);
    let n_prompt = long_prompt.len() + 1;
    assert!(n_prompt >= 3 * spec.prefill_len);
    assert!(n_prompt + 8 <= spec.max_seq);

    let mut batcher = Batcher::new(engine, 4).unwrap();
    let mut done: Vec<(u64, Response)> = Vec::new();
    let over = batcher.admit(
        vec![pending(1, &long_prompt, "i-glass", 8, 0)],
        &mut respond(&mut done),
    );
    assert!(over.is_empty(), "unexpected admission overflow");
    assert_eq!(batcher.prefilling(), 1, "long prompt streams in");
    assert_eq!(batcher.active(), 0, "no decoding before the final chunk");
    drive(&mut batcher, &mut done, 1);
    assert_eq!(done.len(), 1);
    let r = &done[0].1;
    assert!(r.error.is_none(), "{:?}", r.error);
    assert_eq!(
        r.prompt_tokens, n_prompt,
        "every prompt token must be consumed (no tail truncation)"
    );
    assert_eq!(r.tokens, 8);
    assert!((r.density - 0.5).abs() < 0.02, "glass mask built post-stream");
    assert!(
        batcher.chunks >= 3,
        "expected a multi-chunk stream, got {} chunks",
        batcher.chunks
    );
}

#[test]
fn in_flight_decode_continues_during_chunked_admission() {
    // the stall this PR removes: admitting a long prompt used to run a
    // monolithic prefill while every in-flight slot waited
    let engine = common::engine();
    let spec = engine.spec().clone();
    let mut batcher = Batcher::new(engine, 4).unwrap();
    let mut done: Vec<(u64, Response)> = Vec::new();

    // a short request decodes alone first
    let over = batcher.admit(
        vec![pending(1, "once there was a red fox", "i-glass", 6, 0)],
        &mut respond(&mut done),
    );
    assert!(over.is_empty(), "unexpected admission overflow");
    assert_eq!(batcher.active(), 1);
    for _ in 0..2 {
        batcher.step(&mut respond(&mut done)).unwrap();
    }
    assert!(done.is_empty());

    // a long prompt claims a slot and streams chunk by chunk
    let long_prompt = "abcdefghijklm ".repeat(3 * spec.prefill_len / 14 + 1);
    let n_long = long_prompt.len() + 1;
    assert!(n_long >= 3 * spec.prefill_len && n_long + 8 <= spec.max_seq);
    let over = batcher.admit(
        vec![pending(2, &long_prompt, "griffin", 8, 0)],
        &mut respond(&mut done),
    );
    assert!(over.is_empty(), "unexpected admission overflow");
    assert_eq!(batcher.prefilling(), 1);
    assert_eq!(batcher.active(), 1, "short request still in flight");

    drive(&mut batcher, &mut done, 2);
    assert_eq!(done.len(), 2, "both requests must complete");
    // the short request keeps decoding THROUGH the stream and finishes
    // first — its slot never stalls for the newcomer's prompt
    assert_eq!(done[0].0, 1, "short request delivered first");
    assert_eq!(done[1].0, 2);
    let short = &done[0].1;
    let long = &done[1].1;
    assert!(short.error.is_none() && long.error.is_none());
    assert_eq!(short.tokens, 6);
    assert_eq!(long.tokens, 8);
    assert_eq!(long.prompt_tokens, n_long, "stream consumed in full");
    assert!(
        batcher.overlap_steps > 0,
        "decode steps must overlap prefill streaming (no-stall evidence)"
    );
    assert!(batcher.chunks >= 3, "got {} chunks", batcher.chunks);
}

// ------------------------------------------------- shared-prefix cache

/// Drive one request through a batcher to completion.
fn serve_one(batcher: &mut Batcher, p: Pending) -> Response {
    let mut done: Vec<(u64, Response)> = Vec::new();
    let over = batcher.admit(vec![p], &mut respond(&mut done));
    assert!(over.is_empty(), "unexpected admission overflow");
    drive(batcher, &mut done, 1);
    assert_eq!(done.len(), 1, "request must complete");
    done.pop().unwrap().1
}

/// A multi-frame shared system prefix plus a per-request user suffix.
fn shared_prefix_prompts() -> Option<(String, String, String)> {
    let engine = common::engine();
    if engine.rt.manifest.exe("prefill_chunk_b1").is_err() {
        return None;
    }
    let spec = engine.spec().clone();
    let sys =
        "shared system prompt: answer with terse grammar-world prose. "
            .repeat(2 * spec.prefill_len / 61 + 1);
    assert!(sys.len() >= 2 * spec.prefill_len);
    let p1 = format!("{sys} alpha asks about the fox");
    let p2 = format!("{sys} beta asks about the owl");
    // both must fit the serving capacity with an 8-token budget
    if p2.len().max(p1.len()) + 1 + 8 > spec.max_seq + 1 {
        return None;
    }
    Some((sys, p1, p2))
}

#[test]
fn shared_prefix_hit_is_bit_identical_to_cold_and_reports_savings() {
    // THE cache-correctness contract: for a prompt pair sharing a
    // prefix, the second request's generated text (and mask density)
    // must be identical with the cache on vs. off, while its telemetry
    // proves the prefix was spliced, not recomputed.
    let engine = common::engine();
    let Some((sys, p1, p2)) = shared_prefix_prompts() else {
        eprintln!("artifact bundle lacks prefill_chunk — skipping");
        return;
    };
    let spec = engine.spec().clone();

    // cache ON: p1 warms the prefix, p2 splices it
    let mut on = Batcher::new(engine.clone(), 4).unwrap();
    assert!(on.cache_enabled());
    let first = serve_one(&mut on, pending(1, &p1, "i-glass", 8, 0));
    assert!(first.error.is_none(), "{:?}", first.error);
    assert_eq!(first.cached_prompt_tokens, 0, "first request is cold");
    let warm = serve_one(&mut on, pending(2, &p2, "i-glass", 8, 0));

    // cache OFF: p2 served cold by a fresh batcher
    let mut off = Batcher::from_config(
        engine.clone(),
        &ServerConfig::new(4).with_cache_bytes(0),
        0,
    )
    .unwrap();
    assert!(!off.cache_enabled());
    let cold = serve_one(&mut off, pending(3, &p2, "i-glass", 8, 0));

    assert!(warm.error.is_none(), "{:?}", warm.error);
    assert!(cold.error.is_none(), "{:?}", cold.error);
    assert_eq!(
        warm.text, cold.text,
        "cached splice changed the generated tokens"
    );
    assert_eq!(warm.tokens, cold.tokens);
    assert_eq!(
        warm.density, cold.density,
        "cached splice changed the GLASS mask"
    );
    assert_eq!(warm.prompt_tokens, cold.prompt_tokens);
    assert_eq!(warm.prompt_tokens, p2.len() + 1, "full prompt consumed");
    // ...and the splice actually happened
    assert!(
        warm.cached_prompt_tokens >= spec.prefill_len,
        "expected ≥ one cached frame, got {}",
        warm.cached_prompt_tokens
    );
    assert!(
        warm.cached_prompt_tokens <= sys.len() + 2,
        "cached span cannot exceed the shared prefix"
    );
    assert_eq!(warm.cache_hits, 1);
    assert_eq!(cold.cached_prompt_tokens, 0);
    assert_eq!(cold.cache_hits, 0);
    assert!(
        on.prefill_tokens_saved >= spec.prefill_len as u64,
        "batcher-level savings counter must record the splice"
    );
}

#[test]
fn exact_repeat_prompt_skips_prefill_entirely() {
    let engine = common::engine();
    let mut batcher = Batcher::new(engine, 4).unwrap();
    let prompt = "the grey cat is quiet and";
    let a = serve_one(&mut batcher, pending(1, prompt, "i-glass", 6, 0));
    let b = serve_one(&mut batcher, pending(2, prompt, "i-glass", 6, 0));
    assert!(a.error.is_none() && b.error.is_none());
    assert_eq!(a.text, b.text, "same prompt, same greedy output");
    assert_eq!(a.cached_prompt_tokens, 0);
    // the repeat hit the full-prompt entry: every token spliced
    assert_eq!(b.cached_prompt_tokens, prompt.len() + 1);
    assert_eq!(b.cache_hits, 1);
    assert_eq!(b.prompt_tokens, prompt.len() + 1);
    assert_eq!(b.prefill_ms, 0.0, "exact hit makes no prefill call");
}

#[test]
fn cache_off_mode_bypasses_and_readonly_never_inserts() {
    let engine = common::engine();
    let mut batcher = Batcher::new(engine, 4).unwrap();
    let prompt = "every morning the wolf";
    let telemetry = batcher.telemetry();

    // readonly on a cold cache: reads (miss), never publishes
    let r = serve_one(
        &mut batcher,
        pending_cached(1, prompt, "dense", 4, 0, CacheMode::ReadOnly),
    );
    assert!(r.error.is_none());
    let snap = telemetry.snapshot();
    assert_eq!(snap.inserts, 0, "readonly must never insert");
    assert_eq!(snap.misses, 1);

    // a later identical readonly request still misses (nothing stored)
    let r2 = serve_one(
        &mut batcher,
        pending_cached(2, prompt, "dense", 4, 0, CacheMode::ReadOnly),
    );
    assert_eq!(r2.cached_prompt_tokens, 0);
    assert_eq!(telemetry.snapshot().inserts, 0);

    // mode `on` publishes; a following `off` request bypasses entirely
    let r3 = serve_one(
        &mut batcher,
        pending_cached(3, prompt, "dense", 4, 0, CacheMode::On),
    );
    assert!(r3.error.is_none());
    assert!(telemetry.snapshot().inserts >= 1, "on-mode publishes");
    let r4 = serve_one(
        &mut batcher,
        pending_cached(4, prompt, "dense", 4, 0, CacheMode::Off),
    );
    assert_eq!(
        r4.cached_prompt_tokens, 0,
        "off-mode must not read the warm entry"
    );
    assert_eq!(r4.cache_hits, 0);
    assert_eq!(r4.text, r3.text, "bypass serves the same output");

    // ...while an `on` request does hit it
    let r5 = serve_one(
        &mut batcher,
        pending_cached(5, prompt, "dense", 4, 0, CacheMode::On),
    );
    assert_eq!(r5.cached_prompt_tokens, prompt.len() + 1);
}

#[test]
fn same_prefix_burst_pays_the_prefix_miss_once() {
    let engine = common::engine();
    let Some((_sys, p1, p2)) = shared_prefix_prompts() else {
        eprintln!("artifact bundle lacks prefill_chunk — skipping");
        return;
    };
    let spec = engine.spec().clone();
    let mut batcher = Batcher::new(engine, 4).unwrap();
    // both requests submitted in ONE admission burst: the follower is
    // deferred (returned with the overflow) while the leader streams,
    // then splices the published prefix on retry
    let sched = Scheduler::new(4, Duration::from_millis(1));
    let _ = sched.submit(pending(1, &p1, "i-glass", 8, 0));
    let _ = sched.submit(pending(2, &p2, "i-glass", 8, 0));
    sched.close();
    let mut done: Vec<(u64, Response)> = Vec::new();
    batcher.run(&sched, &mut respond(&mut done));
    assert_eq!(done.len(), 2);
    let by_conn = |c: u64| {
        &done.iter().find(|(cc, _)| *cc == c).unwrap().1
    };
    let (leader, follower) = (by_conn(1), by_conn(2));
    assert!(leader.error.is_none() && follower.error.is_none());
    assert_eq!(leader.cached_prompt_tokens, 0, "leader pays the miss");
    assert!(
        follower.cached_prompt_tokens >= spec.prefill_len,
        "deferred follower must splice the published prefix \
         (got {} cached tokens)",
        follower.cached_prompt_tokens
    );

    // warm re-burst: with every prefix cached, NOBODY defers or pays —
    // both requests splice (the deferral check peeks the cache first)
    let sched = Scheduler::new(4, Duration::from_millis(1));
    let _ = sched.submit(pending(3, &p1, "i-glass", 8, 0));
    let _ = sched.submit(pending(4, &p2, "i-glass", 8, 0));
    sched.close();
    let mut done: Vec<(u64, Response)> = Vec::new();
    batcher.run(&sched, &mut respond(&mut done));
    assert_eq!(done.len(), 2);
    for (c, r) in &done {
        assert!(r.error.is_none(), "conn {c}: {:?}", r.error);
        assert!(
            r.cached_prompt_tokens >= spec.prefill_len,
            "conn {c}: warm burst must hit (got {} cached tokens)",
            r.cached_prompt_tokens
        );
    }
}

#[test]
fn stats_command_reports_server_cache_counters() {
    let server = start_server();
    let mut client = connect(&server.addr);
    // cold stats: all zero
    let s0 = client.stats().unwrap();
    assert_eq!(s0.hits + s0.misses + s0.inserts, 0);
    // one served request (miss + publish), one repeat (hit)
    let prompt = "once there was a red fox";
    for _ in 0..2 {
        let resp =
            client.call(request(prompt, "i-glass", 0.5)).unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
    }
    let s = client.stats().unwrap();
    assert!(s.misses >= 1, "first request misses: {s:?}");
    assert!(s.hits >= 1, "repeat request hits: {s:?}");
    assert!(s.inserts >= 1, "miss publishes: {s:?}");
    assert!(s.bytes_resident > 0, "entries are byte-accounted: {s:?}");
    assert!(s.entries >= 1);
    server.stop();
}

#[test]
fn stats_occupancy_is_consistent_under_concurrent_load() {
    // the stats-race satellite over the wire: hammer the stats command
    // while a burst is admitted/retired and assert every per-shard row
    // stays mutually consistent (occupancy never exceeds the width)
    let server = start_server_sharded(2);
    let addr = server.addr.clone();
    let burst = std::thread::spawn(move || {
        let mut c = Client::connect(&addr).unwrap();
        let reqs: Vec<Request> = (0..24)
            .map(|i| {
                let mut r = request(
                    &format!("stress prompt number {i} says"),
                    "i-glass",
                    0.5,
                );
                r.id = i as u64 + 1;
                r.max_tokens = 12;
                r
            })
            .collect();
        let out = c.call_many(reqs).unwrap();
        assert!(out.iter().all(|(r, _)| r.error.is_none()));
    });
    let mut stats_client = connect(&server.addr);
    let mut polls = 0usize;
    while !burst.is_finished() || polls < 20 {
        let (_, shards) = stats_client.stats_full().unwrap();
        for sh in &shards {
            assert!(
                sh.slots_active <= sh.batch_width,
                "slots_active {} > batch width {} on shard {}",
                sh.slots_active,
                sh.batch_width,
                sh.shard
            );
            assert!(
                sh.slots_active + sh.slots_prefilling <= sh.batch_width,
                "occupancy pair inconsistent on shard {}: {} + {} > {}",
                sh.shard,
                sh.slots_active,
                sh.slots_prefilling,
                sh.batch_width
            );
        }
        polls += 1;
        if polls > 3000 {
            break;
        }
    }
    burst.join().unwrap();
    server.stop();
}

// ----------------------------------- cache warm-start persistence

/// The warm-start acceptance proof: `stop()` snapshots the hot cache
/// entries into `--cache-dir`; a server restarted on the same dir
/// serves the cached prompt as an exact full-prompt hit — zero engine
/// prefill calls — and the stats line attributes it to
/// `warm_start_hits`.
#[test]
fn restart_with_cache_dir_serves_warm_with_zero_prefill() {
    let dir = std::env::temp_dir().join(format!(
        "glass-test-warm-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let prompt = "once there was a red fox";
    let first = {
        let cfg = ServerConfig::new(4)
            .with_bind("127.0.0.1:0")
            .with_cache_dir(Some(dir.clone()));
        let server =
            Server::start_with_config(common::engine(), &cfg).unwrap();
        let mut c = connect(&server.addr);
        let r = c.call(request(prompt, "i-glass", 0.5)).unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.cached_prompt_tokens, 0, "first serve is cold");
        server.stop(); // drains, then snapshots the hot entries
        r
    };
    assert!(
        dir.join("prefix-shard-0.gpxs").exists(),
        "stop() must write the shard snapshot into the cache dir"
    );

    let cfg = ServerConfig::new(4)
        .with_bind("127.0.0.1:0")
        .with_cache_dir(Some(dir.clone()));
    let server =
        Server::start_with_config(common::engine(), &cfg).unwrap();
    let mut c = connect(&server.addr);
    let warm = c.call(request(prompt, "i-glass", 0.5)).unwrap();
    assert!(warm.error.is_none(), "{:?}", warm.error);
    assert_eq!(
        warm.cached_prompt_tokens,
        prompt.len() + 1,
        "restart must exact-hit the snapshot-imported prompt"
    );
    assert_eq!(warm.cache_hits, 1);
    assert_eq!(
        warm.prefill_ms, 0.0,
        "a warm-started exact hit makes no engine prefill call"
    );
    assert_eq!(warm.text, first.text, "warm bits identical to cold");
    let s = c.stats().unwrap();
    assert!(
        s.warm_start_hits >= 1,
        "a hit on an imported entry must count as a warm-start \
         hit: {s:?}"
    );
    server.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_snapshot_starts_cold_never_fatal() {
    // a damaged snapshot degrades to a cold cache — loudly skipped at
    // startup, never a crash, and never a partial import
    let dir = std::env::temp_dir().join(format!(
        "glass-test-corrupt-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("prefix-shard-0.gpxs"), b"not a snapshot")
        .unwrap();
    let cfg = ServerConfig::new(4)
        .with_bind("127.0.0.1:0")
        .with_cache_dir(Some(dir.clone()));
    let server = Server::start_with_config(common::engine(), &cfg)
        .unwrap(); // startup must survive the bad file
    let mut c = connect(&server.addr);
    let prompt = "the blue owl is";
    let r = c.call(request(prompt, "i-glass", 0.5)).unwrap();
    assert!(r.error.is_none(), "{:?}", r.error);
    assert_eq!(
        r.cached_prompt_tokens, 0,
        "nothing from a corrupt snapshot may be imported"
    );
    let s = c.stats().unwrap();
    assert_eq!(s.warm_start_hits, 0, "no warm entries can exist");
    // cold degradation, not disablement: the cache still works
    let rep = c.call(request(prompt, "i-glass", 0.5)).unwrap();
    assert_eq!(rep.cached_prompt_tokens, prompt.len() + 1);
    assert_eq!(rep.text, r.text);
    server.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

// --------------------------------------------------- sharded serving

/// A fixed mixed request set: every strategy over the short prompts,
/// plus (when the bundle supports chunked prefill) a multi-chunk long
/// prompt and a shared-prefix pair. Ids are distinct and deterministic.
fn fixed_workload() -> Vec<Request> {
    let engine = common::engine();
    let spec = engine.spec().clone();
    let mut reqs = Vec::new();
    let mut id = 0u64;
    for strategy in ["i-glass", "dense", "griffin"] {
        for prompt in [
            "once there was a red fox",
            "the blue owl is",
            "every morning the wolf",
            "the grey cat is quiet and",
        ] {
            id += 1;
            let mut r = request(prompt, strategy, 0.5);
            r.id = id;
            r.max_tokens = 8;
            reqs.push(r);
        }
    }
    if engine.rt.manifest.exe("prefill_chunk_b1").is_ok() {
        let long = "abcdefghij ".repeat(3 * spec.prefill_len / 11 + 1);
        if long.len() + 1 + 8 <= spec.max_seq {
            id += 1;
            let mut r = request(&long, "i-glass", 0.5);
            r.id = id;
            r.max_tokens = 8;
            reqs.push(r);
        }
        if let Some((_sys, p1, p2)) = shared_prefix_prompts() {
            for p in [p1, p2] {
                id += 1;
                let mut r = request(&p, "i-glass", 0.5);
                r.id = id;
                r.max_tokens = 8;
                reqs.push(r);
            }
        }
    }
    reqs
}

/// Per-request observables compared across shard counts: text, tokens,
/// prompt_tokens, mask density, finish reason (timing fields excluded).
type Digest = HashMap<u64, (String, usize, usize, f64, String)>;

#[test]
fn four_shards_serve_bit_identical_outputs_to_one_shard() {
    // THE sharding-correctness contract: splitting the serving stack
    // into per-shard decode loops (separate engines, KV, caches with a
    // split byte budget) must not change a single generated token. The
    // sim backend is deterministic per slot, so any divergence here is
    // a real sharding bug, not noise.
    let digest = |shards: usize| -> Digest {
        let server = start_server_sharded(shards);
        let mut client = connect(&server.addr);
        let out = client.call_many(fixed_workload()).unwrap();
        server.stop();
        out.into_iter()
            .map(|(r, _latency)| {
                assert!(r.error.is_none(), "id {}: {:?}", r.id, r.error);
                (
                    r.id,
                    (r.text, r.tokens, r.prompt_tokens, r.density, r.finish),
                )
            })
            .collect()
    };
    let one = digest(1);
    let four = digest(4);
    assert_eq!(one.len(), four.len());
    for (id, resp) in &one {
        assert_eq!(
            four.get(id),
            Some(resp),
            "request {id} diverged between --shards 1 and --shards 4"
        );
    }
}

#[test]
fn radix_cache_serves_fixed_workload_bit_identical_to_cache_off() {
    // THE radix-index acceptance proof: the trie-indexed prefix cache
    // serves the fixed mixed workload (every strategy, long prompts,
    // shared-prefix pair) with the exact bits the cache-off path
    // produces — splices change cost, never content
    let serve = |cache_on: bool| -> Digest {
        let cfg = if cache_on {
            ServerConfig::new(4)
        } else {
            ServerConfig::new(4).with_cache_bytes(0)
        };
        let mut batcher =
            Batcher::from_config(common::engine(), &cfg, 0).unwrap();
        let sched = Scheduler::new(4, Duration::from_millis(1));
        for r in fixed_workload() {
            let conn = r.id;
            let _ = sched.submit(Pending {
                request: r,
                arrived: Instant::now(),
                conn_id: conn,
                stream: false,
                resume_from: 0,
                degraded: false,
                reported_floor: usize::MAX,
            });
        }
        sched.close();
        let mut done: Vec<(u64, Response)> = Vec::new();
        batcher.run(&sched, &mut respond(&mut done));
        done.into_iter()
            .map(|(_, r)| {
                assert!(r.error.is_none(), "id {}: {:?}", r.id, r.error);
                (
                    r.id,
                    (r.text, r.tokens, r.prompt_tokens, r.density, r.finish),
                )
            })
            .collect()
    };
    let on = serve(true);
    let off = serve(false);
    assert_eq!(on.len(), off.len());
    for (id, resp) in &off {
        assert_eq!(
            on.get(id),
            Some(resp),
            "request {id} diverged with the radix cache on"
        );
    }
}

#[test]
fn same_prefix_burst_across_connections_pays_one_miss_on_shards() {
    // prefix-affinity routing colocates a shared-system-prompt burst
    // on ONE shard even when the requests arrive on different
    // connections — so the whole burst still pays exactly one cold
    // prefill, exactly like the single-shard deferral test above
    let Some((_sys, p1, p2)) = shared_prefix_prompts() else {
        eprintln!("artifact bundle lacks prefill_chunk — skipping");
        return;
    };
    let spec = common::engine().spec().clone();
    let p3 = {
        // third distinct suffix over the same system prefix
        let cut = p2.len() - "beta asks about the owl".len();
        format!("{}gamma asks about the cat", &p2[..cut])
    };
    let server = start_server_sharded(4);
    let mut clients: Vec<Client> =
        (0..3).map(|_| connect(&server.addr)).collect();
    // all three submitted before any response is read, from three
    // distinct connections (order of arrival at the shard is whatever
    // the kernel makes of it — the invariant must hold regardless)
    for (i, (c, p)) in
        clients.iter_mut().zip([&p1, &p2, &p3]).enumerate()
    {
        let mut r = request(p, "i-glass", 0.5);
        r.id = (i as u64 + 1) * 11;
        r.max_tokens = 8;
        c.send(r).unwrap();
    }
    let resps: Vec<Response> = clients
        .iter_mut()
        .map(|c| c.recv().unwrap())
        .collect();
    let mut cold = 0usize;
    for r in &resps {
        assert!(r.error.is_none(), "id {}: {:?}", r.id, r.error);
        if r.cached_prompt_tokens == 0 {
            cold += 1;
        } else {
            assert!(
                r.cached_prompt_tokens >= spec.prefill_len,
                "id {}: warm member spliced only {} tokens",
                r.id,
                r.cached_prompt_tokens
            );
        }
    }
    assert_eq!(
        cold, 1,
        "a same-prefix burst must pay exactly one cold prefill \
         (cached_prompt_tokens per response: {:?})",
        resps
            .iter()
            .map(|r| r.cached_prompt_tokens)
            .collect::<Vec<_>>()
    );
    server.stop();
}

#[test]
fn repeat_prompt_across_connections_hits_the_same_shard() {
    // behavioral proof of routing determinism: the second connection's
    // identical prompt must land on the shard that cached it, turning
    // into an exact full-prompt hit with zero prefill
    let server = start_server_sharded(4);
    let prompt = "every morning the wolf";
    let mut a = connect(&server.addr);
    let first = a.call(request(prompt, "i-glass", 0.5)).unwrap();
    assert!(first.error.is_none(), "{:?}", first.error);
    assert_eq!(first.cached_prompt_tokens, 0, "first serve is cold");
    let mut b = connect(&server.addr);
    let second = b.call(request(prompt, "i-glass", 0.5)).unwrap();
    assert!(second.error.is_none(), "{:?}", second.error);
    assert_eq!(
        second.cached_prompt_tokens,
        prompt.len() + 1,
        "deterministic routing must land the repeat on the warm shard"
    );
    assert_eq!(second.text, first.text);
    server.stop();
}

#[test]
fn stats_reports_per_shard_queue_depth_and_occupancy() {
    let server = start_server_sharded(4);
    let mut client = connect(&server.addr);
    // cold: four shards, correct widths, nothing queued or occupied
    let (agg0, shards0) = client.stats_full().unwrap();
    assert_eq!(shards0.len(), 4);
    for (i, sh) in shards0.iter().enumerate() {
        assert_eq!(sh.shard, i as u64);
        assert_eq!(sh.batch_width, 4);
        assert_eq!(sh.queue_depth, 0);
        assert_eq!(sh.slots_active, 0);
        assert_eq!(sh.slots_prefilling, 0);
    }
    assert_eq!(agg0.hits + agg0.misses + agg0.inserts, 0);

    // serve a few requests, then wait for the gauges to drain: the
    // batcher publishes occupancy after the retiring step, so poll
    // briefly instead of racing it
    let out = client
        .call_many(
            (1..=6u64)
                .map(|i| {
                    let mut r = request(
                        &format!("the blue owl is number {i}"),
                        "dense",
                        0.5,
                    );
                    r.id = i;
                    r.max_tokens = 4;
                    r
                })
                .collect(),
        )
        .unwrap();
    assert!(out.iter().all(|(r, _)| r.error.is_none()));
    let deadline = Instant::now() + Duration::from_secs(2);
    loop {
        let (agg, shards) = client.stats_full().unwrap();
        assert_eq!(shards.len(), 4);
        let queued: u64 = shards.iter().map(|s| s.queue_depth).sum();
        let busy: u64 = shards
            .iter()
            .map(|s| s.slots_active + s.slots_prefilling)
            .sum();
        assert_eq!(queued, 0, "queues drain before responses return");
        if busy == 0 {
            // requests were served, caches touched, slots all free
            assert!(agg.misses >= 1, "{agg:?}");
            break;
        }
        assert!(
            Instant::now() < deadline,
            "shard gauges never drained: {shards:?}"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    server.stop();
}

#[test]
fn burst_wider_than_free_slots_is_requeued_not_failed() {
    // Batcher::admit used to shed overload with "batcher overloaded"
    // errors, losing requests; overflow now flows back to the scheduler
    // queue front and every request is eventually served (FCFS)
    let engine = common::engine();
    let mut batcher = Batcher::new(engine, 4).unwrap();
    // scheduler wider than the batcher, so next_batch can hand admit()
    // more requests than there are decode slots
    let sched = Scheduler::new(10, Duration::from_millis(1));
    for i in 0..10 {
        let _ = sched.submit(pending(i, "the blue owl is", "dense", 3, 0));
    }
    sched.close();
    let mut done: Vec<(u64, Response)> = Vec::new();
    batcher.run(&sched, &mut respond(&mut done));
    assert_eq!(done.len(), 10, "every burst request must be served");
    for (c, r) in &done {
        assert!(r.error.is_none(), "conn {c}: {:?}", r.error);
        assert_eq!(r.tokens, 3);
    }
}

// ------------------------------- readiness reactor + backpressure

/// The readiness acceptance proof: a fleet of idle connections costs
/// ZERO read syscalls while another connection streams — the reactor
/// reads only on poller-reported readability, never by sweeping the
/// connection table. Skipped on the portable sleep-tick poller, which
/// by design reports every registered fd each tick.
#[test]
fn idle_fleet_costs_zero_reads_between_events() {
    let server = start_server_sharded(1);
    if server.poller_kind() == "sleep" {
        eprintln!("skipping: sleep-tick fallback poller sweeps by design");
        server.stop();
        return;
    }
    // 64 connections that never send a byte after connecting
    let idle: Vec<std::net::TcpStream> = (0..64)
        .map(|_| {
            std::net::TcpStream::connect(&server.addr)
                .expect("idle connect")
        })
        .collect();
    let mut c = connect(&server.addr);
    // a warm-up call (plus settle time) guarantees every idle
    // connection's one-time adoption read happened before the baseline
    let warm = c.call(request("the blue owl is", "dense", 0.5)).unwrap();
    assert!(warm.error.is_none(), "{:?}", warm.error);
    std::thread::sleep(Duration::from_millis(150));
    let base = server.io_stats().reads;
    let mut r = request("once there was a red fox", "i-glass", 0.5);
    r.max_tokens = 16;
    let resp = c.call(r).unwrap();
    assert!(resp.error.is_none(), "{:?}", resp.error);
    let delta = server.io_stats().reads - base;
    // the active connection costs a few reads (its request frames +
    // the trailing WouldBlock per readiness event); 64 swept idle
    // connections would add ≥ 64
    assert!(
        delta <= 16,
        "one active stream among 64 idle conns cost {delta} reads — \
         the reactor is sweeping instead of reacting"
    );
    drop(idle);
    server.stop();
}

/// Queue-position frames: sessions waiting behind a full batch get v2
/// `queue` events whose positions shrink as the queue drains, before
/// their `accepted` frame arrives.
#[test]
fn v2_queued_session_receives_queue_position_frames() {
    let server = start_server_sharded(1);
    let mut c = Client::connect_v2(&server.addr).unwrap();
    // fill all 4 decode slots with long streams...
    let mut fillers = Vec::new();
    for i in 0..4 {
        let mut r = request(
            &format!("stress prompt number {i} says"),
            "i-glass",
            0.5,
        );
        r.max_tokens = 128;
        fillers.push(c.generate_stream(r).unwrap());
    }
    // ...then two more that must wait for a slot
    let mk_waiter = |c: &mut Client, prompt: &str| {
        let mut r = request(prompt, "dense", 0.5);
        r.max_tokens = 4;
        c.generate_stream(r).unwrap()
    };
    let w1 = mk_waiter(&mut c, "the blue owl is");
    let w2 = mk_waiter(&mut c, "every morning the wolf");
    for (which, id) in [("first", w1), ("second", w2)] {
        let mut positions: Vec<u64> = Vec::new();
        let mut accepted = false;
        let mut saw_delta = false;
        loop {
            match c.next_event(id).unwrap() {
                Event::Queue { position, .. } => {
                    // queue frames live strictly between `accepted`
                    // (pushed at submission) and the first delta
                    // (admission happened)
                    assert!(
                        accepted,
                        "{which} waiter: queue frame before accepted"
                    );
                    assert!(
                        !saw_delta,
                        "{which} waiter: queue frame after admission"
                    );
                    positions.push(position);
                }
                Event::Accepted { .. } => accepted = true,
                Event::Delta { .. } => saw_delta = true,
                Event::Done(resp) => {
                    assert!(resp.error.is_none(), "{:?}", resp.error);
                    break;
                }
                Event::Error { error, .. } => {
                    panic!("{which} waiter failed: {error}")
                }
                _ => {}
            }
        }
        assert!(accepted, "{which} waiter never accepted");
        assert!(
            !positions.is_empty(),
            "{which} waiter saw no queue frames while slots were full"
        );
        assert!(
            positions.windows(2).all(|w| w[1] < w[0]),
            "{which} waiter: positions must strictly shrink, got \
             {positions:?}"
        );
    }
    // drain the fillers so stop() is quick and every stream completed
    for id in fillers {
        loop {
            match c.next_event(id).unwrap() {
                Event::Done(resp) => {
                    assert!(resp.error.is_none(), "{:?}", resp.error);
                    break;
                }
                Event::Error { error, .. } => panic!("filler: {error}"),
                _ => {}
            }
        }
    }
    server.stop();
}

// ------------------------------------------------ overload governor

#[test]
fn reordered_admission_never_reports_a_growing_queue_position() {
    // tier-aware ordering can move a later interactive admission ahead
    // of a queued batch request; the wire contract is that a session's
    // reported queue positions never GROW (monotone non-increasing),
    // even right after being overtaken. Without the per-session
    // reported floor, the batch waiter below would report position 1,
    // then 2 once the interactive request jumps ahead of it.
    let server = start_server_sharded(1);
    let mut c = Client::connect_v2(&server.addr).unwrap();
    // staggered fillers: slots free one at a time, so the two waiters
    // are admitted at clearly different moments
    let mut fillers = Vec::new();
    for i in 0..4 {
        let mut r = request(
            &format!("tier filler number {i} says"),
            "i-glass",
            0.5,
        );
        r.max_tokens = 32 + 32 * i;
        fillers.push(c.generate_stream(r).unwrap());
    }
    let mut wb = request("the batch waiter asks", "i-glass", 0.5);
    wb.max_tokens = 4;
    wb.tier = Tier::Batch;
    let wb_id = c.generate_stream(wb).unwrap();
    // wait until the batch waiter has reported at least one position
    let mut positions: Vec<u64> = Vec::new();
    let mut early_done: Option<Response> = None;
    while positions.is_empty() {
        match c.next_event(wb_id).unwrap() {
            Event::Queue { position, .. } => positions.push(position),
            Event::Done(r) => {
                early_done = Some(r);
                break;
            }
            Event::Error { error, .. } => panic!("batch waiter: {error}"),
            _ => {}
        }
    }
    assert!(
        early_done.is_none(),
        "batch waiter was admitted while every slot was held"
    );
    // an interactive request jumps the queue ahead of it
    let mut wi = request("the interactive waiter asks", "i-glass", 0.5);
    wi.max_tokens = 4;
    wi.tier = Tier::Interactive;
    let wi_id = c.generate_stream(wi).unwrap();
    let wb_resp = loop {
        match c.next_event(wb_id).unwrap() {
            Event::Queue { position, .. } => positions.push(position),
            Event::Done(r) => break r,
            Event::Error { error, .. } => panic!("batch waiter: {error}"),
            _ => {}
        }
    };
    assert!(wb_resp.error.is_none(), "{:?}", wb_resp.error);
    assert!(
        positions.len() >= 2,
        "need positions from before and after the overtake: {positions:?}"
    );
    assert!(
        positions.windows(2).all(|w| w[1] <= w[0]),
        "a reordered session's reported position must never grow: \
         {positions:?}"
    );
    let wi_resp = loop {
        match c.next_event(wi_id).unwrap() {
            Event::Done(r) => break r,
            Event::Error { error, .. } => {
                panic!("interactive waiter: {error}")
            }
            _ => {}
        }
    };
    assert!(wi_resp.error.is_none(), "{:?}", wi_resp.error);
    // the overtake really happened: the interactive waiter arrived
    // later yet waited less (it took the first freed slot)
    assert!(
        wi_resp.queue_ms <= wb_resp.queue_ms + 5.0,
        "interactive waiter (queued {} ms) must not wait longer than \
         the earlier batch waiter (queued {} ms)",
        wi_resp.queue_ms,
        wb_resp.queue_ms
    );
    for id in fillers {
        loop {
            match c.next_event(id).unwrap() {
                Event::Done(r) => {
                    assert!(r.error.is_none(), "{:?}", r.error);
                    break;
                }
                Event::Error { error, .. } => panic!("filler: {error}"),
                _ => {}
            }
        }
    }
    server.stop();
}

#[test]
fn overload_governor_completes_more_in_the_same_wall_clock_window() {
    // THE governor acceptance proof: a paced overload burst (every
    // prompt shares its leading bytes, so prefix-affinity pins the
    // whole burst to ONE home shard of a width-limited 2-shard server)
    // completes ≥ 1.5× as many requests with the governor on as off in
    // the same wall-clock window, sheds nothing, degrades observably,
    // and fully recovers once the burst drains.
    let total = 12usize;
    let upfront = 3usize;
    let tier_of = |i: usize| match i % 3 {
        0 => Tier::Interactive,
        1 => Tier::Standard,
        _ => Tier::Batch,
    };
    // prefix-affinity routing hashes the first `prefill_len - 1` bytes
    // (the route window), so sharing a pad that long pins the whole
    // burst onto ONE home shard — the overload shape under test
    let pad: String = "overload burst shared context "
        .chars()
        .cycle()
        .take(common::engine().spec().prefill_len.max(2))
        .collect();
    let prompt_of = move |i: usize| format!("{pad}item {i}");
    let send_one = |c: &mut Client, i: usize| {
        let mut r = request(&prompt_of(i), "i-glass", 0.8);
        r.id = i as u64 + 1;
        r.max_tokens = 24;
        r.tier = tier_of(i);
        c.generate_stream(r).unwrap()
    };
    // closed-loop pacing: `upfront` outstanding, one new admission per
    // completion — the home shard stays saturated for the whole burst,
    // and with the governor on each admission that finds the sibling
    // idle is stolen across
    let run = |governor: bool| -> (f64, Vec<f64>, Vec<Response>, u64, u64)
    {
        let cfg = ServerConfig::new(1)
            .with_bind("127.0.0.1:0")
            .with_shards(2)
            .with_governor(governor);
        let server = Server::start_with_config(common::engine(), &cfg)
            .expect("governor server");
        let mut c = Client::connect_v2(&server.addr).unwrap();
        let t0 = Instant::now();
        let mut sent = 0usize;
        while sent < upfront {
            send_one(&mut c, sent);
            sent += 1;
        }
        let mut offsets = Vec::new();
        let mut done = Vec::new();
        while done.len() < total {
            let resp = c.recv().unwrap();
            assert!(
                resp.error.is_none(),
                "governor={governor}: {:?}",
                resp.error
            );
            offsets.push(t0.elapsed().as_secs_f64());
            done.push(resp);
            if sent < total {
                send_one(&mut c, sent);
                sent += 1;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let (_snap, shards) = c.stats_full().unwrap();
        let stolen = shards.iter().map(|s| s.stolen_requests).sum();
        let degraded =
            shards.iter().map(|s| s.degraded_requests).sum();
        // reversibility: the drained server serves full quality again
        // (an idle shard sheds its degradation level in one
        // observation before the next admission is claimed)
        let mut probe = request(&prompt_of(999), "i-glass", 0.8);
        probe.id = 900;
        probe.max_tokens = 4;
        let pr = c.call(probe).unwrap();
        assert!(pr.error.is_none(), "{:?}", pr.error);
        assert!(
            !pr.degraded,
            "post-burst request must run at full quality"
        );
        assert!(
            (pr.effective_density - 0.8).abs() < 1e-9,
            "post-burst effective density must equal the requested 0.8, \
             got {}",
            pr.effective_density
        );
        server.stop();
        (wall, offsets, done, stolen, degraded)
    };
    let (_off_wall, off_offsets, off_done, off_stolen, off_degraded) =
        run(false);
    assert_eq!(off_stolen, 0, "disabled governor must never steal");
    assert_eq!(off_degraded, 0, "disabled governor must never degrade");
    assert!(off_done.iter().all(|r| !r.degraded));
    let (on_wall, _on_offsets, on_done, on_stolen, on_degraded) =
        run(true);
    // zero shed: every request — interactive above all — completed
    assert_eq!(on_done.len(), total);
    let interactive_done = on_done
        .iter()
        .filter(|r| matches!(tier_of((r.id - 1) as usize), Tier::Interactive))
        .count();
    assert_eq!(
        interactive_done, 4,
        "every interactive request must complete under governance"
    );
    // the wall-clock claim: inside the governed run's own wall window
    // the ungoverned server had completed at most total/1.5 requests
    let off_within =
        off_offsets.iter().filter(|&&t| t <= on_wall).count();
    assert!(
        total as f64 >= 1.5 * off_within as f64,
        "governed run must complete ≥1.5× the ungoverned completions \
         in the same window: governed {total} in {on_wall:.2}s, \
         ungoverned {off_within}"
    );
    // the mechanisms are observable end to end
    assert!(
        on_stolen >= 1,
        "a saturated home with an idle sibling must steal at least once"
    );
    assert!(
        on_degraded >= 1,
        "a sustained overload must degrade at least one admission"
    );
    assert!(
        on_done
            .iter()
            .any(|r| r.degraded && r.effective_density < 0.8 - 1e-9),
        "at least one done frame must report its degraded density"
    );
}

#[test]
fn degraded_request_is_bit_identical_to_explicit_degraded_knobs() {
    // the governor never changes the math — only which knob values a
    // request runs with: re-sending a degraded request's prompt with
    // its reported effective knobs on an ungoverned server reproduces
    // the exact bits
    let burst = 16usize;
    let cfg = ServerConfig::new(2)
        .with_bind("127.0.0.1:0")
        .with_shards(1)
        .with_governor(true);
    let server = Server::start_with_config(common::engine(), &cfg)
        .expect("governed server");
    let mut c = Client::connect_v2(&server.addr).unwrap();
    let prompt_of = |i: u64| format!("degradation probe number {i} says");
    let reqs: Vec<Request> = (0..burst)
        .map(|i| {
            let mut r =
                request(&prompt_of(i as u64 + 1), "i-glass", 0.8);
            r.id = i as u64 + 1;
            r.max_tokens = 8;
            r
        })
        .collect();
    let out = c.call_many(reqs).unwrap();
    server.stop();
    let degraded: Vec<&Response> = out
        .iter()
        .map(|(r, _)| r)
        .filter(|r| {
            assert!(r.error.is_none(), "{:?}", r.error);
            r.degraded
        })
        .collect();
    assert!(
        !degraded.is_empty(),
        "an 8×-capacity standard burst at density 0.8 must degrade at \
         least one admission"
    );
    let reference = Server::start_with_config(
        common::engine(),
        &ServerConfig::new(2).with_bind("127.0.0.1:0"),
    )
    .expect("reference server");
    let mut rc = Client::connect_v2(&reference.addr).unwrap();
    for r in degraded {
        assert!(
            r.effective_density < 0.8 - 1e-9,
            "degraded response must report a lowered density: {r:?}"
        );
        let mut explicit = request(
            &prompt_of(r.id),
            "i-glass",
            r.effective_density,
        );
        explicit.max_tokens = 8;
        let resp = rc.call(explicit).unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert!(!resp.degraded, "quiet server must not degrade");
        assert_eq!(
            resp.text, r.text,
            "request {} must be bit-identical to its explicit twin",
            r.id
        );
        assert!(
            (resp.density - r.density).abs() < 1e-9,
            "request {}: mask density diverged",
            r.id
        );
    }
    reference.stop();
}

#[test]
fn stolen_shared_prefix_request_warm_hits_and_matches_home_bits() {
    // the work-stealing acceptance proof: a same-prefix request stolen
    // off its saturated home shard still warm-hits (the thief
    // replicates the hot prefix at admission) and generates the exact
    // bits an unstolen serve produces
    let Some((_sys, p1, p2)) = shared_prefix_prompts() else {
        eprintln!("artifact bundle lacks prefill_chunk — skipping");
        return;
    };
    let cfg = ServerConfig::new(1)
        .with_bind("127.0.0.1:0")
        .with_shards(2)
        .with_governor(true);
    let server = Server::start_with_config(common::engine(), &cfg)
        .expect("steal server");
    let mut c = Client::connect_v2(&server.addr).unwrap();
    // 1. warm: serving p1 cold publishes the shared prefix (and its
    //    chunk-boundary entries) on the home shard's cache
    let mut warm = request(&p1, "i-glass", 0.5);
    warm.max_tokens = 4;
    let w = c.call(warm).unwrap();
    assert!(w.error.is_none(), "{:?}", w.error);
    // 2. saturate home: three long same-prefix streams on width 1 keep
    //    its pressure ≥ 2 (the steal threshold) however the queue and
    //    the occupancy gauges interleave
    let fillers: Vec<u64> = (0..3)
        .map(|_| {
            let mut r = request(&p1, "i-glass", 0.5);
            r.max_tokens = 64;
            c.generate_stream(r).unwrap()
        })
        .collect();
    // 3. probe: the idle sibling steals it and replicates the prefix
    let mut probe = request(&p2, "i-glass", 0.5);
    probe.max_tokens = 8;
    let pid = c.generate_stream(probe).unwrap();
    let stolen_resp = loop {
        match c.next_event(pid).unwrap() {
            Event::Done(r) => break r,
            Event::Error { error, .. } => panic!("probe: {error}"),
            _ => {}
        }
    };
    assert!(stolen_resp.error.is_none(), "{:?}", stolen_resp.error);
    assert!(
        stolen_resp.cached_prompt_tokens > 0,
        "stolen request must still warm-hit the replicated prefix: \
         {stolen_resp:?}"
    );
    let (_snap, shards) = c.stats_full().unwrap();
    let stolen_total: u64 =
        shards.iter().map(|s| s.stolen_requests).sum();
    assert!(
        stolen_total >= 1,
        "stats must count the cross-shard steal: {shards:?}"
    );
    for id in fillers {
        loop {
            match c.next_event(id).unwrap() {
                Event::Done(r) => {
                    assert!(r.error.is_none(), "{:?}", r.error);
                    break;
                }
                Event::Error { error, .. } => panic!("filler: {error}"),
                _ => {}
            }
        }
    }
    server.stop();
    // byte-identical: the same request served unstolen on a quiet
    // ungoverned single-shard server (splices change cost, never
    // content — so cold vs replicated-warm must agree too)
    let reference = Server::start_with_config(
        common::engine(),
        &ServerConfig::new(1).with_bind("127.0.0.1:0"),
    )
    .expect("reference server");
    let mut rc = Client::connect_v2(&reference.addr).unwrap();
    let mut again = request(&p2, "i-glass", 0.5);
    again.max_tokens = 8;
    let ref_resp = rc.call(again).unwrap();
    assert!(ref_resp.error.is_none(), "{:?}", ref_resp.error);
    assert_eq!(
        ref_resp.text, stolen_resp.text,
        "stolen serve diverged from the unstolen reference"
    );
    reference.stop();
}

/// A consumer that stalls mid-stream is parked, never disconnected,
/// and the stream it eventually drains is byte-identical to an
/// unstalled run. Also the end-to-end exercise of the ServerConfig
/// construction path with explicit watermarks.
#[test]
fn stalled_consumer_is_parked_not_dropped_and_stream_is_identical() {
    let cfg = glass::config::ServerConfig::new(4)
        .with_bind("127.0.0.1:0")
        // the floor values: park as early as the server allows so the
        // stall below plausibly crosses the mark on any kernel
        .with_watermarks(1 << 12, 1 << 10);
    let server =
        Server::start_with_config(common::engine(), &cfg).unwrap();
    let mk = || {
        let mut r = request("once there was a red fox", "i-glass", 0.5);
        r.max_tokens = 96;
        r.refresh_every = 8;
        r.cache = CacheMode::Off;
        r
    };
    // reference: an unstalled blocking run
    let mut fast = Client::connect(&server.addr).unwrap();
    let reference = fast.call(mk()).unwrap();
    assert!(reference.error.is_none(), "{:?}", reference.error);

    // stalled consumer: start the stream, then refuse to read while
    // the server generates (kernel buffers + wbuf absorb the backlog;
    // crossing the watermark parks the session rather than killing it)
    let mut slow = Client::connect_v2(&server.addr).unwrap();
    let id = slow.generate_stream(mk()).unwrap();
    std::thread::sleep(Duration::from_millis(400));
    let mut concat = String::new();
    let done = loop {
        match slow.next_event(id).unwrap() {
            Event::Delta { text, .. } => concat.push_str(&text),
            Event::Done(resp) => break resp,
            Event::Error { error, .. } => {
                panic!("stalled consumer must not be failed: {error}")
            }
            _ => {}
        }
    };
    assert_eq!(
        concat, reference.text,
        "post-stall delta concatenation diverged"
    );
    assert_eq!(done.text, reference.text);
    assert_eq!(done.tokens, reference.tokens);
    // the connection survived the stall and keeps serving
    let again = slow.call(mk()).unwrap();
    assert!(again.error.is_none(), "{:?}", again.error);
    assert_eq!(again.text, reference.text);
    server.stop();
}

// --------------------------------------- cpu-q8 backend end-to-end

/// A fresh engine pinned to the cpu-q8 backend (independent of
/// GLASS_TEST_BACKEND, so these tests cover the quantized backend on
/// every CI leg).
fn cpu_q8_engine() -> glass::engine::Engine {
    match common::artifacts_dir() {
        Some(dir) => {
            glass::engine::Engine::load_with_backend(&dir, "cpu-q8")
                .expect("load cpu-q8 engine")
        }
        None => glass::engine::Engine::synthetic_with_backend("cpu-q8")
            .expect("synthetic cpu-q8 engine"),
    }
}

/// The quantized backend behind the full TCP serving stack: a mixed
/// strategy workload completes without errors, and two independent
/// server runs produce identical text/token/density outputs (the
/// capability matrix says cpu-q8 is deterministic — hold it to that
/// over the wire, not just at the runtime layer).
#[test]
fn cpu_q8_backend_serves_tcp_workload_deterministically() {
    let serve_once = || -> Vec<(u64, String, usize, f64)> {
        let engine = cpu_q8_engine();
        assert_eq!(engine.rt.backend_name(), "cpu-q8");
        let cfg = ServerConfig::new(4)
            .with_bind("127.0.0.1:0")
            .with_backend("cpu-q8");
        let server = Server::start_with_config(engine, &cfg).unwrap();
        let mut c = connect(&server.addr);
        let mut out = Vec::new();
        for (i, (prompt, strategy)) in [
            ("once there was a red fox", "i-glass"),
            ("the blue owl is", "dense"),
            ("every morning the wolf", "a-glass"),
            ("the grey cat is quiet and", "griffin"),
        ]
        .iter()
        .enumerate()
        {
            let mut r = request(prompt, strategy, 0.5);
            r.id = i as u64 + 1;
            r.max_tokens = 8;
            let resp = c.call(r).unwrap();
            assert!(
                resp.error.is_none(),
                "id {}: {:?}",
                resp.id,
                resp.error
            );
            assert!(resp.tokens >= 1, "id {} emitted nothing", resp.id);
            out.push((resp.id, resp.text, resp.tokens, resp.density));
        }
        server.stop();
        out
    };
    let first = serve_once();
    let second = serve_once();
    assert_eq!(
        first, second,
        "cpu-q8 serving must be deterministic across server restarts"
    );
}

/// `ServerConfig::with_backend` is an expectation, not a knob: naming
/// a backend the engine wasn't loaded with fails fast at startup with
/// an error that names both sides.
#[test]
fn server_config_backend_mismatch_fails_fast() {
    let engine = cpu_q8_engine();
    let cfg = ServerConfig::new(4)
        .with_bind("127.0.0.1:0")
        .with_backend("sim");
    let err = Server::start_with_config(engine, &cfg).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("sim") && msg.contains("cpu-q8"),
        "mismatch error must name both backends: {msg}"
    );
}
