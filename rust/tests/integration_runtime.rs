//! Runtime-level integration: manifest → compile → execute round trips
//! against the real artifact bundle.

mod common;

use glass::runtime::{DType, Value};
use glass::tensor::{TensorF, TensorI};

#[test]
fn manifest_lists_expected_executables() {
    let engine = common::engine();
    let man = &engine.rt.manifest;
    for kind in [
        "prefill",
        "prefill_chunk",
        "decode",
        "decode_topk",
        "score",
        "generate",
    ] {
        for b in [1usize, 4] {
            assert!(
                man.exe(&format!("{kind}_b{b}")).is_ok(),
                "missing {kind}_b{b}"
            );
        }
    }
    assert_eq!(man.model.ffn_m % 2, 0);
    assert_eq!(man.topk_k, man.model.ffn_m / 2);
}

#[test]
fn priors_load_and_are_well_formed() {
    let engine = common::engine();
    for kind in glass::glass::PriorKind::all() {
        let p = glass::glass::GlobalPrior::load(&engine.rt, kind).unwrap();
        assert_eq!(p.map.n_layers(), engine.spec().n_layers);
        assert_eq!(p.map.m(), engine.spec().ffn_m);
        assert!(p.map.is_well_formed(), "{:?} has bad values", kind);
        // a prior that is all-equal would make ranks meaningless
        let l0 = &p.map.layers[0];
        assert!(l0.iter().any(|&x| (x - l0[0]).abs() > 1e-9));
    }
}

#[test]
fn call_validates_operands() {
    let engine = common::engine();
    // wrong operand count
    assert!(engine.rt.call("decode_b1", &[]).is_err());
    // wrong shape
    let spec = engine.spec().clone();
    let bad = vec![
        Value::I32(TensorI::zeros(&[2])), // token should be [1]
        Value::I32(TensorI::zeros(&[1])),
        Value::F32(TensorF::zeros(&[
            spec.n_layers,
            1,
            spec.n_heads,
            spec.max_seq,
            spec.head_dim,
        ])),
        Value::F32(TensorF::zeros(&[
            spec.n_layers,
            1,
            spec.n_heads,
            spec.max_seq,
            spec.head_dim,
        ])),
        Value::F32(TensorF::zeros(&[1, spec.n_layers, spec.ffn_m])),
    ];
    let err = engine.rt.call("decode_b1", &bad).unwrap_err();
    assert!(err.to_string().contains("shape"), "{err}");
}

#[test]
fn prefill_outputs_match_manifest_shapes() {
    let engine = common::engine();
    let spec = engine.spec().clone();
    let pre = engine
        .prefill(&["the red fox runs".to_string()], 1)
        .unwrap();
    assert_eq!(pre.logits.shape, vec![1, spec.vocab]);
    assert_eq!(
        pre.kv.k.shape,
        vec![spec.n_layers, 1, spec.n_heads, spec.max_seq, spec.head_dim]
    );
    assert_eq!(pre.stats.shape, vec![1, spec.n_layers, spec.ffn_m]);
    assert!(pre.logits.data.iter().all(|x| x.is_finite()));
    assert!(pre.stats.data.iter().all(|x| x.is_finite() && *x >= 0.0));
    // the model is trained: logits should be far from uniform
    let mx = pre.logits.data.iter().cloned().fold(f32::MIN, f32::max);
    let mn = pre.logits.data.iter().cloned().fold(f32::MAX, f32::min);
    assert!(mx - mn > 2.0, "trained model should be confident");
}

#[test]
fn manifest_dtype_contract_holds() {
    let engine = common::engine();
    let gen = engine
        .generate(
            &["the red fox".to_string()],
            &engine.dense_mask(1),
            1,
        )
        .unwrap();
    // gen tokens are I32 per manifest
    assert_eq!(gen.tokens.shape[0], 1);
    assert!(gen
        .tokens
        .data
        .iter()
        .all(|&t| t >= 0 && (t as usize) < engine.spec().vocab));
    let spec_out = engine.rt.manifest.exe("generate_b1").unwrap();
    assert_eq!(spec_out.outputs[0].dtype, DType::I32);
}
