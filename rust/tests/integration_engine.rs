//! Engine-level integration: cross-executable consistency contracts.
//! These pin the L3↔L2 interface — KV layout, positions, masks, and the
//! fused-vs-step decode equivalence.

mod common;

use glass::glass::{build_mask, pack_indices, ImportanceMap, Strategy};
use glass::tensor::argmax;

const ATOL: f32 = 2e-3; // distinct XLA programs; fused ops reorder floats

#[test]
fn fused_generate_matches_step_decode_greedy() {
    let engine = common::engine();
    let prompts = vec!["once there was a red fox".to_string()];
    let mask = engine.dense_mask(1);
    let gen = engine.generate(&prompts, &mask, 1).unwrap();

    // manual loop: prefill + greedy decode_step
    let pre = engine.prefill(&prompts, 1).unwrap();
    let mut kv = pre.kv;
    let mut tok = argmax(pre.logits.row(0)) as i32;
    let mut pos = pre.lens[0] as i32;
    let n = gen.tokens.shape[1].min(12); // compare a prefix (speed)
    for i in 0..n {
        assert_eq!(
            gen.tokens.data[i], tok,
            "fused and step decode diverged at token {i}"
        );
        let (logits, _) = engine
            .decode_step(&mut kv, &[tok], &[pos], &mask)
            .unwrap();
        // logits match too (distributional contract for the KLD metric)
        let g = &gen.logits.data
            [i * engine.spec().vocab..(i + 1) * engine.spec().vocab];
        let max_err = g
            .iter()
            .zip(logits.row(0))
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < ATOL, "logits diverged at {i}: {max_err}");
        tok = argmax(logits.row(0)) as i32;
        pos += 1;
    }
}

#[test]
fn decode_topk_matches_masked_decode() {
    let engine = common::engine();
    let spec = engine.spec().clone();
    let prompts = vec!["the blue owl is".to_string()];
    let pre = engine.prefill(&prompts, 1).unwrap();
    let local = ImportanceMap::from_stats(&pre.stats, 0).unwrap();
    let k = engine.rt.manifest.topk_k;
    let mask = build_mask(&Strategy::LocalOnly, &local, None, k).unwrap();
    let idx = pack_indices(&[&mask], spec.n_layers, k).unwrap();
    let mask_t = glass::engine::session::pack_slot_masks(
        &[mask],
        1,
        1,
        &spec,
    );

    let tok = [100i32];
    let pos = [pre.lens[0] as i32];
    let mut kv1 = pre.kv.clone();
    let (lg_masked, _) = engine
        .decode_step(&mut kv1, &tok, &pos, &mask_t)
        .unwrap();
    let mut kv2 = pre.kv.clone();
    let (lg_topk, _) = engine
        .decode_step_topk(&mut kv2, &tok, &pos, &idx)
        .unwrap();

    let max_err = lg_masked
        .data
        .iter()
        .zip(&lg_topk.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(
        max_err < ATOL,
        "gathered (Pallas) and masked decode disagree: {max_err}"
    );
    // KV caches also match
    let kv_err = kv1
        .k
        .data
        .iter()
        .zip(&kv2.k.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(kv_err < ATOL, "kv diverged: {kv_err}");
}

#[test]
fn score_is_consistent_with_generate_dense() {
    // Teacher-forcing the dense model along its own dense trajectory must
    // produce (a) near-zero top-100 KLD and (b) low NLL at every step —
    // the foundation of the deviation metrics.
    let engine = common::engine();
    let cfg = glass::config::RunConfig {
        lg_samples: 4,
        ..Default::default()
    };
    let prompts = common::sample_prompts(4);
    let batch = glass::harness::lgeval::prepare_batch(&engine, &prompts, 4)
        .unwrap();
    let dense_masks = glass::harness::lgeval::batch_masks(
        &engine,
        &batch,
        &Strategy::Dense,
        None,
        1.0,
    )
    .unwrap();
    let metrics = glass::harness::lgeval::eval_masks(
        &engine,
        &batch,
        &dense_masks,
        cfg.kld_top,
    )
    .unwrap();
    for m in &metrics {
        assert!(
            m.kld < 5e-3,
            "dense self-KLD should be ~0, got {}",
            m.kld
        );
        assert!(
            m.ppl < 1.6,
            "dense self-PPL should be near 1 under greedy, got {}",
            m.ppl
        );
    }
}

#[test]
fn masks_change_generation() {
    let engine = common::engine();
    let prompts = vec!["every morning the wolf".to_string()];
    let dense = engine
        .generate(&prompts, &engine.dense_mask(1), 1)
        .unwrap();
    // aggressive 10% density random mask must change the trajectory
    let pre = engine.prefill(&prompts, 1).unwrap();
    let local = ImportanceMap::from_stats(&pre.stats, 0).unwrap();
    let k = engine.spec().budget(0.1);
    let mask =
        build_mask(&Strategy::Random { seed: 3 }, &local, None, k).unwrap();
    let mask_t = glass::engine::session::pack_slot_masks(
        &[mask],
        1,
        1,
        engine.spec(),
    );
    let sparse = engine.generate(&prompts, &mask_t, 1).unwrap();
    assert_ne!(
        dense.tokens.data, sparse.tokens.data,
        "10% random mask should alter the greedy trajectory"
    );
}

#[test]
fn batched_prefill_slots_are_independent() {
    // Prompt in slot 0 must produce the same stats whether alone (b1) or
    // batched with others (b4) — continuous-batching correctness.
    let engine = common::engine();
    let p0 = "once there was a golden otter".to_string();
    let solo = engine.prefill(&[p0.clone()], 1).unwrap();
    let batch = engine
        .prefill(
            &[
                p0,
                "the grey cat is".to_string(),
                "every dusk the raven".to_string(),
            ],
            4,
        )
        .unwrap();
    let max_err = solo
        .logits
        .row(0)
        .iter()
        .zip(batch.logits.row(0))
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < ATOL, "slot-0 logits depend on batchmates: {max_err}");
    let s_err = solo.stats.data[..]
        .iter()
        .zip(batch.stats.chunk0(0))
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(s_err < ATOL, "slot-0 stats depend on batchmates: {s_err}");
}

#[test]
fn trained_model_continues_grammar() {
    // End-to-end sanity that the build-time training worked: a corpus
    // prefix should continue with plausible grammar-world text.
    let engine = common::engine();
    let gen = engine
        .generate(
            &["the red fox is quick and".to_string()],
            &engine.dense_mask(1),
            1,
        )
        .unwrap();
    let n = gen.tokens.shape[1];
    let text = engine.decode_text(&gen.tokens.data[..n]);
    assert!(
        text.chars().all(|c| c.is_ascii()),
        "generation should be ascii, got {text:?}"
    );
    assert!(
        text.contains(' ') && text.len() > 20,
        "generation too degenerate: {text:?}"
    );
}
