//! Engine-level integration: cross-executable consistency contracts.
//! These pin the L3↔L2 interface — KV layout, positions, masks, and the
//! fused-vs-step decode equivalence.

mod common;

use glass::glass::{
    build_mask, pack_indices, GlobalPrior, ImportanceMap, PriorKind,
    Strategy,
};
use glass::prop_assert;
use glass::tensor::argmax;
use glass::util::quickcheck::{forall, UsizeGen};

const ATOL: f32 = 2e-3; // distinct XLA programs; fused ops reorder floats

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn fused_generate_matches_step_decode_greedy() {
    let engine = common::engine();
    let prompts = vec!["once there was a red fox".to_string()];
    let mask = engine.dense_mask(1);
    let gen = engine.generate(&prompts, &mask, 1).unwrap();

    // manual loop: prefill + greedy decode_step
    let pre = engine.prefill(&prompts, 1).unwrap();
    let mut kv = pre.kv;
    let mut tok = argmax(pre.logits.row(0)) as i32;
    let mut pos = pre.lens[0] as i32;
    let n = gen.tokens.shape[1].min(12); // compare a prefix (speed)
    for i in 0..n {
        assert_eq!(
            gen.tokens.data[i], tok,
            "fused and step decode diverged at token {i}"
        );
        let (logits, _) = engine
            .decode_step(&mut kv, &[tok], &[pos], &mask)
            .unwrap();
        // logits match too (distributional contract for the KLD metric)
        let g = &gen.logits.data
            [i * engine.spec().vocab..(i + 1) * engine.spec().vocab];
        let max_err = g
            .iter()
            .zip(logits.row(0))
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < ATOL, "logits diverged at {i}: {max_err}");
        tok = argmax(logits.row(0)) as i32;
        pos += 1;
    }
}

#[test]
fn decode_topk_matches_masked_decode() {
    let engine = common::engine();
    let spec = engine.spec().clone();
    let prompts = vec!["the blue owl is".to_string()];
    let pre = engine.prefill(&prompts, 1).unwrap();
    let local = ImportanceMap::from_stats(&pre.stats, 0).unwrap();
    let k = engine.rt.manifest.topk_k;
    let mask = build_mask(&Strategy::LocalOnly, &local, None, k).unwrap();
    let idx = pack_indices(&[&mask], spec.n_layers, k).unwrap();
    let mask_t = glass::engine::session::pack_slot_masks(
        &[mask],
        1,
        1,
        &spec,
    );

    let tok = [100i32];
    let pos = [pre.lens[0] as i32];
    let mut kv1 = pre.kv.clone();
    let (lg_masked, _) = engine
        .decode_step(&mut kv1, &tok, &pos, &mask_t)
        .unwrap();
    let mut kv2 = pre.kv.clone();
    let (lg_topk, _) = engine
        .decode_step_topk(&mut kv2, &tok, &pos, &idx)
        .unwrap();

    let max_err = lg_masked
        .data
        .iter()
        .zip(&lg_topk.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(
        max_err < ATOL,
        "gathered (Pallas) and masked decode disagree: {max_err}"
    );
    // KV caches also match
    let kv_err = kv1
        .k
        .data
        .iter()
        .zip(&kv2.k.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(kv_err < ATOL, "kv diverged: {kv_err}");
}

#[test]
fn score_is_consistent_with_generate_dense() {
    // Teacher-forcing the dense model along its own dense trajectory must
    // produce (a) near-zero top-100 KLD and (b) low NLL at every step —
    // the foundation of the deviation metrics.
    let engine = common::engine();
    let cfg = glass::config::RunConfig {
        lg_samples: 4,
        ..Default::default()
    };
    let prompts = common::sample_prompts(4);
    let batch = glass::harness::lgeval::prepare_batch(&engine, &prompts, 4)
        .unwrap();
    let dense_masks = glass::harness::lgeval::batch_masks(
        &engine,
        &batch,
        &Strategy::Dense,
        None,
        1.0,
    )
    .unwrap();
    let metrics = glass::harness::lgeval::eval_masks(
        &engine,
        &batch,
        &dense_masks,
        cfg.kld_top,
    )
    .unwrap();
    for m in &metrics {
        assert!(
            m.kld < 5e-3,
            "dense self-KLD should be ~0, got {}",
            m.kld
        );
        assert!(
            m.ppl < 1.6,
            "dense self-PPL should be near 1 under greedy, got {}",
            m.ppl
        );
    }
}

#[test]
fn masks_change_generation() {
    let engine = common::engine();
    let prompts = vec!["every morning the wolf".to_string()];
    let dense = engine
        .generate(&prompts, &engine.dense_mask(1), 1)
        .unwrap();
    // aggressive 10% density random mask must change the trajectory
    let pre = engine.prefill(&prompts, 1).unwrap();
    let local = ImportanceMap::from_stats(&pre.stats, 0).unwrap();
    let k = engine.spec().budget(0.1);
    let mask =
        build_mask(&Strategy::Random { seed: 3 }, &local, None, k).unwrap();
    let mask_t = glass::engine::session::pack_slot_masks(
        &[mask],
        1,
        1,
        engine.spec(),
    );
    let sparse = engine.generate(&prompts, &mask_t, 1).unwrap();
    assert_ne!(
        dense.tokens.data, sparse.tokens.data,
        "10% random mask should alter the greedy trajectory"
    );
}

#[test]
fn batched_prefill_slots_are_independent() {
    // Prompt in slot 0 must produce the same stats whether alone (b1) or
    // batched with others (b4) — continuous-batching correctness.
    let engine = common::engine();
    let p0 = "once there was a golden otter".to_string();
    let solo = engine.prefill(&[p0.clone()], 1).unwrap();
    let batch = engine
        .prefill(
            &[
                p0,
                "the grey cat is".to_string(),
                "every dusk the raven".to_string(),
            ],
            4,
        )
        .unwrap();
    let max_err = solo
        .logits
        .row(0)
        .iter()
        .zip(batch.logits.row(0))
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < ATOL, "slot-0 logits depend on batchmates: {max_err}");
    let s_err = solo.stats.data[..]
        .iter()
        .zip(batch.stats.chunk0(0))
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(s_err < ATOL, "slot-0 stats depend on batchmates: {s_err}");
}

// ---------------------------------------------------- chunked prefill
//
// The chunk-capable prefill contract: feeding a prompt through
// `prefill_len`-sized (or smaller) chunks with carry-in KV must
// reproduce the monolithic prefill — same KV rows, same final logits,
// same merged local importance — no matter how the prompt is
// partitioned.

#[test]
fn chunked_prefill_single_frame_matches_monolithic_bitwise() {
    let engine = common::engine();
    if engine.rt.manifest.exe("prefill_chunk_b1").is_err() {
        eprintln!("artifact bundle lacks prefill_chunk — skipping");
        return;
    }
    let spec = engine.spec().clone();
    let prompts = vec!["once there was a red fox".to_string()];
    let mono = engine.prefill(&prompts, 1).unwrap();
    let chunked = engine.prefill_chunked(&prompts, 1).unwrap();
    assert_eq!(mono.lens, chunked.lens);
    let len = mono.lens[0];
    if engine.rt.capabilities().deterministic {
        // one backend, one arithmetic path → bit-identical
        assert_eq!(
            bits(&mono.logits.data),
            bits(&chunked.logits.data),
            "logits"
        );
        assert_eq!(
            bits(&mono.stats.data),
            bits(&chunked.stats.data),
            "local importance"
        );
        // KV over the valid prompt rows; the monolithic path also writes
        // PAD scratch rows at len..prefill_len, which decode overwrites
        // before they can be attended (excluded by construction)
        let (hn, tn, dh) = (spec.n_heads, spec.max_seq, spec.head_dim);
        for l in 0..spec.n_layers {
            for h in 0..hn {
                for p in 0..len {
                    let base = ((l * hn + h) * tn + p) * dh;
                    assert_eq!(
                        bits(&mono.kv.k.data[base..base + dh]),
                        bits(&chunked.kv.k.data[base..base + dh]),
                        "k l{l} h{h} p{p}"
                    );
                    assert_eq!(
                        bits(&mono.kv.v.data[base..base + dh]),
                        bits(&chunked.kv.v.data[base..base + dh]),
                        "v l{l} h{h} p{p}"
                    );
                }
            }
        }
    } else {
        // distinct XLA programs: tolerance compare
        for (name, a, b) in [
            ("logits", &mono.logits.data, &chunked.logits.data),
            ("stats", &mono.stats.data, &chunked.stats.data),
        ] {
            let max_err = a
                .iter()
                .zip(b.iter())
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max);
            assert!(max_err < ATOL, "{name} diverged: {max_err}");
        }
    }
}

#[test]
fn chunk_partition_never_changes_kv_logits_or_glass_mask() {
    let engine = common::engine();
    if engine.rt.manifest.exe("prefill_chunk_b1").is_err() {
        eprintln!("artifact bundle lacks prefill_chunk — skipping");
        return;
    }
    if !engine.rt.capabilities().deterministic {
        // distinct XLA programs per partition need not be bitwise
        // reproducible; bit-exactness is a deterministic-backend contract
        eprintln!(
            "nondeterministic backend — skipping bit-exact partition \
             property"
        );
        return;
    }
    let spec = engine.spec().clone();
    // a prompt spanning ≥ 3 prefill frames
    let prompt =
        "the quick grey cat naps ".repeat(3 * spec.prefill_len / 24 + 1);
    let n_prompt = prompt.len() + 1;
    assert!(n_prompt >= 3 * spec.prefill_len && n_prompt <= spec.max_seq);
    let prior = GlobalPrior::load(&engine.rt, PriorKind::INps).unwrap();
    let k = spec.budget(0.5);

    // canonical stream: full prefill_len-sized chunks
    let reference = {
        let mut st = engine.chunked_prefill_start(&prompt).unwrap();
        while !engine.chunked_prefill_step(&mut st).unwrap() {}
        st
    };
    let ref_pre = reference.result().unwrap();
    let ref_mask = build_mask(
        &Strategy::Glass { lambda: 0.5 },
        reference.local_importance(),
        Some(&prior),
        k,
    )
    .unwrap();

    forall(10, 91, &UsizeGen { lo: 1, hi: spec.prefill_len }, |&chunk| {
        let mut st = engine
            .chunked_prefill_start_with(&prompt, chunk)
            .map_err(|e| e.to_string())?;
        let mut guard = 0;
        while !engine
            .chunked_prefill_step(&mut st)
            .map_err(|e| e.to_string())?
        {
            guard += 1;
            prop_assert!(guard <= n_prompt, "runaway chunk loop");
        }
        prop_assert!(
            st.chunks_done == n_prompt.div_ceil(chunk),
            "chunk={chunk}: {} chunk calls",
            st.chunks_done
        );
        // KV rows are pure functions of (token, position): the full
        // cache must be bit-identical for every partition
        prop_assert!(
            bits(&st.kv.k.data) == bits(&reference.kv.k.data),
            "K cache diverged at chunk={chunk}"
        );
        prop_assert!(
            bits(&st.kv.v.data) == bits(&reference.kv.v.data),
            "V cache diverged at chunk={chunk}"
        );
        let pre = st.result().map_err(|e| e.to_string())?;
        prop_assert!(
            bits(&pre.logits.data) == bits(&ref_pre.logits.data),
            "final logits diverged at chunk={chunk}"
        );
        // merged statistics agree to fp-merge tolerance...
        let max_err = pre
            .stats
            .data
            .iter()
            .zip(&ref_pre.stats.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        prop_assert!(
            max_err < 1e-5,
            "merged importance err {max_err} at chunk={chunk}"
        );
        // ...and the selected GLASS mask NEVER depends on the chunking
        let mask = build_mask(
            &Strategy::Glass { lambda: 0.5 },
            st.local_importance(),
            Some(&prior),
            k,
        )
        .map_err(|e| e.to_string())?;
        prop_assert!(
            mask == ref_mask,
            "GLASS mask changed under chunk={chunk}"
        );
        Ok(())
    });
}

#[test]
fn cached_prefix_resume_is_bitwise_equal_and_mask_invariant() {
    // The shared-prefix cache's core claim, at the engine layer: a
    // stream resumed from a boundary published by a DIFFERENT prompt
    // sharing the prefix must reproduce the cold stream bit for bit —
    // KV rows, final logits, merged statistics — and therefore select
    // the identical GLASS mask.
    use glass::engine::prefix_cache::{CacheTelemetry, PrefixCache};
    use std::sync::Arc;

    let engine = common::engine();
    if engine.rt.manifest.exe("prefill_chunk_b1").is_err() {
        eprintln!("artifact bundle lacks prefill_chunk — skipping");
        return;
    }
    if !engine.rt.capabilities().deterministic {
        eprintln!(
            "nondeterministic backend — skipping bit-exact cache property"
        );
        return;
    }
    let spec = engine.spec().clone();
    let sys = "the common system header reads: "
        .repeat(2 * spec.prefill_len / 32 + 1);
    assert!(sys.len() >= 2 * spec.prefill_len);
    let p1 = format!("{sys} alpha question");
    let p2 = format!("{sys} beta question");
    assert!(p1.len().max(p2.len()) + 1 <= spec.max_seq);

    // stream p1 cold, publishing every completed-chunk prefix — the
    // batcher's publication discipline, reproduced by hand
    let mut cache = PrefixCache::new(
        spec.clone(),
        usize::MAX,
        Arc::new(CacheTelemetry::default()),
    );
    let mut st1 = engine.chunked_prefill_start(&p1).unwrap();
    loop {
        let done = engine.chunked_prefill_step(&mut st1).unwrap();
        cache.insert(
            &st1.tokens()[..st1.consumed()],
            &st1.kv,
            0,
            st1.local_importance(),
            st1.merged_weight(),
            st1.logits(),
        );
        if done {
            break;
        }
    }

    // cold p2 reference
    let mut cold = engine.chunked_prefill_start(&p2).unwrap();
    while !engine.chunked_prefill_step(&mut cold).unwrap() {}

    // warm p2: resume from the longest published prefix
    let toks2 = engine.tok.encode_with_bos(&p2);
    let hit = cache.lookup(&toks2).expect("shared prefix must hit");
    assert!(
        hit.seed.len >= 2 * spec.prefill_len
            && hit.seed.len < toks2.len(),
        "expected a multi-frame partial hit, got {} of {}",
        hit.seed.len,
        toks2.len()
    );
    let cached = hit.seed.len;
    let mut warm = engine
        .chunked_prefill_resume(toks2, spec.prefill_len, hit.seed)
        .unwrap();
    while !engine.chunked_prefill_step(&mut warm).unwrap() {}
    cache.release(hit.id);
    assert_eq!(warm.cached, cached);

    // bit-identical stream state...
    assert_eq!(
        bits(&cold.kv.k.data),
        bits(&warm.kv.k.data),
        "K cache diverged after a cached splice"
    );
    assert_eq!(bits(&cold.kv.v.data), bits(&warm.kv.v.data), "V cache");
    assert_eq!(
        bits(cold.logits()),
        bits(warm.logits()),
        "final logits"
    );
    let (a, b) = (cold.result().unwrap(), warm.result().unwrap());
    assert_eq!(a.lens, b.lens);
    assert_eq!(
        bits(&a.stats.data),
        bits(&b.stats.data),
        "merged prompt statistics must be bit-identical"
    );
    // ...and the identical GLASS mask
    let prior = GlobalPrior::load(&engine.rt, PriorKind::INps).unwrap();
    let k = spec.budget(0.5);
    let mask = |st: &glass::engine::chunked::ChunkedPrefill| {
        build_mask(
            &Strategy::Glass { lambda: 0.5 },
            st.local_importance(),
            Some(&prior),
            k,
        )
        .unwrap()
    };
    assert_eq!(
        mask(&cold),
        mask(&warm),
        "GLASS mask changed under a cached prefix splice"
    );
}

#[test]
fn truncation_is_flagged_and_chunked_path_never_truncates() {
    // regression for the silent tail-truncation bug: a clipped prompt
    // must be distinguishable from a fully-consumed one at every layer
    let engine = common::engine();
    let spec = engine.spec().clone();
    let long = "z".repeat(spec.prefill_len * 2);
    let (_, lens, truncated) =
        engine.encode_prompts(&[long.clone()], 1).unwrap();
    assert!(truncated[0], "over-frame prompt must be flagged");
    assert_eq!(lens[0], spec.prefill_len);
    let pre = engine.prefill(&[long.clone()], 1).unwrap();
    assert!(pre.truncated[0], "prefill must surface the flag");
    let full = engine
        .prefill(&["a short prompt".to_string()], 1)
        .unwrap();
    assert!(!full.truncated[0], "in-frame prompt must not be flagged");
    if engine.rt.manifest.exe("prefill_chunk_b1").is_ok() {
        let chunked = engine.prefill_chunked(&[long.clone()], 1).unwrap();
        assert!(!chunked.truncated[0]);
        assert_eq!(
            chunked.lens[0],
            long.len() + 1,
            "chunked path consumes every prompt token"
        );
    }
}

#[test]
fn trained_model_continues_grammar() {
    // End-to-end sanity that the build-time training worked: a corpus
    // prefix should continue with plausible grammar-world text.
    let engine = common::engine();
    let gen = engine
        .generate(
            &["the red fox is quick and".to_string()],
            &engine.dense_mask(1),
            1,
        )
        .unwrap();
    let n = gen.tokens.shape[1];
    let text = engine.decode_text(&gen.tokens.data[..n]);
    assert!(
        text.chars().all(|c| c.is_ascii()),
        "generation should be ascii, got {text:?}"
    );
    assert!(
        text.contains(' ') && text.len() > 20,
        "generation too degenerate: {text:?}"
    );
}
