//! Shared helpers for integration tests.
//!
//! When an artifact bundle is available (GLASS_ARTIFACTS env var, or an
//! `artifacts/` directory with a manifest), the tests exercise the real
//! AOT executables. Otherwise they run on a synthetic engine — by
//! default the deterministic simulator backend, or whatever
//! GLASS_TEST_BACKEND names (`sim`, `cpu-q8`, ...) — every backend
//! implements the same executable contract, so the suite is green
//! offline and in CI, and the CI matrix re-runs it per backend.

use std::path::PathBuf;
use std::sync::{Mutex, OnceLock};

use glass::engine::Engine;

pub fn artifacts_dir() -> Option<PathBuf> {
    let dir = std::env::var("GLASS_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string());
    let p = PathBuf::from(dir);
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        None
    }
}

/// Backend the suite runs on, from GLASS_TEST_BACKEND ("auto" when
/// unset — the registry's default resolution).
pub fn test_backend() -> String {
    std::env::var("GLASS_TEST_BACKEND")
        .unwrap_or_else(|_| "auto".to_string())
}

/// One engine per test binary (client setup + weight upload is ~100 ms;
/// executables compile lazily and are cached inside).
pub fn engine() -> Engine {
    static ENGINE: OnceLock<Mutex<Engine>> = OnceLock::new();
    ENGINE
        .get_or_init(|| {
            let backend = test_backend();
            let engine = match artifacts_dir() {
                Some(dir) => Engine::load_with_backend(&dir, &backend)
                    .expect("load engine from artifacts"),
                None => Engine::synthetic_with_backend(&backend)
                    .expect("synthetic engine"),
            };
            Mutex::new(engine)
        })
        .lock()
        .unwrap()
        .clone()
}

#[allow(dead_code)]
pub fn sample_prompts(n: usize) -> Vec<String> {
    let base = [
        "once there was a red fox",
        "the blue owl is",
        "every morning the wolf",
        "once there was a golden otter",
        "the grey cat is quiet and",
        "every dusk the raven",
    ];
    (0..n).map(|i| base[i % base.len()].to_string()).collect()
}
