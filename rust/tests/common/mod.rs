//! Shared helpers for integration tests. All of these need the artifact
//! bundle (`make artifacts`) — they exercise the real AOT executables.

use std::path::PathBuf;
use std::sync::{Mutex, OnceLock};

use glass::engine::Engine;

pub fn artifacts_dir() -> PathBuf {
    let dir = std::env::var("GLASS_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string());
    let p = PathBuf::from(dir);
    assert!(
        p.join("manifest.json").exists(),
        "artifact bundle missing at {:?} — run `make artifacts` first",
        p
    );
    p
}

/// One engine per test binary (PJRT client + weight upload is ~100 ms;
/// executables compile lazily and are cached inside).
pub fn engine() -> Engine {
    static ENGINE: OnceLock<Mutex<Engine>> = OnceLock::new();
    ENGINE
        .get_or_init(|| {
            Mutex::new(Engine::load(&artifacts_dir()).expect("load engine"))
        })
        .lock()
        .unwrap()
        .clone()
}

pub fn sample_prompts(n: usize) -> Vec<String> {
    let base = [
        "once there was a red fox",
        "the blue owl is",
        "every morning the wolf",
        "once there was a golden otter",
        "the grey cat is quiet and",
        "every dusk the raven",
    ];
    (0..n).map(|i| base[i % base.len()].to_string()).collect()
}
