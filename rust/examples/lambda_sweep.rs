//! λ sensitivity sweep (Fig. 4): PPL/KLD as the global-local mixing
//! weight moves from GRIFFIN (λ=0) to a static global mask (λ=1).
//!
//!     cargo run --release --example lambda_sweep -- [n_samples]

use std::path::Path;

use anyhow::Result;
use glass::engine::Engine;
use glass::glass::{GlobalPrior, PriorKind, Strategy};
use glass::harness::lg_prompts;
use glass::harness::lgeval::eval_strategies;
use glass::util::table::{fnum, Table};

fn main() -> Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(16);
    let engine = Engine::load_or_synthetic(Path::new("artifacts"))?;
    let prompts = lg_prompts(&engine, n)?;
    let prior = GlobalPrior::load(&engine.rt, PriorKind::INps)?;

    let lambdas: Vec<f64> = (0..=10).map(|i| i as f64 / 10.0).collect();
    let strategies: Vec<(String, Strategy, Option<&GlobalPrior>)> = lambdas
        .iter()
        .map(|&l| {
            (
                format!("{l:.1}"),
                Strategy::Glass { lambda: l },
                Some(&prior),
            )
        })
        .collect();
    let results =
        eval_strategies(&engine, &prompts, 4, &strategies, 0.5, 100)?;

    let mut t = Table::new(
        &format!("PPL/KLD vs λ @ 50% density ({} samples)", prompts.len()),
        &["λ", "PPL", "KLD", ""],
    );
    let max_ppl = results
        .iter()
        .map(|(_, m, _)| m.ppl.mean)
        .fold(f64::MIN, f64::max);
    let min_ppl = results
        .iter()
        .map(|(_, m, _)| m.ppl.mean)
        .fold(f64::MAX, f64::min);
    for (name, m, _) in &results {
        // ascii bar: lower PPL = longer bar
        let frac = if max_ppl > min_ppl {
            1.0 - (m.ppl.mean - min_ppl) / (max_ppl - min_ppl)
        } else {
            1.0
        };
        let bar = "#".repeat(1 + (frac * 30.0) as usize);
        t.row(vec![
            name.clone(),
            fnum(m.ppl.mean, 4),
            fnum(m.kld.mean, 4),
            bar,
        ]);
    }
    println!("{}", t.to_ascii());
    println!(
        "endpoints: λ=0 is GRIFFIN (local-only), λ=1 is the static \
         global mask.\nThe paper (App. C.2) finds a smooth curve with the \
         minimum near λ=0.5."
    );
    Ok(())
}
