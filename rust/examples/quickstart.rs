//! Quickstart: load the artifact bundle, run one prompt dense and with
//! I-GLASS at 50% FFN sparsity, and compare.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;
use glass::engine::session::{run_dense_batch, run_sparse_batch};
use glass::engine::Engine;
use glass::glass::{GlobalPrior, PriorKind, Strategy};
use std::path::Path;

fn main() -> Result<()> {
    let engine = Engine::load_or_synthetic(Path::new("artifacts"))?;
    let spec = engine.spec().clone();
    println!(
        "loaded model: {} layers, d={}, ffn_m={}, {:.1} MB weights\n",
        spec.n_layers,
        spec.d_model,
        spec.ffn_m,
        engine.rt.weight_bytes() as f64 / 1e6
    );

    let prompt = "once there was a red fox".to_string();
    println!("prompt: {prompt:?}\n");

    // dense reference
    let t0 = std::time::Instant::now();
    let dense = run_dense_batch(&engine, &[prompt.clone()], 1)?;
    let n = dense.tokens.shape[1];
    let dense_text = engine.decode_text(&dense.tokens.data[..n]);
    println!(
        "dense   ({:5.1} ms): {dense_text:?}",
        t0.elapsed().as_secs_f64() * 1e3
    );

    // GLASS: prefill -> local stats -> rank-fuse with the NPS prior ->
    // static 50% mask -> sparse decode
    let prior = GlobalPrior::load(&engine.rt, PriorKind::INps)?;
    let t1 = std::time::Instant::now();
    let sparse = run_sparse_batch(
        &engine,
        &[prompt.clone()],
        &Strategy::Glass { lambda: 0.5 },
        Some(&prior),
        0.5,
        1,
    )?;
    println!(
        "i-glass ({:5.1} ms): {:?}",
        t1.elapsed().as_secs_f64() * 1e3,
        sparse.texts[0]
    );
    println!(
        "\nmask: {:.0}% of FFN neurons kept per layer (k={} of m={})",
        sparse.masks[0].density() * 100.0,
        sparse.masks[0].layers[0].len(),
        spec.ffn_m
    );
    let same = dense_text
        .chars()
        .zip(sparse.texts[0].chars())
        .take_while(|(a, b)| a == b)
        .count();
    println!(
        "dense/sparse agree on the first {same} characters of {}",
        dense_text.len().min(sparse.texts[0].len())
    );
    Ok(())
}
