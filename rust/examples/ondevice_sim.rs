//! On-device decode simulation (Fig. 5 / §4.5): replay the paper's three
//! phone workloads across edge-device profiles and show where the
//! residency transition produces the order-of-magnitude speedup.
//!
//!     cargo run --release --example ondevice_sim

use anyhow::Result;
use glass::harness::fig5::paper_workloads;
use glass::memsim::{decode_speedup, simulate_decode, DeviceProfile};
use glass::util::table::{fnum, Table};

fn main() -> Result<()> {
    for dev in DeviceProfile::all() {
        let mut t = Table::new(
            &format!(
                "{} — decode @ 50% FFN density (RAM budget {:.1} GB)",
                dev.name,
                dev.ram_budget_bytes as f64 / 1e9
            ),
            &[
                "workload",
                "dense tok/s",
                "GLASS tok/s",
                "speedup",
                "dense fits RAM",
                "GLASS fits RAM",
            ],
        );
        for (model, tokens, _paper) in paper_workloads() {
            let (dense, sparse, speedup) =
                decode_speedup(&dev, &model, 0.5, tokens);
            t.row(vec![
                model.name.clone(),
                fnum(dense.tokens_per_s, 1),
                fnum(sparse.tokens_per_s, 1),
                format!("{speedup:.2}x"),
                dense.resident.to_string(),
                sparse.resident.to_string(),
            ]);
        }
        println!("{}", t.to_ascii());
    }

    // density sweep on the headline case: watch the cliff where the
    // working set crosses the RAM budget
    let dev = DeviceProfile::galaxy_s25_ultra();
    let gemma = &paper_workloads()[2].0;
    let mut sweep = Table::new(
        "gemma-7b-bf16 on galaxy-s25-ultra: density sweep",
        &["FFN density %", "tok/s", "resident", "paging ms/tok"],
    );
    for d10 in (1..=10).rev() {
        let d = d10 as f64 / 10.0;
        let r = simulate_decode(&dev, gemma, d, 64);
        sweep.row(vec![
            format!("{:.0}", d * 100.0),
            fnum(r.tokens_per_s, 1),
            r.resident.to_string(),
            fnum(r.paging_s / r.tokens as f64 * 1e3, 2),
        ]);
    }
    println!("{}", sweep.to_ascii());
    println!(
        "note: the jump where `resident` flips is the paper's ~11x case —\n\
         static 50% FFN masking shrinks the working set under the RAM\n\
         budget and per-token flash paging disappears."
    );
    Ok(())
}
