//! Long-generation study: the paper's headline experiment (Tab. 2) on a
//! configurable sample budget, printing per-strategy deviation PPL/KLD
//! plus a worked sample showing trajectory drift.
//!
//!     cargo run --release --example long_generation_study -- [n_samples]

use std::path::Path;

use anyhow::Result;
use glass::engine::Engine;
use glass::glass::{GlobalPrior, PriorKind, Strategy};
use glass::harness::lgeval::{eval_strategies, prepare_batch};
use glass::harness::lg_prompts;
use glass::util::table::{fnum, improvement_pct, mean_std, Table};

fn main() -> Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(32);
    let engine = Engine::load_or_synthetic(Path::new("artifacts"))?;
    let prompts = lg_prompts(&engine, n)?;
    println!(
        "LG study: {} short prompts, {} generated tokens each\n",
        prompts.len(),
        engine.spec().gen_len
    );

    let a_nps = GlobalPrior::load(&engine.rt, PriorKind::ANps)?;
    let i_nps = GlobalPrior::load(&engine.rt, PriorKind::INps)?;
    let strategies = vec![
        ("GRIFFIN (local-only)".to_string(), Strategy::LocalOnly, None),
        ("Global-only".to_string(), Strategy::GlobalOnly, Some(&a_nps)),
        (
            "A-GLASS (λ=0.5)".to_string(),
            Strategy::Glass { lambda: 0.5 },
            Some(&a_nps),
        ),
        (
            "I-GLASS (λ=0.5)".to_string(),
            Strategy::Glass { lambda: 0.5 },
            Some(&i_nps),
        ),
        ("Oracle (post-hoc)".to_string(), Strategy::Oracle, None),
        ("Random".to_string(), Strategy::Random { seed: 1 }, None),
    ];
    let results =
        eval_strategies(&engine, &prompts, 4, &strategies, 0.5, 100)?;

    let grif_ppl = results[0].1.ppl.mean;
    let grif_kld = results[0].1.kld.mean;
    let mut t = Table::new(
        "deviation from dense @ 50% FFN sparsity",
        &["strategy", "PPL (sem)", "vs GRIFFIN", "KLD (sem)", "vs GRIFFIN"],
    );
    for (name, m, _) in &results {
        t.row(vec![
            name.clone(),
            mean_std(m.ppl.mean, m.ppl.sem(), 4),
            format!("{:+.1}%", improvement_pct(grif_ppl, m.ppl.mean)),
            mean_std(m.kld.mean, m.kld.sem(), 4),
            format!("{:+.1}%", improvement_pct(grif_kld, m.kld.mean)),
        ]);
    }
    println!("{}", t.to_ascii());

    // worked sample: show the dense trajectory and where sparse drifts
    let batch = prepare_batch(&engine, &prompts[..1], 4)?;
    let n_gen = batch.n_gen;
    let dense_text =
        engine.decode_text(&batch.dense.tokens.data[..n_gen]);
    println!("worked sample:");
    println!("  prompt:  {:?}", prompts[0]);
    println!(
        "  dense:   {:?}",
        &dense_text[..dense_text.len().min(90)]
    );
    println!(
        "\nper-strategy mean Jaccard of layer-0 masks to the oracle set:"
    );
    let oracle = glass::harness::lgeval::batch_masks(
        &engine,
        &batch,
        &Strategy::Oracle,
        None,
        0.5,
    )?;
    for (name, strat, prior) in [
        ("local", Strategy::LocalOnly, None),
        ("global", Strategy::GlobalOnly, Some(&a_nps)),
        ("fused", Strategy::Glass { lambda: 0.5 }, Some(&a_nps)),
    ] {
        let masks = glass::harness::lgeval::batch_masks(
            &engine, &batch, &strat, prior, 0.5,
        )?;
        println!(
            "  {name:7} {}",
            fnum(masks[0].jaccard_mean(&oracle[0]), 3)
        );
    }
    Ok(())
}
