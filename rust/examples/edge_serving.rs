//! End-to-end serving driver (DESIGN.md §7): start the reactor server
//! on the real trained model, submit batched requests dense and
//! GLASS-sparse over TCP (legacy v1 blocking protocol), stream one
//! generation over protocol v2 with a mid-stream refresh, and report
//! latency/throughput + quality spot checks.
//!
//!     make artifacts && cargo run --release --example edge_serving

use std::path::Path;
use std::time::Instant;

use anyhow::Result;
use glass::engine::Engine;
use glass::server::client::{request, Client};
use glass::server::protocol::{Event, Request};
use glass::server::Server;
use glass::util::stats::summarize;
use glass::util::table::{fnum, Table};

const N_REQUESTS: usize = 24;
const MAX_TOKENS: usize = 48;

fn main() -> Result<()> {
    let engine = Engine::load_or_synthetic(Path::new("artifacts"))?;
    let server = Server::start(engine, "127.0.0.1:0", 4)?;
    println!("server up at {}\n", server.addr);

    let prompts = [
        "once there was a red fox",
        "the blue owl is",
        "every morning the wolf",
        "once there was a golden otter",
        "the grey cat is quiet and",
        "every dusk the raven",
    ];

    let mut table = Table::new(
        "edge serving: batched requests over TCP (this host, 1 core)",
        &[
            "strategy",
            "n",
            "p50 latency ms",
            "p95 latency ms",
            "req/s",
            "tok/s",
        ],
    );

    let mut sample_outputs: Vec<(String, String)> = Vec::new();
    for strategy in ["dense", "griffin", "i-glass"] {
        let mut client = Client::connect(&server.addr)?;
        let reqs: Vec<Request> = (0..N_REQUESTS)
            .map(|i| {
                let mut r =
                    request(prompts[i % prompts.len()], strategy, 0.5);
                r.max_tokens = MAX_TOKENS;
                r
            })
            .collect();
        let t0 = Instant::now();
        let out = client.call_many(reqs)?;
        let wall = t0.elapsed().as_secs_f64();

        let lat_ms: Vec<f64> = out
            .iter()
            .map(|(_, l)| l.as_secs_f64() * 1e3)
            .collect();
        let s = summarize(&lat_ms);
        let total_tokens: usize = out.iter().map(|(r, _)| r.tokens).sum();
        for (r, _) in &out {
            assert!(r.error.is_none(), "{strategy}: {:?}", r.error);
        }
        table.row(vec![
            strategy.to_string(),
            format!("{N_REQUESTS}"),
            fnum(s.p50, 1),
            fnum(s.p95, 1),
            fnum(N_REQUESTS as f64 / wall, 2),
            fnum(total_tokens as f64 / wall, 1),
        ]);
        sample_outputs.push((strategy.to_string(), out[0].0.text.clone()));
    }
    println!("{}", table.to_ascii());

    println!("sample outputs (same prompt, different strategies):");
    for (strategy, text) in &sample_outputs {
        println!("  {strategy:8} -> {:?}", &text[..text.len().min(70)]);
    }

    // ------------------------- protocol v2: one streamed session
    // the same server speaks the framed streaming protocol on the same
    // port (auto-detected per connection): tokens arrive as deltas, the
    // GLASS mask refresh is observable mid-stream, and the session is
    // adjustable while in flight
    println!("\nprotocol v2 stream (i-glass, refresh every 8 tokens):");
    let mut v2 = Client::connect_v2(&server.addr)?;
    let mut req = request(prompts[0], "i-glass", 0.5);
    req.max_tokens = MAX_TOKENS;
    req.refresh_every = 8;
    let id = v2.generate_stream(req)?;
    let mut deltas = 0usize;
    let mut refreshes = 0usize;
    loop {
        match v2.next_event(id)? {
            Event::Accepted { queue_pos, .. } => {
                println!("  accepted at queue position {queue_pos}");
            }
            Event::Queue { position, .. } => {
                println!("  still queued at position {position}");
            }
            Event::Delta { .. } => deltas += 1,
            Event::Refresh { changed, .. } => {
                refreshes += 1;
                if changed {
                    println!("  mask refreshed (kept set changed)");
                }
            }
            Event::Done(resp) => {
                println!(
                    "  done: {} tokens across {deltas} deltas, \
                     {refreshes} refreshes, finish {:?}",
                    resp.tokens, resp.finish
                );
                break;
            }
            Event::Error { error, .. } => {
                anyhow::bail!("stream failed: {error}")
            }
        }
    }
    server.stop();
    Ok(())
}
