//! Compile-time stub of the `xla` (xla-rs) API surface the glass PJRT
//! backend uses. It keeps the `--features pjrt` build type-checking in
//! environments without the xla_extension C++ toolchain; every entry
//! point fails at runtime with a clear message. To run real HLO
//! artifacts, replace the `xla` path dependency in rust/Cargo.toml with
//! an actual xla-rs checkout (API v0.1.x / xla_extension 0.5.1).

use std::path::Path;

#[derive(Debug, Clone)]
pub struct Error(pub String);

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "xla stub: {what} unavailable — this build vendors a compile-time \
         stub of xla-rs; link a real xla_extension to use the PJRT backend"
    )))
}

/// Marker for element types PJRT buffers can hold.
pub trait ArrayElement: Copy {}
impl ArrayElement for f32 {}
impl ArrayElement for i32 {}

pub struct PjRtClient;
pub struct PjRtBuffer;
pub struct PjRtLoadedExecutable;
pub struct HloModuleProto;
pub struct XlaComputation;
pub struct Literal;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn buffer_from_host_buffer<T: ArrayElement>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("buffer_from_host_buffer")
    }

    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        unavailable("compile")
    }
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b(
        &self,
        _args: &[&PjRtBuffer],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("execute_b")
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("to_literal_sync")
    }
}

impl Literal {
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}
