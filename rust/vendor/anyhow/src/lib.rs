//! Offline vendored subset of the `anyhow` API.
//!
//! The build image has no crates.io access, so this path crate provides
//! the slice of anyhow the workspace actually uses: [`Error`],
//! [`Result`], the [`anyhow!`]/[`bail!`]/[`ensure!`] macros, and the
//! [`Context`] extension trait for `Result` and `Option`. Error values
//! carry a context chain; `{}` prints the outermost message and `{:#}`
//! prints the whole chain, matching upstream formatting closely enough
//! for the CLI and tests.

use std::fmt;

/// A string-backed error with a context chain. `chain[0]` is the
/// outermost (most recently attached) message.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Attach an outer context message (used by [`Context`]).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        for cause in self.chain.iter().skip(1) {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

/// Like upstream anyhow, `Error` deliberately does NOT implement
/// `std::error::Error`; that is what makes the blanket `From` below and
/// the dual `Context` impls coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        Error::msg(err)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

// ------------------------------------------------------------- Context

mod private {
    pub trait Sealed {}
    impl<T, E> Sealed for Result<T, E> {}
    impl<T> Sealed for Option<T> {}
}

/// Mirror of upstream's private ext trait: "anything that can become an
/// [`Error`] while absorbing a context message". Implemented for real
/// `std::error::Error` types and for [`Error`] itself — coherent because
/// `Error` is local and never implements `std::error::Error`.
pub trait ToError {
    fn into_error(self) -> Error;
}

impl<E> ToError for E
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn into_error(self) -> Error {
        Error::msg(self)
    }
}

impl ToError for Error {
    fn into_error(self) -> Error {
        self
    }
}

pub trait Context<T, E>: private::Sealed {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: ToError> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T, Error> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

// -------------------------------------------------------------- macros

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path/glass-vendor-test")?;
        Ok(())
    }

    #[test]
    fn from_std_error_via_question_mark() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn context_chain_and_alternate_format() {
        let e: Result<()> = Err(anyhow!("root {}", 7));
        let e = e.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        assert_eq!(format!("{e:#}"), "outer: root 7");
        assert_eq!(e.root_cause(), "root 7");
    }

    #[test]
    fn with_context_on_result_and_option() {
        let r: Result<(), Error> = Err(anyhow!("inner"));
        let e = r.with_context(|| format!("ctx {}", 1)).unwrap_err();
        assert_eq!(format!("{e:#}"), "ctx 1: inner");
        let o: Option<u32> = None;
        assert!(o.context("missing").is_err());
    }

    fn bails(x: i32) -> Result<i32> {
        if x < 0 {
            bail!("negative: {x}");
        }
        ensure!(x != 3, "three is right out");
        Ok(x)
    }

    #[test]
    fn bail_and_ensure() {
        assert_eq!(bails(1).unwrap(), 1);
        assert_eq!(bails(-2).unwrap_err().to_string(), "negative: -2");
        assert!(bails(3).is_err());
    }
}
