//! Decode-path benchmarks (§4.5 runtime claims on this host):
//! prefill, step decode (dense / masked / top-k gathered), the fused
//! generator, the teacher-forced scorer, and the serving-layer
//! continuous batcher (step-mode with mid-flight admission + chunked
//! long-prompt admission).
//!
//!     cargo bench --bench bench_decode             # full run
//!     cargo bench --bench bench_decode -- --smoke  # CI smoke (tiny
//!                                                  # counts, ~seconds)
//!     ... -- --smoke --check-against BENCH_baseline.json
//!                                   # CI regression gate: non-zero
//!                                   # exit on a >15% decode-throughput
//!                                   # drop, lost prefix-cache savings,
//!                                   # lost chunked-admission overlap,
//!                                   # or a p95 latency blow-up
//!     ... -- --smoke --write-baseline BENCH_baseline.json
//!                                   # refresh the checked-in baseline
//!
//! Results land in BENCH_decode.json next to the bench's working
//! directory, including the fused-vs-step speedup, the continuous
//! batcher's tokens/s and p95 per-request queue+decode latency, the
//! mixed long+short workload's stall-removal evidence (one
//! deterministic pass's prefill chunks + decode steps overlapped with
//! prefill streaming), the shared-system-prompt workload's prefill
//! tokens saved by the prefix cache, the radix lookup-scaling row
//! (`cache_lookup_us_p95` with hundreds of resident entries — a
//! ceiling breach means lookups regressed toward entry-count scans),
//! the warm-restart row (`warm_start_hits` served from a disk
//! snapshot after a simulated restart), the sharded-serving rows (the
//! continuous workload split across per-shard batcher threads by the
//! server's prefix-affinity router — the multi-shard scaling proof on
//! the sim backend), and the protocol-v2 streaming row: the same
//! workload over real TCP through the nonblocking reactor with a crowd
//! of idle connections attached (`idle_conns_toks_per_s` — proof that
//! idle connections cost table entries, not throughput;
//! `idle_cpu_sweeps_per_token` — poller wakeups per generated token,
//! ceilinged so a regression back to per-connection sweeping fails CI;
//! and `backpressure_pauses` — park transitions from one deterministic
//! slow-consumer pass, floored so backpressure keeps engaging), plus
//! the quantized-kernel rows: the cpu-q8 masked FFN GEMV at densities
//! {1.0, 0.5, 0.3} over one shared int8 weight set (`q8_toks_per_s`
//! floors the dense throughput; `q8_sparse_speedup_x` floors the
//! density-0.3 speedup — the machine-independent proof that a GLASS
//! mask skips real row traffic, not just mask bookkeeping), and the
//! overload-governor rows: three SLO-tiered burst shapes (bursty
//! chat, shared-prefix RAG, long-form generation) against a
//! width-limited 2-shard server with the governor off vs on
//! (`governed_completed_requests` floors governed completions inside
//! the ungoverned wall windows; `governed_p95_queue_ms` ceilings the
//! interactive tier's queue wait under governance).
//! `--backend sim|cpu-q8|pjrt` selects the engine's execution backend
//! through the registry ("auto" when omitted).

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use glass::config::ServerConfig;
use glass::engine::prefix_cache::{
    CacheMode, CacheTelemetry, PrefixCache,
};
use glass::engine::{Engine, KvState};
use glass::glass::{build_mask, pack_indices, ImportanceMap, Strategy};
use glass::runtime::quant;
use glass::server::batcher::Batcher;
use glass::server::client::Client;
use glass::server::protocol::{Event, Request, Tier};
use glass::server::scheduler::{Control, Pending, Scheduler};
use glass::server::{route_shard, route_window, Server};
use glass::tensor::TensorF;
use glass::util::bench::{check_regression, Bencher};
use glass::util::json::Json;
use glass::util::stats::percentile;

/// Value of `--flag <value>` in raw argv, if present.
fn arg_value(flag: &str) -> Option<String> {
    let argv: Vec<String> = std::env::args().collect();
    argv.iter()
        .position(|a| a == flag)
        .and_then(|i| argv.get(i + 1).cloned())
}

fn main() {
    // --smoke: run every row at minimal iteration counts so CI can keep
    // the bench code compiling AND executing without a multi-minute job
    let smoke = std::env::args().any(|a| a == "--smoke");
    let check_against = arg_value("--check-against");
    let write_baseline = arg_value("--write-baseline");
    // --backend sim|cpu-q8|pjrt picks an ExecBackend from the registry;
    // "auto" keeps the default resolution (pjrt when compiled in)
    let backend =
        arg_value("--backend").unwrap_or_else(|| "auto".into());
    let engine = Engine::load_or_synthetic_with_backend(
        Path::new("artifacts"),
        &backend,
    )
    .expect("load engine");
    let spec = engine.spec().clone();
    let mut b = Bencher::default();
    b.budget_s = 2.0;
    if smoke {
        b.warmup_iters = 1;
        b.min_iters = 1;
        b.max_iters = 2;
        b.budget_s = 0.01;
    }

    let prompts: Vec<String> = vec![
        "once there was a red fox".into(),
        "the blue owl is".into(),
        "every morning the wolf".into(),
        "the grey cat is quiet and".into(),
    ];

    // ---------------------------------------------------------- prefill
    b.bench("prefill b=1", 1.0, || {
        engine.prefill(&prompts[..1], 1).unwrap()
    });
    b.bench("prefill b=4", 4.0, || {
        engine.prefill(&prompts, 4).unwrap()
    });

    // ------------------------------------------------------ step decode
    let pre1 = engine.prefill(&prompts[..1], 1).unwrap();
    let local = ImportanceMap::from_stats(&pre1.stats, 0).unwrap();
    let k = engine.rt.manifest.topk_k;
    let half = build_mask(&Strategy::LocalOnly, &local, None, k).unwrap();
    let idx = pack_indices(&[&half], spec.n_layers, k).unwrap();
    let half_t = glass::engine::session::pack_slot_masks(
        &[half],
        1,
        1,
        &spec,
    );
    let dense_t = engine.dense_mask(1);
    let tok = [65i32];
    let pos = [pre1.lens[0] as i32];

    let mut kv = pre1.kv.clone();
    b.bench("decode step b=1 dense", 1.0, || {
        engine.decode_step(&mut kv, &tok, &pos, &dense_t).unwrap()
    });
    let mut kv = pre1.kv.clone();
    b.bench("decode step b=1 masked50", 1.0, || {
        engine.decode_step(&mut kv, &tok, &pos, &half_t).unwrap()
    });
    let mut kv = pre1.kv.clone();
    b.bench("decode step b=1 topk50 (pallas)", 1.0, || {
        engine
            .decode_step_topk(&mut kv, &tok, &pos, &idx)
            .unwrap()
    });

    // batched step decode
    let pre4 = engine.prefill(&prompts, 4).unwrap();
    let dense4 = engine.dense_mask(4);
    let tok4 = [65i32, 66, 67, 68];
    let pos4: Vec<i32> = pre4.lens.iter().map(|&l| l as i32).collect();
    let mut kv4 = pre4.kv.clone();
    b.bench("decode step b=4 dense", 4.0, || {
        engine
            .decode_step(&mut kv4, &tok4, &pos4, &dense4)
            .unwrap()
    });

    // --------------------------------------------- fused generate loop
    let n_gen = spec.gen_len as f64;
    b.bench("generate b=1 (fused scan)", n_gen, || {
        engine
            .generate(&prompts[..1], &engine.dense_mask(1), 1)
            .unwrap()
    });
    b.bench("generate b=4 (fused scan)", 4.0 * n_gen, || {
        engine.generate(&prompts, &engine.dense_mask(4), 4).unwrap()
    });

    // ------------------------------------------------------------ score
    let batch =
        glass::harness::lgeval::prepare_batch(&engine, &prompts, 4)
            .unwrap();
    let w = TensorF::zeros(&[4, spec.score_len]);
    b.bench("score b=4 (teacher-forced)", 4.0 * n_gen, || {
        engine
            .score(&batch.score_tokens, &w, &dense4)
            .unwrap()
    });

    // -------------------------------------- continuous batching (serve)
    // 16 requests through the serving engine loop: step-mode decode,
    // mid-flight admission, immediate retirement. Tokens per iteration =
    // 16 × gen_len, directly comparable with the fused rows above.
    let n_reqs = if smoke { 4usize } else { 16usize };
    let max_tokens = spec.gen_len;
    let submit_all = |sched: &Scheduler, refresh_every: usize| {
        for i in 0..n_reqs {
            let _ = sched.submit(Pending {
                request: Request {
                    id: i as u64 + 1,
                    prompt: prompts[i % prompts.len()].clone(),
                    strategy: "i-glass".into(),
                    lambda: 0.5,
                    density: 0.5,
                    max_tokens,
                    refresh_every,
                    cache: CacheMode::On,
                    tier: Tier::Standard,
                },
                arrived: Instant::now(),
                conn_id: i as u64,
                stream: false,
                resume_from: 0,
                degraded: false,
                reported_floor: usize::MAX,
            });
        }
        sched.close();
    };
    // setup (prior loading + executable warm-up) stays OUTSIDE the
    // measured closures so these rows compare fairly with the fused
    // rows above, which also time only the engine call. The prefix
    // cache is DISABLED here so these rows keep measuring the cold
    // prefill + decode path (the shared-prefix rows below measure the
    // cache).
    let mut batcher = Batcher::from_config(
        engine.clone(),
        &ServerConfig::new(4).with_cache_bytes(0),
        0,
    )
    .expect("batcher");
    // per-request queue+prefill+decode latency, collected across every
    // pass of the plain continuous row — its p95 is the gate's latency
    // ceiling observable (a stall anywhere in admission or decode shows
    // up here even when aggregate throughput survives)
    let mut latencies_ms: Vec<f64> = Vec::new();
    b.bench(
        "continuous batch serve (b=4, 16 reqs)",
        (n_reqs * max_tokens) as f64,
        || {
            let sched = Scheduler::new(4, Duration::from_millis(1));
            submit_all(&sched, 0);
            let mut served = 0usize;
            batcher.run(&sched, &mut |_, ev| {
                if let Some(resp) = ev.into_response() {
                    assert!(resp.error.is_none(), "{:?}", resp.error);
                    served += resp.tokens;
                    latencies_ms.push(
                        resp.queue_ms + resp.prefill_ms + resp.decode_ms,
                    );
                }
            });
            served
        },
    );
    let p95_latency_ms = percentile(&latencies_ms, 0.95);
    println!(
        "continuous serve per-request latency: p95 {p95_latency_ms:.2} ms \
         over {} requests",
        latencies_ms.len()
    );
    // same workload with in-flight mask refresh every 8 tokens
    b.bench(
        "continuous serve + refresh R=8",
        (n_reqs * max_tokens) as f64,
        || {
            let sched = Scheduler::new(4, Duration::from_millis(1));
            submit_all(&sched, 8);
            let mut served = 0usize;
            batcher.run(&sched, &mut |_, ev| {
                if let Some(resp) = ev.into_response() {
                    assert!(resp.error.is_none(), "{:?}", resp.error);
                    served += resp.tokens;
                }
            });
            served
        },
    );

    // ------------------------- mixed long+short workload (chunked admit)
    // every 4th request carries a multi-chunk prompt (≥ 3 prefill
    // frames) admitted next to short in-flight requests; the batcher
    // must keep the short slots decoding while the long prompt streams
    // in. `overlap_steps` counts decode steps that ran concurrently
    // with prefill streaming — the measured stall-removal evidence.
    // Skipped (not failed) on bundles without the prefill_chunk
    // executable or whose KV window cannot hold a 3-frame prompt.
    let long_prompt =
        "the quick grey cat naps ".repeat(3 * spec.prefill_len / 24 + 1);
    let chunking = engine.rt.manifest.exe("prefill_chunk_b1").is_ok();
    let long_fits = long_prompt.len() + 1 >= 3 * spec.prefill_len
        && long_prompt.len() + 1 + max_tokens <= spec.max_seq;
    if !(chunking && long_fits) {
        println!(
            "skipping mixed long+short row (prefill_chunk available: \
             {chunking}, 3-frame prompt fits window: {long_fits})"
        );
    }
    let submit_mixed = |sched: &Scheduler| {
        for i in 0..n_reqs {
            let prompt = if i % 4 == 3 {
                long_prompt.clone()
            } else {
                prompts[i % prompts.len()].clone()
            };
            let _ = sched.submit(Pending {
                request: Request {
                    id: i as u64 + 1,
                    prompt,
                    strategy: "i-glass".into(),
                    lambda: 0.5,
                    density: 0.5,
                    max_tokens,
                    refresh_every: 0,
                    cache: CacheMode::On,
                    tier: Tier::Standard,
                },
                arrived: Instant::now(),
                conn_id: i as u64,
                stream: false,
                resume_from: 0,
                degraded: false,
                reported_floor: usize::MAX,
            });
        }
        sched.close();
    };
    let serve_mixed = |batcher: &mut Batcher| -> usize {
        let sched = Scheduler::new(4, Duration::from_millis(1));
        submit_mixed(&sched);
        let mut served = 0usize;
        batcher.run(&sched, &mut |_, ev| {
            if let Some(resp) = ev.into_response() {
                assert!(resp.error.is_none(), "{:?}", resp.error);
                served += resp.tokens;
            }
        });
        served
    };
    // overlap counters of ONE deterministic mixed pass — what the CI
    // gate pins as floors (cumulative counters across a variable bench
    // iteration count would not be machine-independent)
    let mut mixed_chunks = 0u64;
    let mut mixed_overlap = 0u64;
    if chunking && long_fits {
        b.bench(
            "mixed long+short serve (chunked admission)",
            (n_reqs * max_tokens) as f64,
            || serve_mixed(&mut batcher),
        );
        let (c0, o0) = (batcher.chunks, batcher.overlap_steps);
        serve_mixed(&mut batcher);
        mixed_chunks = batcher.chunks - c0;
        mixed_overlap = batcher.overlap_steps - o0;
        println!(
            "chunked admission (one deterministic pass): {mixed_chunks} \
             prefill chunks streamed, {mixed_overlap} decode steps ran \
             during streaming (stall-free overlap)"
        );
        assert!(
            mixed_overlap > 0,
            "in-flight decode stalled during chunked prefill"
        );
    }

    // -------------------- shared-system-prompt workload (prefix cache)
    // every request = one shared multi-frame system prompt + a short
    // distinct user suffix — the serving pattern the shared-prefix
    // cache exists for. The first pass pays the prefix miss once
    // (same-prefix followers defer behind the publisher); every later
    // pass exact-hits and skips prefill entirely. `prefill_tokens_saved`
    // counts prompt tokens spliced from the cache instead of recomputed.
    let sys_prompt =
        "the shared system prompt is: ans".repeat(2 * spec.prefill_len / 32 + 1);
    let shared_prompt =
        |i: usize| format!("{sys_prompt} user{i} asks");
    let prefix_tokens = sys_prompt.len() + 1; // + BOS
    let longest = shared_prompt(n_reqs - 1).len() + 1;
    let shared_fits = chunking
        && sys_prompt.len() >= 2 * spec.prefill_len
        && longest + max_tokens <= spec.max_seq + 1;
    let submit_shared = |sched: &Scheduler| {
        for i in 0..n_reqs {
            let _ = sched.submit(Pending {
                request: Request {
                    id: i as u64 + 1,
                    prompt: shared_prompt(i),
                    strategy: "i-glass".into(),
                    lambda: 0.5,
                    density: 0.5,
                    max_tokens,
                    refresh_every: 0,
                    cache: CacheMode::On,
                    tier: Tier::Standard,
                },
                arrived: Instant::now(),
                conn_id: i as u64,
                stream: false,
                resume_from: 0,
                degraded: false,
                reported_floor: usize::MAX,
            });
        }
        sched.close();
    };
    let serve_shared = |batcher: &mut Batcher| {
        let sched = Scheduler::new(4, Duration::from_millis(1))
            .with_prefix_grouping(spec.prefill_len);
        submit_shared(&sched);
        let mut served = 0usize;
        batcher.run(&sched, &mut |_, ev| {
            if let Some(resp) = ev.into_response() {
                assert!(resp.error.is_none(), "{:?}", resp.error);
                served += resp.tokens;
            }
        });
        served
    };
    let mut saved_warm = 0u64;
    if !shared_fits {
        println!(
            "skipping shared-prefix rows (prefill_chunk available: \
             {chunking}, workload fits window: {})",
            longest + max_tokens <= spec.max_seq + 1
        );
    } else {
        let mut cold = Batcher::from_config(
            engine.clone(),
            &ServerConfig::new(4).with_cache_bytes(0),
            0,
        )
        .expect("batcher");
        b.bench(
            "shared-prefix serve (cache off)",
            (n_reqs * max_tokens) as f64,
            || serve_shared(&mut cold),
        );
        let mut warm =
            Batcher::new(engine.clone(), 4).expect("batcher");
        b.bench(
            "shared-prefix serve (cache on)",
            (n_reqs * max_tokens) as f64,
            || serve_shared(&mut warm),
        );
        // one extra fully-warm pass, measured in tokens not time: with
        // every full prompt published, each request exact-hits, so the
        // pass saves every single prompt token — deterministic and
        // machine-independent, which is what the CI gate pins
        let before = warm.prefill_tokens_saved;
        serve_shared(&mut warm);
        saved_warm = warm.prefill_tokens_saved - before;
        let snap = warm.telemetry().snapshot();
        println!(
            "prefix cache: {} prompt tokens saved on the warm pass \
             (shared prefix is {prefix_tokens} tokens), {} total; \
             {} hits / {} misses, {} inserts, {} evictions, \
             {} bytes resident",
            saved_warm,
            warm.prefill_tokens_saved,
            snap.hits,
            snap.misses,
            snap.inserts,
            snap.evictions,
            snap.bytes_resident
        );
        assert!(
            saved_warm >= prefix_tokens as u64,
            "warm pass saved {saved_warm} < the {prefix_tokens}-token \
             shared prefix — the cache is not hitting"
        );
    }

    // ------------------ radix lookup scaling (hundreds of residents)
    // the radix index measured directly: with hundreds of entries
    // resident, one lookup walks the trie edge-by-edge in O(prefix
    // length) — never a scan over the entry table. Per-call p95 lands
    // in the CI gate as `cache_lookup_us_p95`; a ceiling breach means
    // lookups regressed toward entry-count scans.
    let resident = 256usize;
    let lookup_probes = if smoke { 512 } else { 4096 };
    let mut radix = PrefixCache::new(
        spec.clone(),
        usize::MAX,
        Arc::new(CacheTelemetry::default()),
    );
    let tail = spec.max_seq.min(10).saturating_sub(3);
    let lookup_keys: Vec<Vec<i32>> = (0..resident)
        .map(|i| {
            // distinct two-token branch point + shared tail: the trie
            // holds `resident` leaves behind a fan-out near the root
            let mut key = vec![spec.bos_id, (i % 251) as i32 + 1];
            key.push((i / 251) as i32 + 1);
            key.extend((0..tail).map(|j| j as i32 + 1));
            key
        })
        .collect();
    {
        let kv_seed = KvState::zeros(&spec, 1);
        let stats_seed = ImportanceMap::from_layers(vec![
            vec![0.0; spec.ffn_m];
            spec.n_layers
        ])
        .expect("stats seed");
        let logits_seed = vec![0.0f32; spec.vocab];
        for key in &lookup_keys {
            radix.insert(
                key, &kv_seed, 0, &stats_seed, 1.0, &logits_seed,
            );
        }
    }
    assert_eq!(radix.len(), resident, "scaling rig lost entries");
    let mut lookup_us = Vec::with_capacity(lookup_probes);
    for p in 0..lookup_probes {
        let key = &lookup_keys[p % resident];
        let t0 = Instant::now();
        let hit = radix.lookup(key);
        let dt = t0.elapsed();
        let hit = hit.expect("probe must exact-hit");
        radix.release(hit.id);
        lookup_us.push(dt.as_secs_f64() * 1e6);
    }
    let cache_lookup_us_p95 = percentile(&lookup_us, 0.95);
    println!(
        "radix lookup with {resident} resident entries: p95 \
         {cache_lookup_us_p95:.1} us per call ({lookup_probes} probes)"
    );

    // ------------------ warm restart (snapshot persistence round-trip)
    // the persistence path measured end to end: serve the shared
    // workload once, snapshot the hot entries to disk, then "restart" —
    // a fresh batcher warm-starts from the snapshot and serves the same
    // pass out of imported entries. `warm_start_hits` lands in the CI
    // gate as a floor: losing them means restart persistence silently
    // stopped working.
    let mut warm_start_hits = 0u64;
    if shared_fits {
        let dir = std::env::temp_dir().join(format!(
            "glass-bench-warm-{}",
            std::process::id()
        ));
        // cache_dir on the config indexes the shard-0 snapshot path,
        // exactly as the server's per-shard lowering would
        let cfg_snap =
            ServerConfig::new(4).with_cache_dir(Some(dir.clone()));
        let mut first =
            Batcher::from_config(engine.clone(), &cfg_snap, 0)
                .expect("batcher");
        serve_shared(&mut first); // populate the cache, then persist
        first.snapshot_hot();
        let mut restarted =
            Batcher::from_config(engine.clone(), &cfg_snap, 0)
                .expect("batcher");
        b.bench(
            "warm-restart serve (snapshot-started cache)",
            (n_reqs * max_tokens) as f64,
            || serve_shared(&mut restarted),
        );
        warm_start_hits =
            restarted.telemetry().snapshot().warm_start_hits;
        println!(
            "warm restart: {warm_start_hits} hits served from \
             snapshot-imported entries"
        );
        assert!(
            warm_start_hits > 0,
            "restarted cache never hit a snapshot-imported entry"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    // ---------------------------- sharded serving (per-shard batchers)
    // the same continuous workload split across N independent shard
    // threads by the server's prefix-affinity router (route_shard).
    // Every shard owns its own batcher — engine state, KV, slots — so
    // the sim backend's host math runs genuinely in parallel; the
    // 4-shard row over the 1-shard row is the multi-shard scaling
    // evidence. Batcher construction happens inside the timed closure
    // for BOTH rows, so the comparison stays apples-to-apples.
    let serve_sharded = |n_shards: usize| -> usize {
        let scheds: Vec<Arc<Scheduler>> = (0..n_shards)
            .map(|_| {
                Arc::new(Scheduler::new(4, Duration::from_millis(1)))
            })
            .collect();
        for i in 0..n_reqs {
            let prompt = prompts[i % prompts.len()].clone();
            let si = route_shard(
                &prompt,
                n_shards,
                route_window(spec.prefill_len),
            );
            let _ = scheds[si].submit(Pending {
                request: Request {
                    id: i as u64 + 1,
                    prompt,
                    strategy: "i-glass".into(),
                    lambda: 0.5,
                    density: 0.5,
                    max_tokens,
                    refresh_every: 0,
                    cache: CacheMode::On,
                    tier: Tier::Standard,
                },
                arrived: Instant::now(),
                conn_id: i as u64,
                stream: false,
                resume_from: 0,
                degraded: false,
                reported_floor: usize::MAX,
            });
        }
        for s in &scheds {
            s.close();
        }
        let handles: Vec<std::thread::JoinHandle<usize>> = scheds
            .iter()
            .map(|sched| {
                let engine = engine.clone();
                let sched = Arc::clone(sched);
                std::thread::spawn(move || {
                    let mut shard = Batcher::from_config(
                        engine,
                        &ServerConfig::new(4).with_cache_bytes(0),
                        0,
                    )
                    .expect("shard batcher");
                    let mut served = 0usize;
                    shard.run(&sched, &mut |_, ev| {
                        if let Some(resp) = ev.into_response() {
                            assert!(
                                resp.error.is_none(),
                                "{:?}",
                                resp.error
                            );
                            served += resp.tokens;
                        }
                    });
                    served
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("shard thread"))
            .sum()
    };
    b.bench(
        "sharded serve (1 shard, b=4)",
        (n_reqs * max_tokens) as f64,
        || serve_sharded(1),
    );
    b.bench(
        "sharded serve (4 shards, b=4)",
        (n_reqs * max_tokens) as f64,
        || serve_sharded(4),
    );

    // ---------------- v2 streaming over the reactor, many idle conns
    // the reactor claim measured end to end: a crowd of idle
    // connections must cost table entries, not threads or throughput.
    // One active v2 client streams the continuous workload over real
    // TCP while `idle_n` connected-but-silent sockets sit in the same
    // reactor; tokens/s lands in the CI gate as idle_conns_toks_per_s.
    let idle_n = if smoke { 32 } else { 256 };
    let server = Server::start_with_config(
        engine.clone(),
        &ServerConfig::new(4).with_bind("127.0.0.1:0"),
    )
    .expect("bench server");
    let idle_conns: Vec<std::net::TcpStream> = (0..idle_n)
        .map(|_| {
            std::net::TcpStream::connect(&server.addr)
                .expect("idle conn")
        })
        .collect();
    let mut v2_client =
        Client::connect_v2(&server.addr).expect("v2 client");
    let io_before = server.io_stats();
    b.bench(
        &format!("v2 streaming serve (b=4, {idle_n} idle conns)"),
        (n_reqs * max_tokens) as f64,
        || {
            let reqs: Vec<Request> = (0..n_reqs)
                .map(|i| Request {
                    id: i as u64 + 1,
                    prompt: prompts[i % prompts.len()].clone(),
                    strategy: "i-glass".into(),
                    lambda: 0.5,
                    density: 0.5,
                    max_tokens,
                    refresh_every: 0,
                    cache: CacheMode::On,
                    tier: Tier::Standard,
                })
                .collect();
            let out = v2_client.call_many(reqs).expect("v2 workload");
            assert!(out.iter().all(|(r, _)| r.error.is_none()));
            out.len()
        },
    );
    // idle fleet (N=256 conns): poller wakeups per generated token over
    // the row above — the readiness-CPU observable the gate ceilings.
    // With a reacting poller this sits near 1 (one sweep drains a whole
    // batch of events); a reactor that went back to sweeping the fleet
    // scales with idle_n instead. Warmup iterations land in the sweep
    // window but not in the token denominator, so the reported rate is
    // conservative (never flattering).
    let io_after = server.io_stats();
    let v2_iters = b
        .results
        .iter()
        .find(|r| r.name.starts_with("v2 streaming serve"))
        .map(|r| r.iters)
        .unwrap_or(1)
        .max(1);
    let idle_cpu_sweeps_per_token =
        io_after.sweeps.saturating_sub(io_before.sweeps) as f64
            / (v2_iters * n_reqs * max_tokens) as f64;
    println!(
        "idle fleet (N={idle_n} conns): {idle_cpu_sweeps_per_token:.2} \
         poller sweeps per generated token ({} poller)",
        server.poller_kind()
    );
    drop(idle_conns);
    server.stop();

    // --------------- slow consumer (backpressure park/resume), one
    // deterministic pass: a streaming session is parked mid-decode
    // (exactly what the reactor does when a consumer's outbound backlog
    // crosses the high-water mark), rides along emitting nothing, then
    // resumes and completes. The park count is the gate's backpressure
    // floor — cumulative reactor-side counts would depend on kernel
    // socket buffering and would not be machine-independent.
    let backpressure_pauses = {
        let mut bp = Batcher::from_config(
            engine.clone(),
            &ServerConfig::new(4).with_cache_bytes(0),
            0,
        )
        .expect("backpressure batcher");
        let base = bp.backpressure_pauses;
        let sched = Scheduler::new(4, Duration::from_millis(1));
        let _ = sched.submit(Pending {
            request: Request {
                id: 1,
                prompt: prompts[0].clone(),
                strategy: "i-glass".into(),
                lambda: 0.5,
                density: 0.5,
                max_tokens,
                refresh_every: 0,
                cache: CacheMode::Off,
                tier: Tier::Standard,
            },
            arrived: Instant::now(),
            conn_id: 1,
            stream: true,
            resume_from: 0,
            degraded: false,
            reported_floor: usize::MAX,
        });
        // Cell counters: the sink closure stays live across the
        // mid-pass reads below, so plain `&mut` captures won't borrow
        let events = std::cell::Cell::new(0usize);
        let done_tokens = std::cell::Cell::new(0usize);
        let mut sink = |_c: u64, ev: Event| {
            events.set(events.get() + 1);
            if let Event::Done(resp) = ev {
                assert!(resp.error.is_none(), "{:?}", resp.error);
                done_tokens.set(resp.tokens);
            }
        };
        let over = bp
            .admit(sched.next_batch().expect("batch"), &mut sink);
        assert!(over.is_empty());
        for _ in 0..4 {
            bp.step(&mut sink).expect("step");
        }
        sched.control(Control::Park { conn_id: 1, id: 1 });
        bp.apply_controls(&sched, &mut sink);
        assert_eq!(bp.paused(), 1, "park must pause the live slot");
        let during_park = events.get();
        for _ in 0..4 {
            bp.step(&mut sink).expect("parked step");
        }
        assert_eq!(
            events.get(),
            during_park,
            "a parked session must emit nothing"
        );
        sched.control(Control::Unpark { conn_id: 1, id: 1 });
        bp.apply_controls(&sched, &mut sink);
        while bp.runnable_active() > 0 {
            bp.step(&mut sink).expect("resume step");
        }
        assert!(
            done_tokens.get() > 0,
            "parked session must still complete after resume"
        );
        bp.backpressure_pauses - base
    };
    println!(
        "slow consumer (one deterministic pass): {backpressure_pauses} \
         park transition(s); stream completed in full after resume"
    );
    assert!(backpressure_pauses >= 1);

    // -------------------- overload governor (SLO-tiered burst rows)
    // three governed traffic shapes — a bursty chat fan-out, a
    // shared-prefix RAG burst whose common leading bytes route every
    // request onto ONE home shard (the work-stealing case), and
    // batch-heavy long-form generation — each fired at a deliberately
    // width-limited 2-shard server twice: governor off, then governor
    // on. Every burst is ~3x the server's decode capacity with tiers
    // cycling interactive/standard/batch. Two observables land in the
    // CI gate: `governed_completed_requests` (FLOOR: governed
    // completions inside the ungoverned run's own wall window, summed
    // across scenarios — tier degradation plus hot-prefix stealing
    // must keep buying completions under overload) and
    // `governed_p95_queue_ms` (CEILING: p95 queue wait of the
    // interactive tier under governance — degradation must keep
    // shielding the latency-sensitive tier from the batch backlog).
    let gov_burst = 12usize;
    let long_tokens = if 64 + 2 * max_tokens <= spec.max_seq {
        2 * max_tokens
    } else {
        max_tokens
    };
    let rag_ctx =
        "retrieved context: the red fox keeps a den beneath the oak. ";
    let scenarios: Vec<(&str, Vec<String>, usize)> = vec![
        (
            "bursty chat",
            (0..gov_burst)
                .map(|i| format!("chat user {i} asks about topic {i}"))
                .collect(),
            max_tokens,
        ),
        (
            "shared-prefix RAG",
            (0..gov_burst)
                .map(|i| format!("{rag_ctx}question {i}"))
                .collect(),
            max_tokens,
        ),
        (
            "long-form generation",
            (0..gov_burst)
                .map(|i| format!("write a long essay {}", i % 4))
                .collect(),
            long_tokens,
        ),
    ];
    let tier_of = |i: usize| match i % 3 {
        0 => Tier::Interactive,
        1 => Tier::Standard,
        _ => Tier::Batch,
    };
    let mut governed_completed = 0u64;
    let mut interactive_queue_ms: Vec<f64> = Vec::new();
    for (name, gov_prompts, toks) in &scenarios {
        let gov_reqs = || -> Vec<Request> {
            gov_prompts
                .iter()
                .enumerate()
                .map(|(i, p)| Request {
                    id: i as u64 + 1,
                    prompt: p.clone(),
                    strategy: "i-glass".into(),
                    lambda: 0.5,
                    density: 0.8,
                    max_tokens: *toks,
                    refresh_every: 8,
                    cache: CacheMode::On,
                    tier: tier_of(i),
                })
                .collect()
        };
        // per-setting: one bench row over a persistent server, then one
        // deterministic pass recording per-request completion offsets
        // (send-to-done latency) and queue waits
        let mut run_setting =
            |governor: bool| -> Vec<(u64, f64, f64)> {
                let mut scfg = ServerConfig::new(2)
                    .with_bind("127.0.0.1:0")
                    .with_governor(governor);
                scfg.shards = 2;
                let server =
                    Server::start_with_config(engine.clone(), &scfg)
                        .expect("governor bench server");
                let mut c = Client::connect_v2(&server.addr)
                    .expect("governor bench client");
                b.bench(
                    &format!(
                        "governed {name} (governor {})",
                        if governor { "on" } else { "off" }
                    ),
                    (gov_burst * toks) as f64,
                    || {
                        let out = c
                            .call_many(gov_reqs())
                            .expect("governed burst");
                        assert!(
                            out.iter().all(|(r, _)| r.error.is_none())
                        );
                        out.len()
                    },
                );
                let out =
                    c.call_many(gov_reqs()).expect("governed pass");
                let rows = out
                    .iter()
                    .map(|(r, d)| {
                        (r.id, d.as_secs_f64() * 1e3, r.queue_ms)
                    })
                    .collect();
                server.stop();
                rows
            };
        let off = run_setting(false);
        let t_off_ms =
            off.iter().map(|&(_, ms, _)| ms).fold(0.0, f64::max);
        let on = run_setting(true);
        let within = on
            .iter()
            .filter(|&&(_, ms, _)| ms <= t_off_ms)
            .count();
        governed_completed += within as u64;
        for &(id, _, queue_ms) in &on {
            if matches!(
                tier_of((id - 1) as usize),
                Tier::Interactive
            ) {
                interactive_queue_ms.push(queue_ms);
            }
        }
        println!(
            "governed {name}: {within} of {gov_burst} governed \
             completions inside the ungoverned {t_off_ms:.0} ms window"
        );
    }
    let governed_p95_queue_ms = percentile(&interactive_queue_ms, 0.95);
    println!(
        "governor rows: {governed_completed} governed completions \
         inside the ungoverned windows (of {}), interactive queue p95 \
         {governed_p95_queue_ms:.1} ms",
        gov_burst * scenarios.len()
    );

    // -------------- int8 masked FFN GEMV (the cpu-q8 kernel directly)
    // The cpu-q8 backend's quantized FFN kernel timed at
    // LLM-representative dims — the synthetic spec's 16×32 FFN is far
    // too small for row skipping to show up against loop overhead, so
    // these rows use d=512, m=2048 (3·m·d ≈ 3.1M MACs per token, the
    // same shape class as a small transformer block). All three density
    // rows share ONE quantized weight set and ONE input token, so the
    // density-0.3 row against the density-1.0 row isolates pure
    // row-traffic savings: the measured proof that a GLASS mask buys
    // skipped memory traffic and FLOPs, not just a smaller mask tensor.
    // `q8_toks_per_s` (dense-row throughput, a conservative floor) and
    // `q8_sparse_speedup_x` (dense mean over density-0.3 mean — machine
    // independent, both sides of the ratio run on this host) land in
    // the CI gate.
    let (q8_d, q8_m) = (512usize, 2048usize);
    let q8_simd = quant::detect();
    let lcg_mat = |seed: u32, rows: usize, cols: usize| {
        let mut v = Vec::with_capacity(rows * cols);
        let mut s = seed;
        for _ in 0..rows * cols {
            s = s.wrapping_mul(1664525).wrapping_add(1013904223);
            v.push((s >> 16) as i16 as f32 / 32768.0);
        }
        quant::QuantMatrix::from_rows(rows, cols, &v)
            .expect("quantize bench matrix")
    };
    let q8_up = lcg_mat(1, q8_m, q8_d);
    let q8_gate = lcg_mat(2, q8_m, q8_d);
    let q8_down = lcg_mat(3, q8_m, q8_d);
    let q8_x: Vec<f32> = (0..q8_d)
        .map(|i| (i * 37 % 97) as f32 / 48.0 - 1.0)
        .collect();
    let (q8_xq, q8_xs) = quant::quantize_row(&q8_x);
    let mut q8_y = vec![0.0f32; q8_d];
    let mut q8_acts = vec![0.0f32; q8_m];
    let mut q8_means_s: Vec<f64> = Vec::new();
    for &density in &[1.0f64, 0.5, 0.3] {
        let keep = (q8_m as f64 * density).round() as usize;
        // evenly strided keep-list — the shape a GLASS mask produces
        // (scattered unit indices, not one contiguous block)
        let rows: Vec<usize> =
            (0..keep).map(|i| i * q8_m / keep).collect();
        b.bench(
            &format!(
                "q8 ffn gemv d={q8_d} m={q8_m} density={density:.1} \
                 ({})",
                q8_simd.label()
            ),
            1.0,
            || {
                q8_y.iter_mut().for_each(|v| *v = 0.0);
                quant::ffn_forward_masked(
                    q8_simd,
                    &q8_up,
                    &q8_gate,
                    &q8_down,
                    &q8_xq,
                    q8_xs,
                    &rows,
                    &mut q8_y,
                    Some(&mut q8_acts),
                )
            },
        );
        let r = b.results.last().expect("q8 row just pushed");
        q8_means_s.push(r.mean_s);
    }
    let q8_toks_per_s = 1.0 / q8_means_s[0];
    let q8_sparse_speedup_x = q8_means_s[0] / q8_means_s[2];
    println!(
        "q8 masked FFN ({}): {q8_toks_per_s:.0} tok/s dense, \
         {q8_sparse_speedup_x:.2}x faster at density 0.3 \
         (row skipping turns the mask into real FLOP savings)",
        q8_simd.label()
    );

    println!("\n{}", b.report());
    // headline comparisons for EXPERIMENTS.md §Perf — rows looked up by
    // name so reordering the bench list cannot silently misreport
    let row = |name: &str| {
        b.results
            .iter()
            .find(|r| r.name.starts_with(name))
            .unwrap_or_else(|| panic!("missing bench row '{name}'"))
    };
    let step_per_tok = row("decode step b=1 dense").mean_s;
    let fused_per_tok = row("generate b=1").mean_s / n_gen;
    let fused_b4 = row("generate b=4");
    let continuous = row("continuous batch serve");
    println!(
        "fused-scan speedup over step decode (b=1): {:.1}x per token",
        step_per_tok / fused_per_tok
    );
    println!(
        "continuous batching throughput: {:.1} tok/s \
         (fused b=4: {:.1} tok/s)",
        continuous.throughput(),
        fused_b4.throughput()
    );
    let sharded_1 = row("sharded serve (1 shard").throughput();
    let sharded_4 = row("sharded serve (4 shards").throughput();
    println!(
        "sharded serving: {sharded_1:.1} tok/s on 1 shard, \
         {sharded_4:.1} tok/s on 4 shards ({:.2}x)",
        sharded_4 / sharded_1
    );

    // ------------------------------------------------- BENCH json entry
    let mut doc = Json::obj();
    doc.set("bench", Json::Str("decode".into()));
    doc.set(
        "backend",
        Json::Str(engine.rt.backend_name().into()),
    );
    doc.set("q8_simd", Json::Str(q8_simd.label().into()));
    let mut rows = Vec::new();
    for r in &b.results {
        let mut o = Json::obj();
        o.set("name", Json::Str(r.name.clone()))
            .set("mean_s", Json::Num(r.mean_s))
            .set("p50_s", Json::Num(r.p50_s))
            .set("p95_s", Json::Num(r.p95_s))
            .set("iters", Json::Num(r.iters as f64))
            .set("items_per_s", Json::Num(r.throughput()));
        rows.push(o);
    }
    doc.set("results", Json::Arr(rows));
    doc.set(
        "fused_vs_step_speedup_b1",
        Json::Num(step_per_tok / fused_per_tok),
    );
    doc.set(
        "continuous_toks_per_s",
        Json::Num(continuous.throughput()),
    );
    doc.set(
        "fused_b4_toks_per_s",
        Json::Num(fused_b4.throughput()),
    );
    doc.set("p95_queue_decode_ms", Json::Num(p95_latency_ms));
    doc.set(
        "idle_conns_toks_per_s",
        Json::Num(row("v2 streaming serve").throughput()),
    );
    // readiness observables (see the idle-fleet + slow-consumer passes
    // above) — the CI gate enforces the first as a ceiling (idle
    // connections must not cost poller sweeps) and the second as a
    // floor (backpressure parking must keep engaging)
    doc.set(
        "idle_cpu_sweeps_per_token",
        Json::Num(idle_cpu_sweeps_per_token),
    );
    doc.set(
        "backpressure_pauses",
        Json::Num(backpressure_pauses as f64),
    );
    doc.set(
        "cache_lookup_us_p95",
        Json::Num(cache_lookup_us_p95),
    );
    // overload-governor observables (see the governed scenario rows
    // above) — the gate floors governed completions inside the
    // ungoverned wall windows and ceilings the interactive tier's p95
    // queue wait under governance
    doc.set(
        "governed_completed_requests",
        Json::Num(governed_completed as f64),
    );
    doc.set(
        "governed_p95_queue_ms",
        Json::Num(governed_p95_queue_ms),
    );
    // quantized-kernel observables (see the q8 masked-FFN rows above) —
    // the gate floors the dense throughput like any counter and floors
    // the density-0.3 speedup ratio, the machine-independent proof
    // that masked-out rows keep skipping memory traffic
    doc.set("q8_toks_per_s", Json::Num(q8_toks_per_s));
    doc.set(
        "q8_sparse_speedup_x",
        Json::Num(q8_sparse_speedup_x),
    );
    doc.set("sharded_1_toks_per_s", Json::Num(sharded_1));
    doc.set("sharded_4_toks_per_s", Json::Num(sharded_4));
    doc.set(
        "sharded_scaling_x",
        Json::Num(sharded_4 / sharded_1),
    );
    if chunking && long_fits {
        let mixed = row("mixed long+short serve");
        doc.set("mixed_toks_per_s", Json::Num(mixed.throughput()));
        // one deterministic pass's counters (see serve_mixed above) —
        // the values the CI gate enforces as floors
        doc.set("prefill_chunks", Json::Num(mixed_chunks as f64));
        doc.set(
            "decode_steps_during_prefill",
            Json::Num(mixed_overlap as f64),
        );
    }
    if shared_fits {
        doc.set(
            "shared_prefix_toks_per_s",
            Json::Num(row("shared-prefix serve (cache on)").throughput()),
        );
        doc.set(
            "shared_prefix_off_toks_per_s",
            Json::Num(
                row("shared-prefix serve (cache off)").throughput(),
            ),
        );
        doc.set(
            "prefill_tokens_saved_warm",
            Json::Num(saved_warm as f64),
        );
        doc.set(
            "shared_prefix_tokens",
            Json::Num(prefix_tokens as f64),
        );
        // one restart round-trip's counter (see warm-restart row) —
        // the CI gate enforces it as a floor
        doc.set(
            "warm_start_hits",
            Json::Num(warm_start_hits as f64),
        );
    }
    let path = Path::new("BENCH_decode.json");
    doc.write_file(path).expect("write BENCH_decode.json");
    println!("wrote {}", path.display());

    // ------------------------------------------------- regression gate
    if let Some(base_path) = check_against {
        let baseline = Json::parse_file(Path::new(&base_path))
            .unwrap_or_else(|e| {
                panic!("cannot read baseline {base_path}: {e}")
            });
        let report = check_regression(&doc, &baseline, 0.15);
        for line in &report.checked {
            println!("gate: {line}");
        }
        if !report.passed() {
            for f in &report.failures {
                eprintln!("BENCH REGRESSION: {f}");
            }
            std::process::exit(1);
        }
        println!("bench gate passed against {base_path}");
    }
    if let Some(out) = write_baseline {
        doc.write_file(Path::new(&out))
            .unwrap_or_else(|e| panic!("cannot write {out}: {e}"));
        println!("wrote baseline {out}");
    }
}
