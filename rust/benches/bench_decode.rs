//! Decode-path benchmarks (§4.5 runtime claims on this host):
//! prefill, step decode (dense / masked / top-k gathered), the fused
//! generator, and the teacher-forced scorer.
//!
//!     cargo bench --bench bench_decode

use std::path::Path;

use glass::engine::Engine;
use glass::glass::{build_mask, pack_indices, ImportanceMap, Strategy};
use glass::tensor::TensorF;
use glass::util::bench::Bencher;

fn main() {
    let engine = Engine::load(Path::new("artifacts")).expect(
        "artifact bundle missing — run `make artifacts` before benching",
    );
    let spec = engine.spec().clone();
    let mut b = Bencher::default();
    b.budget_s = 2.0;

    let prompts: Vec<String> = vec![
        "once there was a red fox".into(),
        "the blue owl is".into(),
        "every morning the wolf".into(),
        "the grey cat is quiet and".into(),
    ];

    // ---------------------------------------------------------- prefill
    b.bench("prefill b=1", 1.0, || {
        engine.prefill(&prompts[..1], 1).unwrap()
    });
    b.bench("prefill b=4", 4.0, || {
        engine.prefill(&prompts, 4).unwrap()
    });

    // ------------------------------------------------------ step decode
    let pre1 = engine.prefill(&prompts[..1], 1).unwrap();
    let local = ImportanceMap::from_stats(&pre1.stats, 0).unwrap();
    let k = engine.rt.manifest.topk_k;
    let half = build_mask(&Strategy::LocalOnly, &local, None, k).unwrap();
    let idx = pack_indices(&[&half], spec.n_layers, k).unwrap();
    let half_t = glass::engine::session::pack_slot_masks(
        &[half],
        1,
        1,
        &spec,
    );
    let dense_t = engine.dense_mask(1);
    let tok = [65i32];
    let pos = [pre1.lens[0] as i32];

    let mut kv = pre1.kv.clone();
    b.bench("decode step b=1 dense", 1.0, || {
        engine.decode_step(&mut kv, &tok, &pos, &dense_t).unwrap()
    });
    let mut kv = pre1.kv.clone();
    b.bench("decode step b=1 masked50", 1.0, || {
        engine.decode_step(&mut kv, &tok, &pos, &half_t).unwrap()
    });
    let mut kv = pre1.kv.clone();
    b.bench("decode step b=1 topk50 (pallas)", 1.0, || {
        engine
            .decode_step_topk(&mut kv, &tok, &pos, &idx)
            .unwrap()
    });

    // batched step decode
    let pre4 = engine.prefill(&prompts, 4).unwrap();
    let dense4 = engine.dense_mask(4);
    let tok4 = [65i32, 66, 67, 68];
    let pos4: Vec<i32> = pre4.lens.iter().map(|&l| l as i32).collect();
    let mut kv4 = pre4.kv.clone();
    b.bench("decode step b=4 dense", 4.0, || {
        engine
            .decode_step(&mut kv4, &tok4, &pos4, &dense4)
            .unwrap()
    });

    // --------------------------------------------- fused generate loop
    let n_gen = spec.gen_len as f64;
    b.bench("generate b=1 (fused scan)", n_gen, || {
        engine
            .generate(&prompts[..1], &engine.dense_mask(1), 1)
            .unwrap()
    });
    b.bench("generate b=4 (fused scan)", 4.0 * n_gen, || {
        engine.generate(&prompts, &engine.dense_mask(4), 4).unwrap()
    });

    // ------------------------------------------------------------ score
    let batch =
        glass::harness::lgeval::prepare_batch(&engine, &prompts, 4)
            .unwrap();
    let w = TensorF::zeros(&[4, spec.score_len]);
    b.bench("score b=4 (teacher-forced)", 4.0 * n_gen, || {
        engine
            .score(&batch.score_tokens, &w, &dense4)
            .unwrap()
    });

    println!("\n{}", b.report());
    // headline comparisons for EXPERIMENTS.md §Perf
    let step_per_tok = b.results[2].mean_s; // b=1 dense step
    let fused_per_tok = b.results[6].mean_s / n_gen;
    println!(
        "fused-scan speedup over step decode (b=1): {:.1}x per token",
        step_per_tok / fused_per_tok
    );
}
