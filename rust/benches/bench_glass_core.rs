//! GLASS core hot-path micro-benchmarks: the mask-selection work that
//! runs between prefill and the first decode step. Target: orders of
//! magnitude below one decode step (DESIGN.md §8).
//!
//!     cargo bench --bench bench_glass_core

use glass::glass::{
    build_mask, fuse_and_select, pack_masks, rank_ascending, GlobalPrior,
    ImportanceMap, Strategy,
};
use glass::util::bench::Bencher;
use glass::util::prng::Prng;

fn main() {
    let mut b = Bencher::default();
    b.budget_s = 1.5;
    let mut rng = Prng::new(7);

    for m in [512usize, 4096, 14336] {
        // 14336 = Llama-3-8B FFN width: paper-scale per-layer cost
        let local: Vec<f32> = (0..m).map(|_| rng.f32()).collect();
        let global: Vec<f32> = (0..m).map(|_| rng.f32()).collect();
        b.bench(&format!("rank_ascending m={m}"), m as f64, || {
            rank_ascending(&local)
        });
        b.bench(&format!("fuse_and_select m={m} k=m/2"), m as f64, || {
            fuse_and_select(&local, &global, 0.5, m / 2)
        });
    }

    // full per-request mask build at our model scale and paper scale
    for (l, m) in [(4usize, 512usize), (32, 14336)] {
        let local = ImportanceMap::from_layers(
            (0..l).map(|_| (0..m).map(|_| rng.f32()).collect()).collect(),
        )
        .unwrap();
        let prior = GlobalPrior::new(
            "bench",
            (0..l).map(|_| (0..m).map(|_| rng.f32()).collect()).collect(),
        )
        .unwrap();
        b.bench(
            &format!("build_mask glass L={l} m={m}"),
            (l * m) as f64,
            || {
                build_mask(
                    &Strategy::Glass { lambda: 0.5 },
                    &local,
                    Some(&prior),
                    m / 2,
                )
                .unwrap()
            },
        );
        let mask = build_mask(
            &Strategy::Glass { lambda: 0.5 },
            &local,
            Some(&prior),
            m / 2,
        )
        .unwrap();
        b.bench(
            &format!("pack_masks b=4 L={l} m={m}"),
            (4 * l * m) as f64,
            || {
                pack_masks(
                    &[Some(&mask), Some(&mask), Some(&mask), Some(&mask)],
                    l,
                    m,
                )
            },
        );
    }

    println!("\n{}", b.report());
}
