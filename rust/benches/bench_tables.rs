//! One bench per paper table/figure: runs every harness runner on a tiny
//! sample budget and times it. This guarantees `cargo bench` exercises
//! the full code path behind each reported table (the full-budget runs
//! are `glass exp <id>`; see EXPERIMENTS.md).
//!
//!     cargo bench --bench bench_tables

use std::path::Path;

use glass::config::RunConfig;
use glass::engine::Engine;
use glass::harness::run_experiment;
use glass::util::timer;

fn main() {
    let engine = Engine::load_or_synthetic(Path::new("artifacts"))
        .expect("load engine");
    let cfg = RunConfig {
        lg_samples: 8,
        sweep_samples: 4,
        cls_samples: 4,
        sg_samples: 4,
        oracle_samples: 8,
        lambda_grid: vec![0.0, 0.5, 1.0],
        density_grid: vec![0.9, 0.5, 0.1],
        results_dir: std::env::temp_dir().join("glass_bench_results"),
        ..Default::default()
    };

    for id in ["table1", "table2", "table3", "table5", "table6", "fig4",
               "fig5"] {
        let t0 = std::time::Instant::now();
        match run_experiment(id, &engine, &cfg) {
            Ok(report) => {
                let dt = t0.elapsed().as_secs_f64();
                println!(
                    "bench {id:8} regenerated ({} table(s)) in {dt:6.2}s \
                     [tiny budget]",
                    report.tables.len()
                );
            }
            Err(e) => {
                eprintln!("bench {id}: FAILED: {e:#}");
                std::process::exit(1);
            }
        }
    }
    println!("\nruntime profile over all table regenerations:");
    println!("{}", timer::global().report());
}
