//! Memory-simulator benchmarks + Fig. 5 sensitivity analysis: the
//! residency-transition speedup must be robust to ±2× on every device
//! constant (DESIGN.md §3 justification for the substitution).
//!
//!     cargo bench --bench bench_memsim

use glass::harness::fig5::paper_workloads;
use glass::memsim::{decode_speedup, simulate_decode, DeviceProfile};
use glass::util::bench::Bencher;
use glass::util::table::{fnum, Table};

fn main() {
    let mut b = Bencher::default();
    b.budget_s = 1.0;
    let dev = DeviceProfile::galaxy_s25_ultra();
    let gemma = &paper_workloads()[2].0;

    b.bench("simulate_decode 256 tok", 256.0, || {
        simulate_decode(&dev, gemma, 0.5, 256)
    });

    // sensitivity: scale each constant by 0.5x / 2x and re-check the
    // Gemma-7B residency speedup stays order-of-magnitude
    let mut t = Table::new(
        "fig5 sensitivity: gemma-7b-bf16 speedup under perturbed device \
         constants",
        &["constant", "0.5x", "1x", "2x"],
    );
    let base = |d: &DeviceProfile| decode_speedup(d, gemma, 0.5, 64).2;
    let nominal = base(&dev);
    let variants: Vec<(&str, Box<dyn Fn(f64) -> DeviceProfile>)> = vec![
        (
            "ram_bw",
            Box::new(|s| DeviceProfile {
                ram_bw_bytes_s: 60e9 * s,
                ..DeviceProfile::galaxy_s25_ultra()
            }),
        ),
        (
            "flash_bw",
            Box::new(|s| DeviceProfile {
                flash_bw_bytes_s: 3.5e9 * s,
                ..DeviceProfile::galaxy_s25_ultra()
            }),
        ),
        (
            "compute",
            Box::new(|s| DeviceProfile {
                compute_flops_s: 2.0e12 * s,
                ..DeviceProfile::galaxy_s25_ultra()
            }),
        ),
        (
            "flash_latency",
            Box::new(|s| DeviceProfile {
                flash_latency_s: 150e-6 * s,
                ..DeviceProfile::galaxy_s25_ultra()
            }),
        ),
    ];
    let mut all_big = true;
    for (name, make) in &variants {
        let lo = base(&make(0.5));
        let hi = base(&make(2.0));
        all_big &= lo > 3.0 && hi > 3.0;
        t.row(vec![
            name.to_string(),
            format!("{lo:.1}x"),
            format!("{nominal:.1}x"),
            format!("{hi:.1}x"),
        ]);
    }
    println!("{}", t.to_ascii());
    println!(
        "residency-transition speedup stays >3x under every ±2x \
         perturbation: {all_big}"
    );

    // density cliff trace (Fig. 5 companion)
    let mut cliff = Table::new(
        "density cliff",
        &["density %", "tok/s", "resident"],
    );
    for d10 in (1..=10).rev() {
        let r = simulate_decode(&dev, gemma, d10 as f64 / 10.0, 64);
        cliff.row(vec![
            (d10 * 10).to_string(),
            fnum(r.tokens_per_s, 1),
            r.resident.to_string(),
        ]);
    }
    println!("{}", cliff.to_ascii());
    println!("\n{}", b.report());
}
