//! `glass` — CLI for the GLASS reproduction.
//!
//! Subcommands:
//!   info                      — artifact bundle + model summary
//!   generate  --prompt ...    — one sparse generation (quick demo)
//!   exp <id|all>              — regenerate a paper table/figure
//!   nps [--check]             — run Null-Prompt Stimulation via the runtime
//!   serve                     — start the JSON-line TCP server
//!   client --prompt ...       — send one request to a running server
//!   profile                   — dump the section profiler after a workload

use std::path::Path;

use anyhow::{bail, Result};
use glass::config::RunConfig;
use glass::engine::session::{run_dense_batch, run_sparse_batch};
use glass::engine::Engine;
use glass::glass::{GlobalPrior, PriorKind, Strategy};
use glass::harness::run_experiment;
use glass::nps::{prior_agreement, run_nps, NpsConfig};
use glass::server::client::{request, Client};
use glass::server::Server;
use glass::util::cli::Args;
use glass::util::logging;
use glass::util::stats::mean;

const USAGE: &str = "\
glass — GLASS: Global-Local Aggregation for Inference-time Sparsification

USAGE:
    glass <subcommand> [options]

SUBCOMMANDS:
    info                      artifact bundle + model summary
    generate                  sparse generation demo
                              [--prompt STR] [--strategy dense|griffin|
                               global|a-glass|i-glass] [--density F]
                               [--lambda F]
    exp <table1|table2|table3|table5|table6|fig1|fig4|fig5|all>
                              regenerate a paper table/figure
    nps                       run NPS through the runtime [--check]
                              [--seqs N] [--len N]
    serve                     start the server [--bind ADDR] [--batch N]
                              [--shards N]  (per-shard engine + reactor
                              thread + prefix cache; prompts are routed
                              by leading-bytes hash so same-prefix
                              traffic colocates; default 1)
                              [--cache-bytes N]  (total across shards;
                              0 disables the shared-prefix cache)
                              [--cache-dir DIR]  (persist each shard's
                              prefix cache across restarts: snapshot on
                              graceful stop, warm-start at startup)
                              [--max-frame-bytes N] [--conn-buffer-bytes N]
                              (per-connection read / write buffer caps;
                              both protocols are served, auto-detected
                              per connection)
                              [--high-water-bytes N] [--low-water-bytes N]
                              (backpressure watermarks: a backlogged
                              consumer is parked past high and resumed
                              below low; 0 = derive from the buffer cap)
                              [--governor on|off]  (overload governor:
                              SLO-tiered GLASS degradation + hot-prefix
                              work-stealing under load; default off)
                              [--governor-floor-interactive F]
                              [--governor-floor-standard F]
                              [--governor-floor-batch F]
                              (per-tier effective-density floors the
                              governor never degrades below)
                              [--steal-threshold F]  (home-shard
                              pressure at which an idle sibling may
                              steal an admission)
    client                    send a request [--bind ADDR] [--prompt STR]
                              [--strategy S] [--density F]
                              [--tier interactive|standard|batch]
                              (SLO tier for governor admission;
                              default standard)
                              [--cache on|off|readonly] [--stats]
                              [--protocol v1|v2] (default v2)
                              [--stream]  (v2: print deltas as they
                              arrive, then the session summary)
    profile                   run a mixed workload and print the profiler

COMMON OPTIONS:
    --artifacts DIR           artifact bundle (default: artifacts)
    --results DIR             report output (default: results)
    --config FILE             TOML run config
    --backend NAME            execution backend: auto (default), sim,
                              cpu-q8 (int8 weight-quantized CPU GEMV
                              with native masked FFN), or pjrt
                              (requires --features pjrt)
    --lg-samples N --sweep-samples N --cls-samples N --sg-samples N
    --oracle-samples N --density F --lambda F --batch N --seed N
";

fn main() {
    logging::init();
    let args = match Args::from_env(&["check", "help", "stats", "stream"]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if args.has_flag("help") || args.subcommand.is_none() {
        println!("{USAGE}");
        return;
    }
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    let cfg = RunConfig::load(args)?;
    let sub = args.subcommand.as_deref().unwrap();
    match sub {
        "info" => info(&cfg),
        "generate" => generate(args, &cfg),
        "exp" => exp(args, &cfg),
        "nps" => nps(args, &cfg),
        "serve" => serve(args, &cfg),
        "client" => client(args, &cfg),
        "profile" => profile(&cfg),
        other => bail!("unknown subcommand '{other}'\n\n{USAGE}"),
    }
}

fn load_engine(cfg: &RunConfig) -> Result<Engine> {
    // falls back to a synthetic engine on the configured backend when
    // the AOT bundle is absent, so `glass serve` / `glass generate`
    // work out of the box in offline environments
    Engine::load_or_synthetic_with_backend(
        Path::new(&cfg.artifacts_dir),
        &cfg.backend,
    )
}

fn info(cfg: &RunConfig) -> Result<()> {
    let engine = load_engine(cfg)?;
    let man = &engine.rt.manifest;
    let spec = &man.model;
    println!("GLASS artifact bundle: {}", man.dir.display());
    println!(
        "model: vocab={} d_model={} layers={} heads={} ffn_m={} max_seq={}",
        spec.vocab,
        spec.d_model,
        spec.n_layers,
        spec.n_heads,
        spec.ffn_m,
        spec.max_seq
    );
    println!(
        "weights: {:.2} MB across {} tensors",
        engine.rt.weight_bytes() as f64 / 1e6,
        man.params.len()
    );
    let fp = glass::model::WeightFootprint::from_manifest(man);
    println!(
        "footprint: ffn {:.1}% attn {:.1}% embed {:.1}%",
        fp.ffn_fraction() * 100.0,
        fp.attn_bytes as f64 / fp.total_bytes as f64 * 100.0,
        fp.embed_bytes as f64 / fp.total_bytes as f64 * 100.0
    );
    println!("executables:");
    for e in &man.executables {
        println!(
            "  {:18} {} operands, {} outputs",
            e.name,
            e.operands.len(),
            e.outputs.len()
        );
    }
    println!("priors: {:?}", man.priors.iter().map(|(k, _)| k).collect::<Vec<_>>());
    Ok(())
}

fn generate(args: &Args, cfg: &RunConfig) -> Result<()> {
    let engine = load_engine(cfg)?;
    let prompt = args.get_str("prompt", "once there was a red fox");
    let strategy_name = args.get_str("strategy", "i-glass");
    let (strategy, prior) = resolve_strategy(&engine, &strategy_name, cfg)?;

    println!("prompt:   {prompt:?}");
    println!(
        "strategy: {} @ {:.0}% density",
        strategy.name(),
        cfg.density * 100.0
    );
    let t0 = std::time::Instant::now();
    let warn_truncated = |truncated: &[bool]| {
        if truncated.first().copied().unwrap_or(false) {
            println!(
                "WARNING:  prompt exceeds the {}-token prefill frame and \
                 was tail-truncated by the fused generator; serve it via \
                 `glass serve` for full-length chunked prefill",
                engine.spec().prefill_len
            );
        }
    };
    if matches!(strategy, Strategy::Dense) {
        let gen = run_dense_batch(&engine, &[prompt.clone()], 1)?;
        warn_truncated(&gen.truncated);
        let n = gen.tokens.shape[1];
        println!("output:   {:?}", engine.decode_text(&gen.tokens.data[..n]));
    } else {
        let run = run_sparse_batch(
            &engine,
            &[prompt.clone()],
            &strategy,
            prior.as_ref(),
            cfg.density,
            1,
        )?;
        warn_truncated(&run.result.truncated);
        println!("output:   {:?}", run.texts[0]);
        println!(
            "mask:     density {:.3}, layer-0 kept {} / {}",
            run.masks[0].density(),
            run.masks[0].layers[0].len(),
            engine.spec().ffn_m
        );
    }
    println!("elapsed:  {:.1} ms", t0.elapsed().as_secs_f64() * 1e3);
    Ok(())
}

fn resolve_strategy(
    engine: &Engine,
    name: &str,
    cfg: &RunConfig,
) -> Result<(Strategy, Option<GlobalPrior>)> {
    Ok(match name {
        "dense" => (Strategy::Dense, None),
        "griffin" => (Strategy::LocalOnly, None),
        "global" => (
            Strategy::GlobalOnly,
            Some(GlobalPrior::load(&engine.rt, PriorKind::ANps)?),
        ),
        "a-glass" => (
            Strategy::Glass { lambda: cfg.lambda },
            Some(GlobalPrior::load(&engine.rt, PriorKind::ANps)?),
        ),
        "i-glass" => (
            Strategy::Glass { lambda: cfg.lambda },
            Some(GlobalPrior::load(&engine.rt, PriorKind::INps)?),
        ),
        other => bail!("unknown strategy '{other}'"),
    })
}

fn exp(args: &Args, cfg: &RunConfig) -> Result<()> {
    let engine = load_engine(cfg)?;
    let ids: Vec<String> = if args.positional.is_empty()
        || args.positional[0] == "all"
    {
        // table5 and fig1 share a runner; run each id once
        vec!["table1", "table2", "table3", "table5", "table6", "fig4", "fig5"]
            .into_iter()
            .map(String::from)
            .collect()
    } else {
        args.positional.clone()
    };
    for id in &ids {
        crate::println_header(id);
        let report = run_experiment(id, &engine, cfg)?;
        report.emit(cfg)?;
    }
    Ok(())
}

fn nps(args: &Args, cfg: &RunConfig) -> Result<()> {
    let engine = load_engine(cfg)?;
    let ncfg = NpsConfig {
        n_seqs: args.get_usize("seqs", 8)?,
        seq_len: args.get_usize("len", 64)?,
        seed: cfg.seed + 42,
    };
    println!(
        "running NPS via the runtime: {} seqs x {} tokens",
        ncfg.n_seqs, ncfg.seq_len
    );
    let run = run_nps(&engine, &ncfg)?;
    println!("accumulated {} tokens of A^g statistics", run.n_tokens);
    println!("sample[0]: {:?}", &run.samples[0][..run.samples[0].len().min(80)]);
    if args.has_flag("check") {
        let bundled = GlobalPrior::load(&engine.rt, PriorKind::ANps)?;
        let cors = prior_agreement(&run.prior, &bundled);
        println!(
            "Spearman agreement with the bundled python NPS prior, per \
             layer: {:?} (mean {:.3})",
            cors.iter().map(|c| (c * 1000.0).round() / 1000.0).collect::<Vec<_>>(),
            mean(&cors)
        );
    }
    Ok(())
}

fn serve(args: &Args, cfg: &RunConfig) -> Result<()> {
    let engine = load_engine(cfg)?;
    let backend = engine.rt.backend_name();
    let batch = args.get_usize("batch", cfg.batch)?;
    let mut scfg = glass::config::ServerConfig::from_run(cfg, batch);
    scfg.shards = cfg.shards.max(1);
    let server = Server::start_with_config(engine, &scfg)?;
    println!(
        "serving on {} ({} shard{} x batch width {batch}, backend \
         {backend}, prefix cache {}, protocols v1+v2 auto-detected); \
         Ctrl-C to stop",
        server.addr,
        cfg.shards.max(1),
        if cfg.shards.max(1) == 1 { "" } else { "s" },
        if cfg.cache_bytes > 0 {
            format!("{} MiB total", cfg.cache_bytes >> 20)
        } else {
            "off".to_string()
        }
    );
    loop {
        // lint: allow(no-sleep-outside-reactor) -- the main thread
        // parks forever while the server threads do all the work
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn client(args: &Args, cfg: &RunConfig) -> Result<()> {
    let mut c = match cfg.protocol.as_str() {
        "v2" => Client::connect_v2(&cfg.bind)?,
        "v1" => Client::connect(&cfg.bind)?,
        other => bail!("unknown protocol '{other}' (use v1 or v2)"),
    };
    if args.has_flag("stats") {
        let (s, shards) = c.stats_full()?;
        println!(
            "cache: {} hits / {} misses, {} inserts, {} evictions, \
             {} entries, {} bytes resident",
            s.hits, s.misses, s.inserts, s.evictions, s.entries,
            s.bytes_resident
        );
        for sh in &shards {
            println!(
                "shard {}: queue {} / slots {}+{} of {} \
                 (decoding+prefilling)",
                sh.shard,
                sh.queue_depth,
                sh.slots_active,
                sh.slots_prefilling,
                sh.batch_width
            );
            println!(
                "         governor level {}: {} degraded admissions, \
                 {} stolen from saturated siblings",
                sh.governor_level, sh.degraded_requests, sh.stolen_requests
            );
        }
        return Ok(());
    }
    let prompt = args.get_str("prompt", "once there was a red fox");
    let strategy = args.get_str("strategy", "i-glass");
    let mut req = request(&prompt, &strategy, cfg.density);
    req.tier = glass::server::protocol::Tier::parse(
        &args.get_str("tier", "standard"),
    )?;
    req.cache = glass::engine::prefix_cache::CacheMode::parse(
        &args.get_str("cache", "on"),
    )?;
    if args.has_flag("stream") {
        if !c.is_v2() {
            bail!("--stream needs --protocol v2");
        }
        return stream_one(&mut c, req);
    }
    let resp = c.call(req)?;
    match resp.error {
        Some(e) => bail!("server error: {e}"),
        None => {
            println!("text:    {:?}", resp.text);
            println!(
                "tokens:  {}  prefill {:.1} ms  decode {:.1} ms  density {:.2}",
                resp.tokens, resp.prefill_ms, resp.decode_ms, resp.density
            );
            if resp.degraded {
                println!(
                    "governor: degraded under load to effective density \
                     {:.2}",
                    resp.effective_density
                );
            }
            if resp.cached_prompt_tokens > 0 {
                println!(
                    "cache:   {} of {} prompt tokens spliced from the \
                     shared-prefix cache",
                    resp.cached_prompt_tokens, resp.prompt_tokens
                );
            }
        }
    }
    Ok(())
}

/// Stream one v2 session, printing deltas as they arrive.
fn stream_one(
    c: &mut Client,
    req: glass::server::protocol::Request,
) -> Result<()> {
    use glass::server::protocol::Event;
    use std::io::Write as _;
    let id = c.generate_stream(req)?;
    loop {
        match c.next_event(id)? {
            Event::Accepted { queue_pos, .. } => {
                println!("accepted (queue position {queue_pos})");
            }
            Event::Queue { position, .. } => {
                println!("waiting (queue position {position})");
            }
            Event::Delta { text, .. } => {
                print!("{text}");
                std::io::stdout().flush().ok();
            }
            Event::Refresh { changed, .. } => {
                if changed {
                    print!("⟲");
                    std::io::stdout().flush().ok();
                }
            }
            Event::Done(resp) => {
                println!();
                println!(
                    "tokens:  {}  prefill {:.1} ms  decode {:.1} ms  \
                     density {:.2}  refreshes {}  finish {}{}",
                    resp.tokens,
                    resp.prefill_ms,
                    resp.decode_ms,
                    resp.density,
                    resp.refreshes,
                    resp.finish,
                    if resp.degraded {
                        format!(
                            "  (degraded to effective density {:.2})",
                            resp.effective_density
                        )
                    } else {
                        String::new()
                    }
                );
                return Ok(());
            }
            Event::Error { error, .. } => {
                println!();
                bail!("server error: {error}");
            }
        }
    }
}

fn profile(cfg: &RunConfig) -> Result<()> {
    let engine = load_engine(cfg)?;
    let prior = GlobalPrior::load(&engine.rt, PriorKind::INps)?;
    let prompts: Vec<String> = glass::harness::lg_prompts(&engine, 8)?;
    glass::util::timer::global().reset();
    for chunk in prompts.chunks(cfg.batch) {
        run_sparse_batch(
            &engine,
            chunk,
            &Strategy::Glass { lambda: cfg.lambda },
            Some(&prior),
            cfg.density,
            cfg.batch,
        )?;
    }
    println!("{}", glass::util::timer::global().report());
    Ok(())
}

pub fn println_header(id: &str) {
    println!("\n================ {} ================", id);
}
