//! Mini-criterion: warmup + timed iterations with mean/p50/p95 reporting
//! (criterion is unavailable offline; `cargo bench` targets use
//! `harness = false` and call into this), plus the CI **regression
//! gate** that compares a bench's JSON document against a checked-in
//! baseline (`check_regression`) so the bench trajectory is enforced
//! per commit, not just recorded.

use std::time::Instant;

use super::json::Json;
use super::stats::{percentile, summarize};
use super::table::Table;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub std_s: f64,
    /// Optional items-per-iteration for throughput reporting.
    pub items_per_iter: f64,
}

impl BenchResult {
    pub fn throughput(&self) -> f64 {
        if self.mean_s > 0.0 {
            self.items_per_iter / self.mean_s
        } else {
            f64::NAN
        }
    }
}

#[derive(Debug, Clone)]
pub struct Bencher {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// Stop adding iterations once this much wall time is spent.
    pub budget_s: f64,
    pub results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 1000,
            budget_s: 3.0,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 50,
            budget_s: 1.0,
            results: Vec::new(),
        }
    }

    /// Benchmark `f`; `items` = logical items per call (tokens, requests).
    pub fn bench<T>(
        &mut self,
        name: &str,
        items: f64,
        mut f: impl FnMut() -> T,
    ) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters
            || (samples.len() < self.max_iters
                && start.elapsed().as_secs_f64() < self.budget_s)
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let s = summarize(&samples);
        let r = BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            mean_s: s.mean,
            p50_s: percentile(&samples, 0.5),
            p95_s: percentile(&samples, 0.95),
            std_s: s.std,
            items_per_iter: items,
        };
        println!(
            "bench {name:40} {:>10}  p50 {:>10}  p95 {:>10}  ({} iters{})",
            fmt_time(r.mean_s),
            fmt_time(r.p50_s),
            fmt_time(r.p95_s),
            r.iters,
            if items > 0.0 {
                format!(", {:.1} items/s", r.throughput())
            } else {
                String::new()
            }
        );
        self.results.push(r);
        self.results.last().unwrap()
    }

    pub fn report(&self) -> String {
        let mut t = Table::new(
            "benchmarks",
            &["name", "mean", "p50", "p95", "iters", "items/s"],
        );
        for r in &self.results {
            t.row(vec![
                r.name.clone(),
                fmt_time(r.mean_s),
                fmt_time(r.p50_s),
                fmt_time(r.p95_s),
                r.iters.to_string(),
                if r.items_per_iter > 0.0 {
                    format!("{:.1}", r.throughput())
                } else {
                    "-".into()
                },
            ]);
        }
        t.to_ascii()
    }
}

// ------------------------------------------------------ regression gate

/// Outcome of checking a bench document against a baseline: every rule
/// that ran (for the operator's log) and every rule that failed (a
/// non-empty list means the gate must exit non-zero).
#[derive(Debug, Clone, Default)]
pub struct GateReport {
    pub checked: Vec<String>,
    pub failures: Vec<String>,
}

impl GateReport {
    pub fn passed(&self) -> bool {
        self.failures.is_empty()
    }
}

/// Baseline keys holding throughputs (items/s): the current run must
/// reach at least `(1 - tol)` of the baseline value. Keys absent from
/// either document are skipped (the gate degrades gracefully when a
/// bundle cannot run a row), so adding rows never breaks old baselines.
const THROUGHPUT_KEYS: &[&str] = &[
    "continuous_toks_per_s",
    "shared_prefix_toks_per_s",
    // v2 streaming over the reactor with many idle connections
    // attached — a regression here means idle connections started
    // costing threads/CPU again, or the event path got slow
    "idle_conns_toks_per_s",
];

/// Baseline keys holding deterministic counters: the current run must
/// be ≥ the baseline (machine-independent). `prefill_tokens_saved_warm`
/// pins the shared-prefix cache's warm-pass savings (losing them means
/// the cache stopped hitting); `prefill_chunks` and
/// `decode_steps_during_prefill` pin the chunked-admission overlap of
/// one deterministic mixed long+short pass (losing them means long
/// prompts stopped streaming, or in-flight decode stalls while they
/// do — the exact head-of-line regressions the continuous batcher
/// exists to prevent).
const FLOOR_KEYS: &[&str] = &[
    "prefill_tokens_saved_warm",
    "prefill_chunks",
    "decode_steps_during_prefill",
    // slow-consumer row: park transitions observed while a stalled
    // reader is throttled — losing them means backpressure stopped
    // engaging (the consumer is either disconnected or buffered
    // without bound instead of parked)
    "backpressure_pauses",
    // warm-restart row: cache hits served from entries imported out of
    // a persisted snapshot — losing them means restart persistence
    // stopped working (snapshot not written, not loaded, or not hit)
    "warm_start_hits",
    // quantized-GEMV rows: dense cpu-q8 FFN decode throughput at
    // LLM-ish dims (conservative floor — machine-dependent but the
    // baseline sits far below any real host), and the measured
    // density-0.3 speedup ratio (machine-INDEPENDENT: both sides of
    // the ratio run on the same host, so a shrinking ratio means the
    // masked GEMV stopped skipping row traffic — THE acceptance
    // observable for GLASS masks turning into real FLOP savings)
    "q8_toks_per_s",
    "q8_sparse_speedup_x",
    // overload-governor row: requests completed inside the fixed wall
    // window of the synthetic 3x-capacity burst WITH the governor on —
    // falling below the floor means tiered degradation / work-stealing
    // stopped buying extra completions under load
    "governed_completed_requests",
];

/// Baseline keys holding latency ceilings (milliseconds): the current
/// run must stay AT OR BELOW the baseline value. Ceilings are absolute
/// and deliberately generous (the mirror image of the conservative
/// throughput floors), so only a real blow-up — a stall, an accidental
/// sleep, a quadratic admission path — trips them on a slow CI host.
const CEILING_KEYS: &[&str] = &[
    "p95_queue_decode_ms",
    // idle-fleet row: poller sweeps per generated token with 256 idle
    // connections attached — a breach means the reactor went back to
    // per-connection polling (wakeups scaling with fleet size instead
    // of with actual events)
    "idle_cpu_sweeps_per_token",
    // radix-index scaling row: p95 of one cache lookup (microseconds)
    // with hundreds of resident entries — a ceiling breach means
    // lookups regressed toward entry-count scans again
    "cache_lookup_us_p95",
    // overload-governor row: p95 queue wait of interactive requests in
    // the governed burst — a breach means degradation stopped shielding
    // the latency-sensitive tier from the batch backlog
    "governed_p95_queue_ms",
];

/// Compare a bench JSON document against a baseline. `tol` is the
/// allowed fractional throughput drop (0.15 = fail below 85% of
/// baseline). Counter floors and latency ceilings are absolute.
pub fn check_regression(
    current: &Json,
    baseline: &Json,
    tol: f64,
) -> GateReport {
    let mut report = GateReport::default();
    let num = |doc: &Json, key: &str| -> Option<f64> {
        doc.get(key).and_then(|v| v.as_f64().ok())
    };
    for &key in THROUGHPUT_KEYS {
        let (Some(cur), Some(base)) =
            (num(current, key), num(baseline, key))
        else {
            continue;
        };
        let floor = base * (1.0 - tol);
        report.checked.push(format!(
            "{key}: {cur:.1} vs baseline {base:.1} (floor {floor:.1})"
        ));
        if cur < floor {
            report.failures.push(format!(
                "{key} regressed: {cur:.1} < {floor:.1} \
                 ({:.0}% below the {base:.1} baseline)",
                (1.0 - cur / base) * 100.0
            ));
        }
    }
    for &key in FLOOR_KEYS {
        let (Some(cur), Some(base)) =
            (num(current, key), num(baseline, key))
        else {
            continue;
        };
        report.checked.push(format!(
            "{key}: {cur:.0} vs baseline floor {base:.0}"
        ));
        if cur < base {
            report.failures.push(format!(
                "{key} fell below its floor: {cur:.0} < baseline \
                 {base:.0}"
            ));
        }
    }
    for &key in CEILING_KEYS {
        let (Some(cur), Some(base)) =
            (num(current, key), num(baseline, key))
        else {
            continue;
        };
        report.checked.push(format!(
            "{key}: {cur:.2} vs baseline ceiling {base:.2}"
        ));
        if cur > base {
            report.failures.push(format!(
                "{key} blew up: {cur:.2} > the {base:.2} ceiling"
            ));
        }
    }
    if report.checked.is_empty() {
        report.failures.push(
            "baseline shares no checkable keys with this run \
             (wrong baseline file?)"
                .to_string(),
        );
    }
    report
}

pub fn fmt_time(s: f64) -> String {
    if s.is_nan() {
        "-".into()
    } else if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut b = Bencher::quick();
        let r = b.bench("noop", 1.0, || 1 + 1);
        assert!(r.iters >= 3);
        assert!(r.mean_s >= 0.0);
        assert!(b.report().contains("noop"));
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2e-9).ends_with("ns"));
        assert!(fmt_time(2e-6).ends_with("µs"));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2.0).ends_with('s'));
    }

    #[test]
    fn throughput() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_s: 0.5,
            p50_s: 0.5,
            p95_s: 0.5,
            std_s: 0.0,
            items_per_iter: 10.0,
        };
        assert!((r.throughput() - 20.0).abs() < 1e-9);
    }

    fn doc(pairs: &[(&str, f64)]) -> Json {
        let mut o = Json::obj();
        for (k, v) in pairs {
            o.set(k, Json::Num(*v));
        }
        o
    }

    #[test]
    fn gate_fails_on_injected_20_percent_regression() {
        // the acceptance demonstration: a 20% decode-throughput drop
        // against the baseline MUST fail the gate at 15% tolerance
        let base = doc(&[
            ("continuous_toks_per_s", 1000.0),
            ("prefill_tokens_saved_warm", 100.0),
        ]);
        let regressed = doc(&[
            ("continuous_toks_per_s", 800.0),
            ("prefill_tokens_saved_warm", 100.0),
        ]);
        let r = check_regression(&regressed, &base, 0.15);
        assert!(!r.passed(), "20% drop must fail: {:?}", r.checked);
        assert_eq!(r.failures.len(), 1);
        assert!(
            r.failures[0].contains("continuous_toks_per_s"),
            "{:?}",
            r.failures
        );
    }

    #[test]
    fn gate_passes_within_tolerance_and_above() {
        let base = doc(&[
            ("continuous_toks_per_s", 1000.0),
            ("shared_prefix_toks_per_s", 500.0),
            ("prefill_tokens_saved_warm", 100.0),
        ]);
        // 10% down, savings equal: inside the 15% band
        let ok = doc(&[
            ("continuous_toks_per_s", 900.0),
            ("shared_prefix_toks_per_s", 460.0),
            ("prefill_tokens_saved_warm", 100.0),
        ]);
        let r = check_regression(&ok, &base, 0.15);
        assert!(r.passed(), "{:?}", r.failures);
        assert_eq!(r.checked.len(), 3, "{:?}", r.checked);
        // faster than baseline is of course fine
        let faster = doc(&[
            ("continuous_toks_per_s", 2000.0),
            ("prefill_tokens_saved_warm", 250.0),
        ]);
        assert!(check_regression(&faster, &base, 0.15).passed());
    }

    #[test]
    fn gate_enforces_idle_conns_streaming_floor() {
        // the v2-reactor row gates like every throughput key: a 20%
        // drop with many idle connections attached must fail
        let base = doc(&[
            ("continuous_toks_per_s", 1000.0),
            ("idle_conns_toks_per_s", 500.0),
        ]);
        let regressed = doc(&[
            ("continuous_toks_per_s", 1000.0),
            ("idle_conns_toks_per_s", 400.0),
        ]);
        let r = check_regression(&regressed, &base, 0.15);
        assert!(!r.passed(), "{:?}", r.checked);
        assert!(
            r.failures[0].contains("idle_conns_toks_per_s"),
            "{:?}",
            r.failures
        );
        let fine = doc(&[
            ("continuous_toks_per_s", 1000.0),
            ("idle_conns_toks_per_s", 480.0),
        ]);
        assert!(check_regression(&fine, &base, 0.15).passed());
    }

    #[test]
    fn gate_fails_when_prefix_cache_savings_are_lost() {
        let base = doc(&[
            ("continuous_toks_per_s", 1000.0),
            ("prefill_tokens_saved_warm", 100.0),
        ]);
        let broken = doc(&[
            ("continuous_toks_per_s", 1000.0),
            ("prefill_tokens_saved_warm", 0.0),
        ]);
        let r = check_regression(&broken, &base, 0.15);
        assert!(!r.passed());
        assert!(
            r.failures[0].contains("prefill_tokens_saved_warm"),
            "{:?}",
            r.failures
        );
    }

    #[test]
    fn gate_fails_on_injected_latency_blowup() {
        // the p95 queue+decode latency is a CEILING: a run that blows
        // past the baseline value must fail even with throughput intact
        let base = doc(&[
            ("continuous_toks_per_s", 1000.0),
            ("p95_queue_decode_ms", 2000.0),
        ]);
        let slow = doc(&[
            ("continuous_toks_per_s", 1000.0),
            ("p95_queue_decode_ms", 7500.0),
        ]);
        let r = check_regression(&slow, &base, 0.15);
        assert!(!r.passed(), "{:?}", r.checked);
        assert_eq!(r.failures.len(), 1);
        assert!(
            r.failures[0].contains("p95_queue_decode_ms"),
            "{:?}",
            r.failures
        );
        // at or below the ceiling passes (boundary included)
        let ok = doc(&[
            ("continuous_toks_per_s", 1000.0),
            ("p95_queue_decode_ms", 2000.0),
        ]);
        assert!(check_regression(&ok, &base, 0.15).passed());
    }

    #[test]
    fn gate_enforces_idle_sweep_ceiling_and_backpressure_floor() {
        // the reactor rows: sweeps-per-token is a CEILING (wakeups must
        // not scale with idle fleet size), park transitions a FLOOR
        // (the slow-consumer run must actually engage backpressure)
        let base = doc(&[
            ("continuous_toks_per_s", 1000.0),
            ("idle_cpu_sweeps_per_token", 25.0),
            ("backpressure_pauses", 1.0),
        ]);
        let sweeping = doc(&[
            ("continuous_toks_per_s", 1000.0),
            ("idle_cpu_sweeps_per_token", 260.0),
            ("backpressure_pauses", 1.0),
        ]);
        let r = check_regression(&sweeping, &base, 0.15);
        assert!(!r.passed(), "{:?}", r.checked);
        assert!(
            r.failures[0].contains("idle_cpu_sweeps_per_token"),
            "{:?}",
            r.failures
        );
        let never_parks = doc(&[
            ("continuous_toks_per_s", 1000.0),
            ("idle_cpu_sweeps_per_token", 10.0),
            ("backpressure_pauses", 0.0),
        ]);
        let r = check_regression(&never_parks, &base, 0.15);
        assert!(!r.passed(), "{:?}", r.checked);
        assert!(
            r.failures[0].contains("backpressure_pauses"),
            "{:?}",
            r.failures
        );
        let fine = doc(&[
            ("continuous_toks_per_s", 1000.0),
            ("idle_cpu_sweeps_per_token", 10.0),
            ("backpressure_pauses", 3.0),
        ]);
        assert!(check_regression(&fine, &base, 0.15).passed());
    }

    #[test]
    fn gate_fails_when_chunked_admission_overlap_is_lost() {
        // losing the overlap counters means long prompts stopped
        // streaming (prefill_chunks) or in-flight decode stalls during
        // a stream (decode_steps_during_prefill) — each fails alone
        let base = doc(&[
            ("continuous_toks_per_s", 1000.0),
            ("prefill_chunks", 3.0),
            ("decode_steps_during_prefill", 1.0),
        ]);
        let no_chunks = doc(&[
            ("continuous_toks_per_s", 1000.0),
            ("prefill_chunks", 0.0),
            ("decode_steps_during_prefill", 1.0),
        ]);
        let r = check_regression(&no_chunks, &base, 0.15);
        assert!(!r.passed());
        assert!(
            r.failures.iter().any(|f| f.contains("prefill_chunks")),
            "{:?}",
            r.failures
        );
        let stalled = doc(&[
            ("continuous_toks_per_s", 1000.0),
            ("prefill_chunks", 3.0),
            ("decode_steps_during_prefill", 0.0),
        ]);
        let r = check_regression(&stalled, &base, 0.15);
        assert!(!r.passed());
        assert!(
            r.failures
                .iter()
                .any(|f| f.contains("decode_steps_during_prefill")),
            "{:?}",
            r.failures
        );
        // more overlap than baseline is of course fine
        let better = doc(&[
            ("continuous_toks_per_s", 1000.0),
            ("prefill_chunks", 16.0),
            ("decode_steps_during_prefill", 12.0),
        ]);
        assert!(check_regression(&better, &base, 0.15).passed());
    }

    #[test]
    fn gate_fails_when_warm_start_hits_are_lost() {
        // the warm-restart floor: a run whose restarted server never
        // serves a snapshot-imported entry must fail
        let base = doc(&[
            ("continuous_toks_per_s", 1000.0),
            ("warm_start_hits", 1.0),
        ]);
        let cold = doc(&[
            ("continuous_toks_per_s", 1000.0),
            ("warm_start_hits", 0.0),
        ]);
        let r = check_regression(&cold, &base, 0.15);
        assert!(!r.passed());
        assert!(
            r.failures[0].contains("warm_start_hits"),
            "{:?}",
            r.failures
        );
        let warm = doc(&[
            ("continuous_toks_per_s", 1000.0),
            ("warm_start_hits", 6.0),
        ]);
        assert!(check_regression(&warm, &base, 0.15).passed());
    }

    #[test]
    fn gate_enforces_q8_sparse_speedup_floor() {
        // the quantized-backend rows: dense throughput floors like any
        // counter, and the density-0.3 speedup ratio is the machine-
        // independent proof that masked rows actually skip work — a
        // run where sparsity stops paying must fail
        let base = doc(&[
            ("continuous_toks_per_s", 1000.0),
            ("q8_toks_per_s", 50.0),
            ("q8_sparse_speedup_x", 1.8),
        ]);
        let no_speedup = doc(&[
            ("continuous_toks_per_s", 1000.0),
            ("q8_toks_per_s", 120.0),
            ("q8_sparse_speedup_x", 1.05),
        ]);
        let r = check_regression(&no_speedup, &base, 0.15);
        assert!(!r.passed(), "{:?}", r.checked);
        assert!(
            r.failures[0].contains("q8_sparse_speedup_x"),
            "{:?}",
            r.failures
        );
        let slow_dense = doc(&[
            ("continuous_toks_per_s", 1000.0),
            ("q8_toks_per_s", 10.0),
            ("q8_sparse_speedup_x", 2.5),
        ]);
        let r = check_regression(&slow_dense, &base, 0.15);
        assert!(!r.passed(), "{:?}", r.checked);
        assert!(
            r.failures[0].contains("q8_toks_per_s"),
            "{:?}",
            r.failures
        );
        let fine = doc(&[
            ("continuous_toks_per_s", 1000.0),
            ("q8_toks_per_s", 80.0),
            ("q8_sparse_speedup_x", 2.4),
        ]);
        assert!(check_regression(&fine, &base, 0.15).passed());
    }

    #[test]
    fn gate_fails_on_cache_lookup_scaling_blowup() {
        // the radix-scaling ceiling: lookup p95 past the baseline with
        // hundreds of resident entries fails even with throughput fine
        let base = doc(&[
            ("continuous_toks_per_s", 1000.0),
            ("cache_lookup_us_p95", 500.0),
        ]);
        let slow = doc(&[
            ("continuous_toks_per_s", 1000.0),
            ("cache_lookup_us_p95", 2000.0),
        ]);
        let r = check_regression(&slow, &base, 0.15);
        assert!(!r.passed(), "{:?}", r.checked);
        assert!(
            r.failures[0].contains("cache_lookup_us_p95"),
            "{:?}",
            r.failures
        );
        // at the ceiling passes (boundary included)
        let ok = doc(&[
            ("continuous_toks_per_s", 1000.0),
            ("cache_lookup_us_p95", 500.0),
        ]);
        assert!(check_regression(&ok, &base, 0.15).passed());
    }

    #[test]
    fn gate_enforces_governor_completion_floor_and_queue_ceiling() {
        // the overload-governor rows: governed completions in the burst
        // window are a FLOOR (degradation + stealing must keep buying
        // throughput under load), interactive p95 queue wait a CEILING
        let base = doc(&[
            ("continuous_toks_per_s", 1000.0),
            ("governed_completed_requests", 24.0),
            ("governed_p95_queue_ms", 4000.0),
        ]);
        let fewer_done = doc(&[
            ("continuous_toks_per_s", 1000.0),
            ("governed_completed_requests", 12.0),
            ("governed_p95_queue_ms", 3000.0),
        ]);
        let r = check_regression(&fewer_done, &base, 0.15);
        assert!(!r.passed(), "{:?}", r.checked);
        assert!(
            r.failures[0].contains("governed_completed_requests"),
            "{:?}",
            r.failures
        );
        let slow_interactive = doc(&[
            ("continuous_toks_per_s", 1000.0),
            ("governed_completed_requests", 30.0),
            ("governed_p95_queue_ms", 9000.0),
        ]);
        let r = check_regression(&slow_interactive, &base, 0.15);
        assert!(!r.passed(), "{:?}", r.checked);
        assert!(
            r.failures[0].contains("governed_p95_queue_ms"),
            "{:?}",
            r.failures
        );
        let fine = doc(&[
            ("continuous_toks_per_s", 1000.0),
            ("governed_completed_requests", 30.0),
            ("governed_p95_queue_ms", 2500.0),
        ]);
        assert!(check_regression(&fine, &base, 0.15).passed());
    }

    #[test]
    fn gate_skips_absent_keys_but_rejects_disjoint_baselines() {
        let base = doc(&[("continuous_toks_per_s", 1000.0)]);
        // current lacks the shared-prefix row (e.g. old bundle): the
        // one shared key still gates
        let cur = doc(&[("continuous_toks_per_s", 990.0)]);
        let r = check_regression(&cur, &base, 0.15);
        assert!(r.passed());
        assert_eq!(r.checked.len(), 1);
        // nothing in common → explicit failure, not a silent pass
        let r =
            check_regression(&doc(&[("x", 1.0)]), &doc(&[("y", 2.0)]), 0.15);
        assert!(!r.passed());
    }
}
