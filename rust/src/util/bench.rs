//! Mini-criterion: warmup + timed iterations with mean/p50/p95 reporting
//! (criterion is unavailable offline; `cargo bench` targets use
//! `harness = false` and call into this).

use std::time::Instant;

use super::stats::{percentile, summarize};
use super::table::Table;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub std_s: f64,
    /// Optional items-per-iteration for throughput reporting.
    pub items_per_iter: f64,
}

impl BenchResult {
    pub fn throughput(&self) -> f64 {
        if self.mean_s > 0.0 {
            self.items_per_iter / self.mean_s
        } else {
            f64::NAN
        }
    }
}

#[derive(Debug, Clone)]
pub struct Bencher {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// Stop adding iterations once this much wall time is spent.
    pub budget_s: f64,
    pub results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 1000,
            budget_s: 3.0,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 50,
            budget_s: 1.0,
            results: Vec::new(),
        }
    }

    /// Benchmark `f`; `items` = logical items per call (tokens, requests).
    pub fn bench<T>(
        &mut self,
        name: &str,
        items: f64,
        mut f: impl FnMut() -> T,
    ) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters
            || (samples.len() < self.max_iters
                && start.elapsed().as_secs_f64() < self.budget_s)
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let s = summarize(&samples);
        let r = BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            mean_s: s.mean,
            p50_s: percentile(&samples, 0.5),
            p95_s: percentile(&samples, 0.95),
            std_s: s.std,
            items_per_iter: items,
        };
        println!(
            "bench {name:40} {:>10}  p50 {:>10}  p95 {:>10}  ({} iters{})",
            fmt_time(r.mean_s),
            fmt_time(r.p50_s),
            fmt_time(r.p95_s),
            r.iters,
            if items > 0.0 {
                format!(", {:.1} items/s", r.throughput())
            } else {
                String::new()
            }
        );
        self.results.push(r);
        self.results.last().unwrap()
    }

    pub fn report(&self) -> String {
        let mut t = Table::new(
            "benchmarks",
            &["name", "mean", "p50", "p95", "iters", "items/s"],
        );
        for r in &self.results {
            t.row(vec![
                r.name.clone(),
                fmt_time(r.mean_s),
                fmt_time(r.p50_s),
                fmt_time(r.p95_s),
                format!("{}", r.iters),
                if r.items_per_iter > 0.0 {
                    format!("{:.1}", r.throughput())
                } else {
                    "-".into()
                },
            ]);
        }
        t.to_ascii()
    }
}

pub fn fmt_time(s: f64) -> String {
    if s.is_nan() {
        "-".into()
    } else if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut b = Bencher::quick();
        let r = b.bench("noop", 1.0, || 1 + 1);
        assert!(r.iters >= 3);
        assert!(r.mean_s >= 0.0);
        assert!(b.report().contains("noop"));
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2e-9).ends_with("ns"));
        assert!(fmt_time(2e-6).ends_with("µs"));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2.0).ends_with('s'));
    }

    #[test]
    fn throughput() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_s: 0.5,
            p50_s: 0.5,
            p95_s: 0.5,
            std_s: 0.0,
            items_per_iter: 10.0,
        };
        assert!((r.throughput() - 20.0).abs() < 1e-9);
    }
}
