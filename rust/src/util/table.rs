//! Markdown/ASCII table builder for experiment reports (EXPERIMENTS.md
//! rows are generated with this so paper-vs-measured tables stay aligned).

#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells);
        self
    }

    /// Fixed-width ASCII rendering for terminal output.
    pub fn to_ascii(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:w$} |", c, w = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    /// GitHub-flavored markdown rendering for EXPERIMENTS.md.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("**{}**\n\n", self.title));
        }
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            "---|".repeat(self.headers.len())
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }
}

/// Format a float with fixed decimals; NaN renders as "-".
pub fn fnum(x: f64, decimals: usize) -> String {
    if x.is_nan() {
        "-".to_string()
    } else {
        format!("{x:.decimals$}")
    }
}

/// "mean (std)" cell in the paper's Tab. 2 style.
pub fn mean_std(mean: f64, std: f64, decimals: usize) -> String {
    format!("{} ({})", fnum(mean, decimals), fnum(std, decimals))
}

/// Signed improvement percentage over a baseline (paper's "Imp%" column):
/// positive = better (lower metric).
pub fn improvement_pct(baseline: f64, ours: f64) -> f64 {
    if baseline == 0.0 {
        return f64::NAN;
    }
    (baseline - ours) / baseline * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_ascii_and_markdown() {
        let mut t = Table::new("demo", &["a", "metric"]);
        t.row(vec!["x".into(), "1.50".into()]);
        let a = t.to_ascii();
        assert!(a.contains("demo"));
        assert!(a.contains("| x"));
        let m = t.to_markdown();
        assert!(m.contains("| a | metric |"));
        assert!(m.contains("| x | 1.50 |"));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn improvement_sign_convention() {
        // lower is better: going 4.0 -> 3.0 is +25%
        assert!((improvement_pct(4.0, 3.0) - 25.0).abs() < 1e-9);
        assert!(improvement_pct(4.0, 5.0) < 0.0);
    }

    #[test]
    fn fnum_nan() {
        assert_eq!(fnum(f64::NAN, 2), "-");
        assert_eq!(fnum(1.23456, 2), "1.23");
    }
}
