//! Descriptive statistics + bootstrap utilities used by the eval harness
//! and the bench framework.

/// Summary of a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
}

impl Summary {
    /// Standard error of the mean (the paper reports mean (sem) in Tab. 2).
    pub fn sem(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.std / (self.n as f64).sqrt()
        }
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile with linear interpolation; q in [0, 1].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (s.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (s[hi] - s[lo]) * (pos - lo as f64)
    }
}

pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary {
            n: 0,
            mean: f64::NAN,
            std: f64::NAN,
            min: f64::NAN,
            max: f64::NAN,
            p50: f64::NAN,
            p95: f64::NAN,
        };
    }
    Summary {
        n: xs.len(),
        mean: mean(xs),
        std: std_dev(xs),
        min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
        max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        p50: percentile(xs, 0.5),
        p95: percentile(xs, 0.95),
    }
}

/// Percentile bootstrap CI for the mean.
pub fn bootstrap_ci(
    xs: &[f64],
    iters: usize,
    alpha: f64,
    seed: u64,
) -> (f64, f64) {
    use super::prng::Prng;
    if xs.is_empty() {
        return (f64::NAN, f64::NAN);
    }
    let mut rng = Prng::new(seed);
    let mut means = Vec::with_capacity(iters);
    for _ in 0..iters {
        let mut s = 0.0;
        for _ in 0..xs.len() {
            s += xs[rng.below(xs.len())];
        }
        means.push(s / xs.len() as f64);
    }
    (
        percentile(&means, alpha / 2.0),
        percentile(&means, 1.0 - alpha / 2.0),
    )
}

/// Welford online accumulator — used by importance/statistics collectors
/// where the token stream is unbounded.
#[derive(Debug, Clone, Default)]
pub struct Online {
    pub n: u64,
    pub mean: f64,
    m2: f64,
}

impl Online {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Pearson correlation.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        dx += (x - mx).powi(2);
        dy += (y - my).powi(2);
    }
    num / (dx.sqrt() * dy.sqrt() + 1e-12)
}

/// Spearman rank correlation (ties broken by index — consistent with the
/// paper's deterministic tie handling).
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    let rx = rank_f64(xs);
    let ry = rank_f64(ys);
    pearson(&rx, &ry)
}

fn rank_f64(xs: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| {
        xs[a].partial_cmp(&xs[b]).unwrap().then(a.cmp(&b))
    });
    let mut ranks = vec![0.0; xs.len()];
    for (r, &i) in idx.iter().enumerate() {
        ranks[i] = r as f64;
    }
    ranks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_summary() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.p50 - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 0.25) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn online_matches_batch() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 3.0).collect();
        let mut o = Online::default();
        for &x in &xs {
            o.push(x);
        }
        assert!((o.mean - mean(&xs)).abs() < 1e-10);
        assert!((o.variance() - variance(&xs)).abs() < 1e-10);
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [2.0, 4.0, 6.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn spearman_monotone_invariance() {
        let xs = [0.1f64, 0.5, 0.9, 2.0];
        let ys: Vec<f64> = xs.iter().map(|x| x.exp()).collect();
        assert!((spearman(&xs, &ys) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn bootstrap_contains_mean() {
        let xs: Vec<f64> = (0..200).map(|i| (i % 7) as f64).collect();
        let (lo, hi) = bootstrap_ci(&xs, 500, 0.05, 1);
        let m = mean(&xs);
        assert!(lo <= m && m <= hi);
        assert!(hi - lo < 1.0);
    }

    #[test]
    fn empty_safe() {
        assert!(summarize(&[]).mean.is_nan());
        assert!(percentile(&[], 0.5).is_nan());
    }
}
