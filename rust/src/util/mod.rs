//! Hand-rolled infrastructure substrates.
//!
//! The offline image ships only the `xla` crate's dependency closure, so
//! the usual ecosystem crates (serde, clap, criterion, proptest, tokio,
//! rand, log) are unavailable. Each substrate here is a small, tested
//! replacement scoped to what this project needs (DESIGN.md §5).

pub mod bench;
pub mod cli;
pub mod json;
pub mod logging;
pub mod prng;
pub mod quickcheck;
pub mod stats;
pub mod table;
pub mod threadpool;
pub mod timer;
