//! Fixed-size worker pool over std channels (tokio is unavailable
//! offline; the serving layer is thread-based — see DESIGN.md §5).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A simple fixed worker pool. Jobs run FIFO; `join` waits for quiescence
/// by dropping the sender and joining workers.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    tx: Option<mpsc::Sender<Job>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("glass-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // all senders dropped
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool {
            workers,
            tx: Some(tx),
        }
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool already joined")
            .send(Box::new(f))
            .expect("worker pool hung up");
    }

    /// Drop the queue and wait for all workers to finish outstanding jobs.
    pub fn join(mut self) {
        self.tx.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run `f(i)` for i in 0..n on up to `width` threads, collecting results
/// in order. Used by harness runners for independent samples.
pub fn parallel_map<T: Send + 'static>(
    n: usize,
    width: usize,
    f: impl Fn(usize) -> T + Send + Sync + 'static,
) -> Vec<T> {
    if n == 0 {
        return Vec::new();
    }
    let f = Arc::new(f);
    let results: Arc<Mutex<Vec<Option<T>>>> =
        Arc::new(Mutex::new((0..n).map(|_| None).collect()));
    let next = Arc::new(Mutex::new(0usize));
    let width = width.max(1).min(n);
    let mut handles = Vec::new();
    for _ in 0..width {
        let f = Arc::clone(&f);
        let results = Arc::clone(&results);
        let next = Arc::clone(&next);
        handles.push(thread::spawn(move || loop {
            let i = {
                let mut g = next.lock().unwrap();
                if *g >= n {
                    break;
                }
                let i = *g;
                *g += 1;
                i
            };
            let r = f(i);
            results.lock().unwrap()[i] = Some(r);
        }));
    }
    for h in handles {
        let _ = h.join();
    }
    Arc::try_unwrap(results)
        .ok()
        .expect("threads done")
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("all indices computed"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_map_in_order() {
        let out = parallel_map(50, 4, |i| i * 2);
        assert_eq!(out, (0..50).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_empty() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }
}
