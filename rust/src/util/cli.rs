//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `glass <subcommand> [--key value]... [--flag]... [positional]...`

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
    /// (name, takes_value) registered specs, for help + validation.
    known: Vec<(String, bool, String)>,
}

impl Args {
    /// Parse from raw argv (excluding program name). `flag_names` lists
    /// options that take NO value; everything else starting with `--`
    /// consumes the next token.
    pub fn parse(argv: &[String], flag_names: &[&str]) -> Result<Args> {
        let mut a = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    a.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&name) {
                    a.flags.push(name.to_string());
                } else {
                    i += 1;
                    let v = argv.get(i).ok_or_else(|| {
                        anyhow!("option --{name} requires a value")
                    })?;
                    a.options.insert(name.to_string(), v.clone());
                }
            } else if a.subcommand.is_none() && a.positional.is_empty() {
                a.subcommand = Some(tok.clone());
            } else {
                a.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(a)
    }

    pub fn from_env(flag_names: &[&str]) -> Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv, flag_names)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name}: invalid integer '{v}': {e}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name}: invalid float '{v}': {e}")),
        }
    }

    /// Comma-separated list of floats, e.g. `--densities 0.9,0.5,0.1`.
    pub fn get_f64_list(&self, name: &str, default: &[f64]) -> Result<Vec<f64>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|x| {
                    x.trim()
                        .parse()
                        .map_err(|e| anyhow!("--{name}: bad float '{x}': {e}"))
                })
                .collect(),
        }
    }

    pub fn get_str_list(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
        }
    }

    pub fn expect_subcommand(&self, allowed: &[&str]) -> Result<&str> {
        match &self.subcommand {
            Some(s) if allowed.contains(&s.as_str()) => Ok(s),
            Some(s) => bail!(
                "unknown subcommand '{s}' (expected one of: {})",
                allowed.join(", ")
            ),
            None => bail!("missing subcommand (one of: {})", allowed.join(", ")),
        }
    }

    pub fn describe(&mut self, name: &str, takes_value: bool, help: &str) {
        self.known.push((name.into(), takes_value, help.into()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = Args::parse(
            &argv("exp table2 --samples 64 --verbose --lambda 0.5"),
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("exp"));
        assert_eq!(a.positional, vec!["table2"]);
        assert_eq!(a.get_usize("samples", 0).unwrap(), 64);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.get_f64("lambda", 0.0).unwrap(), 0.5);
    }

    #[test]
    fn equals_syntax() {
        let a = Args::parse(&argv("run --k=7"), &[]).unwrap();
        assert_eq!(a.get_usize("k", 0).unwrap(), 7);
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&argv("run --samples"), &[]).is_err());
    }

    #[test]
    fn list_parsing() {
        let a = Args::parse(&argv("x --densities 0.9,0.5,0.1"), &[]).unwrap();
        assert_eq!(
            a.get_f64_list("densities", &[]).unwrap(),
            vec![0.9, 0.5, 0.1]
        );
        let d = a.get_f64_list("other", &[1.0]).unwrap();
        assert_eq!(d, vec![1.0]);
    }

    #[test]
    fn subcommand_validation() {
        let a = Args::parse(&argv("bogus"), &[]).unwrap();
        assert!(a.expect_subcommand(&["serve", "exp"]).is_err());
        let b = Args::parse(&argv("serve"), &[]).unwrap();
        assert_eq!(b.expect_subcommand(&["serve", "exp"]).unwrap(), "serve");
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&argv("x"), &[]).unwrap();
        assert_eq!(a.get_str("out", "results"), "results");
        assert_eq!(a.get_usize("n", 5).unwrap(), 5);
    }
}
