//! Deterministic PRNG (SplitMix64 core) — rand crates are unavailable
//! offline, and every experiment must be reproducible from a seed anyway.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes (workload
/// generation, sampling, property-test case generation).
#[derive(Debug, Clone)]
pub struct Prng {
    state: u64,
}

impl Prng {
    pub fn new(seed: u64) -> Self {
        Prng {
            state: seed.wrapping_add(0x9E3779B97F4A7C15),
        }
    }

    /// Derive an independent stream (for per-thread / per-case rngs).
    pub fn fork(&mut self, tag: u64) -> Prng {
        Prng::new(self.next_u64() ^ tag.wrapping_mul(0xA24BAED4963EE407))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi > lo);
        lo + (self.next_u64() % (hi - lo) as u64) as i64
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }

    /// k distinct indices from 0..n.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut p = self.permutation(n);
        p.truncate(k);
        p
    }

    /// Vector of uniform f32 in [lo, hi).
    pub fn f32_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| lo + self.f32() * (hi - lo)).collect()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Prng::new(1).next_u64(), Prng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Prng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_roughly_uniform() {
        let mut r = Prng::new(3);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn permutation_is_valid() {
        let mut r = Prng::new(9);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Prng::new(11);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 20);
    }

    #[test]
    fn normal_moments() {
        let mut r = Prng::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Prng::new(13);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn fork_streams_independent() {
        let mut r = Prng::new(1);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
