//! Minimal JSON parser + writer (serde is unavailable offline).
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes
//! incl. `\uXXXX`, numbers, bools, null). Object key order is preserved —
//! round-tripping a manifest keeps it diffable.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Objects preserve insertion order.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    // ---------------------------------------------------------- access

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that fails with a path-style error message.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow!("missing json key '{key}'"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected non-negative integer, got {f}");
        }
        Ok(f as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        let f = self.as_f64()?;
        if f.fract() != 0.0 {
            bail!("expected integer, got {f}");
        }
        Ok(f as i64)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => bail!("expected array, got {other:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Ok(o),
            other => bail!("expected object, got {other:?}"),
        }
    }

    pub fn usize_list(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn str_list(&self) -> Result<Vec<String>> {
        self.as_arr()?
            .iter()
            .map(|v| Ok(v.as_str()?.to_string()))
            .collect()
    }

    // ------------------------------------------------------ construction

    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(kv) = self {
            if let Some(slot) = kv.iter_mut().find(|(k, _)| k == key) {
                slot.1 = val;
            } else {
                kv.push((key.to_string(), val));
            }
        }
        self
    }

    pub fn from_f64_slice(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn from_f32_slice(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ---------------------------------------------------------- parsing

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("read {}: {e}", path.display()))?;
        Json::parse(&text).map_err(|e| anyhow!("parse {}: {e}", path.display()))
    }

    pub fn write_file(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_string())
            .map_err(|e| anyhow!("write {}: {e}", path.display()))
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of json"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected '{}' at byte {}, found '{}'",
                c as char,
                self.i,
                self.peek()? as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut kv = Vec::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            kv.push((k, v));
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(kv));
                }
                c => bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']', found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let h = std::str::from_utf8(
                                self.b
                                    .get(self.i..self.i + 4)
                                    .ok_or_else(|| anyhow!("bad \\u"))?,
                            )?;
                            self.i += 4;
                            let cp = u32::from_str_radix(h, 16)?;
                            // surrogate pairs
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let h2 = std::str::from_utf8(
                                        &self.b[self.i + 2..self.i + 6],
                                    )?;
                                    self.i += 6;
                                    let lo = u32::from_str_radix(h2, 16)?;
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| anyhow!("bad codepoint"))?);
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                _ => {
                    // copy raw utf-8 bytes
                    let start = self.i - 1;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        let n: f64 = s
            .parse()
            .map_err(|e| anyhow!("bad number '{s}' at byte {start}: {e}"))?;
        Ok(Json::Num(n))
    }
}

// ----------------------------------------------------------------- write

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn fmt_num(n: f64, out: &mut String) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        out.push_str(&(n as i64).to_string());
    } else {
        out.push_str(&n.to_string());
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_value(self, &mut s);
        f.write_str(&s)
    }
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => fmt_num(*n, out),
        Json::Str(s) => escape(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(x, out);
            }
            out.push(']');
        }
        Json::Obj(kv) => {
            out.push('{');
            for (i, (k, x)) in kv.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_value(x, out);
            }
            out.push('}');
        }
    }
}

/// Convenience: map of string->f64 to a Json object.
pub fn num_obj(pairs: &[(&str, f64)]) -> Json {
    Json::Obj(
        pairs
            .iter()
            .map(|(k, v)| (k.to_string(), Json::Num(*v)))
            .collect(),
    )
}

/// Parse an object into a BTreeMap view (utility for configs).
pub fn obj_map(v: &Json) -> Result<BTreeMap<String, Json>> {
    Ok(v.as_obj()?
        .iter()
        .map(|(k, v)| (k.clone(), v.clone()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
    }

    #[test]
    fn parse_unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v, Json::Str("é😀".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"model":{"d":128,"layers":[1,2,3]},"ok":true,"s":"q\"x","f":0.25}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn preserves_key_order() {
        let v = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v
            .as_obj()
            .unwrap()
            .iter()
            .map(|(k, _)| k.as_str())
            .collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" {\n \"a\" :\t[ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("a").unwrap().usize_list().unwrap(), vec![1, 2]);
    }

    #[test]
    fn set_and_req() {
        let mut o = Json::obj();
        o.set("x", Json::Num(5.0)).set("y", Json::Str("s".into()));
        o.set("x", Json::Num(6.0));
        assert_eq!(o.req("x").unwrap().as_usize().unwrap(), 6);
        assert!(o.req("zzz").is_err());
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
