//! Lightweight section profiler for the perf pass (no cargo-flamegraph
//! offline): named accumulators with call counts, reported as a table.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

use super::table::Table;

#[derive(Debug, Default, Clone, Copy)]
struct Acc {
    total_ns: u128,
    count: u64,
}

/// Global named-section profiler. Cheap enough to leave enabled: one
/// mutex lock per section end (the hot loop spends ms per PJRT execute,
/// so lock cost is noise).
#[derive(Default)]
pub struct Profiler {
    accs: Mutex<HashMap<String, Acc>>,
}

static PROFILER: std::sync::OnceLock<Profiler> = std::sync::OnceLock::new();

pub fn global() -> &'static Profiler {
    PROFILER.get_or_init(Profiler::default)
}

impl Profiler {
    pub fn record(&self, name: &str, elapsed_ns: u128) {
        let mut accs = self.accs.lock().unwrap();
        let a = accs.entry(name.to_string()).or_default();
        a.total_ns += elapsed_ns;
        a.count += 1;
    }

    pub fn start(&self, name: &'static str) -> Section<'_> {
        Section {
            profiler: self,
            name,
            t0: Instant::now(),
        }
    }

    pub fn reset(&self) {
        self.accs.lock().unwrap().clear();
    }

    /// (name, total_seconds, count, mean_us) sorted by total desc.
    pub fn snapshot(&self) -> Vec<(String, f64, u64, f64)> {
        let accs = self.accs.lock().unwrap();
        let mut v: Vec<_> = accs
            .iter()
            .map(|(k, a)| {
                (
                    k.clone(),
                    a.total_ns as f64 / 1e9,
                    a.count,
                    if a.count > 0 {
                        a.total_ns as f64 / 1e3 / a.count as f64
                    } else {
                        0.0
                    },
                )
            })
            .collect();
        v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        v
    }

    pub fn report(&self) -> String {
        let mut t = Table::new(
            "profile (by total time)",
            &["section", "total s", "calls", "mean µs"],
        );
        for (name, total, count, mean_us) in self.snapshot() {
            t.row(vec![
                name,
                format!("{total:.3}"),
                count.to_string(),
                format!("{mean_us:.1}"),
            ]);
        }
        t.to_ascii()
    }
}

/// RAII timing section.
pub struct Section<'a> {
    profiler: &'a Profiler,
    name: &'static str,
    t0: Instant,
}

impl Drop for Section<'_> {
    fn drop(&mut self) {
        self.profiler
            .record(self.name, self.t0.elapsed().as_nanos());
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_sections() {
        let p = Profiler::default();
        for _ in 0..3 {
            let _s = p.start("work");
            std::hint::black_box(1 + 1);
        }
        let snap = p.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].2, 3);
        assert!(snap[0].1 >= 0.0);
    }

    #[test]
    fn timed_measures() {
        let (v, s) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }

    #[test]
    fn report_renders() {
        let p = Profiler::default();
        p.record("a", 1000);
        assert!(p.report().contains("a"));
    }
}
