//! Tiny leveled logger (the `log` crate isn't vendored offline).
//!
//! Level comes from `GLASS_LOG` (error|warn|info|debug|trace), default
//! `info`. Messages go to stderr with elapsed-time stamps so harness
//! stdout stays machine-parsable.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static START: OnceLock<Instant> = OnceLock::new();

pub fn init() {
    START.get_or_init(Instant::now);
    if let Ok(v) = std::env::var("GLASS_LOG") {
        set_level(match v.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        });
    }
}

pub fn set_level(l: Level) {
    // Relaxed: the level is an isolated knob — a message racing the
    // store may use the old level once, which is fine for logging
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    // Relaxed: same isolated-knob rationale as set_level
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{t:9.3}s {tag} {module}] {msg}");
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filtering() {
        init();
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }
}
