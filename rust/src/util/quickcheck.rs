//! Mini property-testing framework (proptest is unavailable offline).
//!
//! `forall(cases, seed, gen, prop)` draws `cases` inputs from `gen`,
//! checks `prop`, and on failure performs greedy shrinking via the
//! generator's `shrink` before reporting the minimal counterexample.
//!
//! Used for the GLASS core invariants (ranking/fusion/mask), the memory
//! simulator, and the batching scheduler (DESIGN.md §5).

use super::prng::Prng;

/// A generator produces a value from randomness and can propose smaller
/// variants of a failing value.
pub trait Gen {
    type Value: std::fmt::Debug + Clone;
    fn generate(&self, rng: &mut Prng) -> Self::Value;
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Run the property over `cases` random inputs. Panics with the minimal
/// failing input + seed on violation.
pub fn forall<G: Gen>(
    cases: usize,
    seed: u64,
    gen: &G,
    prop: impl Fn(&G::Value) -> Result<(), String>,
) {
    let mut rng = Prng::new(seed);
    for case in 0..cases {
        let v = gen.generate(&mut rng);
        if let Err(msg) = prop(&v) {
            // shrink greedily
            let mut best = v.clone();
            let mut best_msg = msg;
            let mut improved = true;
            let mut rounds = 0;
            while improved && rounds < 200 {
                improved = false;
                rounds += 1;
                for cand in gen.shrink(&best) {
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        improved = true;
                        break;
                    }
                }
            }
            panic!(
                "property failed (case {case}, seed {seed}):\n  input: \
                 {best:?}\n  error: {best_msg}"
            );
        }
    }
}

/// Assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

// ----------------------------------------------------------- generators

/// Vec<f32> with values in [lo, hi); length in [min_len, max_len].
pub struct F32VecGen {
    pub min_len: usize,
    pub max_len: usize,
    pub lo: f32,
    pub hi: f32,
}

impl Gen for F32VecGen {
    type Value = Vec<f32>;

    fn generate(&self, rng: &mut Prng) -> Vec<f32> {
        let n = self.min_len + rng.below(self.max_len - self.min_len + 1);
        rng.f32_vec(n, self.lo, self.hi)
    }

    fn shrink(&self, v: &Vec<f32>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            out.push(v[..v.len() / 2.max(self.min_len)].to_vec());
            out.push(v[..v.len() - 1].to_vec());
        }
        // zero-out elements
        if let Some(i) = v.iter().position(|&x| x != 0.0) {
            let mut w = v.clone();
            w[i] = 0.0;
            out.push(w);
        }
        out.retain(|w| w.len() >= self.min_len);
        out
    }
}

/// usize in [lo, hi].
pub struct UsizeGen {
    pub lo: usize,
    pub hi: usize,
}

impl Gen for UsizeGen {
    type Value = usize;

    fn generate(&self, rng: &mut Prng) -> usize {
        self.lo + rng.below(self.hi - self.lo + 1)
    }

    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (v - self.lo) / 2);
            out.push(v - 1);
        }
        out.dedup();
        out
    }
}

/// Pair of independent generators.
pub struct PairGen<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for PairGen<A, B> {
    type Value = (A::Value, B::Value);

    fn generate(&self, rng: &mut Prng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

/// A permutation of 0..n with n in [min_n, max_n].
pub struct PermGen {
    pub min_n: usize,
    pub max_n: usize,
}

impl Gen for PermGen {
    type Value = Vec<usize>;

    fn generate(&self, rng: &mut Prng) -> Vec<usize> {
        let n = self.min_n + rng.below(self.max_n - self.min_n + 1);
        rng.permutation(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_true_property() {
        forall(
            100,
            1,
            &F32VecGen {
                min_len: 0,
                max_len: 20,
                lo: -1.0,
                hi: 1.0,
            },
            |v| {
                prop_assert!(
                    v.iter().all(|x| (-1.0..1.0).contains(x)),
                    "out of range"
                );
                Ok(())
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_false_property() {
        forall(100, 2, &UsizeGen { lo: 0, hi: 50 }, |&n| {
            prop_assert!(n < 40, "n={n} too big");
            Ok(())
        });
    }

    #[test]
    fn shrinking_finds_small_counterexample() {
        // capture the panic message and check the shrunk value is minimal
        let result = std::panic::catch_unwind(|| {
            forall(200, 3, &UsizeGen { lo: 0, hi: 1000 }, |&n| {
                prop_assert!(n < 500, "big");
                Ok(())
            });
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().unwrap(),
            Ok(_) => panic!("property should have failed"),
        };
        // greedy shrink reaches a value close to the boundary
        assert!(msg.contains("input: 500"), "{msg}");
    }

    #[test]
    fn perm_gen_valid() {
        forall(50, 4, &PermGen { min_n: 1, max_n: 30 }, |p| {
            let mut seen = vec![false; p.len()];
            for &i in p {
                prop_assert!(i < p.len() && !seen[i], "not a permutation");
                seen[i] = true;
            }
            Ok(())
        });
    }
}
