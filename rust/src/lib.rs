//! # GLASS — Global-Local Aggregation for Inference-time Sparsification
//!
//! Rust (L3) coordinator of the three-layer reproduction of
//! *"GLASS: Global-Local Aggregation for Inference-time Sparsification of
//! LLMs"*: request handling, prefill→mask→decode orchestration, the
//! paper's rank-aggregation mask selection, serving, evaluation harness,
//! and the edge-memory simulator.
//!
//! The compute graphs (L2 JAX) and the sparse-FFN kernel (L1 Pallas) are
//! AOT-compiled to HLO text by `python/compile/aot.py`; [`runtime`]
//! loads and executes them through the PJRT CPU client (`xla` crate).
//! Python never runs on the request path.
//!
//! Module map (see DESIGN.md §5 for the full inventory):
//!
//! * [`util`]    — hand-rolled substrates (JSON, CLI, PRNG, stats,
//!   logging, tables, bench + property-test harnesses, thread pool)
//! * [`config`]  — typed run configuration + TOML-subset parser
//! * [`tensor`]  — host tensors and numeric helpers
//! * [`runtime`] — PJRT client, artifact manifest, executables
//! * [`model`]   — model metadata, weights, tokenizer, samplers
//! * [`glass`]   — the paper's core: ranking, fusion, importance, masks,
//!   selection strategies (GLASS + all baselines)
//! * [`engine`]  — prefill/decode/score/generate sessions and batching
//! * [`eval`]    — PPL / top-100 KLD / Jaccard / ROUGE / F1-EM / accuracy
//! * [`data`]    — benchmark-set loaders
//! * [`nps`]     — Null-Prompt Stimulation driver over the runtime
//! * [`memsim`]  — edge-device memory-hierarchy simulator (Fig. 5)
//! * [`server`]  — threaded serving layer with a JSON-line protocol
//! * [`harness`] — one runner per paper table/figure

// An `unsafe fn` body gets no implicit unsafe scope: every unsafe
// operation must sit in its own `unsafe {}` block next to the
// `// SAFETY:` comment glass-lint requires for it.
#![deny(unsafe_op_in_unsafe_fn)]
// Public API docs are part of the serving contract. Modules that
// predate the doc sweep opt out individually below; the serving layer
// ([`server`]) is fully documented and stays that way.
#![warn(missing_docs)]

#[allow(missing_docs)] // pre-doc-sweep module
pub mod config;
#[allow(missing_docs)] // pre-doc-sweep module
pub mod data;
#[allow(missing_docs)] // pre-doc-sweep module
pub mod engine;
#[allow(missing_docs)] // pre-doc-sweep module
pub mod eval;
#[allow(missing_docs)] // pre-doc-sweep module
pub mod glass;
#[allow(missing_docs)] // pre-doc-sweep module
pub mod harness;
#[allow(missing_docs)] // pre-doc-sweep module
pub mod memsim;
#[allow(missing_docs)] // pre-doc-sweep module
pub mod model;
#[allow(missing_docs)] // pre-doc-sweep module
pub mod nps;
#[allow(missing_docs)] // pre-doc-sweep module
pub mod runtime;
pub mod server;
#[allow(missing_docs)] // pre-doc-sweep module
pub mod tensor;
#[allow(missing_docs)] // pre-doc-sweep module
pub mod util;

pub use anyhow::{anyhow, bail, Context, Result};
