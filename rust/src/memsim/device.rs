//! Edge-device profiles for the memory simulator.
//!
//! Numbers are order-of-magnitude public specs for a 2025 flagship phone
//! class (the paper's testbed is a Samsung Galaxy S25 Ultra, 12 GB RAM):
//! LPDDR5X-class RAM bandwidth, UFS-4-class flash read bandwidth, and an
//! NPU/CPU mix for int/bf16 GEMV. The simulator's claims are about the
//! *mechanism* (residency vs paging), which is insensitive to ±2× on any
//! of these constants — see the sensitivity bench in bench_memsim.

/// A two-level (RAM + flash) edge device.
#[derive(Debug, Clone)]
pub struct DeviceProfile {
    pub name: String,
    /// RAM available to model weights (OS + KV + activations carved out).
    pub ram_budget_bytes: usize,
    /// Sustained RAM bandwidth (bytes/s).
    pub ram_bw_bytes_s: f64,
    /// Sustained flash read bandwidth (bytes/s).
    pub flash_bw_bytes_s: f64,
    /// Per-access flash latency (s) paid once per token when paging.
    pub flash_latency_s: f64,
    /// Sustained GEMV compute (FLOPs/s).
    pub compute_flops_s: f64,
}

impl DeviceProfile {
    /// 12 GB flagship phone (the paper's testbed class). ~11.5 GB of RAM
    /// usable for weights after OS/runtime/KV overhead — tight enough
    /// that dense Gemma-7B bf16 (~17 GB) pages from flash while the 50%
    /// FFN-masked model fits, exactly the paper's §4.5 situation.
    pub fn galaxy_s25_ultra() -> DeviceProfile {
        DeviceProfile {
            name: "galaxy-s25-ultra".into(),
            ram_budget_bytes: 11_500_000_000,
            ram_bw_bytes_s: 60e9,
            flash_bw_bytes_s: 3.5e9,
            flash_latency_s: 150e-6,
            compute_flops_s: 2.0e12,
        }
    }

    /// 8 GB mid-range phone — tighter RAM, slower flash.
    pub fn midrange_8gb() -> DeviceProfile {
        DeviceProfile {
            name: "midrange-8gb".into(),
            ram_budget_bytes: 5_500_000_000,
            ram_bw_bytes_s: 30e9,
            flash_bw_bytes_s: 1.5e9,
            flash_latency_s: 250e-6,
            compute_flops_s: 0.8e12,
        }
    }

    /// Raspberry-Pi-class SBC: very tight RAM, SD-card flash.
    pub fn sbc_4gb() -> DeviceProfile {
        DeviceProfile {
            name: "sbc-4gb".into(),
            ram_budget_bytes: 3_000_000_000,
            ram_bw_bytes_s: 8e9,
            flash_bw_bytes_s: 0.15e9,
            flash_latency_s: 500e-6,
            compute_flops_s: 0.1e12,
        }
    }

    pub fn all() -> Vec<DeviceProfile> {
        vec![
            DeviceProfile::galaxy_s25_ultra(),
            DeviceProfile::midrange_8gb(),
            DeviceProfile::sbc_4gb(),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_well_formed() {
        for d in DeviceProfile::all() {
            assert!(d.ram_budget_bytes > 0);
            assert!(d.ram_bw_bytes_s > d.flash_bw_bytes_s);
            assert!(d.flash_latency_s > 0.0);
            assert!(d.compute_flops_s > 0.0);
        }
    }
}
