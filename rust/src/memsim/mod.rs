//! Edge-device memory-hierarchy simulator (Fig. 5 / §4.5 substrate).
//!
//! The paper's on-device numbers come from a Samsung Galaxy S25 Ultra
//! running LiteRT; we have no phone, so we build the mechanism instead
//! (DESIGN.md §3): a decode-time cost model over a two-level memory
//! hierarchy with an LRU-resident weight set.
//!
//! Per decode token, each layer's weights must be streamed to the compute
//! units from RAM; weights not resident in RAM must first be paged from
//! flash. GLASS's static 50% FFN mask shrinks the resident set — when
//! that makes the model fit in RAM, per-step flash I/O disappears and the
//! speedup is an order of magnitude (the paper's Gemma-7B ~11× case);
//! when the dense model already fits, the gain is just the reduced
//! compute/bandwidth (the 20–42% Qwen/Llama cases).

pub mod device;

pub use device::DeviceProfile;

use crate::model::WeightFootprint;

// ------------------------------------------------ cache byte accounting
//
// The serving layer's shared-prefix cache (engine::prefix_cache) budgets
// itself in bytes; the conversion from cached artifacts to bytes lives
// here so the cost model and the cache agree on what "resident" means.

/// Bytes of one cached f32 KV prefix (K and V planes) of `len` positions:
/// 2 · L · H · len · Dh · 4.
pub fn kv_prefix_bytes(
    n_layers: usize,
    n_heads: usize,
    head_dim: usize,
    len: usize,
) -> usize {
    2 * n_layers * n_heads * len * head_dim * std::mem::size_of::<f32>()
}

/// Bytes of one merged importance map ([L][m] of f32).
pub fn stats_map_bytes(n_layers: usize, m: usize) -> usize {
    n_layers * m * std::mem::size_of::<f32>()
}

/// Bytes of one cached last-position logits row ([vocab] of f32).
pub fn logits_bytes(vocab: usize) -> usize {
    vocab * std::mem::size_of::<f32>()
}

/// Bytes of the token-id key of a cached prefix ([len] of i32).
pub fn token_ids_bytes(len: usize) -> usize {
    len * std::mem::size_of::<i32>()
}

/// Total resident bytes of one shared-prefix cache entry of `len`
/// tokens: KV rows + merged stats + logits + token-id key. The cache's
/// byte budget AND the snapshot store's size validation both use this,
/// so "resident" means the same thing in memory and on disk.
pub fn prefix_entry_bytes(
    n_layers: usize,
    n_heads: usize,
    head_dim: usize,
    ffn_m: usize,
    vocab: usize,
    len: usize,
) -> usize {
    kv_prefix_bytes(n_layers, n_heads, head_dim, len)
        + stats_map_bytes(n_layers, ffn_m)
        + logits_bytes(vocab)
        + token_ids_bytes(len)
}

/// A simulated model workload (footprint + per-token compute).
#[derive(Debug, Clone)]
pub struct SimModel {
    pub name: String,
    pub footprint: WeightFootprint,
    /// FLOPs per decoded token at density 1.0.
    pub flops_per_token: f64,
}

impl SimModel {
    /// Paper-scale workloads (bytes from param count × bytes/param).
    pub fn paper_workload(
        name: &str,
        params_b: f64,
        bytes_per_param: f64,
        ffn_fraction: f64,
    ) -> SimModel {
        let total = (params_b * 1e9 * bytes_per_param) as usize;
        let ffn = (total as f64 * ffn_fraction) as usize;
        // split the non-FFN remainder so the components sum EXACTLY to
        // total_bytes — integer halving both sides loses a byte on odd
        // remainders, which breaks footprint-conservation invariants
        let attn = (total - ffn) / 2;
        let embed = total - ffn - attn;
        SimModel {
            name: name.to_string(),
            footprint: WeightFootprint {
                total_bytes: total,
                ffn_bytes: ffn,
                attn_bytes: attn,
                embed_bytes: embed,
                other_bytes: 0,
            },
            // ~2 FLOPs per weight per token
            flops_per_token: 2.0 * params_b * 1e9,
        }
    }
}

/// Result of simulating a decode phase.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimResult {
    pub tokens: usize,
    pub total_s: f64,
    pub tokens_per_s: f64,
    /// Seconds spent paging from flash.
    pub paging_s: f64,
    /// Seconds bounded by RAM weight streaming.
    pub stream_s: f64,
    /// Seconds bounded by compute.
    pub compute_s: f64,
    /// Whether the working set fits in RAM.
    pub resident: bool,
}

/// Simulate decoding `tokens` tokens at the given FFN density.
///
/// Model: per token, the kept weights (resident working set W) must be
/// read once from RAM (streaming bound W/ram_bw) while the ALUs execute
/// flops/compute. If W exceeds the RAM budget, the overflow must be paged
/// from flash **every token** (the OS evicts it between steps — the
/// paper's "repeated I/O" regime); each step also pays the flash access
/// latency. Per-token time = max(stream, compute) + paging.
pub fn simulate_decode(
    dev: &DeviceProfile,
    model: &SimModel,
    ffn_density: f64,
    tokens: usize,
) -> SimResult {
    let working_set = model.footprint.resident_bytes(ffn_density) as f64;
    let ram_budget = dev.ram_budget_bytes as f64;
    let fits = working_set <= ram_budget;
    let overflow = (working_set - ram_budget).max(0.0);

    // effective FLOPs scale with kept weights (paper's compute saving)
    let kept_frac = working_set / model.footprint.total_bytes as f64;
    let flops = model.flops_per_token * kept_frac;

    let stream = working_set / dev.ram_bw_bytes_s;
    let compute = flops / dev.compute_flops_s;
    let paging = if fits {
        0.0
    } else {
        overflow / dev.flash_bw_bytes_s + dev.flash_latency_s
    };
    let per_token = stream.max(compute) + paging;
    let total = per_token * tokens as f64;
    SimResult {
        tokens,
        total_s: total,
        tokens_per_s: tokens as f64 / total,
        paging_s: paging * tokens as f64,
        stream_s: stream * tokens as f64,
        compute_s: compute * tokens as f64,
        resident: fits,
    }
}

/// Speedup of the sparse configuration over dense on the same device.
pub fn decode_speedup(
    dev: &DeviceProfile,
    model: &SimModel,
    sparse_density: f64,
    tokens: usize,
) -> (SimResult, SimResult, f64) {
    let dense = simulate_decode(dev, model, 1.0, tokens);
    let sparse = simulate_decode(dev, model, sparse_density, tokens);
    let speedup = dense.total_s / sparse.total_s;
    (dense, sparse, speedup)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prng::Prng;
    use crate::util::quickcheck::{forall, UsizeGen};

    fn phone() -> DeviceProfile {
        DeviceProfile::galaxy_s25_ultra()
    }

    #[test]
    fn paper_workload_components_sum_to_total() {
        // fixed paper workloads plus randomized shapes; odd (total - ffn)
        // remainders used to lose one byte to integer halving
        let fixed = [
            SimModel::paper_workload("gemma7b-bf16", 8.5, 2.0, 0.66),
            SimModel::paper_workload("qwen3-4b-int4", 4.0, 0.5, 0.66),
            SimModel::paper_workload("llama8b-int8", 8.0, 1.0, 0.7),
        ];
        for m in &fixed {
            let f = &m.footprint;
            assert_eq!(
                f.ffn_bytes + f.attn_bytes + f.embed_bytes + f.other_bytes,
                f.total_bytes,
                "{}: components must sum exactly",
                m.name
            );
        }
        forall(200, 73, &UsizeGen { lo: 1, hi: 10_000 }, |&seed| {
            let mut rng = Prng::new(seed as u64);
            let m = SimModel::paper_workload(
                "m",
                0.1 + rng.f64() * 15.0,
                0.25 + rng.f64() * 3.75,
                0.3 + rng.f64() * 0.6,
            );
            let f = &m.footprint;
            prop_assert!(
                f.ffn_bytes + f.attn_bytes + f.embed_bytes + f.other_bytes
                    == f.total_bytes,
                "{} + {} + {} != {}",
                f.ffn_bytes,
                f.attn_bytes,
                f.embed_bytes,
                f.total_bytes
            );
            Ok(())
        });
    }

    #[test]
    fn cache_byte_accounting_scales_linearly() {
        // K+V, 4 layers, 2 heads, 8-wide heads, 10 positions, f32
        assert_eq!(kv_prefix_bytes(4, 2, 8, 10), 2 * 4 * 2 * 10 * 8 * 4);
        assert_eq!(kv_prefix_bytes(4, 2, 8, 0), 0);
        assert_eq!(stats_map_bytes(4, 32), 4 * 32 * 4);
        assert_eq!(logits_bytes(260), 260 * 4);
        assert_eq!(token_ids_bytes(7), 7 * 4);
        // doubling the prefix doubles only the KV term
        assert_eq!(
            kv_prefix_bytes(4, 2, 8, 20),
            2 * kv_prefix_bytes(4, 2, 8, 10)
        );
        // the entry total is exactly the sum of its four components
        assert_eq!(
            prefix_entry_bytes(4, 2, 8, 32, 260, 10),
            kv_prefix_bytes(4, 2, 8, 10)
                + stats_map_bytes(4, 32)
                + logits_bytes(260)
                + token_ids_bytes(10)
        );
    }

    #[test]
    fn fits_vs_not_fits() {
        let dev = phone();
        // bf16 7B ≈ 14-17 GB > 12 GB budget
        let gemma = SimModel::paper_workload("gemma7b-bf16", 8.5, 2.0, 0.66);
        let dense = simulate_decode(&dev, &gemma, 1.0, 64);
        assert!(!dense.resident);
        assert!(dense.paging_s > 0.0);
        let sparse = simulate_decode(&dev, &gemma, 0.5, 64);
        assert!(sparse.resident);
        assert_eq!(sparse.paging_s, 0.0);
    }

    #[test]
    fn residency_transition_gives_order_of_magnitude() {
        let dev = phone();
        let gemma = SimModel::paper_workload("gemma7b-bf16", 8.5, 2.0, 0.66);
        let (_, _, speedup) = decode_speedup(&dev, &gemma, 0.5, 64);
        assert!(
            speedup > 5.0,
            "expected residency-driven speedup >5x, got {speedup:.1}"
        );
    }

    #[test]
    fn compute_bound_regime_modest_speedup() {
        let dev = phone();
        // int4 4B ≈ 2 GB, fits easily
        let qwen = SimModel::paper_workload("qwen3-4b-int4", 4.0, 0.5, 0.66);
        let (_, _, speedup) = decode_speedup(&dev, &qwen, 0.5, 256);
        assert!(
            speedup > 1.05 && speedup < 2.5,
            "expected modest speedup, got {speedup:.2}"
        );
    }

    #[test]
    fn prop_speedup_monotone_in_sparsity() {
        // keeping fewer neurons never slows decoding in this cost model
        forall(100, 71, &UsizeGen { lo: 1, hi: 9 }, |&d10| {
            let mut rng = Prng::new(d10 as u64 * 31);
            let dev = phone();
            let model = SimModel::paper_workload(
                "m",
                1.0 + rng.f64() * 12.0,
                if rng.bool(0.5) { 2.0 } else { 0.5 },
                0.5 + rng.f64() * 0.3,
            );
            let lo = simulate_decode(&dev, &model, d10 as f64 / 10.0, 32);
            let hi = simulate_decode(
                &dev,
                &model,
                (d10 as f64 + 1.0) / 10.0,
                32,
            );
            prop_assert!(
                lo.total_s <= hi.total_s + 1e-12,
                "sparser was slower: {} vs {}",
                lo.total_s,
                hi.total_s
            );
            Ok(())
        });
    }

    #[test]
    fn prop_times_positive_and_consistent() {
        forall(100, 72, &UsizeGen { lo: 1, hi: 10 }, |&d10| {
            let dev = phone();
            let model =
                SimModel::paper_workload("m", d10 as f64, 2.0, 0.66);
            let r = simulate_decode(&dev, &model, 0.5, 128);
            prop_assert!(r.total_s > 0.0, "non-positive time");
            prop_assert!(
                r.tokens_per_s > 0.0 && r.tokens_per_s.is_finite(),
                "bad throughput"
            );
            prop_assert!(
                r.total_s + 1e-12
                    >= r.paging_s.max(r.stream_s).max(r.compute_s)
                        / r.tokens as f64,
                "component exceeds total"
            );
            Ok(())
        });
    }
}
