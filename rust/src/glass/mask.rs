//! FFN masks: per-layer critical-neuron sets and their tensor encodings
//! (Sec. 2.2 — "a 1D binary mask of size m for each FFN layer").

use anyhow::{bail, Result};

use crate::tensor::{TensorF, TensorI};

/// A static per-layer FFN mask for one request.
#[derive(Debug, Clone, PartialEq)]
pub struct MaskSet {
    /// Selected (kept) neuron ids per layer, each sorted ascending.
    pub layers: Vec<Vec<usize>>,
    /// FFN width m.
    pub m: usize,
}

impl MaskSet {
    pub fn dense(n_layers: usize, m: usize) -> Self {
        MaskSet {
            layers: vec![(0..m).collect(); n_layers],
            m,
        }
    }

    pub fn from_indices(layers: Vec<Vec<usize>>, m: usize) -> Result<Self> {
        for (li, l) in layers.iter().enumerate() {
            if l.windows(2).any(|w| w[0] >= w[1]) {
                bail!("layer {li}: indices must be sorted unique");
            }
            if l.iter().any(|&j| j >= m) {
                bail!("layer {li}: index out of range");
            }
        }
        Ok(MaskSet { layers, m })
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Fraction of neurons kept, averaged over layers.
    pub fn density(&self) -> f64 {
        if self.layers.is_empty() {
            return 0.0;
        }
        self.layers
            .iter()
            .map(|l| l.len() as f64 / self.m as f64)
            .sum::<f64>()
            / self.layers.len() as f64
    }

    /// 0/1 mask values for one layer.
    pub fn layer_mask(&self, layer: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; self.m];
        for &j in &self.layers[layer] {
            v[j] = 1.0;
        }
        v
    }

    /// Jaccard similarity of the kept sets at `layer` (App. C.1).
    pub fn jaccard_layer(&self, other: &MaskSet, layer: usize) -> f64 {
        jaccard(&self.layers[layer], &other.layers[layer])
    }

    /// Mean Jaccard across layers.
    pub fn jaccard_mean(&self, other: &MaskSet) -> f64 {
        assert_eq!(self.n_layers(), other.n_layers());
        (0..self.n_layers())
            .map(|l| self.jaccard_layer(other, l))
            .sum::<f64>()
            / self.n_layers() as f64
    }
}

/// Jaccard of two sorted index sets.
pub fn jaccard(a: &[usize], b: &[usize]) -> f64 {
    if a.is_empty() && b.is_empty() {
        return 1.0;
    }
    let mut i = 0;
    let mut j = 0;
    let mut inter = 0usize;
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Equal => {
                inter += 1;
                i += 1;
                j += 1;
            }
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
        }
    }
    let union = a.len() + b.len() - inter;
    inter as f64 / union as f64
}

/// Pack per-slot masks into the [B, L, m] f32 tensor the masked
/// executables take. `slots` may contain None (inactive batch slots →
/// dense ones, harmless).
pub fn pack_masks(
    slots: &[Option<&MaskSet>],
    n_layers: usize,
    m: usize,
) -> TensorF {
    let b = slots.len();
    let mut data = vec![1.0f32; b * n_layers * m];
    for (bi, slot) in slots.iter().enumerate() {
        if let Some(mask) = slot {
            assert_eq!(mask.n_layers(), n_layers);
            assert_eq!(mask.m, m);
            for li in 0..n_layers {
                let base = (bi * n_layers + li) * m;
                data[base..base + m].copy_from_slice(&mask.layer_mask(li));
            }
        }
    }
    TensorF::new(vec![b, n_layers, m], data).expect("pack_masks shape")
}

/// Pack per-slot top-k index sets into the [B, L, K] i32 tensor the
/// gathered (Pallas) executables take. Every layer must have exactly K
/// kept neurons.
pub fn pack_indices(
    slots: &[&MaskSet],
    n_layers: usize,
    k: usize,
) -> Result<TensorI> {
    let b = slots.len();
    let mut data = vec![0i32; b * n_layers * k];
    for (bi, mask) in slots.iter().enumerate() {
        if mask.n_layers() != n_layers {
            bail!("slot {bi}: layer count mismatch");
        }
        for li in 0..n_layers {
            let ids = &mask.layers[li];
            if ids.len() != k {
                bail!(
                    "slot {bi} layer {li}: need exactly k={k} ids, got {}",
                    ids.len()
                );
            }
            let base = (bi * n_layers + li) * k;
            for (x, &j) in data[base..base + k].iter_mut().zip(ids) {
                *x = j as i32;
            }
        }
    }
    TensorI::new(vec![b, n_layers, k], data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prng::Prng;
    use crate::util::quickcheck::{forall, UsizeGen};

    #[test]
    fn dense_mask_full_density() {
        let m = MaskSet::dense(3, 8);
        assert_eq!(m.density(), 1.0);
        assert_eq!(m.layer_mask(0), vec![1.0; 8]);
    }

    #[test]
    fn from_indices_validates() {
        assert!(MaskSet::from_indices(vec![vec![0, 2, 1]], 4).is_err());
        assert!(MaskSet::from_indices(vec![vec![0, 4]], 4).is_err());
        let m = MaskSet::from_indices(vec![vec![1, 3]], 4).unwrap();
        assert_eq!(m.layer_mask(0), vec![0.0, 1.0, 0.0, 1.0]);
        assert_eq!(m.density(), 0.5);
    }

    #[test]
    fn jaccard_cases() {
        assert_eq!(jaccard(&[0, 1], &[0, 1]), 1.0);
        assert_eq!(jaccard(&[0, 1], &[2, 3]), 0.0);
        assert!((jaccard(&[0, 1, 2], &[1, 2, 3]) - 0.5).abs() < 1e-12);
        assert_eq!(jaccard(&[], &[]), 1.0);
    }

    #[test]
    fn pack_masks_layout() {
        let m1 = MaskSet::from_indices(vec![vec![0], vec![1]], 2).unwrap();
        let t = pack_masks(&[Some(&m1), None], 2, 2);
        assert_eq!(t.shape, vec![2, 2, 2]);
        // slot 0: layer0 [1,0], layer1 [0,1]; slot 1: all ones
        assert_eq!(t.data, vec![1., 0., 0., 1., 1., 1., 1., 1.]);
    }

    #[test]
    fn pack_indices_layout_and_validation() {
        let m1 =
            MaskSet::from_indices(vec![vec![1, 3], vec![0, 2]], 4).unwrap();
        let t = pack_indices(&[&m1], 2, 2).unwrap();
        assert_eq!(t.shape, vec![1, 2, 2]);
        assert_eq!(t.data, vec![1, 3, 0, 2]);
        assert!(pack_indices(&[&m1], 2, 3).is_err());
    }

    #[test]
    fn prop_jaccard_bounds_and_symmetry() {
        forall(200, 31, &UsizeGen { lo: 1, hi: 64 }, |&m| {
            let mut rng = Prng::new(m as u64 * 7 + 3);
            let k = 1 + rng.below(m);
            let mut a = rng.sample_indices(m, k);
            let mut b = rng.sample_indices(m, k);
            a.sort_unstable();
            b.sort_unstable();
            let jab = jaccard(&a, &b);
            let jba = jaccard(&b, &a);
            prop_assert!((0.0..=1.0).contains(&jab), "out of bounds");
            prop_assert!((jab - jba).abs() < 1e-12, "asymmetric");
            prop_assert!(
                (jaccard(&a, &a) - 1.0).abs() < 1e-12,
                "self-jaccard != 1"
            );
            Ok(())
        });
    }

    #[test]
    fn prop_pack_masks_density_consistent() {
        forall(100, 32, &UsizeGen { lo: 1, hi: 32 }, |&m| {
            let mut rng = Prng::new(m as u64 + 17);
            let k = 1 + rng.below(m);
            let mut ids = rng.sample_indices(m, k);
            ids.sort_unstable();
            let mask =
                MaskSet::from_indices(vec![ids.clone(), ids], m).unwrap();
            let t = pack_masks(&[Some(&mask)], 2, m);
            let ones = t.data.iter().filter(|&&x| x == 1.0).count();
            prop_assert!(ones == 2 * k, "mask ones {ones} != 2k");
            prop_assert!(
                (mask.density() - k as f64 / m as f64).abs() < 1e-12,
                "density mismatch"
            );
            Ok(())
        });
    }
}
