//! Rank conversion (Sec. 3.4): importance scores → rank space.
//!
//! `rank_ascending` assigns rank 1 to the smallest score and rank m to the
//! largest, with **stable deterministic tie-breaking by neuron index**
//! (paper footnote 3 / App. A): among equal scores, the lower index gets
//! the lower rank. This makes mask selection reproducible bit-for-bit.

/// Rank vector r where r[j] ∈ {1..m} is the rank of neuron j
/// (1 = least important). Ties broken by index (lower index → lower rank).
pub fn rank_ascending(scores: &[f32]) -> Vec<usize> {
    let m = scores.len();
    let mut order: Vec<usize> = (0..m).collect();
    // unstable sort is safe: the index tie-break makes the comparator a
    // total order, so the result is fully deterministic (and ~2x faster
    // at paper-scale m — EXPERIMENTS.md §Perf iteration 7)
    order.sort_unstable_by(|&a, &b| {
        scores[a]
            .partial_cmp(&scores[b])
            .expect("NaN importance score")
            .then(a.cmp(&b))
    });
    let mut ranks = vec![0usize; m];
    for (pos, &j) in order.iter().enumerate() {
        ranks[j] = pos + 1; // 1-based, paper convention
    }
    ranks
}

/// The permutation π (neurons ordered least→most important) induced by
/// sorting scores ascending with the same tie rule.
pub fn permutation_ascending(scores: &[f32]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..scores.len()).collect();
    order.sort_by(|&a, &b| {
        scores[a]
            .partial_cmp(&scores[b])
            .expect("NaN importance score")
            .then(a.cmp(&b))
    });
    order
}

/// Rank vector of a permutation: r[π[pos]] = pos + 1.
pub fn rank_of_permutation(perm: &[usize]) -> Vec<usize> {
    let mut r = vec![0usize; perm.len()];
    for (pos, &j) in perm.iter().enumerate() {
        r[j] = pos + 1;
    }
    r
}

/// Squared Spearman rank distance ‖r(σ1) − r(σ2)‖² (App. A) — the Mallows
/// model's distance; used by tests to verify the MAP theorem numerically.
pub fn spearman_sq_distance(r1: &[usize], r2: &[usize]) -> f64 {
    assert_eq!(r1.len(), r2.len());
    r1.iter()
        .zip(r2)
        .map(|(&a, &b)| {
            let d = a as f64 - b as f64;
            d * d
        })
        .sum()
}

/// Check that `r` is a valid rank vector (a permutation of 1..m).
pub fn is_valid_rank_vector(r: &[usize]) -> bool {
    let m = r.len();
    let mut seen = vec![false; m + 1];
    for &x in r {
        if x == 0 || x > m || seen[x] {
            return false;
        }
        seen[x] = true;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::quickcheck::{forall, F32VecGen};

    #[test]
    fn simple_ranks() {
        // scores: idx0=0.3 idx1=0.1 idx2=0.9 -> ranks 2,1,3
        assert_eq!(rank_ascending(&[0.3, 0.1, 0.9]), vec![2, 1, 3]);
    }

    #[test]
    fn ties_break_by_index() {
        // equal scores: lower index gets lower rank
        assert_eq!(rank_ascending(&[0.5, 0.5, 0.1]), vec![2, 3, 1]);
    }

    #[test]
    fn rank_of_permutation_inverse() {
        let perm = vec![2, 0, 1]; // neuron 2 least important
        let r = rank_of_permutation(&perm);
        assert_eq!(r, vec![2, 3, 1]);
    }

    #[test]
    fn prop_rank_is_permutation() {
        forall(
            300,
            11,
            &F32VecGen {
                min_len: 1,
                max_len: 64,
                lo: -2.0,
                hi: 2.0,
            },
            |scores| {
                let r = rank_ascending(scores);
                prop_assert!(
                    is_valid_rank_vector(&r),
                    "not a rank vector: {r:?}"
                );
                Ok(())
            },
        );
    }

    #[test]
    fn prop_rank_respects_order() {
        forall(
            200,
            12,
            &F32VecGen {
                min_len: 2,
                max_len: 32,
                lo: -1.0,
                hi: 1.0,
            },
            |scores| {
                let r = rank_ascending(scores);
                for i in 0..scores.len() {
                    for j in 0..scores.len() {
                        if scores[i] < scores[j] {
                            prop_assert!(
                                r[i] < r[j],
                                "rank order violated at ({i},{j})"
                            );
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_monotone_transform_invariance() {
        // Ranking is invariant to monotone transforms (Sec. 3.4 claim).
        forall(
            200,
            13,
            &F32VecGen {
                min_len: 1,
                max_len: 48,
                lo: 0.0,
                hi: 3.0,
            },
            |scores| {
                let transformed: Vec<f32> =
                    scores.iter().map(|x| (x * 2.0).exp()).collect();
                prop_assert!(
                    rank_ascending(scores) == rank_ascending(&transformed),
                    "monotone transform changed ranks"
                );
                Ok(())
            },
        );
    }

    #[test]
    fn permutation_and_ranks_consistent() {
        let scores = [0.4f32, 0.1, 0.4, 0.8];
        let perm = permutation_ascending(&scores);
        let r = rank_of_permutation(&perm);
        assert_eq!(r, rank_ascending(&scores));
    }

    #[test]
    fn spearman_distance_zero_iff_equal() {
        let r1 = vec![1, 2, 3];
        assert_eq!(spearman_sq_distance(&r1, &r1), 0.0);
        assert!(spearman_sq_distance(&r1, &[3, 2, 1]) > 0.0);
    }
}
