//! The paper's core algorithm (Sec. 3): local/global neuron importance,
//! rank conversion, weighted Borda fusion (the MAP consensus ranking of
//! App. A), and mask selection for GLASS plus all baselines.

pub mod fusion;
pub mod importance;
pub mod mask;
pub mod prior;
pub mod ranking;
pub mod selector;

pub use fusion::{fuse_and_select, glass_scores, select_topk};
pub use importance::{DecayingImportance, ImportanceMap, OnlineImportance};
pub use mask::{jaccard, pack_indices, pack_masks, MaskSet};
pub use prior::{GlobalPrior, PriorKind};
pub use ranking::rank_ascending;
pub use selector::{build_mask, refresh_mask, Strategy};
