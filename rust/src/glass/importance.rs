//! Importance accumulators: local prompt statistics A^l and general
//! per-layer statistic maps ([L][m] matrices of non-negative scores).
//!
//! The executables emit ℓ2-normalized per-token activation magnitudes
//! aggregated per layer ("stats" outputs, paper Eq. 4); this module holds
//! and merges them on the host.

use anyhow::{bail, Result};

use crate::tensor::TensorF;

/// Per-layer importance map: scores[layer][neuron] ≥ 0.
#[derive(Debug, Clone, PartialEq)]
pub struct ImportanceMap {
    pub layers: Vec<Vec<f32>>,
}

impl ImportanceMap {
    pub fn zeros(n_layers: usize, m: usize) -> Self {
        ImportanceMap {
            layers: vec![vec![0.0; m]; n_layers],
        }
    }

    pub fn from_layers(layers: Vec<Vec<f32>>) -> Result<Self> {
        if layers.is_empty() {
            bail!("importance map needs at least one layer");
        }
        let m = layers[0].len();
        if layers.iter().any(|l| l.len() != m) {
            bail!("ragged importance map");
        }
        Ok(ImportanceMap { layers })
    }

    /// Build from a stats tensor [B, L, m] for one batch slot b.
    pub fn from_stats(stats: &TensorF, b: usize) -> Result<Self> {
        if stats.rank() != 3 {
            bail!("stats must be [B, L, m], got {:?}", stats.shape);
        }
        let (bs, l, m) = (stats.shape[0], stats.shape[1], stats.shape[2]);
        if b >= bs {
            bail!("batch index {b} out of range {bs}");
        }
        let mut layers = Vec::with_capacity(l);
        for li in 0..l {
            let start = (b * l + li) * m;
            layers.push(stats.data[start..start + m].to_vec());
        }
        Ok(ImportanceMap { layers })
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn m(&self) -> usize {
        self.layers[0].len()
    }

    /// Weighted merge: self = (self*w_self + other*w_other)/(w_self+w_other)
    /// — used when local evidence arrives in chunks (chunked prefill) or
    /// when accumulating NPS statistics across generation steps.
    pub fn merge(&mut self, other: &ImportanceMap, w_self: f64, w_other: f64) {
        assert_eq!(self.n_layers(), other.n_layers());
        assert_eq!(self.m(), other.m());
        let tot = w_self + w_other;
        if tot <= 0.0 {
            return;
        }
        for (a, b) in self.layers.iter_mut().zip(&other.layers) {
            for (x, y) in a.iter_mut().zip(b) {
                *x = ((*x as f64 * w_self + *y as f64 * w_other) / tot) as f32;
            }
        }
    }

    /// Pack into a one-slot [1, L, m] stats tensor — the inverse of
    /// [`ImportanceMap::from_stats`] for a single slot. The chunked
    /// prefill uses this to hand chunk-merged evidence to the same
    /// mask-selection/session code paths that consume executable stats.
    pub fn to_stats_tensor(&self) -> TensorF {
        let (l, m) = (self.n_layers(), self.m());
        let mut data = Vec::with_capacity(l * m);
        for layer in &self.layers {
            data.extend_from_slice(layer);
        }
        TensorF::new(vec![1, l, m], data).expect("consistent layer shapes")
    }

    /// All values finite and non-negative?
    pub fn is_well_formed(&self) -> bool {
        self.layers
            .iter()
            .all(|l| l.iter().all(|x| x.is_finite() && *x >= 0.0))
    }
}

/// Online accumulator over decode steps (used by the Rust NPS driver and
/// the oracle statistic collection): running mean of per-token stats.
#[derive(Debug, Clone)]
pub struct OnlineImportance {
    pub map: ImportanceMap,
    pub n_tokens: u64,
}

impl OnlineImportance {
    pub fn new(n_layers: usize, m: usize) -> Self {
        OnlineImportance {
            map: ImportanceMap::zeros(n_layers, m),
            n_tokens: 0,
        }
    }

    /// Push one token's stats [L, m] flattened (from a decode output for
    /// a single batch slot).
    pub fn push(&mut self, stats: &ImportanceMap) {
        self.n_tokens += 1;
        let w = 1.0 / self.n_tokens as f64;
        for (acc, s) in self.map.layers.iter_mut().zip(&stats.layers) {
            for (a, x) in acc.iter_mut().zip(s) {
                *a = (*a as f64 * (1.0 - w) + *x as f64 * w) as f32;
            }
        }
    }
}

/// Decaying (exponentially weighted) accumulator over decode steps with
/// bias correction — the temporal half of the continuous batcher's mask
/// refresh. Recent tokens dominate (weight of a step fades by `decay`
/// per subsequent step), so the accumulator tracks *current* generation
/// behavior instead of an all-history mean.
#[derive(Debug, Clone)]
pub struct DecayingImportance {
    pub map: ImportanceMap,
    /// Accumulated evidence mass: Σ decay^i over pushed steps (bias
    /// correction denominator; → 1/(1-decay) as steps accumulate).
    pub weight: f64,
    pub decay: f64,
}

impl DecayingImportance {
    pub fn new(n_layers: usize, m: usize, decay: f64) -> Self {
        assert!((0.0..=1.0).contains(&decay), "decay out of [0,1]");
        DecayingImportance {
            map: ImportanceMap::zeros(n_layers, m),
            weight: 0.0,
            decay,
        }
    }

    /// Push one step's statistics [L, m].
    pub fn push(&mut self, stats: &ImportanceMap) {
        assert_eq!(self.map.n_layers(), stats.n_layers());
        assert_eq!(self.map.m(), stats.m());
        let faded = self.weight * self.decay;
        let total = faded + 1.0;
        for (acc, s) in self.map.layers.iter_mut().zip(&stats.layers) {
            for (a, x) in acc.iter_mut().zip(s) {
                *a = ((*a as f64 * faded + *x as f64) / total) as f32;
            }
        }
        self.weight = total;
    }

    /// Blend with fixed prompt statistics: the prompt contributes
    /// `prompt_weight` pseudo-steps against this accumulator's evidence
    /// mass. With no decode evidence yet this returns the prompt map.
    pub fn blend_with(
        &self,
        prompt: &ImportanceMap,
        prompt_weight: f64,
    ) -> ImportanceMap {
        let mut out = prompt.clone();
        if self.weight > 0.0 {
            let beta = self.weight / (self.weight + prompt_weight.max(0.0));
            out.merge(&self.map, 1.0 - beta, beta);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_stats_extracts_slot() {
        // B=2, L=2, m=3
        let data: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let t = TensorF::new(vec![2, 2, 3], data).unwrap();
        let m0 = ImportanceMap::from_stats(&t, 0).unwrap();
        assert_eq!(m0.layers, vec![vec![0.0, 1.0, 2.0], vec![3.0, 4.0, 5.0]]);
        let m1 = ImportanceMap::from_stats(&t, 1).unwrap();
        assert_eq!(m1.layers[0], vec![6.0, 7.0, 8.0]);
        assert!(ImportanceMap::from_stats(&t, 2).is_err());
    }

    #[test]
    fn stats_tensor_roundtrip() {
        let m = ImportanceMap::from_layers(vec![
            vec![1.0, 2.0, 3.0],
            vec![4.0, 5.0, 6.0],
        ])
        .unwrap();
        let t = m.to_stats_tensor();
        assert_eq!(t.shape, vec![1, 2, 3]);
        assert_eq!(ImportanceMap::from_stats(&t, 0).unwrap(), m);
    }

    #[test]
    fn merge_weighted_mean() {
        let mut a = ImportanceMap::from_layers(vec![vec![1.0, 0.0]]).unwrap();
        let b = ImportanceMap::from_layers(vec![vec![0.0, 1.0]]).unwrap();
        a.merge(&b, 3.0, 1.0);
        assert!((a.layers[0][0] - 0.75).abs() < 1e-6);
        assert!((a.layers[0][1] - 0.25).abs() < 1e-6);
    }

    #[test]
    fn online_mean_matches_batch_mean() {
        let mut acc = OnlineImportance::new(1, 2);
        let samples = [[1.0f32, 2.0], [3.0, 4.0], [5.0, 6.0]];
        for s in samples {
            acc.push(
                &ImportanceMap::from_layers(vec![s.to_vec()]).unwrap(),
            );
        }
        assert_eq!(acc.n_tokens, 3);
        assert!((acc.map.layers[0][0] - 3.0).abs() < 1e-5);
        assert!((acc.map.layers[0][1] - 4.0).abs() < 1e-5);
    }

    #[test]
    fn well_formed_detects_nan_and_negatives() {
        let ok = ImportanceMap::from_layers(vec![vec![0.0, 1.0]]).unwrap();
        assert!(ok.is_well_formed());
        let bad =
            ImportanceMap::from_layers(vec![vec![f32::NAN, 1.0]]).unwrap();
        assert!(!bad.is_well_formed());
        let neg = ImportanceMap::from_layers(vec![vec![-1.0, 1.0]]).unwrap();
        assert!(!neg.is_well_formed());
    }

    #[test]
    fn ragged_rejected() {
        assert!(ImportanceMap::from_layers(vec![vec![1.0], vec![1.0, 2.0]])
            .is_err());
    }

    #[test]
    fn decaying_recent_steps_dominate() {
        let mut acc = DecayingImportance::new(1, 2, 0.5);
        let a = ImportanceMap::from_layers(vec![vec![1.0, 0.0]]).unwrap();
        let b = ImportanceMap::from_layers(vec![vec![0.0, 1.0]]).unwrap();
        for _ in 0..8 {
            acc.push(&a);
        }
        acc.push(&b);
        // last step carries weight 1 of total ≈ 2 (Σ 0.5^i)
        assert!(acc.map.layers[0][1] > 0.45, "{:?}", acc.map.layers);
        assert!(acc.map.layers[0][1] < 0.6);
        assert!(acc.weight > 1.9 && acc.weight < 2.1);
    }

    #[test]
    fn decaying_is_unweighted_mean_at_decay_one() {
        let mut acc = DecayingImportance::new(1, 2, 1.0);
        for s in [[2.0f32, 0.0], [4.0, 2.0], [6.0, 4.0]] {
            acc.push(
                &ImportanceMap::from_layers(vec![s.to_vec()]).unwrap(),
            );
        }
        assert!((acc.map.layers[0][0] - 4.0).abs() < 1e-5);
        assert!((acc.map.layers[0][1] - 2.0).abs() < 1e-5);
    }

    #[test]
    fn blend_interpolates_toward_decode_evidence() {
        let prompt =
            ImportanceMap::from_layers(vec![vec![1.0, 0.0]]).unwrap();
        let mut acc = DecayingImportance::new(1, 2, 0.9);
        // no evidence → prompt unchanged
        assert_eq!(acc.blend_with(&prompt, 1.0), prompt);
        let dec = ImportanceMap::from_layers(vec![vec![0.0, 1.0]]).unwrap();
        for _ in 0..8 {
            acc.push(&dec);
        }
        let blended = acc.blend_with(&prompt, 1.0);
        // β = w/(w+1) with w ≈ 5.7 → decode side dominates
        assert!(blended.layers[0][1] > 0.8, "{:?}", blended.layers);
        assert!(blended.layers[0][0] < 0.2);
    }
}
