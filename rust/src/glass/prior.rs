//! Global model-intrinsic priors (Sec. 3.1–3.3): A^g (activation
//! magnitude) and I^g (Taylor impact), computed offline via NPS or a
//! held-out corpus and loaded from the artifact bundle.
//!
//! The prior's rank vectors are computed ONCE at load time — only the
//! local signal is ranked per request (hot-path optimization measured in
//! bench_glass_core).

use anyhow::Result;

use super::importance::ImportanceMap;
use super::ranking::rank_ascending;
use crate::runtime::Runtime;

/// Named prior variants matching the artifact bundle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PriorKind {
    /// A^g from Null-Prompt Stimulation (A-GLASS, NPS).
    ANps,
    /// I^g from NPS teacher-forced replay (I-GLASS, NPS).
    INps,
    /// A^g from the held-out external corpus (Tab. 3's "Wiki" variant).
    ACorpus,
    /// I^g from the held-out external corpus.
    ICorpus,
}

impl PriorKind {
    pub fn artifact_name(self) -> &'static str {
        match self {
            PriorKind::ANps => "a_nps",
            PriorKind::INps => "i_nps",
            PriorKind::ACorpus => "a_corpus",
            PriorKind::ICorpus => "i_corpus",
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            PriorKind::ANps => "A-GLASS (NPS)",
            PriorKind::INps => "I-GLASS (NPS)",
            PriorKind::ACorpus => "A-GLASS (corpus)",
            PriorKind::ICorpus => "I-GLASS (corpus)",
        }
    }

    pub fn all() -> [PriorKind; 4] {
        [
            PriorKind::ANps,
            PriorKind::INps,
            PriorKind::ACorpus,
            PriorKind::ICorpus,
        ]
    }
}

/// A loaded global prior with precomputed per-layer rank vectors.
#[derive(Debug, Clone)]
pub struct GlobalPrior {
    pub name: String,
    pub map: ImportanceMap,
    /// rank_ascending of each layer's scores, cached.
    pub ranks: Vec<Vec<usize>>,
}

impl GlobalPrior {
    pub fn new(name: &str, layers: Vec<Vec<f32>>) -> Result<GlobalPrior> {
        let map = ImportanceMap::from_layers(layers)?;
        let ranks = map.layers.iter().map(|l| rank_ascending(l)).collect();
        Ok(GlobalPrior {
            name: name.to_string(),
            map,
            ranks,
        })
    }

    /// Load a prior from the artifact bundle.
    pub fn load(rt: &Runtime, kind: PriorKind) -> Result<GlobalPrior> {
        let layers = rt.load_prior(kind.artifact_name())?;
        GlobalPrior::new(kind.artifact_name(), layers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranks_precomputed() {
        let p =
            GlobalPrior::new("t", vec![vec![0.3, 0.1, 0.9]]).unwrap();
        assert_eq!(p.ranks[0], vec![2, 1, 3]);
    }

    #[test]
    fn kinds_map_to_artifacts() {
        assert_eq!(PriorKind::ANps.artifact_name(), "a_nps");
        assert_eq!(PriorKind::ICorpus.artifact_name(), "i_corpus");
        assert_eq!(PriorKind::all().len(), 4);
    }
}
