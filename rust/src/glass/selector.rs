//! Mask-selection strategies: GLASS (A-/I-) and every baseline the paper
//! compares or ablates against (GRIFFIN local-only, static global-only,
//! oracle, random, CATS-like and TDA-like threshold rules).
//!
//! A selector maps (local prompt statistics, global prior, budget) to a
//! [`MaskSet`]. Selection runs on the L3 hot path between prefill and the
//! first decode step; it is pure host code (a few µs per request —
//! benchmarked in bench_glass_core).

use anyhow::{bail, Result};

use super::fusion::{glass_scores_from_ranks, select_topk};
use super::importance::ImportanceMap;
use super::mask::MaskSet;
use super::prior::GlobalPrior;
use crate::tensor::topk_indices;
use crate::util::prng::Prng;

/// Which neurons to keep, given the evidence.
#[derive(Debug, Clone)]
pub enum Strategy {
    /// No pruning (the dense reference).
    Dense,
    /// GRIFFIN: top-k by local prompt statistics only (λ = 0).
    LocalOnly,
    /// Static global mask: top-k by the prior only (λ = 1).
    GlobalOnly,
    /// GLASS rank fusion with mixing weight λ (Sec. 3.4, Eq. 7).
    Glass { lambda: f64 },
    /// Uniform-random kept set (sanity floor).
    Random { seed: u64 },
    /// Oracle: top-k by post-hoc decoding-time statistics (App. C.1) —
    /// the caller supplies those statistics as the "local" argument.
    Oracle,
    /// CATS-like: one scalar threshold at the (1-density) quantile of
    /// the pooled *global prior* magnitudes, applied per layer —
    /// offline-statistics thresholding with a variable per-layer
    /// keep-count (clamped to ≥ 1).
    CatsThreshold,
    /// TDA-like: the same thresholding rule over the pooled *prefill*
    /// activations (first-activations thresholding).
    TdaThreshold,
}

impl Strategy {
    pub fn name(&self) -> String {
        match self {
            Strategy::Dense => "dense".into(),
            Strategy::LocalOnly => "griffin".into(),
            Strategy::GlobalOnly => "global-only".into(),
            Strategy::Glass { lambda } => format!("glass(λ={lambda})"),
            Strategy::Random { .. } => "random".into(),
            Strategy::Oracle => "oracle".into(),
            Strategy::CatsThreshold => "cats-threshold".into(),
            Strategy::TdaThreshold => "tda-threshold".into(),
        }
    }

    pub fn needs_prior(&self) -> bool {
        matches!(
            self,
            Strategy::GlobalOnly
                | Strategy::Glass { .. }
                | Strategy::CatsThreshold
        )
    }
}

/// Build the mask for one request.
///
/// * `local` — per-layer prompt statistics A^l ([L][m], from prefill); for
///   [`Strategy::Oracle`] pass the post-hoc decode statistics instead.
/// * `prior` — the global prior (A^g or I^g); required iff
///   `strategy.needs_prior()`.
/// * `k` — per-layer neuron budget.
pub fn build_mask(
    strategy: &Strategy,
    local: &ImportanceMap,
    prior: Option<&GlobalPrior>,
    k: usize,
) -> Result<MaskSet> {
    let n_layers = local.n_layers();
    let m = local.m();
    if k == 0 || k > m {
        bail!("budget k={k} out of range (m={m})");
    }
    if strategy.needs_prior() && prior.is_none() {
        bail!("{} requires a global prior", strategy.name());
    }
    if let Some(p) = prior {
        if p.map.n_layers() != n_layers || p.map.m() != m {
            bail!("prior shape mismatch");
        }
    }

    let layers: Vec<Vec<usize>> = match strategy {
        Strategy::Dense => {
            return Ok(MaskSet::dense(n_layers, m));
        }
        Strategy::LocalOnly | Strategy::Oracle => (0..n_layers)
            .map(|l| sorted(topk_indices(&local.layers[l], k)))
            .collect(),
        Strategy::GlobalOnly => {
            let p = prior.unwrap();
            (0..n_layers)
                .map(|l| sorted(topk_indices(&p.map.layers[l], k)))
                .collect()
        }
        Strategy::Glass { lambda } => {
            let p = prior.unwrap();
            (0..n_layers)
                .map(|l| {
                    let rl =
                        super::ranking::rank_ascending(&local.layers[l]);
                    let s =
                        glass_scores_from_ranks(&rl, &p.ranks[l], *lambda);
                    select_topk(&s, k)
                })
                .collect()
        }
        Strategy::Random { seed } => {
            let mut rng = Prng::new(*seed);
            (0..n_layers)
                .map(|_| sorted(rng.sample_indices(m, k)))
                .collect()
        }
        Strategy::CatsThreshold => {
            let p = prior.unwrap();
            threshold_select_layers(&p.map.layers, k)
        }
        Strategy::TdaThreshold => threshold_select_layers(&local.layers, k),
    };
    MaskSet::from_indices(layers, m)
}

/// Rebuild a request's mask mid-generation from blended (prompt +
/// decode-time) local statistics — the continuous batcher's periodic
/// GLASS refresh. Returns the new mask and whether the kept set changed
/// relative to `current`.
pub fn refresh_mask(
    strategy: &Strategy,
    blended: &ImportanceMap,
    prior: Option<&GlobalPrior>,
    k: usize,
    current: &MaskSet,
) -> Result<(MaskSet, bool)> {
    if !blended.is_well_formed() {
        bail!("blended statistics are not well-formed");
    }
    let mask = build_mask(strategy, blended, prior, k)?;
    let changed = &mask != current;
    Ok((mask, changed))
}

fn sorted(mut v: Vec<usize>) -> Vec<usize> {
    v.sort_unstable();
    v
}

/// CATS/TDA-style thresholding: one scalar threshold at the
/// (1 − density) quantile of the *pooled* score distribution across all
/// layers, then applied per layer. Unlike top-k this yields a variable
/// per-layer keep-count (layers with stronger statistics keep more
/// units, clamped to ≥ 1), with only the *expected* total matching the
/// budget — the defining behavior of threshold rules vs. rank rules.
fn threshold_select_layers(layers: &[Vec<f32>], k: usize) -> Vec<Vec<usize>> {
    let mut pooled: Vec<f32> =
        layers.iter().flat_map(|l| l.iter().copied()).collect();
    pooled.sort_unstable_by(|a, b| {
        b.partial_cmp(a).expect("NaN threshold score")
    });
    // pooled count matching an average of k kept per layer
    let cut = (k * layers.len()).min(pooled.len());
    let theta = pooled[cut.saturating_sub(1)];
    layers
        .iter()
        .map(|scores| {
            let kept: Vec<usize> = (0..scores.len())
                .filter(|&j| scores[j] >= theta)
                .collect();
            if kept.is_empty() {
                // clamp: always keep the layer's strongest unit
                sorted(topk_indices(scores, 1))
            } else {
                kept
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::glass::prior::GlobalPrior;
    use crate::prop_assert;
    use crate::util::quickcheck::{forall, UsizeGen};

    fn imap(layers: Vec<Vec<f32>>) -> ImportanceMap {
        ImportanceMap::from_layers(layers).unwrap()
    }

    #[test]
    fn dense_keeps_everything() {
        let local = imap(vec![vec![0.1, 0.2, 0.3]]);
        let m = build_mask(&Strategy::Dense, &local, None, 1).unwrap();
        assert_eq!(m.density(), 1.0);
    }

    #[test]
    fn local_only_is_griffin() {
        let local = imap(vec![vec![0.9, 0.1, 0.5, 0.7]]);
        let m = build_mask(&Strategy::LocalOnly, &local, None, 2).unwrap();
        assert_eq!(m.layers[0], vec![0, 3]);
    }

    #[test]
    fn global_only_ignores_local() {
        let local = imap(vec![vec![0.9, 0.1, 0.5, 0.7]]);
        let prior =
            GlobalPrior::new("g", vec![vec![0.0, 1.0, 0.9, 0.1]]).unwrap();
        let m =
            build_mask(&Strategy::GlobalOnly, &local, Some(&prior), 2)
                .unwrap();
        assert_eq!(m.layers[0], vec![1, 2]);
    }

    #[test]
    fn glass_lambda_endpoints_match_baselines() {
        let local = imap(vec![vec![0.9, 0.1, 0.5, 0.7], vec![
            0.2, 0.8, 0.6, 0.4,
        ]]);
        let prior = GlobalPrior::new(
            "g",
            vec![vec![0.0, 1.0, 0.9, 0.1], vec![0.5, 0.1, 0.9, 0.2]],
        )
        .unwrap();
        let g0 = build_mask(
            &Strategy::Glass { lambda: 0.0 },
            &local,
            Some(&prior),
            2,
        )
        .unwrap();
        let grif =
            build_mask(&Strategy::LocalOnly, &local, Some(&prior), 2)
                .unwrap();
        assert_eq!(g0, grif);
        let g1 = build_mask(
            &Strategy::Glass { lambda: 1.0 },
            &local,
            Some(&prior),
            2,
        )
        .unwrap();
        let glob =
            build_mask(&Strategy::GlobalOnly, &local, Some(&prior), 2)
                .unwrap();
        assert_eq!(g1, glob);
    }

    #[test]
    fn missing_prior_rejected() {
        let local = imap(vec![vec![0.1, 0.2]]);
        assert!(build_mask(
            &Strategy::Glass { lambda: 0.5 },
            &local,
            None,
            1
        )
        .is_err());
    }

    #[test]
    fn budget_validated() {
        let local = imap(vec![vec![0.1, 0.2]]);
        assert!(build_mask(&Strategy::LocalOnly, &local, None, 0).is_err());
        assert!(build_mask(&Strategy::LocalOnly, &local, None, 3).is_err());
    }

    #[test]
    fn random_deterministic_per_seed() {
        let local = imap(vec![vec![0.0; 16]]);
        let a = build_mask(&Strategy::Random { seed: 5 }, &local, None, 4)
            .unwrap();
        let b = build_mask(&Strategy::Random { seed: 5 }, &local, None, 4)
            .unwrap();
        let c = build_mask(&Strategy::Random { seed: 6 }, &local, None, 4)
            .unwrap();
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn prop_all_strategies_respect_budget() {
        forall(120, 41, &UsizeGen { lo: 2, hi: 48 }, |&m| {
            let mut rng = Prng::new(m as u64);
            let k = 1 + rng.below(m);
            let local = imap(vec![
                (0..m).map(|_| rng.f32()).collect(),
                (0..m).map(|_| rng.f32()).collect(),
            ]);
            let prior = GlobalPrior::new(
                "p",
                vec![
                    (0..m).map(|_| rng.f32()).collect(),
                    (0..m).map(|_| rng.f32()).collect(),
                ],
            )
            .unwrap();
            // rank-based strategies keep exactly k per layer
            for strat in [
                Strategy::LocalOnly,
                Strategy::GlobalOnly,
                Strategy::Glass { lambda: 0.5 },
                Strategy::Random { seed: 1 },
                Strategy::Oracle,
            ] {
                let mask =
                    build_mask(&strat, &local, Some(&prior), k).unwrap();
                for l in 0..2 {
                    prop_assert!(
                        mask.layers[l].len() == k,
                        "{} layer {l}: {} != k={k}",
                        strat.name(),
                        mask.layers[l].len()
                    );
                }
            }
            // threshold strategies have a VARIABLE per-layer keep-count:
            // ≥ 1 (clamped), ≤ m, and a pooled total that only has to
            // stay near the budget (≤ 2k plus the per-layer clamp).
            for strat in [Strategy::CatsThreshold, Strategy::TdaThreshold] {
                let mask =
                    build_mask(&strat, &local, Some(&prior), k).unwrap();
                let mut total = 0;
                for l in 0..2 {
                    let kept = mask.layers[l].len();
                    total += kept;
                    prop_assert!(
                        (1..=m).contains(&kept),
                        "{} layer {l}: keep-count {kept} out of [1, {m}]",
                        strat.name()
                    );
                }
                prop_assert!(
                    total <= 2 * k + 2,
                    "{}: pooled total {total} far above budget 2k={}",
                    strat.name(),
                    2 * k
                );
            }
            Ok(())
        });
    }

    #[test]
    fn threshold_keep_count_varies_per_layer() {
        // layer 0 holds the 3 strongest pooled values, layer 1 only one
        // above the pooled cut — a per-layer top-k would keep 2+2.
        let local = imap(vec![
            vec![0.9, 0.8, 0.7, 0.1],
            vec![0.6, 0.05, 0.02, 0.01],
        ]);
        let m = build_mask(&Strategy::TdaThreshold, &local, None, 2).unwrap();
        assert_eq!(m.layers[0], vec![0, 1, 2]);
        assert_eq!(m.layers[1], vec![0]);
    }

    #[test]
    fn threshold_clamps_empty_layers_to_one() {
        // all of layer 1 sits below the pooled threshold
        let local = imap(vec![vec![1.0, 0.9, 0.8, 0.7], vec![
            0.01, 0.04, 0.02, 0.03,
        ]]);
        let m = build_mask(&Strategy::TdaThreshold, &local, None, 2).unwrap();
        assert_eq!(m.layers[0], vec![0, 1, 2, 3]);
        assert_eq!(m.layers[1], vec![1], "clamp keeps the strongest unit");
    }

    #[test]
    fn cats_thresholds_prior_not_local() {
        let local = imap(vec![vec![0.0, 0.0, 1.0, 1.0]; 2]);
        let prior = GlobalPrior::new(
            "g",
            vec![vec![1.0, 0.9, 0.1, 0.05]; 2],
        )
        .unwrap();
        let m = build_mask(&Strategy::CatsThreshold, &local, Some(&prior), 2)
            .unwrap();
        assert_eq!(m.layers[0], vec![0, 1]);
    }

    #[test]
    fn refresh_mask_reports_changes() {
        let before = imap(vec![vec![0.9, 0.5, 0.1, 0.05]]);
        let mask0 =
            build_mask(&Strategy::LocalOnly, &before, None, 2).unwrap();
        // no drift → unchanged
        let (same, changed) =
            refresh_mask(&Strategy::LocalOnly, &before, None, 2, &mask0)
                .unwrap();
        assert!(!changed);
        assert_eq!(same, mask0);
        // unit 3 overtakes unit 1 during decode
        let after = imap(vec![vec![0.9, 0.1, 0.05, 0.8]]);
        let (refreshed, changed) =
            refresh_mask(&Strategy::LocalOnly, &after, None, 2, &mask0)
                .unwrap();
        assert!(changed);
        assert_eq!(refreshed.layers[0], vec![0, 3]);
        // malformed blended stats rejected
        let bad = imap(vec![vec![f32::NAN, 0.1, 0.2, 0.3]]);
        assert!(refresh_mask(&Strategy::LocalOnly, &bad, None, 2, &mask0)
            .is_err());
    }

    #[test]
    fn glass_consensus_prefers_agreement() {
        // Neuron good in both signals beats neurons good in only one.
        let local = imap(vec![vec![1.0, 0.0, 0.9, 0.1]]);
        let prior =
            GlobalPrior::new("g", vec![vec![0.0, 1.0, 0.9, 0.1]]).unwrap();
        let m = build_mask(
            &Strategy::Glass { lambda: 0.5 },
            &local,
            Some(&prior),
            1,
        )
        .unwrap();
        assert_eq!(m.layers[0], vec![2]);
    }
}
