//! Weighted Borda rank aggregation — the GLASS consensus rule (Sec. 3.4,
//! Eq. 7) and its MAP interpretation (App. A).
//!
//! GLASS_j = (1 − λ) R_j^(l) + λ R_j^(g); keep the k neurons with the
//! largest fused score. App. A shows this is the MAP consensus permutation
//! of a Mallows-type model with squared Spearman distance; the property
//! tests below verify that theorem numerically by brute force on small m.

use super::ranking::{rank_ascending, rank_of_permutation, spearman_sq_distance};

/// Fused GLASS scores from raw importance values (converts to ranks
/// internally). λ ∈ [0,1]; λ=0 ≡ GRIFFIN (local-only), λ=1 ≡ static
/// global mask (Sec. 4.3 / App. C.2 endpoints).
pub fn glass_scores(local: &[f32], global: &[f32], lambda: f64) -> Vec<f64> {
    assert_eq!(local.len(), global.len());
    assert!((0.0..=1.0).contains(&lambda), "lambda out of [0,1]");
    let rl = rank_ascending(local);
    let rg = rank_ascending(global);
    rl.iter()
        .zip(&rg)
        .map(|(&l, &g)| (1.0 - lambda) * l as f64 + lambda * g as f64)
        .collect()
}

/// Fused scores from precomputed rank vectors (hot path — rank the global
/// prior once per model, not once per request).
pub fn glass_scores_from_ranks(
    r_local: &[usize],
    r_global: &[usize],
    lambda: f64,
) -> Vec<f64> {
    assert_eq!(r_local.len(), r_global.len());
    r_local
        .iter()
        .zip(r_global)
        .map(|(&l, &g)| (1.0 - lambda) * l as f64 + lambda * g as f64)
        .collect()
}

/// Select the top-k neurons by fused score, ties by lower index (paper's
/// deterministic boundary rule). Returned ids are sorted ascending (the
/// gathered kernel's preferred layout).
///
/// Uses O(m) partial selection instead of a full sort — at Llama-3-8B
/// scale (m=14336) this cut per-request mask building from ~103 ms to a
/// few ms (EXPERIMENTS.md §Perf iteration 6).
pub fn select_topk(scores: &[f64], k: usize) -> Vec<usize> {
    let k = k.min(scores.len());
    if k == 0 {
        return Vec::new();
    }
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    let desc = |a: &usize, b: &usize| {
        scores[*b]
            .partial_cmp(&scores[*a])
            .expect("NaN fused score")
            .then(a.cmp(b))
    };
    if k < idx.len() {
        // partition so idx[..k] holds the k best under `desc` (ties by
        // lower index are part of the comparator, so the boundary is
        // deterministic)
        idx.select_nth_unstable_by(k - 1, desc);
        idx.truncate(k);
    }
    idx.sort_unstable();
    idx
}

/// One-call convenience: raw importances → selected neuron ids.
pub fn fuse_and_select(
    local: &[f32],
    global: &[f32],
    lambda: f64,
    k: usize,
) -> Vec<usize> {
    select_topk(&glass_scores(local, global, lambda), k)
}

/// The MAP objective of App. A Eq. 13:
/// β_l‖r(π_l) − r(π)‖² + β_g‖r(π_g) − r(π)‖².
/// Exposed for the theorem-verification tests.
pub fn map_objective(
    candidate_perm: &[usize],
    r_local: &[usize],
    r_global: &[usize],
    beta_l: f64,
    beta_g: f64,
) -> f64 {
    let r = rank_of_permutation(candidate_perm);
    beta_l * spearman_sq_distance(r_local, &r)
        + beta_g * spearman_sq_distance(r_global, &r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prng::Prng;
    use crate::util::quickcheck::{forall, PairGen, UsizeGen};

    #[test]
    fn lambda_endpoints_recover_baselines() {
        let local = [0.9f32, 0.1, 0.5, 0.7];
        let global = [0.1f32, 0.9, 0.7, 0.5];
        // λ=0 -> pure local ordering
        let s0 = glass_scores(&local, &global, 0.0);
        assert_eq!(select_topk(&s0, 2), vec![0, 3]);
        // λ=1 -> pure global ordering
        let s1 = glass_scores(&local, &global, 1.0);
        assert_eq!(select_topk(&s1, 2), vec![1, 2]);
    }

    #[test]
    fn fusion_balances_signals() {
        // neuron 2 is strong in both; 0 great locally only; 1 great
        // globally only. With k=1 and λ=0.5, consensus picks neuron 2.
        let local = [1.0f32, 0.0, 0.9, 0.1];
        let global = [0.0f32, 1.0, 0.9, 0.1];
        assert_eq!(fuse_and_select(&local, &global, 0.5, 1), vec![2]);
    }

    #[test]
    fn select_topk_ties_by_index() {
        let s = [1.0f64, 2.0, 2.0, 2.0];
        assert_eq!(select_topk(&s, 2), vec![1, 2]);
    }

    #[test]
    fn selected_sorted_ascending() {
        let s = [5.0f64, 1.0, 9.0, 3.0];
        assert_eq!(select_topk(&s, 2), vec![0, 2]);
    }

    /// Brute-force verification of the App. A theorem: the Borda ordering
    /// minimizes the Mallows MAP objective over ALL m! permutations.
    #[test]
    fn borda_is_map_minimizer_bruteforce() {
        fn permutations(n: usize) -> Vec<Vec<usize>> {
            if n == 0 {
                return vec![vec![]];
            }
            let mut out = Vec::new();
            for p in permutations(n - 1) {
                for i in 0..=p.len() {
                    let mut q = p.clone();
                    q.insert(i, n - 1);
                    out.push(q);
                }
            }
            out
        }

        let mut rng = Prng::new(99);
        for trial in 0..20 {
            let m = 3 + (trial % 3); // m in {3,4,5}
            let local: Vec<f32> = (0..m).map(|_| rng.f32()).collect();
            let global: Vec<f32> = (0..m).map(|_| rng.f32()).collect();
            let beta_l = 0.3 + rng.f64();
            let beta_g = 0.2 + rng.f64();
            let lambda = beta_g / (beta_l + beta_g);

            let rl = rank_ascending(&local);
            let rg = rank_ascending(&global);
            // Borda consensus permutation: sort ascending by fused score
            let s = glass_scores_from_ranks(&rl, &rg, lambda);
            let mut borda_perm: Vec<usize> = (0..m).collect();
            borda_perm.sort_by(|&a, &b| {
                s[a].partial_cmp(&s[b]).unwrap().then(a.cmp(&b))
            });

            let borda_obj =
                map_objective(&borda_perm, &rl, &rg, beta_l, beta_g);
            for p in permutations(m) {
                let obj = map_objective(&p, &rl, &rg, beta_l, beta_g);
                assert!(
                    borda_obj <= obj + 1e-9,
                    "Borda not MAP: m={m} borda={borda_obj} perm={p:?} \
                     obj={obj}"
                );
            }
        }
    }

    #[test]
    fn prop_topk_size_and_validity() {
        forall(
            300,
            21,
            &PairGen(
                UsizeGen { lo: 1, hi: 64 },
                UsizeGen { lo: 0, hi: 80 },
            ),
            |&(m, k)| {
                let mut rng = Prng::new((m * 1000 + k) as u64);
                let local: Vec<f32> = (0..m).map(|_| rng.f32()).collect();
                let global: Vec<f32> = (0..m).map(|_| rng.f32()).collect();
                let sel = fuse_and_select(&local, &global, 0.5, k);
                prop_assert!(
                    sel.len() == k.min(m),
                    "wrong selection size {} for m={m} k={k}",
                    sel.len()
                );
                prop_assert!(
                    sel.windows(2).all(|w| w[0] < w[1]),
                    "not sorted/unique: {sel:?}"
                );
                prop_assert!(
                    sel.iter().all(|&j| j < m),
                    "out of range: {sel:?}"
                );
                Ok(())
            },
        );
    }

    #[test]
    fn prop_normalization_invariance() {
        // Multiplying both β by a constant (equivalently keeping the same
        // λ) must not change the selection (App. A Eq. 26-28).
        forall(100, 22, &UsizeGen { lo: 2, hi: 40 }, |&m| {
            let mut rng = Prng::new(m as u64 + 5);
            let local: Vec<f32> = (0..m).map(|_| rng.f32()).collect();
            let global: Vec<f32> = (0..m).map(|_| rng.f32()).collect();
            let k = 1 + m / 2;
            let s1 = glass_scores(&local, &global, 0.4);
            let scaled: Vec<f64> = s1.iter().map(|x| x * 7.5).collect();
            prop_assert!(
                select_topk(&s1, k) == select_topk(&scaled, k),
                "positive scaling changed selection"
            );
            Ok(())
        });
    }

    #[test]
    fn prop_identical_signals_are_fixed_point() {
        // When local == global, any λ yields the local-only selection.
        forall(100, 23, &UsizeGen { lo: 1, hi: 50 }, |&m| {
            let mut rng = Prng::new(m as u64 * 3 + 1);
            let sc: Vec<f32> = (0..m).map(|_| rng.f32()).collect();
            let k = 1 + m / 3;
            let base = fuse_and_select(&sc, &sc, 0.0, k);
            for lam in [0.25, 0.5, 0.75, 1.0] {
                prop_assert!(
                    fuse_and_select(&sc, &sc, lam, k) == base,
                    "λ={lam} changed selection with identical signals"
                );
            }
            Ok(())
        });
    }
}
