//! Host tensors and numeric helpers shared across the coordinator.
//!
//! These are deliberately simple row-major buffers: the heavy math runs
//! inside the AOT-compiled XLA executables; the host side only needs
//! shaping, softmax/log-softmax for metric computation, top-k, and
//! masks. Kept dependency-free and well tested.

use anyhow::{bail, Result};

/// Row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorF {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

/// Row-major i32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorI {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

impl TensorF {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        if numel(&shape) != data.len() {
            bail!(
                "shape {:?} needs {} elements, got {}",
                shape,
                numel(&shape),
                data.len()
            );
        }
        Ok(TensorF { shape, data })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        TensorF {
            shape: shape.to_vec(),
            data: vec![0.0; numel(shape)],
        }
    }

    pub fn ones(shape: &[usize]) -> Self {
        TensorF {
            shape: shape.to_vec(),
            data: vec![1.0; numel(shape)],
        }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.rank(), 2, "row() needs rank-2");
        let w = self.shape[1];
        &self.data[i * w..(i + 1) * w]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        assert_eq!(self.rank(), 2);
        let w = self.shape[1];
        &mut self.data[i * w..(i + 1) * w]
    }

    /// Slice along the leading axis: returns the sub-tensor at index i.
    pub fn index0(&self, i: usize) -> TensorF {
        assert!(self.rank() >= 1 && i < self.shape[0]);
        let sub = numel(&self.shape[1..]);
        TensorF {
            shape: self.shape[1..].to_vec(),
            data: self.data[i * sub..(i + 1) * sub].to_vec(),
        }
    }

    /// View of the flattened chunk at leading index i (no copy).
    pub fn chunk0(&self, i: usize) -> &[f32] {
        let sub = numel(&self.shape[1..]);
        &self.data[i * sub..(i + 1) * sub]
    }

    pub fn chunk0_mut(&mut self, i: usize) -> &mut [f32] {
        let sub = numel(&self.shape[1..]);
        &mut self.data[i * sub..(i + 1) * sub]
    }

    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Self> {
        if numel(&shape) != self.data.len() {
            bail!("reshape {:?} -> {:?} mismatch", self.shape, shape);
        }
        self.shape = shape;
        Ok(self)
    }
}

impl TensorI {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Result<Self> {
        if numel(&shape) != data.len() {
            bail!(
                "shape {:?} needs {} elements, got {}",
                shape,
                numel(&shape),
                data.len()
            );
        }
        Ok(TensorI { shape, data })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        TensorI {
            shape: shape.to_vec(),
            data: vec![0; numel(shape)],
        }
    }

    pub fn full(shape: &[usize], v: i32) -> Self {
        TensorI {
            shape: shape.to_vec(),
            data: vec![v; numel(shape)],
        }
    }
}

// --------------------------------------------------------------- numerics

/// log(sum(exp(x))) with the max trick.
pub fn logsumexp(xs: &[f32]) -> f32 {
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        return m;
    }
    let s: f32 = xs.iter().map(|x| (x - m).exp()).sum();
    m + s.ln()
}

/// In-place softmax.
pub fn softmax(xs: &mut [f32]) {
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut s = 0.0;
    for x in xs.iter_mut() {
        *x = (*x - m).exp();
        s += *x;
    }
    for x in xs.iter_mut() {
        *x /= s;
    }
}

/// Log-probabilities from logits (new vector).
pub fn log_softmax(xs: &[f32]) -> Vec<f32> {
    let lse = logsumexp(xs);
    xs.iter().map(|x| x - lse).collect()
}

/// Index of the maximum (ties -> lowest index, matching jnp.argmax).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Indices of the k largest values, descending; ties broken by lower
/// index first (the paper's deterministic tie rule).
pub fn topk_indices(xs: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| {
        xs[b].partial_cmp(&xs[a]).unwrap().then(a.cmp(&b))
    });
    idx.truncate(k.min(xs.len()));
    idx
}

/// ℓ2 norm.
pub fn l2_norm(xs: &[f32]) -> f32 {
    xs.iter().map(|x| x * x).sum::<f32>().sqrt()
}

pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_checked() {
        assert!(TensorF::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(TensorF::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn rows_and_chunks() {
        let t = TensorF::new(vec![2, 3], (0..6).map(|i| i as f32).collect())
            .unwrap();
        assert_eq!(t.row(1), &[3.0, 4.0, 5.0]);
        assert_eq!(t.chunk0(0), &[0.0, 1.0, 2.0]);
        let s = t.index0(1);
        assert_eq!(s.shape, vec![3]);
        assert_eq!(s.data, vec![3.0, 4.0, 5.0]);
    }

    #[test]
    fn softmax_normalizes() {
        let mut xs = vec![1.0, 2.0, 3.0];
        softmax(&mut xs);
        let s: f32 = xs.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(xs[2] > xs[1] && xs[1] > xs[0]);
    }

    #[test]
    fn log_softmax_consistent_with_softmax() {
        let xs = vec![0.5, -1.0, 2.0, 0.0];
        let lp = log_softmax(&xs);
        let mut sm = xs.clone();
        softmax(&mut sm);
        for (l, p) in lp.iter().zip(&sm) {
            assert!((l.exp() - p).abs() < 1e-6);
        }
    }

    #[test]
    fn logsumexp_stable() {
        let xs = vec![1000.0, 1000.0];
        let l = logsumexp(&xs);
        assert!((l - (1000.0 + (2f32).ln())).abs() < 1e-3);
    }

    #[test]
    fn argmax_tie_lowest_index() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
    }

    #[test]
    fn topk_deterministic_ties() {
        let idx = topk_indices(&[1.0, 5.0, 5.0, 0.0, 5.0], 3);
        assert_eq!(idx, vec![1, 2, 4]);
    }

    #[test]
    fn topk_k_larger_than_len() {
        assert_eq!(topk_indices(&[2.0, 1.0], 10), vec![0, 1]);
    }

    #[test]
    fn reshape_checks() {
        let t = TensorF::zeros(&[4, 2]);
        assert!(t.clone().reshape(vec![2, 4]).is_ok());
        assert!(t.reshape(vec![3, 3]).is_err());
    }
}
