//! Model-facing host utilities: tokenizer, samplers, and weight-store
//! inspection. The actual network weights live on the device (uploaded
//! once by [`crate::runtime::Runtime`]); this module provides the host
//! views the memory simulator and diagnostics need.

pub mod sampling;
pub mod tokenizer;

pub use sampling::{sample, NpsSampler, SamplerConfig};
pub use tokenizer::Tokenizer;

use crate::runtime::{Manifest, ModelSpec};

/// Byte-size breakdown of the model weights by component — the input to
/// the edge-memory simulator's residency model (FFN vs non-FFN split is
/// what GLASS's static masking exploits on-device, Sec. 4.5).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WeightFootprint {
    pub total_bytes: usize,
    pub ffn_bytes: usize,
    pub attn_bytes: usize,
    pub embed_bytes: usize,
    pub other_bytes: usize,
}

impl WeightFootprint {
    pub fn from_manifest(man: &Manifest) -> WeightFootprint {
        let mut f = WeightFootprint {
            total_bytes: 0,
            ffn_bytes: 0,
            attn_bytes: 0,
            embed_bytes: 0,
            other_bytes: 0,
        };
        for p in &man.params {
            let bytes = p.numel * 4;
            f.total_bytes += bytes;
            if p.name.contains("w_up")
                || p.name.contains("w_gate")
                || p.name.contains("w_down")
            {
                f.ffn_bytes += bytes;
            } else if p.name.contains("wq")
                || p.name.contains("wk")
                || p.name.contains("wv")
                || p.name.contains("wo")
            {
                f.attn_bytes += bytes;
            } else if p.name.contains("embed") || p.name.contains("head") {
                f.embed_bytes += bytes;
            } else {
                f.other_bytes += bytes;
            }
        }
        f
    }

    /// Bytes resident when the FFN is pruned to `density` (static mask ⇒
    /// only the kept columns/rows of W_up/W_gate/W_down stay in fast
    /// memory — the paper's edge-deployment benefit).
    pub fn resident_bytes(&self, ffn_density: f64) -> usize {
        let kept_ffn = (self.ffn_bytes as f64 * ffn_density).round() as usize;
        self.total_bytes - self.ffn_bytes + kept_ffn
    }

    pub fn ffn_fraction(&self) -> f64 {
        self.ffn_bytes as f64 / self.total_bytes.max(1) as f64
    }
}

/// Rough per-token decode FLOPs for the spec at a given FFN density —
/// used by the memory simulator's compute roofline.
pub fn decode_flops_per_token(spec: &ModelSpec, ffn_density: f64) -> f64 {
    let d = spec.d_model as f64;
    let m = spec.ffn_m as f64 * ffn_density;
    let layers = spec.n_layers as f64;
    let attn_proj = 4.0 * d * d; // q,k,v,o projections
    let attn_kv = 2.0 * (spec.max_seq as f64) * d; // scores + values
    let ffn = 3.0 * d * m;
    let head = d * spec.vocab as f64;
    2.0 * (layers * (attn_proj + attn_kv + ffn) + head)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ModelSpec {
        ModelSpec {
            vocab: 260,
            d_model: 128,
            n_layers: 4,
            n_heads: 4,
            head_dim: 32,
            ffn_m: 512,
            max_seq: 224,
            prefill_len: 96,
            score_len: 224,
            gen_len: 96,
            bos_id: 256,
            pad_id: 257,
        }
    }

    #[test]
    fn flops_scale_with_density() {
        let s = spec();
        let dense = decode_flops_per_token(&s, 1.0);
        let half = decode_flops_per_token(&s, 0.5);
        assert!(half < dense);
        // FFN dominates: 3dm vs 4dd per layer (m=4d here)
        let ffn_dense = 2.0 * 4.0 * 3.0 * 128.0 * 512.0;
        assert!((dense - half) * 2.0 - ffn_dense < 1e-6);
    }

    #[test]
    fn resident_bytes_interpolates() {
        let f = WeightFootprint {
            total_bytes: 100,
            ffn_bytes: 60,
            attn_bytes: 20,
            embed_bytes: 20,
            other_bytes: 0,
        };
        assert_eq!(f.resident_bytes(1.0), 100);
        assert_eq!(f.resident_bytes(0.5), 70);
        assert_eq!(f.resident_bytes(0.0), 40);
        assert!((f.ffn_fraction() - 0.6).abs() < 1e-12);
    }
}
