//! Byte-level tokenizer: ids 0..255 are raw bytes, plus BOS and PAD
//! specials (mirrors the python-side encoding in train.py/data.py).

use crate::runtime::ModelSpec;

#[derive(Debug, Clone)]
pub struct Tokenizer {
    pub vocab: usize,
    pub bos_id: i32,
    pub pad_id: i32,
}

impl Tokenizer {
    pub fn from_spec(spec: &ModelSpec) -> Self {
        Tokenizer {
            vocab: spec.vocab,
            bos_id: spec.bos_id,
            pad_id: spec.pad_id,
        }
    }

    /// Encode text as bytes (no BOS).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.bytes().map(|b| b as i32).collect()
    }

    /// Encode with a leading BOS token.
    pub fn encode_with_bos(&self, text: &str) -> Vec<i32> {
        let mut v = Vec::with_capacity(text.len() + 1);
        v.push(self.bos_id);
        v.extend(text.bytes().map(|b| b as i32));
        v
    }

    /// Decode ids to text; specials and invalid utf-8 are dropped
    /// (lossy) — generation output is ASCII in practice.
    pub fn decode(&self, ids: &[i32]) -> String {
        let bytes: Vec<u8> = ids
            .iter()
            .filter(|&&t| (0..256).contains(&t))
            .map(|&t| t as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// Pad/truncate to exactly `len`, returning (tokens, true_len).
    pub fn pad_to(&self, ids: &[i32], len: usize) -> (Vec<i32>, usize) {
        let mut v = ids.to_vec();
        let true_len = v.len().min(len);
        v.truncate(len);
        v.resize(len, self.pad_id);
        (v, true_len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok() -> Tokenizer {
        Tokenizer {
            vocab: 260,
            bos_id: 256,
            pad_id: 257,
        }
    }

    #[test]
    fn roundtrip_ascii() {
        let t = tok();
        let ids = t.encode("the red fox");
        assert_eq!(t.decode(&ids), "the red fox");
    }

    #[test]
    fn bos_prepended() {
        let t = tok();
        let ids = t.encode_with_bos("ab");
        assert_eq!(ids, vec![256, 97, 98]);
        assert_eq!(t.decode(&ids), "ab"); // BOS dropped on decode
    }

    #[test]
    fn pad_and_truncate() {
        let t = tok();
        let (p, n) = t.pad_to(&[1, 2, 3], 5);
        assert_eq!(p, vec![1, 2, 3, 257, 257]);
        assert_eq!(n, 3);
        let (q, m) = t.pad_to(&[1, 2, 3, 4, 5, 6], 4);
        assert_eq!(q, vec![1, 2, 3, 4]);
        assert_eq!(m, 4);
    }
}
