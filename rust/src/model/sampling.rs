//! Token samplers: greedy, temperature/top-k, and the NPS schedule
//! (App. B.3: temperature 1.5 + bigram repetition penalty for the first
//! 10 tokens, then temperature 1.0; top-k = 20 throughout).

use std::collections::HashSet;

use crate::tensor::{argmax, softmax, topk_indices};
use crate::util::prng::Prng;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SamplerConfig {
    pub temperature: f32,
    pub top_k: usize,
}

impl SamplerConfig {
    pub fn greedy() -> Self {
        SamplerConfig {
            temperature: 0.0,
            top_k: 1,
        }
    }
}

/// Sample one token from logits under the config. temperature == 0 means
/// greedy (deterministic).
pub fn sample(logits: &[f32], cfg: SamplerConfig, rng: &mut Prng) -> i32 {
    if cfg.temperature <= 0.0 || cfg.top_k <= 1 {
        return argmax(logits) as i32;
    }
    let cand = topk_indices(logits, cfg.top_k.min(logits.len()));
    let mut probs: Vec<f32> = cand
        .iter()
        .map(|&i| logits[i] / cfg.temperature)
        .collect();
    softmax(&mut probs);
    let w: Vec<f64> = probs.iter().map(|&p| p as f64).collect();
    cand[rng.weighted(&w)] as i32
}

/// NPS sampling schedule state (paper App. B.3 / python compile/nps.py).
#[derive(Debug, Clone)]
pub struct NpsSampler {
    pub hot_tokens: usize,
    pub hot_temperature: f32,
    pub temperature: f32,
    pub top_k: usize,
    pub bigram_penalty: f32,
    seen_bigrams: HashSet<(i32, i32)>,
    last: Option<i32>,
    step: usize,
}

impl Default for NpsSampler {
    fn default() -> Self {
        NpsSampler {
            hot_tokens: 10,
            hot_temperature: 1.5,
            temperature: 1.0,
            top_k: 20,
            bigram_penalty: 2.5,
            seen_bigrams: HashSet::new(),
            last: None,
            step: 0,
        }
    }
}

impl NpsSampler {
    /// Sample the next token given raw logits, applying the schedule.
    pub fn next(&mut self, logits: &[f32], rng: &mut Prng) -> i32 {
        let hot = self.step < self.hot_tokens;
        let temp = if hot {
            self.hot_temperature
        } else {
            self.temperature
        };
        let mut adj: Vec<f32> =
            logits.iter().map(|&x| x / temp).collect();
        if hot {
            if let Some(last) = self.last {
                for (tok, v) in adj.iter_mut().enumerate() {
                    if self.seen_bigrams.contains(&(last, tok as i32)) {
                        // divisor-penalty mirrors python nps.py
                        *v /= self.bigram_penalty;
                    }
                }
            }
        }
        let chosen = sample(
            &adj,
            SamplerConfig {
                temperature: 1.0, // temp already applied
                top_k: self.top_k,
            },
            rng,
        );
        if let Some(last) = self.last {
            self.seen_bigrams.insert((last, chosen));
        }
        self.last = Some(chosen);
        self.step += 1;
        chosen
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax() {
        let mut rng = Prng::new(0);
        let logits = vec![0.1, 3.0, 0.5];
        assert_eq!(sample(&logits, SamplerConfig::greedy(), &mut rng), 1);
    }

    #[test]
    fn topk_excludes_tail() {
        let mut rng = Prng::new(1);
        let mut logits = vec![-100.0; 50];
        logits[7] = 5.0;
        logits[9] = 4.0;
        let cfg = SamplerConfig {
            temperature: 1.0,
            top_k: 2,
        };
        for _ in 0..100 {
            let t = sample(&logits, cfg, &mut rng);
            assert!(t == 7 || t == 9);
        }
    }

    #[test]
    fn sampling_deterministic_per_seed() {
        let logits: Vec<f32> = (0..30).map(|i| (i as f32 * 0.37).sin()).collect();
        let cfg = SamplerConfig {
            temperature: 1.0,
            top_k: 10,
        };
        let a: Vec<i32> = {
            let mut r = Prng::new(5);
            (0..20).map(|_| sample(&logits, cfg, &mut r)).collect()
        };
        let b: Vec<i32> = {
            let mut r = Prng::new(5);
            (0..20).map(|_| sample(&logits, cfg, &mut r)).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn nps_schedule_cools_down() {
        let mut s = NpsSampler::default();
        assert_eq!(s.step, 0);
        let mut rng = Prng::new(2);
        let logits = vec![1.0; 30];
        for _ in 0..12 {
            s.next(&logits, &mut rng);
        }
        assert_eq!(s.step, 12);
        assert!(!s.seen_bigrams.is_empty());
    }

    #[test]
    fn nps_hot_phase_penalizes_repeats() {
        // With two candidate tokens and a strongly-preferred one, the
        // penalty makes an immediate repeat of the same bigram unlikely.
        let mut s = NpsSampler {
            top_k: 2,
            bigram_penalty: 1e6,
            ..NpsSampler::default()
        };
        let mut rng = Prng::new(3);
        let mut logits = vec![-50.0f32; 10];
        logits[4] = 10.0; // dominant
        logits[5] = 9.0;
        let t1 = s.next(&logits, &mut rng);
        let t2 = s.next(&logits, &mut rng);
        let t3 = s.next(&logits, &mut rng);
        // after (t1,t2)=(x,y) occurs once, the same continuation is
        // heavily penalized while hot
        let _ = (t1, t2, t3); // sequence must simply be drawn from {4,5}
        assert!([t1, t2, t3].iter().all(|t| *t == 4 || *t == 5));
    }
}
