//! High-level sparse-generation sessions: the paper's full request flow
//! (Fig. 2) — prefill, learn A^l, fuse with the global prior, build the
//! static mask, then decode with it.

use anyhow::Result;

use super::{Engine, GenerateResult};
use crate::glass::{
    build_mask, pack_masks, GlobalPrior, ImportanceMap, MaskSet, Strategy,
};
use crate::tensor::TensorF;

/// Everything produced by a sparse batch request.
#[derive(Debug, Clone)]
pub struct SparseRun {
    pub masks: Vec<MaskSet>,
    pub locals: Vec<ImportanceMap>,
    pub result: GenerateResult,
    pub texts: Vec<String>,
}

/// Run the full GLASS flow on a batch of prompts: prefill → per-slot mask
/// via `strategy` → fused sparse generation.
///
/// `density` sets the per-layer budget k = round(m · density); `prior`
/// must be supplied when the strategy needs one.
pub fn run_sparse_batch(
    engine: &Engine,
    prompts: &[String],
    strategy: &Strategy,
    prior: Option<&GlobalPrior>,
    density: f64,
    b: usize,
) -> Result<SparseRun> {
    let spec = engine.spec().clone();
    let k = spec.budget(density);

    let pre = engine.prefill(prompts, b)?;
    let mut locals = Vec::with_capacity(prompts.len());
    let mut masks = Vec::with_capacity(prompts.len());
    for slot in 0..prompts.len() {
        let local = engine.local_importance(&pre, slot)?;
        let mask = build_mask(strategy, &local, prior, k)?;
        locals.push(local);
        masks.push(mask);
    }

    let mask_t = pack_slot_masks(&masks, prompts.len(), b, &spec);
    let result = engine.generate(prompts, &mask_t, b)?;
    let texts = (0..prompts.len())
        .map(|i| {
            let n = result.tokens.shape[1];
            engine.decode_text(&result.tokens.data[i * n..(i + 1) * n])
        })
        .collect();
    Ok(SparseRun {
        masks,
        locals,
        result,
        texts,
    })
}

/// Pack per-request masks into [B, L, m], padding unused slots dense.
pub fn pack_slot_masks(
    masks: &[MaskSet],
    active: usize,
    b: usize,
    spec: &crate::runtime::ModelSpec,
) -> TensorF {
    let refs: Vec<Option<&MaskSet>> = (0..b)
        .map(|i| if i < active { Some(&masks[i]) } else { None })
        .collect();
    pack_masks(&refs, spec.n_layers, spec.ffn_m)
}

/// Dense reference generation for the same prompts (the trajectory the
/// deviation metrics condition on, App. B.2).
pub fn run_dense_batch(
    engine: &Engine,
    prompts: &[String],
    b: usize,
) -> Result<GenerateResult> {
    let mask = engine.dense_mask(b);
    engine.generate(prompts, &mask, b)
}
