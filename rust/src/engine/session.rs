//! High-level sparse-generation sessions: the paper's full request flow
//! (Fig. 2) — prefill, learn A^l, fuse with the global prior, build the
//! static mask, then decode with it — plus the per-slot
//! [`DecodeSession`] state machine the continuous batcher drives token
//! by token (position, stop state, decode-time statistics accumulator,
//! and the current mask with its refresh bookkeeping).

use anyhow::Result;

use super::{Engine, GenerateResult, PrefillResult};
use crate::glass::{
    build_mask, pack_masks, DecayingImportance, GlobalPrior, ImportanceMap,
    MaskSet, Strategy,
};
use crate::tensor::{argmax, TensorF};

/// Everything produced by a sparse batch request.
#[derive(Debug, Clone)]
pub struct SparseRun {
    pub masks: Vec<MaskSet>,
    pub locals: Vec<ImportanceMap>,
    pub result: GenerateResult,
    pub texts: Vec<String>,
}

/// Run the full GLASS flow on a batch of prompts: prefill → per-slot mask
/// via `strategy` → fused sparse generation.
///
/// `density` sets the per-layer budget k = round(m · density); `prior`
/// must be supplied when the strategy needs one.
pub fn run_sparse_batch(
    engine: &Engine,
    prompts: &[String],
    strategy: &Strategy,
    prior: Option<&GlobalPrior>,
    density: f64,
    b: usize,
) -> Result<SparseRun> {
    let spec = engine.spec().clone();
    let k = spec.budget(density);

    let pre = engine.prefill(prompts, b)?;
    let mut locals = Vec::with_capacity(prompts.len());
    let mut masks = Vec::with_capacity(prompts.len());
    for slot in 0..prompts.len() {
        let local = engine.local_importance(&pre, slot)?;
        let mask = build_mask(strategy, &local, prior, k)?;
        locals.push(local);
        masks.push(mask);
    }

    let mask_t = pack_slot_masks(&masks, prompts.len(), b, &spec);
    let result = engine.generate(prompts, &mask_t, b)?;
    let texts = (0..prompts.len())
        .map(|i| {
            let n = result.tokens.shape[1];
            engine.decode_text(&result.tokens.data[i * n..(i + 1) * n])
        })
        .collect();
    Ok(SparseRun {
        masks,
        locals,
        result,
        texts,
    })
}

/// Pack per-request masks into [B, L, m], padding unused slots dense.
pub fn pack_slot_masks(
    masks: &[MaskSet],
    active: usize,
    b: usize,
    spec: &crate::runtime::ModelSpec,
) -> TensorF {
    let refs: Vec<Option<&MaskSet>> = (0..b)
        .map(|i| if i < active { Some(&masks[i]) } else { None })
        .collect();
    pack_masks(&refs, spec.n_layers, spec.ffn_m)
}

// ------------------------------------------------- continuous decoding

/// Why a slot stopped decoding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Hit max_tokens or the KV window.
    Length,
    /// The model emitted a special (≥ byte range) token.
    Stop,
}

impl FinishReason {
    pub fn as_str(self) -> &'static str {
        match self {
            FinishReason::Length => "length",
            FinishReason::Stop => "stop",
        }
    }
}

/// Per-slot decode state for the continuous batcher: everything one
/// in-flight request needs between steps. The decode-time activation
/// statistics are folded into a decaying average so a periodic GLASS
/// mask refresh can re-aggregate them with the prompt statistics.
#[derive(Debug, Clone)]
pub struct DecodeSession {
    /// Prompt length including BOS (also the first decode position).
    pub prompt_len: usize,
    /// Next write position in the KV cache.
    pub pos: i32,
    /// Last emitted token (the next step's input).
    pub last_tok: i32,
    /// Generated tokens so far (first comes from the prefill logits).
    pub generated: Vec<i32>,
    /// Prompt-time local statistics A^l (fixed at prefill).
    pub prompt_local: ImportanceMap,
    /// Decaying average of per-step decode statistics.
    pub decode_acc: DecayingImportance,
    /// Current mask (starts as the prefill-time mask; refreshes may
    /// replace it, counted in `mask_updates`).
    pub mask: MaskSet,
    /// Per-layer neuron budget.
    pub k: usize,
    /// Mask refreshes applied / refreshes that changed the kept set.
    pub refreshes: usize,
    pub mask_updates: usize,
    pub finished: Option<FinishReason>,
}

impl DecodeSession {
    /// Start a session from one prefilled slot: seed the first token
    /// from the prefill logits and position decoding at the prompt end.
    /// The `pre` may come from the monolithic `prefill` executable or
    /// from a completed chunked stream ([`ChunkedPrefill::result`]) —
    /// both produce the same shapes and statistics.
    ///
    /// [`ChunkedPrefill::result`]: super::chunked::ChunkedPrefill::result
    ///
    /// Serving semantics: the first token deliberately comes from the
    /// *dense* prefill forward pass — the mask is only built from the
    /// prefill statistics, so it cannot causally apply before the first
    /// decode step. (The fused `generate` executable instead applies
    /// the mask retroactively to the prefill position; the two paths
    /// may emit different first tokens for aggressive masks.)
    pub fn from_prefill(
        pre: &PrefillResult,
        slot: usize,
        mask: MaskSet,
        k: usize,
        stat_decay: f64,
    ) -> Result<DecodeSession> {
        let local = ImportanceMap::from_stats(&pre.stats, slot)?;
        let first = argmax(pre.logits.row(slot)) as i32;
        // same stop rule as absorb_step: a special first token ends the
        // request at prefill instead of being decoded against
        let (generated, finished) = if first >= 256 {
            (Vec::new(), Some(FinishReason::Stop))
        } else {
            (vec![first], None)
        };
        Ok(DecodeSession {
            prompt_len: pre.lens[slot],
            pos: pre.lens[slot] as i32,
            last_tok: first,
            generated,
            decode_acc: DecayingImportance::new(
                local.n_layers(),
                local.m(),
                stat_decay,
            ),
            prompt_local: local,
            mask,
            k,
            refreshes: 0,
            mask_updates: 0,
            finished,
        })
    }

    /// Fold one decode step's outputs into the session: accumulate the
    /// slot's activation statistics, advance the position, emit the next
    /// token, and update the stop state. Returns true when finished.
    pub fn absorb_step(
        &mut self,
        logits_row: &[f32],
        stats: &TensorF,
        slot: usize,
        max_tokens: usize,
        max_seq: usize,
    ) -> Result<bool> {
        debug_assert!(self.finished.is_none(), "step on finished session");
        self.decode_acc
            .push(&ImportanceMap::from_stats(stats, slot)?);
        self.pos += 1;
        let next = argmax(logits_row) as i32;
        if next >= 256 {
            self.finished = Some(FinishReason::Stop);
        } else {
            self.generated.push(next);
            self.last_tok = next;
            if self.generated.len() >= max_tokens.max(1)
                || self.pos as usize >= max_seq
            {
                self.finished = Some(FinishReason::Length);
            }
        }
        Ok(self.finished.is_some())
    }

    /// The paper's aggregation over the generation horizon: blend the
    /// fixed prompt statistics with the decaying decode-time average.
    /// `prompt_weight` is the pseudo-count mass of the prompt evidence.
    pub fn blended_local(&self, prompt_weight: f64) -> ImportanceMap {
        self.decode_acc
            .blend_with(&self.prompt_local, prompt_weight)
    }
}

/// Dense reference generation for the same prompts (the trajectory the
/// deviation metrics condition on, App. B.2).
pub fn run_dense_batch(
    engine: &Engine,
    prompts: &[String],
    b: usize,
) -> Result<GenerateResult> {
    let mask = engine.dense_mask(b);
    engine.generate(prompts, &mask, b)
}
