//! Persistent shared-prefix cache snapshots (`--cache-dir`).
//!
//! The prefix cache is a pure function of token prefixes, so its hot
//! entries survive a process restart losslessly: on graceful shutdown
//! each shard's batcher serializes its resident entries to
//! `<cache-dir>/prefix-shard-<i>.gpxs`, and the next startup imports
//! them back ([`PrefixCache::import_seed`]) before serving — a restart
//! then answers a previously-cached prompt with zero engine prefill
//! calls, observable as `warm_start_hits` in the stats command.
//!
//! # Format (version 1)
//!
//! Little-endian throughout, no external dependencies:
//!
//! ```text
//! magic      4 bytes   "GPXS"
//! version    u32       SNAPSHOT_VERSION (1)
//! spec       6 × u32   n_layers, n_heads, head_dim, ffn_m, vocab,
//!                      max_seq — the model fingerprint; a snapshot
//!                      from a different bundle is skipped whole
//! count      u32       entry count
//! entry*     per entry:
//!              tokens   u32 len, then len × i32
//!              weight   f64
//!              k_rows   u32 len, then len × f32
//!              v_rows   u32 len, then len × f32
//!              stats    u32 len (= n_layers · ffn_m), then len × f32
//!              logits   u32 len (= vocab), then len × f32
//! checksum   u64       FNV-1a over every preceding byte
//! ```
//!
//! Every length is validated while parsing and the checksum is
//! verified before any entry is trusted, so a truncated, corrupted, or
//! mismatched file is reported loudly ([`load`] errors, the caller
//! logs and serves cold) — **never** a startup failure and never a
//! partially-imported snapshot with undetected damage. [`save`] writes
//! to a temp file and renames it into place so a crash mid-snapshot
//! leaves the previous snapshot intact.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::prefix_cache::PrefixSeed;
use crate::glass::ImportanceMap;
use crate::runtime::ModelSpec;

/// On-disk snapshot format version (bump on any layout change; a
/// version mismatch skips the file, it never aborts startup).
pub const SNAPSHOT_VERSION: u32 = 1;

const MAGIC: &[u8; 4] = b"GPXS";

/// FNV-1a 64-bit — the same hash family `route_shard` uses, so the
/// whole serving stack needs exactly one hash primitive.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// The snapshot file for one serving shard under `cache_dir`. Shards
/// are stable across restarts (`route_shard` is deterministic), so a
/// per-shard file always warms the shard that will serve its prefixes.
pub fn snapshot_path(cache_dir: &Path, shard: usize) -> PathBuf {
    cache_dir.join(format!("prefix-shard-{shard}.gpxs"))
}

// ------------------------------------------------------------- writing

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn i32s(&mut self, v: &[i32]) {
        self.u32(v.len() as u32);
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

fn spec_fingerprint(spec: &ModelSpec) -> [u32; 6] {
    [
        spec.n_layers as u32,
        spec.n_heads as u32,
        spec.head_dim as u32,
        spec.ffn_m as u32,
        spec.vocab as u32,
        spec.max_seq as u32,
    ]
}

/// Serialize `entries` (token key + seed pairs, as produced by
/// `PrefixCache::export_hot`) to `path` atomically.
pub fn save(
    path: &Path,
    spec: &ModelSpec,
    entries: &[(Vec<i32>, PrefixSeed)],
) -> Result<()> {
    let mut w = Writer { buf: Vec::new() };
    w.buf.extend_from_slice(MAGIC);
    w.u32(SNAPSHOT_VERSION);
    for v in spec_fingerprint(spec) {
        w.u32(v);
    }
    w.u32(entries.len() as u32);
    for (tokens, seed) in entries {
        w.i32s(tokens);
        w.f64(seed.weight);
        w.f32s(&seed.k_rows);
        w.f32s(&seed.v_rows);
        let mut stats = Vec::with_capacity(
            seed.stats.n_layers() * seed.stats.m(),
        );
        for layer in &seed.stats.layers {
            stats.extend_from_slice(layer);
        }
        w.f32s(&stats);
        w.f32s(&seed.logits);
    }
    let sum = fnv1a(&w.buf);
    w.buf.extend_from_slice(&sum.to_le_bytes());
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
    }
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, &w.buf)
        .with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming into {}", path.display()))?;
    Ok(())
}

// ------------------------------------------------------------- reading

struct Reader<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.at + n > self.buf.len() {
            bail!(
                "truncated snapshot: need {n} bytes at offset {}, have {}",
                self.at,
                self.buf.len() - self.at
            );
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        // lint: allow(no-unwrap-on-serving-paths) -- take(4) returned
        // exactly 4 bytes, so the array conversion cannot fail
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        // lint: allow(no-unwrap-on-serving-paths) -- take(8) returned
        // exactly 8 bytes, so the array conversion cannot fail
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn i32s(&mut self, max: usize) -> Result<Vec<i32>> {
        let n = self.u32()? as usize;
        if n > max {
            bail!("snapshot list of {n} i32s exceeds the {max} sanity cap");
        }
        Ok(self
            .take(n * 4)?
            .chunks_exact(4)
            // lint: allow(no-unwrap-on-serving-paths) -- chunks_exact
            // yields 4-byte chunks, the conversion cannot fail
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn f32s(&mut self, max: usize) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        if n > max {
            bail!("snapshot list of {n} f32s exceeds the {max} sanity cap");
        }
        Ok(self
            .take(n * 4)?
            .chunks_exact(4)
            // lint: allow(no-unwrap-on-serving-paths) -- chunks_exact
            // yields 4-byte chunks, the conversion cannot fail
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

/// Parse a snapshot written by [`save`]. Errors (reported with the
/// offending detail) on ANY damage: bad magic, unknown version, spec
/// fingerprint mismatch, truncation, oversized lengths, or checksum
/// failure — the caller logs the error and starts cold. A missing file
/// is `Ok(vec![])`: a first boot is not a warning.
pub fn load(
    path: &Path,
    spec: &ModelSpec,
) -> Result<Vec<(Vec<i32>, PrefixSeed)>> {
    if !path.exists() {
        return Ok(Vec::new());
    }
    let buf = std::fs::read(path)
        .with_context(|| format!("reading {}", path.display()))?;
    if buf.len() < MAGIC.len() + 4 + 6 * 4 + 4 + 8 {
        bail!("snapshot of {} bytes is too short to be valid", buf.len());
    }
    let (body, sum_bytes) = buf.split_at(buf.len() - 8);
    // lint: allow(no-unwrap-on-serving-paths) -- split_at leaves
    // exactly 8 checksum bytes, the conversion cannot fail
    let want = u64::from_le_bytes(sum_bytes.try_into().unwrap());
    let got = fnv1a(body);
    if got != want {
        bail!("snapshot checksum mismatch ({got:#x} != {want:#x})");
    }
    let mut r = Reader { buf: body, at: 0 };
    if r.take(4)? != MAGIC {
        bail!("snapshot magic mismatch (not a prefix-cache snapshot)");
    }
    let version = r.u32()?;
    if version != SNAPSHOT_VERSION {
        bail!(
            "snapshot version {version} != supported {SNAPSHOT_VERSION}"
        );
    }
    let fp = spec_fingerprint(spec);
    let mut disk_fp = [0u32; 6];
    for v in disk_fp.iter_mut() {
        *v = r.u32()?;
    }
    if disk_fp != fp {
        bail!(
            "snapshot model fingerprint {disk_fp:?} does not match the \
             loaded bundle {fp:?}"
        );
    }
    let count = r.u32()? as usize;
    // sanity caps: a prefix key fits the KV window, rows/logits are
    // fixed functions of the spec — anything larger is corruption
    let row_cap =
        spec.n_layers * spec.n_heads * spec.max_seq * spec.head_dim;
    let lm = spec.n_layers * spec.ffn_m;
    let mut out = Vec::with_capacity(count.min(1024));
    for i in 0..count {
        let err = |what: &str| format!("snapshot entry {i}: {what}");
        let tokens = r.i32s(spec.max_seq)?;
        if tokens.is_empty() {
            bail!("{}", err("empty token key"));
        }
        let weight = r.f64()?;
        let k_rows = r.f32s(row_cap)?;
        let v_rows = r.f32s(row_cap)?;
        let stats_flat = r.f32s(lm)?;
        if stats_flat.len() != lm {
            bail!("{}", err("statistics length mismatch"));
        }
        let stats = ImportanceMap::from_layers(
            stats_flat
                .chunks_exact(spec.ffn_m)
                .map(|c| c.to_vec())
                .collect(),
        )?;
        let logits = r.f32s(spec.vocab)?;
        let seed = PrefixSeed {
            len: tokens.len(),
            k_rows,
            v_rows,
            stats,
            weight,
            logits,
        };
        out.push((tokens, seed));
    }
    if r.at != body.len() {
        bail!(
            "snapshot has {} trailing bytes after the last entry",
            body.len() - r.at
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::prefix_cache::{CacheTelemetry, PrefixCache};
    use crate::engine::KvState;
    use std::sync::Arc;

    fn tiny_spec() -> ModelSpec {
        ModelSpec {
            vocab: 260,
            d_model: 4,
            n_layers: 2,
            n_heads: 1,
            head_dim: 4,
            ffn_m: 8,
            max_seq: 16,
            prefill_len: 4,
            score_len: 6,
            gen_len: 2,
            bos_id: 256,
            pad_id: 257,
        }
    }

    fn tmp_path(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!(
            "glass-prefix-store-{}-{name}",
            std::process::id()
        ));
        p
    }

    fn sample_entries(
        spec: &ModelSpec,
    ) -> Vec<(Vec<i32>, PrefixSeed)> {
        let tele = Arc::new(CacheTelemetry::default());
        let mut c = PrefixCache::new(spec.clone(), usize::MAX, tele);
        let mut kv = KvState::zeros(spec, 1);
        for (i, x) in kv.k.data.iter_mut().enumerate() {
            *x = i as f32 * 0.5;
        }
        for (i, x) in kv.v.data.iter_mut().enumerate() {
            *x = -(i as f32) * 0.25;
        }
        let stats = ImportanceMap::from_layers(vec![
            (0..spec.ffn_m).map(|i| i as f32).collect();
            spec.n_layers
        ])
        .unwrap();
        let logits: Vec<f32> =
            (0..spec.vocab).map(|i| i as f32 * 0.125).collect();
        c.insert(&[256, 97, 98], &kv, 0, &stats, 3.0, &logits);
        c.insert(&[256, 120], &kv, 0, &stats, 2.0, &logits);
        c.export_hot()
    }

    #[test]
    fn save_load_roundtrip_is_bit_identical() {
        let spec = tiny_spec();
        let entries = sample_entries(&spec);
        let path = tmp_path("roundtrip.gpxs");
        save(&path, &spec, &entries).unwrap();
        let back = load(&path, &spec).unwrap();
        assert_eq!(back.len(), entries.len());
        for ((tk_a, a), (tk_b, b)) in entries.iter().zip(back.iter()) {
            assert_eq!(tk_a, tk_b);
            assert_eq!(a.len, b.len);
            assert_eq!(a.k_rows, b.k_rows);
            assert_eq!(a.v_rows, b.v_rows);
            assert_eq!(a.stats.layers, b.stats.layers);
            assert_eq!(a.weight.to_bits(), b.weight.to_bits());
            assert_eq!(a.logits, b.logits);
        }
        // and the loaded entries import cleanly as warm entries
        let tele = Arc::new(CacheTelemetry::default());
        let mut c = PrefixCache::new(spec.clone(), usize::MAX, tele);
        for (tokens, seed) in back {
            assert!(c.import_seed(&tokens, seed).unwrap());
        }
        assert_eq!(c.warm_len(), entries.len());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_is_a_clean_cold_start() {
        let spec = tiny_spec();
        let loaded =
            load(&tmp_path("never-written.gpxs"), &spec).unwrap();
        assert!(loaded.is_empty());
    }

    #[test]
    fn corruption_is_detected_never_imported() {
        let spec = tiny_spec();
        let entries = sample_entries(&spec);
        let path = tmp_path("corrupt.gpxs");
        save(&path, &spec, &entries).unwrap();
        let good = std::fs::read(&path).unwrap();

        // flip one payload byte → checksum mismatch
        let mut bad = good.clone();
        bad[good.len() / 2] ^= 0x40;
        std::fs::write(&path, &bad).unwrap();
        let err = load(&path, &spec).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");

        // truncate → too short / truncated
        std::fs::write(&path, &good[..good.len() / 3]).unwrap();
        assert!(load(&path, &spec).is_err());

        // bad magic (checksum recomputed so the magic check fires)
        let mut bad = good.clone();
        bad[0] = b'X';
        let body_len = bad.len() - 8;
        let sum = super::fnv1a(&bad[..body_len]);
        bad[body_len..].copy_from_slice(&sum.to_le_bytes());
        std::fs::write(&path, &bad).unwrap();
        let err = load(&path, &spec).unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");

        // future version is skipped, not mis-parsed
        let mut bad = good.clone();
        bad[4..8].copy_from_slice(
            &(SNAPSHOT_VERSION + 1).to_le_bytes(),
        );
        let sum = super::fnv1a(&bad[..body_len]);
        bad[body_len..].copy_from_slice(&sum.to_le_bytes());
        std::fs::write(&path, &bad).unwrap();
        let err = load(&path, &spec).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");

        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn spec_mismatch_is_skipped_loudly() {
        let spec = tiny_spec();
        let entries = sample_entries(&spec);
        let path = tmp_path("spec-mismatch.gpxs");
        save(&path, &spec, &entries).unwrap();
        let mut other = tiny_spec();
        other.vocab += 1;
        let err = load(&path, &other).unwrap_err();
        assert!(err.to_string().contains("fingerprint"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn shard_paths_are_distinct_and_stable() {
        let dir = PathBuf::from("/tmp/cache");
        assert_eq!(
            snapshot_path(&dir, 0),
            PathBuf::from("/tmp/cache/prefix-shard-0.gpxs")
        );
        assert_ne!(snapshot_path(&dir, 0), snapshot_path(&dir, 1));
    }
}
