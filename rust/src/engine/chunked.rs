//! Chunked prefill: long-prompt support over the fixed prefill frame.
//!
//! The compiled prefill executable consumes at most `prefill_len` tokens
//! per call, so prompts used to be silently tail-truncated at admission.
//! Instead, [`ChunkedPrefill`] streams a prompt of any length (up to the
//! `max_seq` KV window) through `prefill_len`-sized chunks of the
//! `prefill_chunk` executable: each chunk carries the KV cache in at a
//! sequence offset and appends its rows in place, and emits *per-chunk*
//! local statistics that the host merges token-count-weighted via
//! [`ImportanceMap::merge`] — reproducing exactly the statistics a
//! monolithic prefill over the whole prompt would produce (bit-identical
//! when the prompt fits one frame; see the integration equivalence
//! tests).
//!
//! The API is deliberately incremental — one executable call per
//! [`Engine::chunked_prefill_step`] — so the continuous batcher can
//! interleave a newcomer's prefill chunks between decode steps (a
//! per-step admission budget) instead of stalling every in-flight slot
//! for the whole prompt. [`Engine::prefill_chunked`] drives the loop to
//! completion for batch callers (tests, harnesses, benches).

use anyhow::{bail, Result};

use super::prefix_cache::PrefixSeed;
use super::{Engine, KvState, PrefillResult};
use crate::glass::ImportanceMap;
use crate::tensor::{TensorF, TensorI};

/// In-flight state of one request's chunked prefill (batch width 1).
#[derive(Debug, Clone)]
pub struct ChunkedPrefill {
    /// Full encoded prompt (BOS + bytes) — never truncated.
    tokens: Vec<i32>,
    /// Frame fill per chunk (≤ `prefill_len`; tests shrink it to check
    /// partition invariance).
    chunk_len: usize,
    /// Tokens consumed so far == the next chunk's absolute offset.
    consumed: usize,
    /// KV cache being filled (batch width 1, `max_seq` positions).
    pub kv: KvState,
    /// Token-count-weighted merge of per-chunk local statistics A^l.
    merged: ImportanceMap,
    /// Evidence mass (token count) behind `merged`.
    merged_weight: f64,
    /// Next-token logits at the last consumed position ([vocab]).
    logits: Vec<f32>,
    /// Chunk executable calls made so far.
    pub chunks_done: usize,
    /// Tokens seeded from the shared-prefix cache (0 on a cold stream):
    /// the stream started at this offset instead of recomputing the
    /// prefix — the serving layer's `cached_prompt_tokens` telemetry.
    pub cached: usize,
}

impl ChunkedPrefill {
    /// Total prompt length in tokens (incl. BOS).
    pub fn total_len(&self) -> usize {
        self.tokens.len()
    }

    /// Tokens consumed so far.
    pub fn consumed(&self) -> usize {
        self.consumed
    }

    /// Prompt tokens not yet prefilled.
    pub fn remaining(&self) -> usize {
        self.tokens.len() - self.consumed
    }

    pub fn is_done(&self) -> bool {
        self.consumed >= self.tokens.len()
    }

    /// Merged local importance over all consumed chunks.
    pub fn local_importance(&self) -> &ImportanceMap {
        &self.merged
    }

    /// Full encoded prompt (BOS + token ids).
    pub fn tokens(&self) -> &[i32] {
        &self.tokens
    }

    /// Evidence mass (token count) behind [`Self::local_importance`].
    pub fn merged_weight(&self) -> f64 {
        self.merged_weight
    }

    /// Last-position logits after the most recent chunk ([vocab]).
    pub fn logits(&self) -> &[f32] {
        &self.logits
    }

    /// Assemble the finished stream into a one-slot [`PrefillResult`] —
    /// the same shape a monolithic `prefill` call returns, so mask
    /// selection and [`DecodeSession::from_prefill`] work unchanged.
    ///
    /// [`DecodeSession::from_prefill`]: super::session::DecodeSession::from_prefill
    pub fn result(&self) -> Result<PrefillResult> {
        if !self.is_done() {
            bail!(
                "chunked prefill still has {} of {} tokens pending",
                self.remaining(),
                self.total_len()
            );
        }
        Ok(PrefillResult {
            logits: TensorF::new(
                vec![1, self.logits.len()],
                self.logits.clone(),
            )?,
            kv: self.kv.clone(),
            stats: self.merged.to_stats_tensor(),
            lens: vec![self.tokens.len()],
            truncated: vec![false],
        })
    }

    /// Consuming variant of [`ChunkedPrefill::result`] that moves the
    /// KV cache out instead of cloning it (megabytes per request at
    /// real model scale) — the batcher's admission path.
    pub fn into_result(self) -> Result<PrefillResult> {
        if !self.is_done() {
            bail!(
                "chunked prefill still has {} of {} tokens pending",
                self.remaining(),
                self.total_len()
            );
        }
        Ok(PrefillResult {
            logits: TensorF::new(vec![1, self.logits.len()], self.logits)?,
            kv: self.kv,
            stats: self.merged.to_stats_tensor(),
            lens: vec![self.tokens.len()],
            truncated: vec![false],
        })
    }
}

impl Engine {
    /// Begin a chunked prefill with the standard `prefill_len` chunk
    /// size. Errors when the prompt cannot fit the KV window at all.
    pub fn chunked_prefill_start(
        &self,
        prompt: &str,
    ) -> Result<ChunkedPrefill> {
        self.chunked_prefill_start_with(prompt, self.spec().prefill_len)
    }

    /// Begin a chunked prefill with an explicit chunk size (tests use
    /// smaller-than-frame chunks to verify partition invariance).
    pub fn chunked_prefill_start_with(
        &self,
        prompt: &str,
        chunk_len: usize,
    ) -> Result<ChunkedPrefill> {
        self.chunked_prefill_from_tokens(
            self.tok.encode_with_bos(prompt),
            chunk_len,
        )
    }

    /// Begin a chunked prefill from an already-encoded prompt (BOS +
    /// token ids) — the batcher's path, which tokenizes once during
    /// admission screening and hands the ids straight through.
    pub fn chunked_prefill_from_tokens(
        &self,
        tokens: Vec<i32>,
        chunk_len: usize,
    ) -> Result<ChunkedPrefill> {
        let spec = self.spec();
        if chunk_len == 0 || chunk_len > spec.prefill_len {
            bail!(
                "chunk_len {chunk_len} outside 1..={}",
                spec.prefill_len
            );
        }
        if tokens.len() > spec.max_seq {
            bail!(
                "prompt needs {} KV positions but the window holds {}",
                tokens.len(),
                spec.max_seq
            );
        }
        Ok(ChunkedPrefill {
            tokens,
            chunk_len,
            consumed: 0,
            kv: KvState::zeros(spec, 1),
            merged: ImportanceMap::zeros(spec.n_layers, spec.ffn_m),
            merged_weight: 0.0,
            logits: vec![0.0; spec.vocab],
            chunks_done: 0,
            cached: 0,
        })
    }

    /// Begin a chunked prefill from a cached prefix: the stream starts
    /// at the seed's length with the prefix's KV rows spliced in and the
    /// merge state `(stats, weight, logits)` restored — continuing with
    /// the same chunk partition and merge arithmetic a cold stream would
    /// have used from that point, so the finished statistics are
    /// bit-identical when the seed was published at a chunk boundary of
    /// the same partition. A seed covering the whole prompt yields a
    /// stream that [`ChunkedPrefill::is_done`] immediately (exact-hit:
    /// zero executable calls).
    pub fn chunked_prefill_resume(
        &self,
        tokens: Vec<i32>,
        chunk_len: usize,
        seed: PrefixSeed,
    ) -> Result<ChunkedPrefill> {
        let mut st = self.chunked_prefill_from_tokens(tokens, chunk_len)?;
        let spec = self.spec();
        if seed.len > st.tokens.len() {
            bail!(
                "cached prefix of {} tokens exceeds the {}-token prompt",
                seed.len,
                st.tokens.len()
            );
        }
        if seed.logits.len() != spec.vocab {
            bail!(
                "cached logits of {} values do not match vocab {}",
                seed.logits.len(),
                spec.vocab
            );
        }
        if seed.stats.n_layers() != spec.n_layers
            || seed.stats.m() != spec.ffn_m
        {
            bail!("cached statistics shape mismatch");
        }
        let row_n =
            spec.n_layers * spec.n_heads * seed.len * spec.head_dim;
        if seed.k_rows.len() != row_n || seed.v_rows.len() != row_n {
            bail!("cached KV rows shape mismatch");
        }
        if seed.len == 0 {
            return Ok(st);
        }
        st.kv
            .write_prefix_rows(0, seed.len, &seed.k_rows, &seed.v_rows);
        st.merged = seed.stats;
        st.merged_weight = seed.weight;
        st.logits = seed.logits;
        st.consumed = seed.len;
        st.cached = seed.len;
        Ok(st)
    }

    /// Feed ONE chunk of the prompt through the `prefill_chunk`
    /// executable: KV rows land at the stream's current offset, the
    /// chunk's local statistics are merged token-count-weighted into the
    /// running aggregate, and the last-position logits are kept. Returns
    /// true once the whole prompt has been consumed.
    pub fn chunked_prefill_step(
        &self,
        st: &mut ChunkedPrefill,
    ) -> Result<bool> {
        if st.is_done() {
            return Ok(true);
        }
        let spec = self.spec();
        let take = st.chunk_len.min(st.remaining());
        let s = spec.prefill_len;
        let mut frame = vec![spec.pad_id; s];
        frame[..take]
            .copy_from_slice(&st.tokens[st.consumed..st.consumed + take]);
        let tokens = TensorI::new(vec![1, s], frame)?;
        let (logits, stats) = self.prefill_chunk(
            &mut st.kv,
            &tokens,
            &[take as i32],
            &[st.consumed as i32],
        )?;
        let chunk_map = ImportanceMap::from_stats(&stats, 0)?;
        if st.merged_weight <= 0.0 {
            // first chunk verbatim: keeps the single-frame case
            // bit-identical to the monolithic prefill statistics
            st.merged = chunk_map;
        } else {
            st.merged
                .merge(&chunk_map, st.merged_weight, take as f64);
        }
        st.merged_weight += take as f64;
        st.logits.copy_from_slice(logits.row(0));
        st.consumed += take;
        st.chunks_done += 1;
        Ok(st.is_done())
    }

    /// Drive a batch of prompts through chunked prefill to completion
    /// and assemble a batch-shaped [`PrefillResult`] — the drop-in
    /// equivalent of [`Engine::prefill`] without any prompt-length
    /// ceiling below the KV window.
    pub fn prefill_chunked(
        &self,
        prompts: &[String],
        b: usize,
    ) -> Result<PrefillResult> {
        let spec = self.spec().clone();
        if prompts.len() > b {
            bail!("{} prompts > batch {b}", prompts.len());
        }
        let mut kv = KvState::zeros(&spec, b);
        let mut logits = vec![0.0f32; b * spec.vocab];
        let lm = spec.n_layers * spec.ffn_m;
        let mut stats = vec![0.0f32; b * lm];
        let mut lens = vec![1usize; b];
        for (i, p) in prompts.iter().enumerate() {
            let mut st = self.chunked_prefill_start(p)?;
            while !self.chunked_prefill_step(&mut st)? {}
            kv.copy_slot_from(i, &st.kv, 0);
            logits[i * spec.vocab..(i + 1) * spec.vocab]
                .copy_from_slice(&st.logits);
            let mut off = i * lm;
            for layer in &st.merged.layers {
                stats[off..off + layer.len()].copy_from_slice(layer);
                off += layer.len();
            }
            lens[i] = st.total_len();
        }
        Ok(PrefillResult {
            logits: TensorF::new(vec![b, spec.vocab], logits)?,
            kv,
            stats: TensorF::new(vec![b, spec.n_layers, spec.ffn_m], stats)?,
            lens,
            truncated: vec![false; b],
        })
    }
}

#[cfg(test)]
mod tests {
    // Cross-executable equivalence (chunked vs monolithic prefill) lives
    // in rust/tests/integration_engine.rs; pure state-machine edges here.
    use super::*;
    use crate::runtime::Runtime;
    use std::sync::Arc;

    fn engine() -> Engine {
        Engine::from_runtime(Arc::new(Runtime::synthetic()))
    }

    #[test]
    fn rejects_bad_chunk_len_and_oversized_prompt() {
        let e = engine();
        let pl = e.spec().prefill_len;
        assert!(e.chunked_prefill_start_with("hi", 0).is_err());
        assert!(e.chunked_prefill_start_with("hi", pl + 1).is_err());
        // prompt of max_seq bytes → max_seq + 1 tokens with BOS
        let too_long = "a".repeat(e.spec().max_seq);
        assert!(e.chunked_prefill_start(&too_long).is_err());
    }

    #[test]
    fn chunk_count_is_ceil_of_len_over_chunk() {
        let e = engine();
        let pl = e.spec().prefill_len;
        // 2.5 frames of prompt bytes (+ BOS) → 3 chunks
        let prompt = "ab ".repeat(pl * 5 / 6);
        let mut st = e.chunked_prefill_start(&prompt).unwrap();
        let total = st.total_len();
        assert!(total > 2 * pl && total <= 3 * pl, "len {total}");
        let mut steps = 0;
        while !e.chunked_prefill_step(&mut st).unwrap() {
            steps += 1;
            assert!(steps < 16, "runaway chunk loop");
        }
        assert_eq!(st.chunks_done, total.div_ceil(pl));
        assert_eq!(st.consumed(), total);
        assert_eq!(st.remaining(), 0);
        // stepping a finished stream is a no-op
        assert!(e.chunked_prefill_step(&mut st).unwrap());
        assert_eq!(st.chunks_done, total.div_ceil(pl));
        let pre = st.result().unwrap();
        assert_eq!(pre.lens, vec![total]);
        assert_eq!(pre.truncated, vec![false]);
    }

    #[test]
    fn resume_from_seed_skips_the_cached_prefix() {
        let e = engine();
        let spec = e.spec().clone();
        let pl = spec.prefill_len;
        let prompt = "abcdef ".repeat(2 * pl / 7 + 1);

        // cold reference stream, captured at the first chunk boundary
        let mut cold = e.chunked_prefill_start(&prompt).unwrap();
        assert!(!e.chunked_prefill_step(&mut cold).unwrap());
        let (k_rows, v_rows) =
            cold.kv.extract_prefix_rows(0, cold.consumed());
        let seed = PrefixSeed {
            len: cold.consumed(),
            k_rows,
            v_rows,
            stats: cold.local_importance().clone(),
            weight: cold.merged_weight(),
            logits: cold.logits().to_vec(),
        };

        let tokens = e.tok.encode_with_bos(&prompt);
        let total = tokens.len();
        let mut warm = e
            .chunked_prefill_resume(tokens.clone(), pl, seed.clone())
            .unwrap();
        assert_eq!(warm.cached, pl);
        assert_eq!(warm.consumed(), pl);
        assert_eq!(warm.chunks_done, 0);
        while !e.chunked_prefill_step(&mut warm).unwrap() {}
        // one fewer executable call than the cold stream needs
        assert_eq!(warm.chunks_done, total.div_ceil(pl) - 1);

        // finish the cold stream and compare: identical results
        while !e.chunked_prefill_step(&mut cold).unwrap() {}
        let (a, b) = (cold.result().unwrap(), warm.result().unwrap());
        assert_eq!(a.lens, b.lens);
        assert_eq!(a.logits.data, b.logits.data);
        assert_eq!(a.stats.data, b.stats.data);
        assert_eq!(a.kv.k.data, b.kv.k.data);
        assert_eq!(a.kv.v.data, b.kv.v.data);

        // a seed longer than the prompt is rejected
        let mut too_long = seed.clone();
        too_long.len = total + 1;
        assert!(e
            .chunked_prefill_resume(tokens.clone(), pl, too_long)
            .is_err());
        // malformed cached rows are rejected, not spliced
        let mut bad_rows = seed;
        bad_rows.k_rows.pop();
        assert!(e.chunked_prefill_resume(tokens, pl, bad_rows).is_err());
    }

    #[test]
    fn result_refuses_unfinished_stream() {
        let e = engine();
        let prompt = "x".repeat(e.spec().prefill_len * 2);
        let mut st = e.chunked_prefill_start(&prompt).unwrap();
        e.chunked_prefill_step(&mut st).unwrap();
        assert!(!st.is_done());
        assert!(st.result().is_err());
    }
}
