//! Inference engine: prefill → mask selection → decode, over the AOT
//! executables. This is the L3 hot path — pure Rust + PJRT, no Python.
//!
//! Two decode modes exist:
//!  * **step mode** (`decode_step*`) — one token per call with per-slot
//!    positions; used by the server's continuous batcher and the NPS
//!    driver. KV round-trips the host each step (xla_extension 0.5.1
//!    returns a single tuple buffer — see runtime docs).
//!  * **fused mode** (`generate`) — the whole greedy decode loop runs
//!    inside one XLA program (L2 `lax.scan`), no per-step host traffic;
//!    used for dense-trajectory generation and batch evaluation. The
//!    speedup of fused over step mode is quantified in bench_decode.
//!
//! Prefill comes in two shapes: the monolithic `prefill` executable
//! (one fixed frame; longer prompts are tail-truncated and flagged) and
//! the **chunked** path ([`chunked`]) that streams a prompt of any
//! length through `prefill_chunk` calls with carry-in KV, merging
//! per-chunk importance on the host — the serving layer's long-prompt
//! route.

pub mod chunked;
pub mod prefix_cache;
pub mod prefix_store;
pub mod session;

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::glass::ImportanceMap;
use crate::model::Tokenizer;
use crate::runtime::{ModelSpec, Runtime, Value};
use crate::tensor::{TensorF, TensorI};

/// Pull the next output of a runtime call, turning a missing output
/// into an error instead of a panic: an executable returning too few
/// outputs is a broken artifact, and the serving layer degrades that
/// request with an error frame rather than killing an engine thread.
fn next_out(
    it: &mut impl Iterator<Item = Value>,
    call: &str,
) -> Result<Value> {
    match it.next() {
        Some(v) => Ok(v),
        None => bail!("{call}: runtime returned too few outputs"),
    }
}

/// Host-side KV cache state for step-mode decode: [L, B, H, T, Dh] pair.
#[derive(Debug, Clone)]
pub struct KvState {
    pub k: TensorF,
    pub v: TensorF,
}

impl KvState {
    /// Zeroed cache for a batch of `b` slots.
    pub fn zeros(spec: &ModelSpec, b: usize) -> KvState {
        let shape =
            [spec.n_layers, b, spec.n_heads, spec.max_seq, spec.head_dim];
        KvState {
            k: TensorF::zeros(&shape),
            v: TensorF::zeros(&shape),
        }
    }

    /// Batch width of this cache.
    pub fn batch(&self) -> usize {
        self.k.shape[1]
    }

    /// Copy one slot's cache planes from another KvState — the slot
    /// surgery the continuous batcher uses to admit a freshly prefilled
    /// request into a free slot of the in-flight batch. Layout is
    /// [L, B, H, T, Dh], so each (layer, slot) plane is contiguous.
    pub fn copy_slot_from(
        &mut self,
        dst_slot: usize,
        src: &KvState,
        src_slot: usize,
    ) {
        let (l_n, b_dst) = (self.k.shape[0], self.k.shape[1]);
        let b_src = src.k.shape[1];
        let plane: usize = self.k.shape[2..].iter().product();
        assert_eq!(&self.k.shape[2..], &src.k.shape[2..], "KV shape mismatch");
        assert!(dst_slot < b_dst && src_slot < b_src, "slot out of range");
        for l in 0..l_n {
            let d = (l * b_dst + dst_slot) * plane;
            let s = (l * b_src + src_slot) * plane;
            self.k.data[d..d + plane]
                .copy_from_slice(&src.k.data[s..s + plane]);
            self.v.data[d..d + plane]
                .copy_from_slice(&src.v.data[s..s + plane]);
        }
    }

    /// Copy one slot's first `len` KV positions out into compact
    /// `[L, H, len, Dh]` buffers (K, V) — the shared-prefix cache's
    /// storage form, which holds only the prefix rows instead of the
    /// whole `max_seq` window. Positions are contiguous within each
    /// (layer, head) plane of the `[L, B, H, T, Dh]` layout, so each
    /// copy is one contiguous `len · Dh` slice.
    pub fn extract_prefix_rows(
        &self,
        slot: usize,
        len: usize,
    ) -> (Vec<f32>, Vec<f32>) {
        let (l_n, b) = (self.k.shape[0], self.k.shape[1]);
        let (h_n, t_n, dh) =
            (self.k.shape[2], self.k.shape[3], self.k.shape[4]);
        assert!(slot < b, "slot out of range");
        assert!(len <= t_n, "prefix longer than the KV window");
        let mut k_rows = vec![0.0f32; l_n * h_n * len * dh];
        let mut v_rows = vec![0.0f32; l_n * h_n * len * dh];
        for l in 0..l_n {
            for h in 0..h_n {
                let src = (((l * b + slot) * h_n + h) * t_n) * dh;
                let dst = ((l * h_n + h) * len) * dh;
                let n = len * dh;
                k_rows[dst..dst + n]
                    .copy_from_slice(&self.k.data[src..src + n]);
                v_rows[dst..dst + n]
                    .copy_from_slice(&self.v.data[src..src + n]);
            }
        }
        (k_rows, v_rows)
    }

    /// Splice compact `[L, H, len, Dh]` prefix rows (as produced by
    /// [`KvState::extract_prefix_rows`]) into one slot's positions
    /// `0..len`, leaving every other row untouched — how a cache hit's
    /// KV lands in a fresh chunked-prefill stream.
    pub fn write_prefix_rows(
        &mut self,
        slot: usize,
        len: usize,
        k_rows: &[f32],
        v_rows: &[f32],
    ) {
        let (l_n, b) = (self.k.shape[0], self.k.shape[1]);
        let (h_n, t_n, dh) =
            (self.k.shape[2], self.k.shape[3], self.k.shape[4]);
        assert!(slot < b, "slot out of range");
        assert!(len <= t_n, "prefix longer than the KV window");
        assert_eq!(k_rows.len(), l_n * h_n * len * dh, "K rows shape");
        assert_eq!(v_rows.len(), l_n * h_n * len * dh, "V rows shape");
        for l in 0..l_n {
            for h in 0..h_n {
                let dst = (((l * b + slot) * h_n + h) * t_n) * dh;
                let src = ((l * h_n + h) * len) * dh;
                let n = len * dh;
                self.k.data[dst..dst + n]
                    .copy_from_slice(&k_rows[src..src + n]);
                self.v.data[dst..dst + n]
                    .copy_from_slice(&v_rows[src..src + n]);
            }
        }
    }
}

/// Prefill output for a batch.
#[derive(Debug, Clone)]
pub struct PrefillResult {
    /// Next-token logits at each prompt's last position: [B, V].
    pub logits: TensorF,
    pub kv: KvState,
    /// Local importance statistics A^l: [B, L, m] (paper Eq. 4).
    pub stats: TensorF,
    /// True prompt lengths per slot.
    pub lens: Vec<usize>,
    /// Per-slot flag: the prompt exceeded the prefill frame and its head
    /// was dropped. Never true on the chunked-prefill path; serving
    /// layers must surface it (or reject the request) rather than
    /// silently serving a clipped prompt.
    pub truncated: Vec<bool>,
}

/// Fused-generation output for a batch.
#[derive(Debug, Clone)]
pub struct GenerateResult {
    /// Generated token ids: [B, N].
    pub tokens: TensorI,
    /// Next-token logits after each generated token: [B, N, V].
    pub logits: TensorF,
    /// Mean decode-time activation statistics: [B, L, m] — the paper's
    /// post-hoc oracle statistic when generated dense (App. C.1).
    pub stats: TensorF,
    /// Per-slot prompt-truncation flags (see [`PrefillResult::truncated`]).
    pub truncated: Vec<bool>,
}

/// The engine. Cheap to clone (shared runtime).
#[derive(Clone)]
pub struct Engine {
    pub rt: Arc<Runtime>,
    pub tok: Tokenizer,
}

impl Engine {
    pub fn load(artifacts_dir: &Path) -> Result<Engine> {
        Engine::load_with_backend(artifacts_dir, "auto")
    }

    /// Load the artifact bundle on a named execution backend (see
    /// [`crate::runtime::BACKEND_NAMES`]).
    pub fn load_with_backend(
        artifacts_dir: &Path,
        backend: &str,
    ) -> Result<Engine> {
        let rt =
            Arc::new(Runtime::load_with_backend(artifacts_dir, backend)?);
        let tok = Tokenizer::from_spec(&rt.manifest.model);
        Ok(Engine { rt, tok })
    }

    pub fn from_runtime(rt: Arc<Runtime>) -> Engine {
        let tok = Tokenizer::from_spec(&rt.manifest.model);
        Engine { rt, tok }
    }

    /// Fully in-memory engine on the simulator backend (no artifacts on
    /// disk). Used by tests, benches, and as the CLI fallback.
    pub fn synthetic() -> Engine {
        Engine::from_runtime(Arc::new(Runtime::synthetic()))
    }

    /// Fully in-memory engine on a named backend (`"sim"`/`"cpu-q8"`).
    pub fn synthetic_with_backend(backend: &str) -> Result<Engine> {
        Ok(Engine::from_runtime(Arc::new(
            Runtime::synthetic_with_backend(backend)?,
        )))
    }

    /// Load the artifact bundle if present, else fall back to the
    /// synthetic simulator engine.
    pub fn load_or_synthetic(artifacts_dir: &Path) -> Result<Engine> {
        Engine::load_or_synthetic_with_backend(artifacts_dir, "auto")
    }

    /// [`Engine::load_or_synthetic`] with an explicit backend name; the
    /// synthetic fallback honors the requested backend too.
    pub fn load_or_synthetic_with_backend(
        artifacts_dir: &Path,
        backend: &str,
    ) -> Result<Engine> {
        if artifacts_dir.join("manifest.json").exists() {
            Engine::load_with_backend(artifacts_dir, backend)
        } else {
            crate::info!(
                "no artifact bundle at {:?} — using the synthetic '{}' \
                 engine",
                artifacts_dir,
                backend
            );
            Engine::synthetic_with_backend(backend)
        }
    }

    pub fn spec(&self) -> &ModelSpec {
        &self.rt.manifest.model
    }

    /// Batch sizes with compiled executables (from the manifest).
    pub fn batch_sizes(&self) -> Vec<usize> {
        let mut out: Vec<usize> = self
            .rt
            .manifest
            .executables
            .iter()
            .filter_map(|e| {
                e.name
                    .strip_prefix("decode_b")
                    .and_then(|s| s.parse().ok())
            })
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Pick the smallest compiled batch size that fits `n` slots.
    pub fn pick_batch(&self, n: usize) -> Result<usize> {
        self.batch_sizes()
            .into_iter()
            .find(|&b| b >= n)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no compiled batch size fits {n} requests (have {:?})",
                    self.batch_sizes()
                )
            })
    }

    /// Encode prompts into the fixed prefill frame: BOS + bytes, PAD to
    /// prefill_len. Prompts longer than prefill_len-1 are tail-truncated
    /// (keeps the most recent context) and flagged in the returned
    /// per-slot `truncated` vector — callers must never ignore a set
    /// flag silently (serve prompts of any length via
    /// [`Engine::prefill_chunked`] instead).
    pub fn encode_prompts(
        &self,
        prompts: &[String],
        b: usize,
    ) -> Result<(TensorI, Vec<usize>, Vec<bool>)> {
        self.frame_encoded(
            prompts.iter().map(|p| self.tok.encode_with_bos(p)).collect(),
            b,
        )
    }

    /// Frame already-encoded prompts (BOS + token ids) — the shared
    /// tail of [`Engine::encode_prompts`] and the encoded entry points.
    fn frame_encoded(
        &self,
        encoded: Vec<Vec<i32>>,
        b: usize,
    ) -> Result<(TensorI, Vec<usize>, Vec<bool>)> {
        let spec = self.spec();
        if encoded.len() > b {
            bail!("{} prompts > batch {b}", encoded.len());
        }
        let s = spec.prefill_len;
        let mut toks = vec![spec.pad_id; b * s];
        let mut lens = vec![1usize; b];
        let mut truncated = vec![false; b];
        for (i, mut ids) in encoded.into_iter().enumerate() {
            if ids.len() > s {
                // keep BOS + most recent tokens
                let tail = ids.split_off(ids.len() - (s - 1));
                ids.truncate(1);
                ids.extend(tail);
                truncated[i] = true;
            }
            lens[i] = ids.len();
            toks[i * s..i * s + ids.len()].copy_from_slice(&ids);
        }
        Ok((TensorI::new(vec![b, s], toks)?, lens, truncated))
    }

    // ------------------------------------------------------------ calls

    pub fn prefill(
        &self,
        prompts: &[String],
        b: usize,
    ) -> Result<PrefillResult> {
        let framed = self.encode_prompts(prompts, b)?;
        self.prefill_framed(framed)
    }

    /// Prefill from already-encoded prompts (BOS + token ids) — the
    /// batcher's admission path, which tokenizes each prompt once at
    /// screening and hands the ids straight through.
    pub fn prefill_encoded(
        &self,
        encoded: Vec<Vec<i32>>,
        b: usize,
    ) -> Result<PrefillResult> {
        let framed = self.frame_encoded(encoded, b)?;
        self.prefill_framed(framed)
    }

    fn prefill_framed(
        &self,
        (tokens, lens, truncated): (TensorI, Vec<usize>, Vec<bool>),
    ) -> Result<PrefillResult> {
        let b = tokens.shape[0];
        let lens_t = TensorI::new(
            vec![b],
            lens.iter().map(|&l| l as i32).collect(),
        )?;
        let out = self.rt.call(
            &format!("prefill_b{b}"),
            &[Value::I32(tokens), Value::I32(lens_t)],
        )?;
        let mut it = out.into_iter();
        let logits = next_out(&mut it, "prefill")?.into_f32()?;
        let k = next_out(&mut it, "prefill")?.into_f32()?;
        let v = next_out(&mut it, "prefill")?.into_f32()?;
        let stats = next_out(&mut it, "prefill")?.into_f32()?;
        Ok(PrefillResult {
            logits,
            kv: KvState { k, v },
            stats,
            lens,
            truncated,
        })
    }

    /// One chunk of a chunked prefill (see [`chunked`]): feed up to
    /// `prefill_len` prompt tokens per slot at per-slot absolute sequence
    /// offsets, appending KV rows in place. `tokens` is a [B, prefill_len]
    /// PAD-filled frame; `lens[i]` is the valid token count of slot i in
    /// this chunk (0 = idle slot); `offsets[i]` is the absolute position
    /// of the chunk's first token. Returns (last-position logits [B, V],
    /// per-chunk local stats [B, L, m]).
    pub fn prefill_chunk(
        &self,
        kv: &mut KvState,
        tokens: &TensorI,
        lens: &[i32],
        offsets: &[i32],
    ) -> Result<(TensorF, TensorF)> {
        let b = kv.batch();
        let out = self.rt.call(
            &format!("prefill_chunk_b{b}"),
            &[
                Value::I32(tokens.clone()),
                Value::I32(TensorI::new(vec![b], lens.to_vec())?),
                Value::I32(TensorI::new(vec![b], offsets.to_vec())?),
                Value::F32(kv.k.clone()),
                Value::F32(kv.v.clone()),
            ],
        )?;
        let mut it = out.into_iter();
        let logits = next_out(&mut it, "prefill_chunk")?.into_f32()?;
        kv.k = next_out(&mut it, "prefill_chunk")?.into_f32()?;
        kv.v = next_out(&mut it, "prefill_chunk")?.into_f32()?;
        let stats = next_out(&mut it, "prefill_chunk")?.into_f32()?;
        Ok((logits, stats))
    }

    /// One masked decode step. `tokens`/`pos` have length B; `mask` is
    /// [B, L, m]. Returns (logits [B, V], per-token stats [B, L, m]) and
    /// updates `kv` in place.
    pub fn decode_step(
        &self,
        kv: &mut KvState,
        tokens: &[i32],
        pos: &[i32],
        mask: &TensorF,
    ) -> Result<(TensorF, TensorF)> {
        let b = tokens.len();
        let out = self.rt.call(
            &format!("decode_b{b}"),
            &[
                Value::I32(TensorI::new(vec![b], tokens.to_vec())?),
                Value::I32(TensorI::new(vec![b], pos.to_vec())?),
                Value::F32(kv.k.clone()),
                Value::F32(kv.v.clone()),
                Value::F32(mask.clone()),
            ],
        )?;
        let mut it = out.into_iter();
        let logits = next_out(&mut it, "decode")?.into_f32()?;
        kv.k = next_out(&mut it, "decode")?.into_f32()?;
        kv.v = next_out(&mut it, "decode")?.into_f32()?;
        let stats = next_out(&mut it, "decode")?.into_f32()?;
        Ok((logits, stats))
    }

    /// One gathered-sparse decode step (L1 Pallas kernel). `idx` is
    /// [B, L, K] with K = manifest.topk_k.
    pub fn decode_step_topk(
        &self,
        kv: &mut KvState,
        tokens: &[i32],
        pos: &[i32],
        idx: &TensorI,
    ) -> Result<(TensorF, TensorF)> {
        let b = tokens.len();
        let out = self.rt.call(
            &format!("decode_topk_b{b}"),
            &[
                Value::I32(TensorI::new(vec![b], tokens.to_vec())?),
                Value::I32(TensorI::new(vec![b], pos.to_vec())?),
                Value::F32(kv.k.clone()),
                Value::F32(kv.v.clone()),
                Value::I32(idx.clone()),
            ],
        )?;
        let mut it = out.into_iter();
        let logits = next_out(&mut it, "decode_topk")?.into_f32()?;
        kv.k = next_out(&mut it, "decode_topk")?.into_f32()?;
        kv.v = next_out(&mut it, "decode_topk")?.into_f32()?;
        let gstats = next_out(&mut it, "decode_topk")?.into_f32()?;
        Ok((logits, gstats))
    }

    /// Teacher-forced scorer: tokens [B, S_score], stats aggregation
    /// weights [B, S_score], mask [B, L, m]. Returns (logits [B, S, V],
    /// stats [B, L, m]).
    pub fn score(
        &self,
        tokens: &TensorI,
        stats_w: &TensorF,
        mask: &TensorF,
    ) -> Result<(TensorF, TensorF)> {
        let b = tokens.shape[0];
        let out = self.rt.call(
            &format!("score_b{b}"),
            &[
                Value::I32(tokens.clone()),
                Value::F32(stats_w.clone()),
                Value::F32(mask.clone()),
            ],
        )?;
        let mut it = out.into_iter();
        let logits = next_out(&mut it, "score")?.into_f32()?;
        let stats = next_out(&mut it, "score")?.into_f32()?;
        Ok((logits, stats))
    }

    /// Fused prefill + greedy decode under a static mask (L2 scan; no
    /// per-step host traffic).
    pub fn generate(
        &self,
        prompts: &[String],
        mask: &TensorF,
        b: usize,
    ) -> Result<GenerateResult> {
        let (tokens, lens, truncated) = self.encode_prompts(prompts, b)?;
        let lens_t = TensorI::new(
            vec![b],
            lens.iter().map(|&l| l as i32).collect(),
        )?;
        let out = self.rt.call(
            &format!("generate_b{b}"),
            &[
                Value::I32(tokens),
                Value::I32(lens_t),
                Value::F32(mask.clone()),
            ],
        )?;
        let mut it = out.into_iter();
        let gen_tokens = next_out(&mut it, "generate")?.into_i32()?;
        let gen_logits = next_out(&mut it, "generate")?.into_f32()?;
        let gen_stats = next_out(&mut it, "generate")?.into_f32()?;
        Ok(GenerateResult {
            tokens: gen_tokens,
            logits: gen_logits,
            stats: gen_stats,
            truncated,
        })
    }

    /// Local importance for one batch slot from prefill stats.
    pub fn local_importance(
        &self,
        pre: &PrefillResult,
        slot: usize,
    ) -> Result<ImportanceMap> {
        ImportanceMap::from_stats(&pre.stats, slot)
    }

    /// Decode generated ids to text, cutting at the first PAD/BOS.
    pub fn decode_text(&self, ids: &[i32]) -> String {
        let stop = ids
            .iter()
            .position(|&t| t >= 256)
            .unwrap_or(ids.len());
        self.tok.decode(&ids[..stop])
    }

    /// Dense ones-mask [B, L, m].
    pub fn dense_mask(&self, b: usize) -> TensorF {
        let spec = self.spec();
        TensorF::ones(&[b, spec.n_layers, spec.ffn_m])
    }
}

#[cfg(test)]
mod tests {
    // Engine calls are covered by the rust/tests/ integration suite
    // (against real artifacts or the simulator). Pure helpers here.
    use super::*;

    fn tiny_spec() -> ModelSpec {
        ModelSpec {
            vocab: 260,
            d_model: 4,
            n_layers: 2,
            n_heads: 1,
            head_dim: 4,
            ffn_m: 8,
            max_seq: 6,
            prefill_len: 4,
            score_len: 6,
            gen_len: 2,
            bos_id: 256,
            pad_id: 257,
        }
    }

    #[test]
    fn kv_slot_copy_moves_one_slot_only() {
        let spec = tiny_spec();
        let mut src = KvState::zeros(&spec, 1);
        for x in src.k.data.iter_mut() {
            *x = 7.0;
        }
        for x in src.v.data.iter_mut() {
            *x = 3.0;
        }
        let mut dst = KvState::zeros(&spec, 4);
        assert_eq!(dst.batch(), 4);
        dst.copy_slot_from(2, &src, 0);
        let plane: usize = dst.k.shape[2..].iter().product();
        for l in 0..spec.n_layers {
            for slot in 0..4 {
                let base = (l * 4 + slot) * plane;
                let expect = if slot == 2 { 7.0 } else { 0.0 };
                assert!(dst.k.data[base..base + plane]
                    .iter()
                    .all(|&x| x == expect));
            }
        }
        assert!(dst.v.data.iter().any(|&x| x == 3.0));
    }

    #[test]
    fn prefix_rows_roundtrip_touches_only_the_prefix() {
        let spec = tiny_spec();
        // fill a 2-slot cache with position-tagged values
        let mut src = KvState::zeros(&spec, 2);
        let (h_n, t_n, dh) =
            (spec.n_heads, spec.max_seq, spec.head_dim);
        for l in 0..spec.n_layers {
            for slot in 0..2 {
                for h in 0..h_n {
                    for p in 0..t_n {
                        let base =
                            (((l * 2 + slot) * h_n + h) * t_n + p) * dh;
                        for e in 0..dh {
                            let tag = (l * 1000
                                + slot * 100
                                + p * 10
                                + e) as f32;
                            src.k.data[base + e] = tag;
                            src.v.data[base + e] = -tag;
                        }
                    }
                }
            }
        }
        let len = 3;
        let (k_rows, v_rows) = src.extract_prefix_rows(1, len);
        assert_eq!(k_rows.len(), spec.n_layers * h_n * len * dh);

        let mut dst = KvState::zeros(&spec, 4);
        dst.write_prefix_rows(2, len, &k_rows, &v_rows);
        for l in 0..spec.n_layers {
            for slot in 0..4 {
                for h in 0..h_n {
                    for p in 0..t_n {
                        let base =
                            (((l * 4 + slot) * h_n + h) * t_n + p) * dh;
                        for e in 0..dh {
                            let expect = if slot == 2 && p < len {
                                (l * 1000 + 100 + p * 10 + e) as f32
                            } else {
                                0.0
                            };
                            assert_eq!(
                                dst.k.data[base + e],
                                expect,
                                "k l{l} s{slot} h{h} p{p} e{e}"
                            );
                            assert_eq!(dst.v.data[base + e], -expect);
                        }
                    }
                }
            }
        }
    }
}
