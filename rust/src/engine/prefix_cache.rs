//! Shared-prefix cache: KV rows + merged GLASS statistics per prompt
//! prefix, indexed by an edge-compressed token-id radix tree.
//!
//! A server handling traffic that shares system prompts / few-shot
//! headers recomputes the same prefill work — both the KV rows and the
//! prompt-local importance evidence A^l — for every admission. Both are
//! pure functions of the token prefix (KV rows of `(token, position)`
//! under causal attention, statistics of the token multiset per chunk),
//! so they can be computed once and spliced into every later request
//! that shares the prefix.
//!
//! Each [`PrefixCache`] entry stores, for one token-id prefix:
//!
//!  * its compact KV rows (`[L, H, len, Dh]`, K and V — only the prefix
//!    positions, not the whole `max_seq` window),
//!  * the token-count-weighted merge of its per-chunk local statistics
//!    plus the evidence mass behind it — exactly the `(merged, weight)`
//!    state of a [`ChunkedPrefill`] after consuming the prefix, so a
//!    resumed stream continues the merge with **bit-identical**
//!    arithmetic to a cold one,
//!  * the last-position logits after the prefix (so an exact full-prompt
//!    hit needs no engine call at all).
//!
//! # Radix index
//!
//! Lookup is **longest-prefix match** over token IDs through an
//! edge-compressed radix tree (an arena of nodes; each edge carries a
//! token run, each node may terminate one cached entry). `lookup`,
//! [`PrefixCache::peek_longest`], [`PrefixCache::contains`], and the
//! duplicate check inside [`PrefixCache::insert`] all walk the tree
//! from the root, so their cost scales with the **query prefix
//! length**, not the resident entry count — the flat scan this replaces
//! went O(entries · prefix) once the byte budget allowed hundreds of
//! prefixes. Edges are split on partial divergence at insert and
//! re-merged when removal leaves a pass-through node, so the tree stays
//! compressed under any insert/evict order. Entry payloads themselves
//! live in a stable slot-map (`entries`) so the pin/release ids handed
//! to the batcher survive unrelated evictions, exactly as before.
//!
//! Entries are **ref-counted**: a hit pins its entry until the resumed
//! stream completes, and eviction never frees a pinned entry. Eviction
//! is LRU under a configurable byte budget, with bytes accounted
//! through the [`memsim`] helpers so the cache and the edge-memory cost
//! model agree on what "resident" means.
//!
//! # Warm-start
//!
//! A cache can be rebuilt from a persisted snapshot at startup
//! ([`PrefixCache::import_seed`]; see [`super::prefix_store`] for the
//! on-disk format): imported entries are flagged *warm* and every later
//! hit on one bumps the `warm_start_hits` telemetry counter, so a
//! restart's savings are observable end to end. [`PrefixCache::
//! export_hot`] walks the resident set for the snapshot writer.
//!
//! [`ChunkedPrefill`]: super::chunked::ChunkedPrefill
//! [`memsim`]: crate::memsim

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, Result};

use super::{KvState, PrefillResult};
use crate::glass::ImportanceMap;
use crate::memsim;
use crate::runtime::ModelSpec;
use crate::tensor::TensorF;

/// Default serving-cache byte budget (32 MiB — generous for the
/// synthetic spec, a deliberate floor for real bundles; tune with
/// `--cache-bytes`).
pub const DEFAULT_CACHE_BYTES: usize = 32 << 20;

/// Per-request cache behavior, carried on the wire (`"cache"` field).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheMode {
    /// Consult the cache and publish new prefixes (default).
    On,
    /// Bypass the cache entirely: no lookup, no insert.
    Off,
    /// Consult the cache but never insert.
    ReadOnly,
}

impl CacheMode {
    pub fn parse(s: &str) -> Result<CacheMode> {
        Ok(match s {
            "on" => CacheMode::On,
            "off" => CacheMode::Off,
            "readonly" => CacheMode::ReadOnly,
            other => bail!("unknown cache mode '{other}' \
                            (expected on|off|readonly)"),
        })
    }

    pub fn as_str(self) -> &'static str {
        match self {
            CacheMode::On => "on",
            CacheMode::Off => "off",
            CacheMode::ReadOnly => "readonly",
        }
    }

    /// May this request read cached prefixes?
    pub fn reads(self) -> bool {
        !matches!(self, CacheMode::Off)
    }

    /// May this request publish new prefixes?
    pub fn writes(self) -> bool {
        matches!(self, CacheMode::On)
    }
}

/// Server-level aggregate cache counters, shared (Arc) between the
/// batcher's engine thread and the connection threads that answer the
/// `stats` protocol command — so operators can watch cache health
/// without scraping per-response telemetry.
#[derive(Debug, Default)]
pub struct CacheTelemetry {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    pub inserts: AtomicU64,
    pub evictions: AtomicU64,
    pub bytes_resident: AtomicU64,
    pub entries: AtomicU64,
    /// Hits whose entry was imported from a persisted snapshot at
    /// startup (a subset of `hits`): the restart's observable savings.
    pub warm_start_hits: AtomicU64,
}

/// A plain-data copy of [`CacheTelemetry`] at one instant.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheStatsSnapshot {
    pub hits: u64,
    pub misses: u64,
    pub inserts: u64,
    pub evictions: u64,
    pub bytes_resident: u64,
    pub entries: u64,
    pub warm_start_hits: u64,
}

impl CacheStatsSnapshot {
    /// Field-wise sum — how a sharded server aggregates its per-shard
    /// cache counters into the one `stats`-command summary.
    pub fn merge(&self, other: &CacheStatsSnapshot) -> CacheStatsSnapshot {
        CacheStatsSnapshot {
            hits: self.hits + other.hits,
            misses: self.misses + other.misses,
            inserts: self.inserts + other.inserts,
            evictions: self.evictions + other.evictions,
            bytes_resident: self.bytes_resident + other.bytes_resident,
            entries: self.entries + other.entries,
            warm_start_hits: self.warm_start_hits
                + other.warm_start_hits,
        }
    }
}

impl CacheTelemetry {
    /// Copy every counter at one instant.
    pub fn snapshot(&self) -> CacheStatsSnapshot {
        // Relaxed loads throughout: each counter is an independent
        // monotonic statistic, and a snapshot taken mid-decode is
        // best-effort by definition — no reader derives a
        // cross-counter invariant from it
        CacheStatsSnapshot {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            inserts: self.inserts.load(Ordering::Relaxed),
            // (Relaxed: same best-effort rationale as above)
            evictions: self.evictions.load(Ordering::Relaxed),
            bytes_resident: self.bytes_resident.load(Ordering::Relaxed),
            entries: self.entries.load(Ordering::Relaxed),
            warm_start_hits: self.warm_start_hits.load(Ordering::Relaxed),
        }
    }
}

/// Everything needed to resume a chunked prefill (or fabricate a whole
/// [`PrefillResult`], on an exact full-prompt hit) from a cached prefix:
/// the data cloned out of a cache entry by [`PrefixCache::lookup`].
#[derive(Debug, Clone)]
pub struct PrefixSeed {
    /// Prefix length in tokens (incl. BOS).
    pub len: usize,
    /// Compact K rows `[L, H, len, Dh]` (see `KvState::extract_prefix_rows`).
    pub k_rows: Vec<f32>,
    /// Compact V rows, same layout.
    pub v_rows: Vec<f32>,
    /// Token-count-weighted merge of the prefix's per-chunk statistics.
    pub stats: ImportanceMap,
    /// Evidence mass (token count) behind `stats`.
    pub weight: f64,
    /// Last-position logits after the prefix (`[vocab]`).
    pub logits: Vec<f32>,
}

/// A successful lookup: the cloned seed plus the pinned entry's id.
/// The caller must [`PrefixCache::release`] the id when the splice (or
/// the stream it resumed) is finished, so the entry becomes evictable
/// again.
#[derive(Debug)]
pub struct PrefixHit {
    pub id: usize,
    pub seed: PrefixSeed,
}

struct Entry {
    tokens: Vec<i32>,
    k_rows: Vec<f32>,
    v_rows: Vec<f32>,
    stats: ImportanceMap,
    weight: f64,
    logits: Vec<f32>,
    bytes: usize,
    refs: usize,
    tick: u64,
    /// Radix node whose path spells this entry's token key.
    node: usize,
    /// Imported from a persisted snapshot (warm-start accounting).
    warm: bool,
}

/// One radix-tree node. The path of edge labels from the root to a node
/// spells a token sequence; a node with `entry = Some(slot)` terminates
/// the cached prefix stored in `entries[slot]`.
struct Node {
    /// Edge label from the parent (empty only at the root). Labels of
    /// sibling edges start with distinct tokens.
    label: Vec<i32>,
    /// Child node indices in the arena.
    children: Vec<usize>,
    /// Slot-map id of the entry terminating exactly here.
    entry: Option<usize>,
    parent: usize,
}

/// Arena index of the radix root (empty label, never freed).
const ROOT: usize = 0;

/// The cache itself (owned by one batcher; not internally synchronized —
/// the engine loop is single-threaded, only the telemetry is shared).
pub struct PrefixCache {
    spec: ModelSpec,
    budget_bytes: usize,
    /// Slot-map of entries: ids are stable across evictions.
    entries: Vec<Option<Entry>>,
    /// Radix-node arena; freed nodes are recycled through `free_nodes`.
    nodes: Vec<Node>,
    free_nodes: Vec<usize>,
    bytes_resident: usize,
    tick: u64,
    telemetry: Arc<CacheTelemetry>,
}

impl PrefixCache {
    pub fn new(
        spec: ModelSpec,
        budget_bytes: usize,
        telemetry: Arc<CacheTelemetry>,
    ) -> PrefixCache {
        PrefixCache {
            spec,
            budget_bytes,
            entries: Vec::new(),
            nodes: vec![Node {
                label: Vec::new(),
                children: Vec::new(),
                entry: None,
                parent: ROOT,
            }],
            free_nodes: Vec::new(),
            bytes_resident: 0,
            tick: 0,
            telemetry,
        }
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    pub fn bytes_resident(&self) -> usize {
        self.bytes_resident
    }

    /// Live entry count.
    pub fn len(&self) -> usize {
        self.entries.iter().filter(|e| e.is_some()).count()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.iter().all(|e| e.is_none())
    }

    // ------------------------------------------------- radix primitives

    fn alloc_node(&mut self, label: Vec<i32>, parent: usize) -> usize {
        let node = Node {
            label,
            children: Vec::new(),
            entry: None,
            parent,
        };
        match self.free_nodes.pop() {
            Some(i) => {
                self.nodes[i] = node;
                i
            }
            None => {
                self.nodes.push(node);
                self.nodes.len() - 1
            }
        }
    }

    /// The child of `node` whose edge label starts with `t`, if any
    /// (sibling labels start with distinct tokens, so it is unique).
    fn child_starting_with(&self, node: usize, t: i32) -> Option<usize> {
        self.nodes[node]
            .children
            .iter()
            .copied()
            .find(|&c| self.nodes[c].label[0] == t)
    }

    /// Walk `tokens` from the root, returning the slot of the LONGEST
    /// cached prefix seen along the way (an entry whose full key was
    /// matched). Cost: O(tokens.len()), independent of entry count.
    fn walk_longest(&self, tokens: &[i32]) -> Option<(usize, usize)> {
        let mut node = ROOT;
        let mut depth = 0usize;
        let mut best: Option<(usize, usize)> = None;
        while depth < tokens.len() {
            let Some(child) =
                self.child_starting_with(node, tokens[depth])
            else {
                break;
            };
            let label = &self.nodes[child].label;
            let rem = &tokens[depth..];
            // the whole edge label must match to reach the child node;
            // a divergence or query end mid-edge means every key at or
            // below the child is longer than the matched span
            if rem.len() < label.len() || rem[..label.len()] != label[..]
            {
                break;
            }
            depth += label.len();
            node = child;
            if let Some(slot) = self.nodes[node].entry {
                best = Some((slot, depth));
            }
        }
        best
    }

    /// Walk to the node terminating exactly `tokens`, if cached.
    fn walk_exact(&self, tokens: &[i32]) -> Option<usize> {
        match self.walk_longest(tokens) {
            Some((slot, len)) if len == tokens.len() => Some(slot),
            _ => None,
        }
    }

    /// Insert `tokens` as a key terminating at a (possibly new) node,
    /// splitting an edge on partial divergence. Returns the node index;
    /// the caller stores the entry slot into it.
    fn index_insert(&mut self, tokens: &[i32]) -> usize {
        let mut node = ROOT;
        let mut depth = 0usize;
        loop {
            if depth == tokens.len() {
                return node;
            }
            let Some(child) =
                self.child_starting_with(node, tokens[depth])
            else {
                let leaf =
                    self.alloc_node(tokens[depth..].to_vec(), node);
                self.nodes[node].children.push(leaf);
                return leaf;
            };
            let rem = &tokens[depth..];
            let common = self.nodes[child]
                .label
                .iter()
                .zip(rem.iter())
                .take_while(|(a, b)| a == b)
                .count();
            if common == self.nodes[child].label.len() {
                depth += common;
                node = child;
                continue;
            }
            // split the child edge at the divergence point: a new mid
            // node takes label[..common], the child keeps the rest
            let rest = self.nodes[child].label.split_off(common);
            let mid_label =
                std::mem::replace(&mut self.nodes[child].label, rest);
            let mid = self.alloc_node(mid_label, node);
            self.nodes[child].parent = mid;
            self.nodes[mid].children.push(child);
            let pos = self.child_pos(node, child);
            self.nodes[node].children[pos] = mid;
            depth += common;
            if depth == tokens.len() {
                return mid;
            }
            let leaf = self.alloc_node(tokens[depth..].to_vec(), mid);
            self.nodes[mid].children.push(leaf);
            return leaf;
        }
    }

    /// Position of `child` in `parent`'s child list — present by the
    /// tree's structural invariant (every node is listed under its
    /// parent, maintained by every insert/remove/split above).
    fn child_pos(&self, parent: usize, child: usize) -> usize {
        self.nodes[parent]
            .children
            .iter()
            .position(|&c| c == child)
            // lint: allow(no-unwrap-on-serving-paths) -- structural
            // invariant: a node is always in its parent's child list
            .expect("child listed under its parent")
    }

    /// Remove the key terminating at `node`, re-merging pass-through
    /// nodes so the tree stays edge-compressed.
    fn index_remove(&mut self, node: usize) {
        self.nodes[node].entry = None;
        let mut node = node;
        loop {
            if node == ROOT || self.nodes[node].entry.is_some() {
                return;
            }
            match self.nodes[node].children.len() {
                0 => {
                    // leaf without an entry: detach and free, then the
                    // parent may itself have become a pass-through
                    let parent = self.nodes[node].parent;
                    let pos = self.child_pos(parent, node);
                    self.nodes[parent].children.swap_remove(pos);
                    self.free_nodes.push(node);
                    node = parent;
                }
                1 => {
                    // pass-through: fold this node's label onto its
                    // only child and splice the child to the parent
                    let child = self.nodes[node].children[0];
                    let parent = self.nodes[node].parent;
                    let mut label = self.nodes[node].label.clone();
                    label.append(&mut self.nodes[child].label);
                    self.nodes[child].label = label;
                    self.nodes[child].parent = parent;
                    let pos = self.child_pos(parent, node);
                    self.nodes[parent].children[pos] = child;
                    self.free_nodes.push(node);
                    return;
                }
                _ => return,
            }
        }
    }

    // ----------------------------------------------------- public API

    /// Is this exact prefix cached? (test/diagnostic helper; does not
    /// touch LRU order or counters)
    pub fn contains(&self, tokens: &[i32]) -> bool {
        self.walk_exact(tokens).is_some()
    }

    /// Length of the longest cached prefix of `tokens`, WITHOUT pinning,
    /// LRU-bumping, or counting a hit/miss — the batcher's deferral
    /// check peeks with this to decide whether a same-prefix admission
    /// would hit anyway (and so must not be deferred).
    pub fn peek_longest(&self, tokens: &[i32]) -> usize {
        self.walk_longest(tokens).map_or(0, |(_, len)| len)
    }

    fn entry_bytes(&self, len: usize) -> usize {
        let s = &self.spec;
        memsim::prefix_entry_bytes(
            s.n_layers, s.n_heads, s.head_dim, s.ffn_m, s.vocab, len,
        )
    }

    /// Longest cached prefix of `tokens` (a cache entry whose token ids
    /// are a prefix of the query — possibly all of it). On a hit the
    /// entry is pinned (ref-counted) and its LRU tick bumped; the caller
    /// must [`PrefixCache::release`] the returned id. Counts one hit or
    /// one miss (plus one warm-start hit when the entry came from a
    /// persisted snapshot).
    pub fn lookup(&mut self, tokens: &[i32]) -> Option<PrefixHit> {
        match self.walk_longest(tokens) {
            Some((id, _)) => {
                self.tick += 1;
                let tick = self.tick;
                // the index listed this id, so the slot is occupied;
                // a torn slot map would mean a corrupt cache — treat
                // it as a miss instead of panicking an engine thread
                let Some(e) = self.entries[id].as_mut() else {
                    self.telemetry
                        .misses
                        .fetch_add(1, Ordering::Relaxed);
                    return None;
                };
                e.tick = tick;
                e.refs += 1;
                // Relaxed counters: independent stats, see snapshot()
                self.telemetry.hits.fetch_add(1, Ordering::Relaxed);
                if e.warm {
                    // Relaxed: independent counter, see snapshot()
                    self.telemetry
                        .warm_start_hits
                        .fetch_add(1, Ordering::Relaxed);
                }
                Some(PrefixHit {
                    id,
                    seed: PrefixSeed {
                        len: e.tokens.len(),
                        k_rows: e.k_rows.clone(),
                        v_rows: e.v_rows.clone(),
                        stats: e.stats.clone(),
                        weight: e.weight,
                        logits: e.logits.clone(),
                    },
                })
            }
            None => {
                // Relaxed: independent counter, see snapshot()
                self.telemetry.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Unpin an entry returned by [`PrefixCache::lookup`]. Safe to call
    /// after the entry was (impossibly) evicted — eviction skips pinned
    /// entries, so a live pin always finds its entry.
    pub fn release(&mut self, id: usize) {
        if let Some(Some(e)) = self.entries.get_mut(id) {
            e.refs = e.refs.saturating_sub(1);
        }
    }

    /// Publish one prefix: KV rows are extracted from `kv` slot `slot`
    /// (positions `0..tokens.len()`), statistics and logits are stored
    /// verbatim. Duplicate prefixes are a no-op (LRU bump only). Entries
    /// larger than the whole budget are refused. Returns the number of
    /// evictions this insert caused.
    pub fn insert(
        &mut self,
        tokens: &[i32],
        kv: &KvState,
        slot: usize,
        stats: &ImportanceMap,
        weight: f64,
        logits: &[f32],
    ) -> usize {
        if tokens.is_empty() {
            return 0;
        }
        self.tick += 1;
        // duplicate: refresh recency, keep the existing entry (its
        // contents are a pure function of the prefix, so equal anyway)
        if let Some(slot_id) = self.walk_exact(tokens) {
            let tick = self.tick;
            if let Some(e) = self.entries[slot_id].as_mut() {
                e.tick = tick;
            }
            return 0;
        }
        let bytes = self.entry_bytes(tokens.len());
        if bytes > self.budget_bytes {
            return 0;
        }
        let evicted = self.evict_to_fit(bytes);
        if self.bytes_resident + bytes > self.budget_bytes {
            // everything still resident is pinned; refuse the insert
            // rather than exceed the budget
            return evicted;
        }
        let (k_rows, v_rows) = kv.extract_prefix_rows(slot, tokens.len());
        self.store_entry(
            Entry {
                tokens: tokens.to_vec(),
                k_rows,
                v_rows,
                stats: stats.clone(),
                weight,
                logits: logits.to_vec(),
                bytes,
                refs: 0,
                tick: self.tick,
                node: ROOT, // patched by store_entry
                warm: false,
            },
            true,
        );
        evicted
    }

    /// Place a fully-built entry into the slot-map and the radix index.
    fn store_entry(&mut self, mut entry: Entry, count_insert: bool) {
        let node = self.index_insert(&entry.tokens);
        entry.node = node;
        self.bytes_resident += entry.bytes;
        let slot = match self.entries.iter().position(|e| e.is_none()) {
            Some(free) => {
                self.entries[free] = Some(entry);
                free
            }
            None => {
                self.entries.push(Some(entry));
                self.entries.len() - 1
            }
        };
        self.nodes[node].entry = Some(slot);
        if count_insert {
            // Relaxed: independent counter, see snapshot()
            self.telemetry.inserts.fetch_add(1, Ordering::Relaxed);
        }
        self.publish_residency();
    }

    /// Evict least-recently-used unpinned entries until `incoming` more
    /// bytes fit the budget (or nothing unpinned remains). Returns the
    /// eviction count.
    fn evict_to_fit(&mut self, incoming: usize) -> usize {
        let mut evicted = 0usize;
        while self.bytes_resident + incoming > self.budget_bytes {
            let victim = self
                .entries
                .iter()
                .enumerate()
                .filter_map(|(i, e)| match e {
                    Some(e) if e.refs == 0 => Some((e.tick, i)),
                    _ => None,
                })
                .min()
                .map(|(_, i)| i);
            let Some(i) = victim else { break };
            // the victim scan just saw this slot occupied
            let Some(e) = self.entries[i].take() else { break };
            self.index_remove(e.node);
            self.bytes_resident -= e.bytes;
            evicted += 1;
        }
        if evicted > 0 {
            // Relaxed: independent counter, see snapshot()
            self.telemetry
                .evictions
                .fetch_add(evicted as u64, Ordering::Relaxed);
            self.publish_residency();
        }
        evicted
    }

    fn publish_residency(&self) {
        // Relaxed stores: gauges read only by stats snapshots; no
        // reader orders other memory against them
        self.telemetry
            .bytes_resident
            .store(self.bytes_resident as u64, Ordering::Relaxed);
        // (same Relaxed rationale)
        self.telemetry
            .entries
            .store(self.len() as u64, Ordering::Relaxed);
    }

    // --------------------------------------------- snapshot import/export

    /// Clone every resident entry as `(token key, seed)` pairs, most
    /// recently used first — the snapshot writer's view. Pinned entries
    /// are included (their contents are valid regardless of pin state).
    pub fn export_hot(&self) -> Vec<(Vec<i32>, PrefixSeed)> {
        let mut live: Vec<&Entry> =
            self.entries.iter().flatten().collect();
        live.sort_by(|a, b| b.tick.cmp(&a.tick));
        live.iter()
            .map(|e| {
                (
                    e.tokens.clone(),
                    PrefixSeed {
                        len: e.tokens.len(),
                        k_rows: e.k_rows.clone(),
                        v_rows: e.v_rows.clone(),
                        stats: e.stats.clone(),
                        weight: e.weight,
                        logits: e.logits.clone(),
                    },
                )
            })
            .collect()
    }

    /// Import one entry from a persisted snapshot (warm-start): the
    /// entry is validated against this cache's model spec, flagged warm
    /// for `warm_start_hits` accounting, and NOT counted as an insert
    /// (it is a restore, so bench floors on organic inserts stay
    /// meaningful). Returns false (without error) when the entry is a
    /// duplicate or would exceed the remaining budget — warm load never
    /// evicts what a newer import already claimed. A malformed seed is
    /// an error so the store can skip it loudly.
    pub fn import_seed(
        &mut self,
        tokens: &[i32],
        seed: PrefixSeed,
    ) -> Result<bool> {
        if tokens.is_empty() || seed.len != tokens.len() {
            bail!(
                "snapshot entry key of {} tokens does not match seed \
                 length {}",
                tokens.len(),
                seed.len
            );
        }
        let s = &self.spec;
        if seed.logits.len() != s.vocab {
            bail!(
                "snapshot logits of {} values do not match vocab {}",
                seed.logits.len(),
                s.vocab
            );
        }
        if seed.stats.n_layers() != s.n_layers || seed.stats.m() != s.ffn_m
        {
            bail!("snapshot statistics shape mismatch");
        }
        let row_n = s.n_layers * s.n_heads * seed.len * s.head_dim;
        if seed.k_rows.len() != row_n || seed.v_rows.len() != row_n {
            bail!("snapshot KV rows shape mismatch");
        }
        if self.walk_exact(tokens).is_some() {
            return Ok(false);
        }
        let bytes = self.entry_bytes(tokens.len());
        if self.bytes_resident + bytes > self.budget_bytes {
            return Ok(false);
        }
        self.tick += 1;
        self.store_entry(
            Entry {
                tokens: tokens.to_vec(),
                k_rows: seed.k_rows,
                v_rows: seed.v_rows,
                stats: seed.stats,
                weight: seed.weight,
                logits: seed.logits,
                bytes,
                refs: 0,
                tick: self.tick,
                node: ROOT, // patched by store_entry
                warm: true,
            },
            false,
        );
        Ok(true)
    }

    /// Resident entries imported from a snapshot (test/diagnostics).
    pub fn warm_len(&self) -> usize {
        self.entries.iter().flatten().filter(|e| e.warm).count()
    }
}

/// Fabricate the one-slot [`PrefillResult`] an exact full-prompt hit
/// stands in for: cached logits, cached stats, and a fresh KV window
/// with the prefix rows spliced at positions `0..len` (rows beyond the
/// prompt are zero — same as the chunked-prefill path leaves them; they
/// are decode-overwritten scratch).
pub fn seed_to_prefill_result(
    spec: &ModelSpec,
    seed: &PrefixSeed,
) -> Result<PrefillResult> {
    if seed.logits.len() != spec.vocab {
        bail!(
            "cached logits of {} values do not match vocab {}",
            seed.logits.len(),
            spec.vocab
        );
    }
    // same hardening as `chunked_prefill_resume`: a malformed seed must
    // be an error, not an assert panic inside the row splice
    let row_n = spec.n_layers * spec.n_heads * seed.len * spec.head_dim;
    if seed.k_rows.len() != row_n || seed.v_rows.len() != row_n {
        bail!("cached KV rows shape mismatch");
    }
    let mut kv = KvState::zeros(spec, 1);
    kv.write_prefix_rows(0, seed.len, &seed.k_rows, &seed.v_rows);
    Ok(PrefillResult {
        logits: TensorF::new(vec![1, spec.vocab], seed.logits.clone())?,
        kv,
        stats: seed.stats.to_stats_tensor(),
        lens: vec![seed.len],
        truncated: vec![false],
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prng::Prng;
    use crate::util::quickcheck::{forall, UsizeGen};

    fn tiny_spec() -> ModelSpec {
        ModelSpec {
            vocab: 260,
            d_model: 4,
            n_layers: 2,
            n_heads: 1,
            head_dim: 4,
            ffn_m: 8,
            max_seq: 16,
            prefill_len: 4,
            score_len: 6,
            gen_len: 2,
            bos_id: 256,
            pad_id: 257,
        }
    }

    fn cache(budget: usize) -> PrefixCache {
        PrefixCache::new(
            tiny_spec(),
            budget,
            Arc::new(CacheTelemetry::default()),
        )
    }

    /// A KV cache whose rows are tagged by position so splices are
    /// checkable, plus matching stats/logits for `insert`.
    fn seed_parts(
        spec: &ModelSpec,
        fill: f32,
    ) -> (KvState, ImportanceMap, Vec<f32>) {
        let mut kv = KvState::zeros(spec, 1);
        for x in kv.k.data.iter_mut() {
            *x = fill;
        }
        for x in kv.v.data.iter_mut() {
            *x = -fill;
        }
        let stats = ImportanceMap::from_layers(vec![
            vec![fill; spec.ffn_m];
            spec.n_layers
        ])
        .unwrap();
        let logits = vec![fill; spec.vocab];
        (kv, stats, logits)
    }

    #[test]
    fn mode_parse_roundtrip_and_rejection() {
        for (s, m) in [
            ("on", CacheMode::On),
            ("off", CacheMode::Off),
            ("readonly", CacheMode::ReadOnly),
        ] {
            assert_eq!(CacheMode::parse(s).unwrap(), m);
            assert_eq!(m.as_str(), s);
        }
        assert!(CacheMode::parse("ON").is_err());
        assert!(CacheMode::parse("").is_err());
        assert!(CacheMode::On.reads() && CacheMode::On.writes());
        assert!(!CacheMode::Off.reads() && !CacheMode::Off.writes());
        assert!(
            CacheMode::ReadOnly.reads() && !CacheMode::ReadOnly.writes()
        );
    }

    #[test]
    fn longest_prefix_match_wins() {
        let spec = tiny_spec();
        let mut c = cache(usize::MAX);
        let (kv, stats, logits) = seed_parts(&spec, 1.0);
        c.insert(&[256, 97], &kv, 0, &stats, 2.0, &logits);
        c.insert(&[256, 97, 98, 99], &kv, 0, &stats, 4.0, &logits);
        c.insert(&[256, 120], &kv, 0, &stats, 2.0, &logits);

        // longest matching prefix is picked over the shorter one
        let hit = c.lookup(&[256, 97, 98, 99, 100, 101]).unwrap();
        assert_eq!(hit.seed.len, 4);
        assert_eq!(hit.seed.weight, 4.0);
        c.release(hit.id);

        // an entry longer than the query never matches
        let hit = c.lookup(&[256, 97, 98]).unwrap();
        assert_eq!(hit.seed.len, 2);
        c.release(hit.id);

        // exact-length match is legal (full-prompt hit)
        let hit = c.lookup(&[256, 97, 98, 99]).unwrap();
        assert_eq!(hit.seed.len, 4);
        c.release(hit.id);

        // divergent token → miss
        assert!(c.lookup(&[256, 98, 98]).is_none());
        let snap = c.telemetry.snapshot();
        assert_eq!(snap.hits, 3);
        assert_eq!(snap.misses, 1);
        assert_eq!(snap.inserts, 3);
        assert_eq!(snap.entries, 3);
        assert_eq!(snap.warm_start_hits, 0, "nothing was imported");
    }

    #[test]
    fn peek_longest_is_nonmutating() {
        let spec = tiny_spec();
        let mut c = cache(usize::MAX);
        assert_eq!(c.peek_longest(&[256, 97]), 0, "empty cache");
        let (kv, stats, logits) = seed_parts(&spec, 1.0);
        c.insert(&[256, 97], &kv, 0, &stats, 2.0, &logits);
        c.insert(&[256, 97, 98], &kv, 0, &stats, 3.0, &logits);
        assert_eq!(c.peek_longest(&[256, 97, 98, 99]), 3);
        assert_eq!(c.peek_longest(&[256, 97, 99]), 2);
        assert_eq!(c.peek_longest(&[257]), 0);
        // no hit/miss counted, nothing pinned or LRU-bumped
        let snap = c.telemetry.snapshot();
        assert_eq!(snap.hits, 0);
        assert_eq!(snap.misses, 0);
        assert!(c
            .entries
            .iter()
            .flatten()
            .all(|e| e.refs == 0), "peek must not pin");
    }

    #[test]
    fn duplicate_insert_is_a_noop() {
        let spec = tiny_spec();
        let mut c = cache(usize::MAX);
        let (kv, stats, logits) = seed_parts(&spec, 1.0);
        c.insert(&[256, 97], &kv, 0, &stats, 2.0, &logits);
        let before = c.bytes_resident();
        c.insert(&[256, 97], &kv, 0, &stats, 2.0, &logits);
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes_resident(), before);
        assert_eq!(c.telemetry.snapshot().inserts, 1);
        // the empty prefix is never cached
        c.insert(&[], &kv, 0, &stats, 0.0, &logits);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn eviction_honors_the_byte_budget_lru_first() {
        let spec = tiny_spec();
        let mut c = cache(0); // sized below
        let two = c.entry_bytes(2);
        // room for exactly two 2-token entries
        c.budget_bytes = 2 * two;
        let (kv, stats, logits) = seed_parts(&spec, 1.0);
        assert_eq!(c.insert(&[256, 97], &kv, 0, &stats, 2.0, &logits), 0);
        assert_eq!(c.insert(&[256, 98], &kv, 0, &stats, 2.0, &logits), 0);
        assert_eq!(c.len(), 2);
        assert!(c.bytes_resident() <= c.budget_bytes());

        // touch the older entry so the OTHER one becomes LRU
        let hit = c.lookup(&[256, 97, 99]).unwrap();
        c.release(hit.id);
        let evicted =
            c.insert(&[256, 99], &kv, 0, &stats, 2.0, &logits);
        assert_eq!(evicted, 1, "third entry must evict exactly one");
        assert!(c.bytes_resident() <= c.budget_bytes());
        assert!(c.contains(&[256, 97]), "recently-used entry survives");
        assert!(!c.contains(&[256, 98]), "LRU entry evicted");
        assert!(c.contains(&[256, 99]));
        assert_eq!(c.telemetry.snapshot().evictions, 1);
    }

    #[test]
    fn pinned_entries_are_never_evicted() {
        let spec = tiny_spec();
        let mut c = cache(0);
        let two = c.entry_bytes(2);
        c.budget_bytes = two; // room for ONE entry
        let (kv, stats, logits) = seed_parts(&spec, 1.0);
        c.insert(&[256, 97], &kv, 0, &stats, 2.0, &logits);
        let pin = c.lookup(&[256, 97]).unwrap();

        // inserting another entry cannot evict the pinned one: the
        // insert is refused instead of exceeding the budget
        let evicted =
            c.insert(&[256, 98], &kv, 0, &stats, 2.0, &logits);
        assert_eq!(evicted, 0);
        assert!(c.contains(&[256, 97]), "pinned entry must survive");
        assert!(!c.contains(&[256, 98]), "insert refused while pinned");
        assert!(c.bytes_resident() <= c.budget_bytes());

        // released → evictable again
        c.release(pin.id);
        let evicted =
            c.insert(&[256, 98], &kv, 0, &stats, 2.0, &logits);
        assert_eq!(evicted, 1);
        assert!(!c.contains(&[256, 97]));
        assert!(c.contains(&[256, 98]));
    }

    #[test]
    fn oversized_entry_is_refused_outright() {
        let spec = tiny_spec();
        let mut c = cache(1); // 1 byte budget: nothing fits
        let (kv, stats, logits) = seed_parts(&spec, 1.0);
        assert_eq!(c.insert(&[256, 97], &kv, 0, &stats, 2.0, &logits), 0);
        assert!(c.is_empty());
        assert_eq!(c.bytes_resident(), 0);
    }

    #[test]
    fn seed_roundtrips_through_prefill_result() {
        let spec = tiny_spec();
        let mut c = cache(usize::MAX);
        let (kv, stats, logits) = seed_parts(&spec, 2.5);
        c.insert(&[256, 97, 98], &kv, 0, &stats, 3.0, &logits);
        let hit = c.lookup(&[256, 97, 98]).unwrap();
        let pre = seed_to_prefill_result(&spec, &hit.seed).unwrap();
        c.release(hit.id);
        assert_eq!(pre.lens, vec![3]);
        assert_eq!(pre.truncated, vec![false]);
        assert_eq!(pre.logits.shape, vec![1, spec.vocab]);
        assert!(pre.logits.data.iter().all(|&x| x == 2.5));
        assert_eq!(
            pre.stats.shape,
            vec![1, spec.n_layers, spec.ffn_m]
        );
        // spliced rows carry the cached values; rows beyond len are zero
        let (hn, tn, dh) = (spec.n_heads, spec.max_seq, spec.head_dim);
        for l in 0..spec.n_layers {
            for h in 0..hn {
                for p in 0..tn {
                    let base = ((l * hn + h) * tn + p) * dh;
                    let expect = if p < 3 { 2.5 } else { 0.0 };
                    for e in 0..dh {
                        assert_eq!(pre.kv.k.data[base + e], expect);
                        assert_eq!(pre.kv.v.data[base + e], -expect);
                    }
                }
            }
        }
        // wrong vocab is rejected
        let mut bad = hit.seed.clone();
        bad.logits.pop();
        assert!(seed_to_prefill_result(&spec, &bad).is_err());
    }

    // --------------------------------------------------- radix structure

    #[test]
    fn edge_split_and_mid_edge_divergence() {
        let spec = tiny_spec();
        let mut c = cache(usize::MAX);
        let (kv, stats, logits) = seed_parts(&spec, 1.0);
        // one long key, then a key diverging mid-edge forces a split
        c.insert(&[256, 97, 98, 99, 100], &kv, 0, &stats, 5.0, &logits);
        c.insert(&[256, 97, 98, 120], &kv, 0, &stats, 4.0, &logits);
        // a query that ends mid-edge (inside the [99, 100] run) must
        // miss: the only keys there are longer than the query
        assert!(c.lookup(&[256, 97, 98, 99]).is_none());
        assert_eq!(c.peek_longest(&[256, 97, 98, 99]), 0);
        // full keys still resolve on both sides of the split
        let hit = c.lookup(&[256, 97, 98, 99, 100, 101]).unwrap();
        assert_eq!(hit.seed.len, 5);
        c.release(hit.id);
        let hit = c.lookup(&[256, 97, 98, 120]).unwrap();
        assert_eq!(hit.seed.len, 4);
        c.release(hit.id);
        // a key terminating exactly at the split point is a new entry
        c.insert(&[256, 97, 98], &kv, 0, &stats, 3.0, &logits);
        let hit = c.lookup(&[256, 97, 98, 99]).unwrap();
        assert_eq!(hit.seed.len, 3, "split-point entry now matches");
        c.release(hit.id);
    }

    #[test]
    fn eviction_remerges_pass_through_nodes() {
        let spec = tiny_spec();
        let mut c = cache(usize::MAX);
        let (kv, stats, logits) = seed_parts(&spec, 1.0);
        c.insert(&[256, 97, 98, 99], &kv, 0, &stats, 4.0, &logits);
        c.insert(&[256, 97, 120], &kv, 0, &stats, 3.0, &logits);
        let nodes_split = c.nodes.len() - c.free_nodes.len();
        // evict everything by shrinking the budget to zero
        c.budget_bytes = 0;
        c.evict_to_fit(0);
        assert!(c.is_empty());
        assert_eq!(c.bytes_resident(), 0);
        // the tree collapsed back to just the root
        assert_eq!(c.nodes.len() - c.free_nodes.len(), 1);
        assert!(nodes_split > 1, "split produced interior nodes");
        // and the index still works after the collapse
        c.budget_bytes = usize::MAX;
        c.insert(&[256, 97, 98, 99], &kv, 0, &stats, 4.0, &logits);
        assert_eq!(c.peek_longest(&[256, 97, 98, 99, 100]), 4);
    }

    // ------------------------------------------------- snapshot import

    #[test]
    fn import_seed_restores_warm_entries_and_counts_hits() {
        let spec = tiny_spec();
        let mut c = cache(usize::MAX);
        let (kv, stats, logits) = seed_parts(&spec, 1.5);
        c.insert(&[256, 97, 98], &kv, 0, &stats, 3.0, &logits);
        let exported = c.export_hot();
        assert_eq!(exported.len(), 1);

        let mut warm = cache(usize::MAX);
        let (tokens, seed) = exported.into_iter().next().unwrap();
        assert!(warm.import_seed(&tokens, seed.clone()).unwrap());
        assert_eq!(warm.len(), 1);
        assert_eq!(warm.warm_len(), 1);
        assert_eq!(
            warm.bytes_resident(),
            c.bytes_resident(),
            "import accounts the same bytes as the original insert"
        );
        // imports are restores, not organic inserts
        assert_eq!(warm.telemetry.snapshot().inserts, 0);

        // a hit on the imported entry counts hit AND warm_start_hit,
        // and the seed round-trips bit-identically
        let hit = warm.lookup(&[256, 97, 98, 99]).unwrap();
        assert_eq!(hit.seed.len, 3);
        assert_eq!(hit.seed.k_rows, seed.k_rows);
        assert_eq!(hit.seed.v_rows, seed.v_rows);
        assert_eq!(hit.seed.logits, seed.logits);
        assert_eq!(hit.seed.weight, seed.weight);
        warm.release(hit.id);
        let snap = warm.telemetry.snapshot();
        assert_eq!(snap.hits, 1);
        assert_eq!(snap.warm_start_hits, 1);

        // duplicates and over-budget imports are refused without error
        assert!(!warm
            .import_seed(&[256, 97, 98], seed.clone())
            .unwrap());
        let mut tiny = cache(1);
        assert!(!tiny.import_seed(&[256, 97, 98], seed).unwrap());
        assert!(tiny.is_empty());
    }

    #[test]
    fn import_seed_rejects_malformed_snapshots() {
        let spec = tiny_spec();
        let mut c = cache(usize::MAX);
        let (kv, stats, logits) = seed_parts(&spec, 1.0);
        c.insert(&[256, 97], &kv, 0, &stats, 2.0, &logits);
        let (tokens, seed) = c.export_hot().into_iter().next().unwrap();

        let mut w = cache(usize::MAX);
        // key/len mismatch
        let mut bad = seed.clone();
        bad.len += 1;
        assert!(w.import_seed(&tokens, bad).is_err());
        // truncated KV rows
        let mut bad = seed.clone();
        bad.k_rows.pop();
        assert!(w.import_seed(&tokens, bad).is_err());
        // wrong vocab
        let mut bad = seed.clone();
        bad.logits.pop();
        assert!(w.import_seed(&tokens, bad).is_err());
        // empty key
        assert!(w
            .import_seed(&[], {
                let mut s = seed.clone();
                s.len = 0;
                s
            })
            .is_err());
        assert!(w.is_empty(), "no malformed entry was admitted");
    }

    // -------------------------------------- flat-scan reference model

    /// The pre-radix flat-scan cache, reduced to its observable
    /// behavior: longest-match lookup, unique ticks, LRU eviction of
    /// unpinned entries, exact byte accounting.
    struct FlatModel {
        budget: usize,
        // (tokens, bytes, refs, tick)
        entries: Vec<(Vec<i32>, usize, usize, u64)>,
        bytes: usize,
        tick: u64,
    }

    impl FlatModel {
        fn longest(&self, q: &[i32]) -> Option<usize> {
            let mut best: Option<usize> = None;
            for (i, (t, ..)) in self.entries.iter().enumerate() {
                let longer = best
                    .map_or(true, |b| t.len() > self.entries[b].0.len());
                if longer && q.starts_with(t) {
                    best = Some(i);
                }
            }
            best
        }

        fn lookup(&mut self, q: &[i32]) -> Option<usize> {
            let best = self.longest(q)?;
            self.tick += 1;
            self.entries[best].3 = self.tick;
            self.entries[best].2 += 1;
            Some(best)
        }

        fn insert(&mut self, t: &[i32], bytes: usize) -> usize {
            self.tick += 1;
            if let Some(e) =
                self.entries.iter_mut().find(|(k, ..)| k == t)
            {
                e.3 = self.tick;
                return 0;
            }
            if bytes > self.budget {
                return 0;
            }
            let mut evicted = 0;
            while self.bytes + bytes > self.budget {
                let victim = self
                    .entries
                    .iter()
                    .enumerate()
                    .filter(|(_, (.., refs, _))| *refs == 0)
                    .min_by_key(|(_, (.., tick))| *tick)
                    .map(|(i, _)| i);
                let Some(i) = victim else { break };
                self.bytes -= self.entries[i].1;
                self.entries.remove(i);
                evicted += 1;
            }
            if self.bytes + bytes > self.budget {
                return evicted;
            }
            self.bytes += bytes;
            self.entries.push((t.to_vec(), bytes, 0, self.tick));
            evicted
        }
    }

    /// Satellite: the radix cache is behavior-identical to the flat
    /// scan it replaced, under randomized insert / lookup / release /
    /// evict sequences — longest-match result, LRU order, pinned never
    /// freed, and byte accounting exact after every step.
    #[test]
    fn radix_matches_flat_scan_reference_model() {
        let spec = tiny_spec();
        forall(
            60,
            0xCAFE,
            &UsizeGen { lo: 0, hi: usize::MAX / 2 },
            |&case_seed| {
                let mut rng = Prng::new(case_seed as u64);
                let mut c = cache(0);
                c.budget_bytes = c.entry_bytes(2) * 3 + 1;
                let mut model = FlatModel {
                    budget: c.budget_bytes,
                    entries: Vec::new(),
                    bytes: 0,
                    tick: 0,
                };
                let (kv, stats, logits) = seed_parts(&spec, 1.0);
                // outstanding pins: (radix id, model tokens)
                let mut pins: Vec<(usize, Vec<i32>)> = Vec::new();
                let alphabet = [256i32, 97, 98, 99];
                for step in 0..120 {
                    let len = 1 + rng.below(5);
                    let toks: Vec<i32> = (0..len)
                        .map(|_| *rng.choice(&alphabet))
                        .collect();
                    match rng.below(4) {
                        0 | 1 => {
                            let got = c.insert(
                                &toks, &kv, 0, &stats, len as f64,
                                &logits,
                            );
                            let want =
                                model.insert(&toks, c.entry_bytes(len));
                            prop_assert!(
                                got == want,
                                "step {step}: insert {toks:?} evicted \
                                 {got}, model {want}"
                            );
                        }
                        2 => {
                            let hit = c.lookup(&toks);
                            let want = model.lookup(&toks);
                            match (&hit, want) {
                                (Some(h), Some(m)) => {
                                    let mk = &model.entries[m].0;
                                    prop_assert!(
                                        h.seed.len == mk.len(),
                                        "step {step}: lookup {toks:?} \
                                         len {} vs model {}",
                                        h.seed.len,
                                        mk.len()
                                    );
                                    pins.push((h.id, mk.clone()));
                                }
                                (None, None) => {}
                                _ => prop_assert!(
                                    false,
                                    "step {step}: lookup {toks:?} hit \
                                     mismatch: {} vs model {}",
                                    hit.is_some(),
                                    want.is_some()
                                ),
                            }
                        }
                        _ => {
                            if !pins.is_empty() {
                                let at = rng.below(pins.len());
                                let (id, key) = pins.swap_remove(at);
                                c.release(id);
                                if let Some(e) = model
                                    .entries
                                    .iter_mut()
                                    .find(|(k, ..)| *k == key)
                                {
                                    e.2 -= 1;
                                }
                            }
                        }
                    }
                    // exact byte accounting + identical resident set,
                    // checked after EVERY step
                    prop_assert!(
                        c.bytes_resident() == model.bytes,
                        "step {step}: bytes_resident {} vs model {}",
                        c.bytes_resident(),
                        model.bytes
                    );
                    prop_assert!(
                        c.len() == model.entries.len(),
                        "step {step}: {} entries vs model {}",
                        c.len(),
                        model.entries.len()
                    );
                    for (k, ..) in &model.entries {
                        prop_assert!(
                            c.contains(k),
                            "step {step}: model key {k:?} missing"
                        );
                    }
                    let probe_len = 1 + rng.below(6);
                    let probe: Vec<i32> = (0..probe_len)
                        .map(|_| *rng.choice(&alphabet))
                        .collect();
                    let want = model
                        .longest(&probe)
                        .map_or(0, |i| model.entries[i].0.len());
                    prop_assert!(
                        c.peek_longest(&probe) == want,
                        "step {step}: peek {probe:?} = {} vs model {}",
                        c.peek_longest(&probe),
                        want
                    );
                }
                // every pinned key must still be resident at the end
                for (_, key) in &pins {
                    prop_assert!(
                        c.contains(key),
                        "pinned key {key:?} was freed"
                    );
                }
                Ok(())
            },
        );
    }
}
