//! Shared-prefix cache: KV rows + merged GLASS statistics per prompt
//! prefix.
//!
//! A server handling traffic that shares system prompts / few-shot
//! headers recomputes the same prefill work — both the KV rows and the
//! prompt-local importance evidence A^l — for every admission. Both are
//! pure functions of the token prefix (KV rows of `(token, position)`
//! under causal attention, statistics of the token multiset per chunk),
//! so they can be computed once and spliced into every later request
//! that shares the prefix.
//!
//! Each [`PrefixCache`] entry stores, for one token-id prefix:
//!
//!  * its compact KV rows (`[L, H, len, Dh]`, K and V — only the prefix
//!    positions, not the whole `max_seq` window),
//!  * the token-count-weighted merge of its per-chunk local statistics
//!    plus the evidence mass behind it — exactly the `(merged, weight)`
//!    state of a [`ChunkedPrefill`] after consuming the prefix, so a
//!    resumed stream continues the merge with **bit-identical**
//!    arithmetic to a cold one,
//!  * the last-position logits after the prefix (so an exact full-prompt
//!    hit needs no engine call at all).
//!
//! Lookup is **longest-prefix match** over token IDs (a flat scan today
//! — entries are byte-budgeted, so the set stays small; a radix tree is
//! the scale-up path, see ROADMAP). Entries are **ref-counted**: a hit
//! pins its entry until the resumed stream completes, and eviction
//! never frees a pinned entry. Eviction is LRU under a configurable
//! byte budget, with bytes accounted through the [`memsim`] helpers so
//! the cache and the edge-memory cost model agree on what "resident"
//! means.
//!
//! [`ChunkedPrefill`]: super::chunked::ChunkedPrefill
//! [`memsim`]: crate::memsim

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, Result};

use super::{KvState, PrefillResult};
use crate::glass::ImportanceMap;
use crate::memsim;
use crate::runtime::ModelSpec;
use crate::tensor::TensorF;

/// Default serving-cache byte budget (32 MiB — generous for the
/// synthetic spec, a deliberate floor for real bundles; tune with
/// `--cache-bytes`).
pub const DEFAULT_CACHE_BYTES: usize = 32 << 20;

/// Per-request cache behavior, carried on the wire (`"cache"` field).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheMode {
    /// Consult the cache and publish new prefixes (default).
    On,
    /// Bypass the cache entirely: no lookup, no insert.
    Off,
    /// Consult the cache but never insert.
    ReadOnly,
}

impl CacheMode {
    pub fn parse(s: &str) -> Result<CacheMode> {
        Ok(match s {
            "on" => CacheMode::On,
            "off" => CacheMode::Off,
            "readonly" => CacheMode::ReadOnly,
            other => bail!("unknown cache mode '{other}' \
                            (expected on|off|readonly)"),
        })
    }

    pub fn as_str(self) -> &'static str {
        match self {
            CacheMode::On => "on",
            CacheMode::Off => "off",
            CacheMode::ReadOnly => "readonly",
        }
    }

    /// May this request read cached prefixes?
    pub fn reads(self) -> bool {
        !matches!(self, CacheMode::Off)
    }

    /// May this request publish new prefixes?
    pub fn writes(self) -> bool {
        matches!(self, CacheMode::On)
    }
}

/// Server-level aggregate cache counters, shared (Arc) between the
/// batcher's engine thread and the connection threads that answer the
/// `stats` protocol command — so operators can watch cache health
/// without scraping per-response telemetry.
#[derive(Debug, Default)]
pub struct CacheTelemetry {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    pub inserts: AtomicU64,
    pub evictions: AtomicU64,
    pub bytes_resident: AtomicU64,
    pub entries: AtomicU64,
}

/// A plain-data copy of [`CacheTelemetry`] at one instant.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheStatsSnapshot {
    pub hits: u64,
    pub misses: u64,
    pub inserts: u64,
    pub evictions: u64,
    pub bytes_resident: u64,
    pub entries: u64,
}

impl CacheStatsSnapshot {
    /// Field-wise sum — how a sharded server aggregates its per-shard
    /// cache counters into the one `stats`-command summary.
    pub fn merge(&self, other: &CacheStatsSnapshot) -> CacheStatsSnapshot {
        CacheStatsSnapshot {
            hits: self.hits + other.hits,
            misses: self.misses + other.misses,
            inserts: self.inserts + other.inserts,
            evictions: self.evictions + other.evictions,
            bytes_resident: self.bytes_resident + other.bytes_resident,
            entries: self.entries + other.entries,
        }
    }
}

impl CacheTelemetry {
    pub fn snapshot(&self) -> CacheStatsSnapshot {
        CacheStatsSnapshot {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            inserts: self.inserts.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            bytes_resident: self.bytes_resident.load(Ordering::Relaxed),
            entries: self.entries.load(Ordering::Relaxed),
        }
    }
}

/// Everything needed to resume a chunked prefill (or fabricate a whole
/// [`PrefillResult`], on an exact full-prompt hit) from a cached prefix:
/// the data cloned out of a cache entry by [`PrefixCache::lookup`].
#[derive(Debug, Clone)]
pub struct PrefixSeed {
    /// Prefix length in tokens (incl. BOS).
    pub len: usize,
    /// Compact K rows `[L, H, len, Dh]` (see `KvState::extract_prefix_rows`).
    pub k_rows: Vec<f32>,
    /// Compact V rows, same layout.
    pub v_rows: Vec<f32>,
    /// Token-count-weighted merge of the prefix's per-chunk statistics.
    pub stats: ImportanceMap,
    /// Evidence mass (token count) behind `stats`.
    pub weight: f64,
    /// Last-position logits after the prefix (`[vocab]`).
    pub logits: Vec<f32>,
}

/// A successful lookup: the cloned seed plus the pinned entry's id.
/// The caller must [`PrefixCache::release`] the id when the splice (or
/// the stream it resumed) is finished, so the entry becomes evictable
/// again.
#[derive(Debug)]
pub struct PrefixHit {
    pub id: usize,
    pub seed: PrefixSeed,
}

struct Entry {
    tokens: Vec<i32>,
    k_rows: Vec<f32>,
    v_rows: Vec<f32>,
    stats: ImportanceMap,
    weight: f64,
    logits: Vec<f32>,
    bytes: usize,
    refs: usize,
    tick: u64,
}

/// The cache itself (owned by one batcher; not internally synchronized —
/// the engine loop is single-threaded, only the telemetry is shared).
pub struct PrefixCache {
    spec: ModelSpec,
    budget_bytes: usize,
    /// Slot-map of entries: ids are stable across evictions.
    entries: Vec<Option<Entry>>,
    bytes_resident: usize,
    tick: u64,
    telemetry: Arc<CacheTelemetry>,
}

impl PrefixCache {
    pub fn new(
        spec: ModelSpec,
        budget_bytes: usize,
        telemetry: Arc<CacheTelemetry>,
    ) -> PrefixCache {
        PrefixCache {
            spec,
            budget_bytes,
            entries: Vec::new(),
            bytes_resident: 0,
            tick: 0,
            telemetry,
        }
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    pub fn bytes_resident(&self) -> usize {
        self.bytes_resident
    }

    /// Live entry count.
    pub fn len(&self) -> usize {
        self.entries.iter().filter(|e| e.is_some()).count()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.iter().all(|e| e.is_none())
    }

    /// Is this exact prefix cached? (test/diagnostic helper; does not
    /// touch LRU order or counters)
    pub fn contains(&self, tokens: &[i32]) -> bool {
        self.entries
            .iter()
            .flatten()
            .any(|e| e.tokens == tokens)
    }

    /// Length of the longest cached prefix of `tokens`, WITHOUT pinning,
    /// LRU-bumping, or counting a hit/miss — the batcher's deferral
    /// check peeks with this to decide whether a same-prefix admission
    /// would hit anyway (and so must not be deferred).
    pub fn peek_longest(&self, tokens: &[i32]) -> usize {
        self.entries
            .iter()
            .flatten()
            .filter(|e| tokens.starts_with(&e.tokens))
            .map(|e| e.tokens.len())
            .max()
            .unwrap_or(0)
    }

    fn entry_bytes(&self, len: usize) -> usize {
        let s = &self.spec;
        memsim::kv_prefix_bytes(s.n_layers, s.n_heads, s.head_dim, len)
            + memsim::stats_map_bytes(s.n_layers, s.ffn_m)
            + memsim::logits_bytes(s.vocab)
            + memsim::token_ids_bytes(len)
    }

    /// Longest cached prefix of `tokens` (a cache entry whose token ids
    /// are a prefix of the query — possibly all of it). On a hit the
    /// entry is pinned (ref-counted) and its LRU tick bumped; the caller
    /// must [`PrefixCache::release`] the returned id. Counts one hit or
    /// one miss.
    pub fn lookup(&mut self, tokens: &[i32]) -> Option<PrefixHit> {
        let mut best: Option<usize> = None;
        let mut best_len = 0usize;
        for (id, e) in self.entries.iter().enumerate() {
            let Some(e) = e else { continue };
            let longer = best.is_none() || e.tokens.len() > best_len;
            if longer && tokens.starts_with(&e.tokens) {
                best = Some(id);
                best_len = e.tokens.len();
            }
        }
        match best {
            Some(id) => {
                self.tick += 1;
                let e = self.entries[id].as_mut().unwrap();
                e.tick = self.tick;
                e.refs += 1;
                self.telemetry.hits.fetch_add(1, Ordering::Relaxed);
                Some(PrefixHit {
                    id,
                    seed: PrefixSeed {
                        len: e.tokens.len(),
                        k_rows: e.k_rows.clone(),
                        v_rows: e.v_rows.clone(),
                        stats: e.stats.clone(),
                        weight: e.weight,
                        logits: e.logits.clone(),
                    },
                })
            }
            None => {
                self.telemetry.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Unpin an entry returned by [`PrefixCache::lookup`]. Safe to call
    /// after the entry was (impossibly) evicted — eviction skips pinned
    /// entries, so a live pin always finds its entry.
    pub fn release(&mut self, id: usize) {
        if let Some(Some(e)) = self.entries.get_mut(id) {
            e.refs = e.refs.saturating_sub(1);
        }
    }

    /// Publish one prefix: KV rows are extracted from `kv` slot `slot`
    /// (positions `0..tokens.len()`), statistics and logits are stored
    /// verbatim. Duplicate prefixes are a no-op (LRU bump only). Entries
    /// larger than the whole budget are refused. Returns the number of
    /// evictions this insert caused.
    pub fn insert(
        &mut self,
        tokens: &[i32],
        kv: &KvState,
        slot: usize,
        stats: &ImportanceMap,
        weight: f64,
        logits: &[f32],
    ) -> usize {
        if tokens.is_empty() {
            return 0;
        }
        self.tick += 1;
        // duplicate: refresh recency, keep the existing entry (its
        // contents are a pure function of the prefix, so equal anyway)
        for e in self.entries.iter_mut().flatten() {
            if e.tokens == tokens {
                e.tick = self.tick;
                return 0;
            }
        }
        let bytes = self.entry_bytes(tokens.len());
        if bytes > self.budget_bytes {
            return 0;
        }
        let evicted = self.evict_to_fit(bytes);
        if self.bytes_resident + bytes > self.budget_bytes {
            // everything still resident is pinned; refuse the insert
            // rather than exceed the budget
            return evicted;
        }
        let (k_rows, v_rows) = kv.extract_prefix_rows(slot, tokens.len());
        let entry = Entry {
            tokens: tokens.to_vec(),
            k_rows,
            v_rows,
            stats: stats.clone(),
            weight,
            logits: logits.to_vec(),
            bytes,
            refs: 0,
            tick: self.tick,
        };
        self.bytes_resident += bytes;
        match self.entries.iter().position(|e| e.is_none()) {
            Some(free) => self.entries[free] = Some(entry),
            None => self.entries.push(Some(entry)),
        }
        self.telemetry.inserts.fetch_add(1, Ordering::Relaxed);
        self.publish_residency();
        evicted
    }

    /// Evict least-recently-used unpinned entries until `incoming` more
    /// bytes fit the budget (or nothing unpinned remains). Returns the
    /// eviction count.
    fn evict_to_fit(&mut self, incoming: usize) -> usize {
        let mut evicted = 0usize;
        while self.bytes_resident + incoming > self.budget_bytes {
            let victim = self
                .entries
                .iter()
                .enumerate()
                .filter_map(|(i, e)| match e {
                    Some(e) if e.refs == 0 => Some((e.tick, i)),
                    _ => None,
                })
                .min()
                .map(|(_, i)| i);
            let Some(i) = victim else { break };
            let e = self.entries[i].take().unwrap();
            self.bytes_resident -= e.bytes;
            evicted += 1;
        }
        if evicted > 0 {
            self.telemetry
                .evictions
                .fetch_add(evicted as u64, Ordering::Relaxed);
            self.publish_residency();
        }
        evicted
    }

    fn publish_residency(&self) {
        self.telemetry
            .bytes_resident
            .store(self.bytes_resident as u64, Ordering::Relaxed);
        self.telemetry
            .entries
            .store(self.len() as u64, Ordering::Relaxed);
    }
}

/// Fabricate the one-slot [`PrefillResult`] an exact full-prompt hit
/// stands in for: cached logits, cached stats, and a fresh KV window
/// with the prefix rows spliced at positions `0..len` (rows beyond the
/// prompt are zero — same as the chunked-prefill path leaves them; they
/// are decode-overwritten scratch).
pub fn seed_to_prefill_result(
    spec: &ModelSpec,
    seed: &PrefixSeed,
) -> Result<PrefillResult> {
    if seed.logits.len() != spec.vocab {
        bail!(
            "cached logits of {} values do not match vocab {}",
            seed.logits.len(),
            spec.vocab
        );
    }
    // same hardening as `chunked_prefill_resume`: a malformed seed must
    // be an error, not an assert panic inside the row splice
    let row_n = spec.n_layers * spec.n_heads * seed.len * spec.head_dim;
    if seed.k_rows.len() != row_n || seed.v_rows.len() != row_n {
        bail!("cached KV rows shape mismatch");
    }
    let mut kv = KvState::zeros(spec, 1);
    kv.write_prefix_rows(0, seed.len, &seed.k_rows, &seed.v_rows);
    Ok(PrefillResult {
        logits: TensorF::new(vec![1, spec.vocab], seed.logits.clone())?,
        kv,
        stats: seed.stats.to_stats_tensor(),
        lens: vec![seed.len],
        truncated: vec![false],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> ModelSpec {
        ModelSpec {
            vocab: 260,
            d_model: 4,
            n_layers: 2,
            n_heads: 1,
            head_dim: 4,
            ffn_m: 8,
            max_seq: 16,
            prefill_len: 4,
            score_len: 6,
            gen_len: 2,
            bos_id: 256,
            pad_id: 257,
        }
    }

    fn cache(budget: usize) -> PrefixCache {
        PrefixCache::new(
            tiny_spec(),
            budget,
            Arc::new(CacheTelemetry::default()),
        )
    }

    /// A KV cache whose rows are tagged by position so splices are
    /// checkable, plus matching stats/logits for `insert`.
    fn seed_parts(
        spec: &ModelSpec,
        fill: f32,
    ) -> (KvState, ImportanceMap, Vec<f32>) {
        let mut kv = KvState::zeros(spec, 1);
        for x in kv.k.data.iter_mut() {
            *x = fill;
        }
        for x in kv.v.data.iter_mut() {
            *x = -fill;
        }
        let stats = ImportanceMap::from_layers(vec![
            vec![fill; spec.ffn_m];
            spec.n_layers
        ])
        .unwrap();
        let logits = vec![fill; spec.vocab];
        (kv, stats, logits)
    }

    #[test]
    fn mode_parse_roundtrip_and_rejection() {
        for (s, m) in [
            ("on", CacheMode::On),
            ("off", CacheMode::Off),
            ("readonly", CacheMode::ReadOnly),
        ] {
            assert_eq!(CacheMode::parse(s).unwrap(), m);
            assert_eq!(m.as_str(), s);
        }
        assert!(CacheMode::parse("ON").is_err());
        assert!(CacheMode::parse("").is_err());
        assert!(CacheMode::On.reads() && CacheMode::On.writes());
        assert!(!CacheMode::Off.reads() && !CacheMode::Off.writes());
        assert!(
            CacheMode::ReadOnly.reads() && !CacheMode::ReadOnly.writes()
        );
    }

    #[test]
    fn longest_prefix_match_wins() {
        let spec = tiny_spec();
        let mut c = cache(usize::MAX);
        let (kv, stats, logits) = seed_parts(&spec, 1.0);
        c.insert(&[256, 97], &kv, 0, &stats, 2.0, &logits);
        c.insert(&[256, 97, 98, 99], &kv, 0, &stats, 4.0, &logits);
        c.insert(&[256, 120], &kv, 0, &stats, 2.0, &logits);

        // longest matching prefix is picked over the shorter one
        let hit = c.lookup(&[256, 97, 98, 99, 100, 101]).unwrap();
        assert_eq!(hit.seed.len, 4);
        assert_eq!(hit.seed.weight, 4.0);
        c.release(hit.id);

        // an entry longer than the query never matches
        let hit = c.lookup(&[256, 97, 98]).unwrap();
        assert_eq!(hit.seed.len, 2);
        c.release(hit.id);

        // exact-length match is legal (full-prompt hit)
        let hit = c.lookup(&[256, 97, 98, 99]).unwrap();
        assert_eq!(hit.seed.len, 4);
        c.release(hit.id);

        // divergent token → miss
        assert!(c.lookup(&[256, 98, 98]).is_none());
        let snap = c.telemetry.snapshot();
        assert_eq!(snap.hits, 3);
        assert_eq!(snap.misses, 1);
        assert_eq!(snap.inserts, 3);
        assert_eq!(snap.entries, 3);
    }

    #[test]
    fn peek_longest_is_nonmutating() {
        let spec = tiny_spec();
        let mut c = cache(usize::MAX);
        assert_eq!(c.peek_longest(&[256, 97]), 0, "empty cache");
        let (kv, stats, logits) = seed_parts(&spec, 1.0);
        c.insert(&[256, 97], &kv, 0, &stats, 2.0, &logits);
        c.insert(&[256, 97, 98], &kv, 0, &stats, 3.0, &logits);
        assert_eq!(c.peek_longest(&[256, 97, 98, 99]), 3);
        assert_eq!(c.peek_longest(&[256, 97, 99]), 2);
        assert_eq!(c.peek_longest(&[257]), 0);
        // no hit/miss counted, nothing pinned or LRU-bumped
        let snap = c.telemetry.snapshot();
        assert_eq!(snap.hits, 0);
        assert_eq!(snap.misses, 0);
        assert!(c
            .entries
            .iter()
            .flatten()
            .all(|e| e.refs == 0), "peek must not pin");
    }

    #[test]
    fn duplicate_insert_is_a_noop() {
        let spec = tiny_spec();
        let mut c = cache(usize::MAX);
        let (kv, stats, logits) = seed_parts(&spec, 1.0);
        c.insert(&[256, 97], &kv, 0, &stats, 2.0, &logits);
        let before = c.bytes_resident();
        c.insert(&[256, 97], &kv, 0, &stats, 2.0, &logits);
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes_resident(), before);
        assert_eq!(c.telemetry.snapshot().inserts, 1);
        // the empty prefix is never cached
        c.insert(&[], &kv, 0, &stats, 0.0, &logits);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn eviction_honors_the_byte_budget_lru_first() {
        let spec = tiny_spec();
        let mut c = cache(0); // sized below
        let two = c.entry_bytes(2);
        // room for exactly two 2-token entries
        c.budget_bytes = 2 * two;
        let (kv, stats, logits) = seed_parts(&spec, 1.0);
        assert_eq!(c.insert(&[256, 97], &kv, 0, &stats, 2.0, &logits), 0);
        assert_eq!(c.insert(&[256, 98], &kv, 0, &stats, 2.0, &logits), 0);
        assert_eq!(c.len(), 2);
        assert!(c.bytes_resident() <= c.budget_bytes());

        // touch the older entry so the OTHER one becomes LRU
        let hit = c.lookup(&[256, 97, 99]).unwrap();
        c.release(hit.id);
        let evicted =
            c.insert(&[256, 99], &kv, 0, &stats, 2.0, &logits);
        assert_eq!(evicted, 1, "third entry must evict exactly one");
        assert!(c.bytes_resident() <= c.budget_bytes());
        assert!(c.contains(&[256, 97]), "recently-used entry survives");
        assert!(!c.contains(&[256, 98]), "LRU entry evicted");
        assert!(c.contains(&[256, 99]));
        assert_eq!(c.telemetry.snapshot().evictions, 1);
    }

    #[test]
    fn pinned_entries_are_never_evicted() {
        let spec = tiny_spec();
        let mut c = cache(0);
        let two = c.entry_bytes(2);
        c.budget_bytes = two; // room for ONE entry
        let (kv, stats, logits) = seed_parts(&spec, 1.0);
        c.insert(&[256, 97], &kv, 0, &stats, 2.0, &logits);
        let pin = c.lookup(&[256, 97]).unwrap();

        // inserting another entry cannot evict the pinned one: the
        // insert is refused instead of exceeding the budget
        let evicted =
            c.insert(&[256, 98], &kv, 0, &stats, 2.0, &logits);
        assert_eq!(evicted, 0);
        assert!(c.contains(&[256, 97]), "pinned entry must survive");
        assert!(!c.contains(&[256, 98]), "insert refused while pinned");
        assert!(c.bytes_resident() <= c.budget_bytes());

        // released → evictable again
        c.release(pin.id);
        let evicted =
            c.insert(&[256, 98], &kv, 0, &stats, 2.0, &logits);
        assert_eq!(evicted, 1);
        assert!(!c.contains(&[256, 97]));
        assert!(c.contains(&[256, 98]));
    }

    #[test]
    fn oversized_entry_is_refused_outright() {
        let spec = tiny_spec();
        let mut c = cache(1); // 1 byte budget: nothing fits
        let (kv, stats, logits) = seed_parts(&spec, 1.0);
        assert_eq!(c.insert(&[256, 97], &kv, 0, &stats, 2.0, &logits), 0);
        assert!(c.is_empty());
        assert_eq!(c.bytes_resident(), 0);
    }

    #[test]
    fn seed_roundtrips_through_prefill_result() {
        let spec = tiny_spec();
        let mut c = cache(usize::MAX);
        let (kv, stats, logits) = seed_parts(&spec, 2.5);
        c.insert(&[256, 97, 98], &kv, 0, &stats, 3.0, &logits);
        let hit = c.lookup(&[256, 97, 98]).unwrap();
        let pre = seed_to_prefill_result(&spec, &hit.seed).unwrap();
        c.release(hit.id);
        assert_eq!(pre.lens, vec![3]);
        assert_eq!(pre.truncated, vec![false]);
        assert_eq!(pre.logits.shape, vec![1, spec.vocab]);
        assert!(pre.logits.data.iter().all(|&x| x == 2.5));
        assert_eq!(
            pre.stats.shape,
            vec![1, spec.n_layers, spec.ffn_m]
        );
        // spliced rows carry the cached values; rows beyond len are zero
        let (hn, tn, dh) = (spec.n_heads, spec.max_seq, spec.head_dim);
        for l in 0..spec.n_layers {
            for h in 0..hn {
                for p in 0..tn {
                    let base = ((l * hn + h) * tn + p) * dh;
                    let expect = if p < 3 { 2.5 } else { 0.0 };
                    for e in 0..dh {
                        assert_eq!(pre.kv.k.data[base + e], expect);
                        assert_eq!(pre.kv.v.data[base + e], -expect);
                    }
                }
            }
        }
        // wrong vocab is rejected
        let mut bad = hit.seed.clone();
        bad.logits.pop();
        assert!(seed_to_prefill_result(&spec, &bad).is_err());
    }
}
