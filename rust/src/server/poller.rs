//! Readiness polling for the serving reactor: epoll (Linux) / kqueue
//! (macOS) behind one thin [`Poller`] trait, with a portable
//! sleep-loop fallback.
//!
//! The reactor registers every connection's fd once and then blocks in
//! [`Poller::wait`]; an idle connection costs a registered fd, not a
//! sweep iteration. Engine-side event arrival (deltas produced while
//! every socket is quiet) is signalled through a [`Waker`] — an
//! eventfd on Linux, a self-pipe on macOS, an atomic flag on the
//! fallback — which makes a blocked `wait` return without any socket
//! becoming ready.
//!
//! No external dependencies: the epoll/kqueue/eventfd/pipe bindings
//! are hand-declared `extern "C"` prototypes against the platform
//! libc the binary already links. [`SleepPoller`] reproduces the
//! pre-readiness sweep semantics (report everything ready on a
//! ~500 µs cadence) and is the single remaining legitimate
//! `thread::sleep` site in the serving stack.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

/// Raw file descriptor type registered with a [`Poller`].
#[cfg(unix)]
pub use std::os::unix::io::RawFd;

/// Raw file descriptor stand-in on non-unix targets, where only the
/// [`SleepPoller`] (which never dereferences fds) is available.
#[cfg(not(unix))]
pub type RawFd = i32;

/// The raw fd of a TCP stream, for poller registration.
#[cfg(unix)]
pub fn stream_fd(s: &std::net::TcpStream) -> RawFd {
    use std::os::unix::io::AsRawFd;
    s.as_raw_fd()
}

/// Non-unix stand-in: the [`SleepPoller`] ignores fd values.
#[cfg(not(unix))]
pub fn stream_fd(_s: &std::net::TcpStream) -> RawFd {
    -1
}

/// The raw fd of a TCP listener, for poller registration.
#[cfg(unix)]
pub fn listener_fd(l: &std::net::TcpListener) -> RawFd {
    use std::os::unix::io::AsRawFd;
    l.as_raw_fd()
}

/// Non-unix stand-in: the [`SleepPoller`] ignores fd values.
#[cfg(not(unix))]
pub fn listener_fd(_l: &std::net::TcpListener) -> RawFd {
    -1
}

/// Which readiness directions a registration subscribes to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Interest {
    /// Readable (plus peer-hangup) only.
    Read,
    /// Writable only.
    Write,
    /// Both directions.
    ReadWrite,
}

/// One readiness event returned by [`Poller::wait`].
#[derive(Debug, Clone, Copy)]
pub struct PollEvent {
    /// The token the fd was registered under.
    pub token: u64,
    /// The fd can be read without blocking (or has hung up / errored —
    /// attempting the read is how the owner finds out).
    pub readable: bool,
    /// The fd can be written without blocking (or has errored).
    pub writable: bool,
}

/// Token reserved for the poller's internal wake channel; `register`
/// rejects it.
pub const WAKE_TOKEN: u64 = 0;

/// A readiness selector: register fds under tokens, then block in
/// [`Poller::wait`] until some registered fd is ready, a [`Waker`]
/// fires, or the timeout lapses. Level-triggered everywhere: an fd
/// that stays ready is reported again on the next `wait`, so owners
/// must drain (or drop interest) to avoid spinning.
pub trait Poller: Send {
    /// Subscribe `fd` under `token` (must not be [`WAKE_TOKEN`]).
    fn register(&mut self, fd: RawFd, token: u64, interest: Interest) -> Result<()>;
    /// Replace the interest set of an already-registered fd.
    fn modify(&mut self, fd: RawFd, token: u64, interest: Interest) -> Result<()>;
    /// Drop a registration. Callers deregister before closing the fd.
    fn deregister(&mut self, fd: RawFd) -> Result<()>;
    /// Block until readiness, a wake, or the timeout (`None` = no
    /// timeout); fills `out` with ready events (possibly none — a
    /// plain wake or timeout yields an empty set).
    fn wait(&mut self, out: &mut Vec<PollEvent>, timeout: Option<Duration>) -> Result<()>;
    /// A clonable cross-thread handle that makes a blocked (or the
    /// next) `wait` return promptly.
    fn waker(&self) -> Waker;
    /// Implementation name for logs and telemetry.
    fn kind(&self) -> &'static str;
}

/// Construct the best poller for this platform: epoll on Linux,
/// kqueue on macOS, falling back to the portable [`SleepPoller`] if
/// the readiness syscalls fail (or on targets without either).
pub fn new_poller() -> Box<dyn Poller> {
    #[cfg(target_os = "linux")]
    {
        match EpollPoller::new() {
            Ok(p) => return Box::new(p),
            Err(e) => crate::warn_!(
                "epoll unavailable ({e}); serving falls back to the sleep poller"
            ),
        }
    }
    #[cfg(target_os = "macos")]
    {
        match KqueuePoller::new() {
            Ok(p) => return Box::new(p),
            Err(e) => crate::warn_!(
                "kqueue unavailable ({e}); serving falls back to the sleep poller"
            ),
        }
    }
    Box::new(SleepPoller::new())
}

// ---------------------------------------------------------------- waker

/// Cross-thread wakeup handle for a [`Poller`]; see [`Poller::waker`].
/// Cheap to clone; wakes coalesce (N wakes before a `wait` produce one
/// return, which is all the reactor needs).
#[derive(Clone)]
pub struct Waker {
    inner: Arc<WakerInner>,
}

enum WakerInner {
    #[cfg(target_os = "linux")]
    Event(CFd),
    #[cfg(target_os = "macos")]
    Pipe { read: CFd, write: CFd },
    Flag(AtomicBool),
}

impl Waker {
    /// Make a blocked (or the next) `wait` on the owning poller return
    /// promptly. Never blocks, never fails: a full wake channel means
    /// a wake is already pending, which is all that is needed.
    pub fn wake(&self) {
        match &*self.inner {
            #[cfg(target_os = "linux")]
            WakerInner::Event(fd) => {
                let one: u64 = 1;
                // SAFETY: fd is a live eventfd owned by this waker's
                // Arc; the buffer is 8 valid bytes. EAGAIN (counter
                // saturated) just means a wake is already pending.
                let _ = unsafe { sys::write(fd.0, (&one as *const u64).cast(), 8) };
            }
            #[cfg(target_os = "macos")]
            WakerInner::Pipe { write, .. } => {
                let b = [1u8];
                // SAFETY: write.0 is the live nonblocking write end of
                // the self-pipe owned by this waker's Arc; the buffer
                // is 1 valid byte. EAGAIN means a wake is pending.
                let _ = unsafe { sys::write(write.0, b.as_ptr(), 1) };
            }
            WakerInner::Flag(flag) => {
                // Release pairs with the Acquire swap in the fallback
                // poller's wait: whatever the waking thread wrote
                // before wake() is visible once the flag is observed.
                flag.store(true, Ordering::Release);
            }
        }
    }

    /// Consume a pending wake signal (owning poller only).
    fn drain(&self) {
        match &*self.inner {
            #[cfg(target_os = "linux")]
            WakerInner::Event(fd) => {
                let mut buf: u64 = 0;
                // SAFETY: nonblocking 8-byte read from the live eventfd
                // owned by this waker's Arc into a valid u64 buffer.
                let _ = unsafe { sys::read(fd.0, (&mut buf as *mut u64).cast(), 8) };
            }
            #[cfg(target_os = "macos")]
            WakerInner::Pipe { read, .. } => {
                let mut buf = [0u8; 64];
                loop {
                    // SAFETY: nonblocking read from the live pipe read
                    // end owned by this waker's Arc into a valid
                    // 64-byte buffer.
                    let n = unsafe { sys::read(read.0, buf.as_mut_ptr(), buf.len()) };
                    if n < buf.len() as isize {
                        break;
                    }
                }
            }
            WakerInner::Flag(flag) => {
                // Acquire pairs with the Release store in wake(); see
                // there for the visibility argument.
                let _ = flag.swap(false, Ordering::Acquire);
            }
        }
    }

    /// The atomic flag, when this is a fallback (flag-based) waker.
    fn flag(&self) -> Option<&AtomicBool> {
        match &*self.inner {
            WakerInner::Flag(f) => Some(f),
            #[cfg(any(target_os = "linux", target_os = "macos"))]
            _ => None,
        }
    }
}

/// Closes the wrapped fd on drop (readiness-platform builds only).
#[cfg(any(target_os = "linux", target_os = "macos"))]
struct CFd(RawFd);

#[cfg(any(target_os = "linux", target_os = "macos"))]
impl Drop for CFd {
    fn drop(&mut self) {
        // SAFETY: self.0 is an open fd this wrapper exclusively owns;
        // closing it exactly once on drop is the ownership contract.
        let _ = unsafe { sys::close(self.0) };
    }
}

// ------------------------------------------------------ fallback poller

/// Portable fallback poller: no readiness syscalls. `wait` sleeps a
/// short tick (≤ 500 µs, further bounded by the caller's timeout)
/// unless a wake is pending, then reports EVERY registered fd as both
/// readable and writable. This is exactly the pre-readiness sweep:
/// correct — the reactor's nonblocking reads/writes tolerate spurious
/// readiness — but honest about its cost, which is O(registered fds)
/// per tick, so an idle fleet burns CPU proportional to connections.
/// The sleep below is the single legitimate `thread::sleep` site in
/// the serving stack (see the `no-sleep-outside-reactor` lint rule).
pub struct SleepPoller {
    registered: Vec<(RawFd, u64)>,
    wake: Waker,
}

impl SleepPoller {
    /// A fallback poller with no registrations.
    pub fn new() -> SleepPoller {
        SleepPoller {
            registered: Vec::new(),
            wake: Waker {
                inner: Arc::new(WakerInner::Flag(AtomicBool::new(false))),
            },
        }
    }
}

impl Default for SleepPoller {
    fn default() -> Self {
        SleepPoller::new()
    }
}

impl Poller for SleepPoller {
    fn register(&mut self, fd: RawFd, token: u64, _interest: Interest) -> Result<()> {
        if token == WAKE_TOKEN {
            bail!("token {WAKE_TOKEN} is reserved for the poller's waker");
        }
        self.registered.retain(|&(f, _)| f != fd);
        self.registered.push((fd, token));
        Ok(())
    }

    fn modify(&mut self, fd: RawFd, token: u64, interest: Interest) -> Result<()> {
        self.register(fd, token, interest)
    }

    fn deregister(&mut self, fd: RawFd) -> Result<()> {
        self.registered.retain(|&(f, _)| f != fd);
        Ok(())
    }

    fn wait(&mut self, out: &mut Vec<PollEvent>, timeout: Option<Duration>) -> Result<()> {
        out.clear();
        let woken = self.wake.flag().is_some_and(|f| {
            // Acquire pairs with the Release store in Waker::wake so
            // the event data written before the wake is visible here.
            f.swap(false, Ordering::Acquire)
        });
        if !woken {
            let tick = Duration::from_micros(500);
            let nap = timeout.map_or(tick, |t| t.min(tick));
            if !nap.is_zero() {
                // lint: allow(no-sleep-outside-reactor) -- the fallback
                // poller's sweep tick IS the reactor's parking site
                std::thread::sleep(nap);
            }
        }
        for &(_, token) in &self.registered {
            out.push(PollEvent {
                token,
                readable: true,
                writable: true,
            });
        }
        Ok(())
    }

    fn waker(&self) -> Waker {
        self.wake.clone()
    }

    fn kind(&self) -> &'static str {
        "sleep"
    }
}

// -------------------------------------------------------- linux / epoll

#[cfg(target_os = "linux")]
mod sys {
    //! Hand-declared libc prototypes (Linux): epoll + eventfd.

    pub const EPOLL_CTL_ADD: i32 = 1;
    pub const EPOLL_CTL_DEL: i32 = 2;
    pub const EPOLL_CTL_MOD: i32 = 3;
    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;
    pub const EPOLL_CLOEXEC: i32 = 0o2000000;
    pub const EFD_CLOEXEC: i32 = 0o2000000;
    pub const EFD_NONBLOCK: i32 = 0o4000;

    /// Kernel ABI `struct epoll_event`; packed on x86-64 only, per the
    /// uapi headers.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        pub fn epoll_create1(flags: i32) -> i32;
        pub fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        pub fn epoll_wait(
            epfd: i32,
            events: *mut EpollEvent,
            maxevents: i32,
            timeout_ms: i32,
        ) -> i32;
        pub fn eventfd(initval: u32, flags: i32) -> i32;
        pub fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
        pub fn write(fd: i32, buf: *const u8, count: usize) -> isize;
        pub fn close(fd: i32) -> i32;
    }
}

/// epoll-backed [`Poller`] (Linux): one `epoll_wait` per reactor
/// wakeup regardless of fleet size, with an eventfd wake channel
/// registered under [`WAKE_TOKEN`]. Level-triggered.
#[cfg(target_os = "linux")]
pub struct EpollPoller {
    epfd: CFd,
    wake: Waker,
    buf: Vec<sys::EpollEvent>,
}

#[cfg(target_os = "linux")]
impl EpollPoller {
    /// Create the epoll instance and its eventfd wake channel.
    pub fn new() -> Result<EpollPoller> {
        // SAFETY: plain syscall, no pointer arguments.
        let epfd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
        if epfd < 0 {
            bail!("epoll_create1: {}", std::io::Error::last_os_error());
        }
        let epfd = CFd(epfd);
        // SAFETY: plain syscall, no pointer arguments.
        let efd = unsafe { sys::eventfd(0, sys::EFD_CLOEXEC | sys::EFD_NONBLOCK) };
        if efd < 0 {
            bail!("eventfd: {}", std::io::Error::last_os_error());
        }
        let wake = Waker {
            inner: Arc::new(WakerInner::Event(CFd(efd))),
        };
        let p = EpollPoller {
            epfd,
            wake,
            buf: vec![sys::EpollEvent { events: 0, data: 0 }; 128],
        };
        p.ctl(sys::EPOLL_CTL_ADD, efd, sys::EPOLLIN, WAKE_TOKEN)?;
        Ok(p)
    }

    fn ctl(&self, op: i32, fd: RawFd, events: u32, token: u64) -> Result<()> {
        let mut ev = sys::EpollEvent {
            events,
            data: token,
        };
        // SAFETY: epfd is the live epoll fd owned by self; ev is a
        // valid epoll_event for the duration of the call.
        let rc = unsafe { sys::epoll_ctl(self.epfd.0, op, fd, &mut ev) };
        if rc < 0 {
            bail!(
                "epoll_ctl(op={op}, fd={fd}): {}",
                std::io::Error::last_os_error()
            );
        }
        Ok(())
    }
}

#[cfg(target_os = "linux")]
fn epoll_mask(interest: Interest) -> u32 {
    match interest {
        Interest::Read => sys::EPOLLIN | sys::EPOLLRDHUP,
        Interest::Write => sys::EPOLLOUT,
        Interest::ReadWrite => sys::EPOLLIN | sys::EPOLLRDHUP | sys::EPOLLOUT,
    }
}

#[cfg(target_os = "linux")]
impl Poller for EpollPoller {
    fn register(&mut self, fd: RawFd, token: u64, interest: Interest) -> Result<()> {
        if token == WAKE_TOKEN {
            bail!("token {WAKE_TOKEN} is reserved for the poller's waker");
        }
        self.ctl(sys::EPOLL_CTL_ADD, fd, epoll_mask(interest), token)
    }

    fn modify(&mut self, fd: RawFd, token: u64, interest: Interest) -> Result<()> {
        if token == WAKE_TOKEN {
            bail!("token {WAKE_TOKEN} is reserved for the poller's waker");
        }
        self.ctl(sys::EPOLL_CTL_MOD, fd, epoll_mask(interest), token)
    }

    fn deregister(&mut self, fd: RawFd) -> Result<()> {
        self.ctl(sys::EPOLL_CTL_DEL, fd, 0, 0)
    }

    fn wait(&mut self, out: &mut Vec<PollEvent>, timeout: Option<Duration>) -> Result<()> {
        out.clear();
        // round a sub-millisecond timeout UP so a 500 µs caller tick
        // does not degenerate into a nonblocking busy spin
        let ms = match timeout {
            None => -1,
            Some(d) => d.as_micros().div_ceil(1000).min(i32::MAX as u128) as i32,
        };
        let cap = self.buf.len() as i32;
        // SAFETY: epfd is the live epoll fd owned by self; buf is a
        // live allocation of `cap` epoll_event slots the kernel may
        // fill; the timeout is a plain integer.
        let n = unsafe { sys::epoll_wait(self.epfd.0, self.buf.as_mut_ptr(), cap, ms) };
        if n < 0 {
            let err = std::io::Error::last_os_error();
            if err.kind() == std::io::ErrorKind::Interrupted {
                return Ok(());
            }
            bail!("epoll_wait: {err}");
        }
        for ev in self.buf.iter().take(n as usize) {
            // copy fields out by value: the struct is packed on
            // x86-64, so references into it would be unaligned
            let bits = ev.events;
            let token = ev.data;
            if token == WAKE_TOKEN {
                self.wake.drain();
                continue;
            }
            let fail = bits & (sys::EPOLLERR | sys::EPOLLHUP) != 0;
            out.push(PollEvent {
                token,
                readable: fail || bits & (sys::EPOLLIN | sys::EPOLLRDHUP) != 0,
                writable: fail || bits & sys::EPOLLOUT != 0,
            });
        }
        Ok(())
    }

    fn waker(&self) -> Waker {
        self.wake.clone()
    }

    fn kind(&self) -> &'static str {
        "epoll"
    }
}

// -------------------------------------------------------- macos / kqueue

#[cfg(target_os = "macos")]
mod sys {
    //! Hand-declared libc prototypes (macOS): kqueue + self-pipe.

    pub const EVFILT_READ: i16 = -1;
    pub const EVFILT_WRITE: i16 = -2;
    pub const EV_ADD: u16 = 0x0001;
    pub const EV_DELETE: u16 = 0x0002;
    pub const EV_EOF: u16 = 0x8000;
    pub const EV_ERROR: u16 = 0x4000;
    pub const F_SETFL: i32 = 4;
    pub const O_NONBLOCK: i32 = 0x0004;

    /// ABI `struct kevent`; `udata` declared as `usize` (same layout
    /// as the C `void *`) so the type stays `Send` without an unsafe
    /// impl — it is never dereferenced.
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct Kevent {
        pub ident: usize,
        pub filter: i16,
        pub flags: u16,
        pub fflags: u32,
        pub data: isize,
        pub udata: usize,
    }

    #[repr(C)]
    pub struct Timespec {
        pub tv_sec: i64,
        pub tv_nsec: i64,
    }

    extern "C" {
        pub fn kqueue() -> i32;
        pub fn kevent(
            kq: i32,
            changelist: *const Kevent,
            nchanges: i32,
            eventlist: *mut Kevent,
            nevents: i32,
            timeout: *const Timespec,
        ) -> i32;
        pub fn pipe(fds: *mut i32) -> i32;
        pub fn fcntl(fd: i32, cmd: i32, arg: i32) -> i32;
        pub fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
        pub fn write(fd: i32, buf: *const u8, count: usize) -> isize;
        pub fn close(fd: i32) -> i32;
    }
}

/// kqueue-backed [`Poller`] (macOS) with a nonblocking self-pipe wake
/// channel. Read and write interest are separate kqueue filters; the
/// fd → token map lives here rather than in `udata`.
#[cfg(target_os = "macos")]
pub struct KqueuePoller {
    kq: CFd,
    wake: Waker,
    tokens: std::collections::HashMap<RawFd, (u64, Interest)>,
    buf: Vec<sys::Kevent>,
}

#[cfg(target_os = "macos")]
impl KqueuePoller {
    /// Create the kqueue instance and its self-pipe wake channel.
    pub fn new() -> Result<KqueuePoller> {
        // SAFETY: plain syscall, no pointer arguments.
        let kq = unsafe { sys::kqueue() };
        if kq < 0 {
            bail!("kqueue: {}", std::io::Error::last_os_error());
        }
        let kq = CFd(kq);
        let mut fds = [0i32; 2];
        // SAFETY: fds is a valid 2-slot i32 buffer for pipe() to fill.
        let rc = unsafe { sys::pipe(fds.as_mut_ptr()) };
        if rc < 0 {
            bail!("pipe: {}", std::io::Error::last_os_error());
        }
        let (r, w) = (CFd(fds[0]), CFd(fds[1]));
        for fd in [r.0, w.0] {
            // SAFETY: fd is a live pipe end we just created; F_SETFL
            // with O_NONBLOCK takes no pointers.
            let rc = unsafe { sys::fcntl(fd, sys::F_SETFL, sys::O_NONBLOCK) };
            if rc < 0 {
                bail!("fcntl(O_NONBLOCK): {}", std::io::Error::last_os_error());
            }
        }
        let wake_read = r.0;
        let wake = Waker {
            inner: Arc::new(WakerInner::Pipe { read: r, write: w }),
        };
        let mut p = KqueuePoller {
            kq,
            wake,
            tokens: std::collections::HashMap::new(),
            buf: vec![
                sys::Kevent {
                    ident: 0,
                    filter: 0,
                    flags: 0,
                    fflags: 0,
                    data: 0,
                    udata: 0,
                };
                128
            ],
        };
        p.change(wake_read, sys::EVFILT_READ, sys::EV_ADD)?;
        Ok(p)
    }

    fn change(&mut self, fd: RawFd, filter: i16, flags: u16) -> Result<()> {
        let ch = sys::Kevent {
            ident: fd as usize,
            filter,
            flags,
            fflags: 0,
            data: 0,
            udata: 0,
        };
        // SAFETY: kq is the live kqueue fd owned by self; ch is one
        // valid kevent change record; no event list is requested.
        let rc = unsafe { sys::kevent(self.kq.0, &ch, 1, std::ptr::null_mut(), 0, std::ptr::null()) };
        if rc < 0 {
            bail!(
                "kevent(change fd={fd} filter={filter}): {}",
                std::io::Error::last_os_error()
            );
        }
        Ok(())
    }

    fn apply(&mut self, fd: RawFd, old: Option<Interest>, new: Option<Interest>) -> Result<()> {
        let wants = |i: Option<Interest>, f: i16| match i {
            Some(Interest::Read) => f == sys::EVFILT_READ,
            Some(Interest::Write) => f == sys::EVFILT_WRITE,
            Some(Interest::ReadWrite) => true,
            None => false,
        };
        for filter in [sys::EVFILT_READ, sys::EVFILT_WRITE] {
            match (wants(old, filter), wants(new, filter)) {
                (false, true) => self.change(fd, filter, sys::EV_ADD)?,
                (true, false) => self.change(fd, filter, sys::EV_DELETE)?,
                _ => {}
            }
        }
        Ok(())
    }

    fn wake_read_fd(&self) -> RawFd {
        match &*self.wake.inner {
            WakerInner::Pipe { read, .. } => read.0,
            _ => -1,
        }
    }
}

#[cfg(target_os = "macos")]
impl Poller for KqueuePoller {
    fn register(&mut self, fd: RawFd, token: u64, interest: Interest) -> Result<()> {
        if token == WAKE_TOKEN {
            bail!("token {WAKE_TOKEN} is reserved for the poller's waker");
        }
        let old = self.tokens.get(&fd).map(|&(_, i)| i);
        self.apply(fd, old, Some(interest))?;
        self.tokens.insert(fd, (token, interest));
        Ok(())
    }

    fn modify(&mut self, fd: RawFd, token: u64, interest: Interest) -> Result<()> {
        self.register(fd, token, interest)
    }

    fn deregister(&mut self, fd: RawFd) -> Result<()> {
        if let Some((_, old)) = self.tokens.remove(&fd) {
            self.apply(fd, Some(old), None)?;
        }
        Ok(())
    }

    fn wait(&mut self, out: &mut Vec<PollEvent>, timeout: Option<Duration>) -> Result<()> {
        out.clear();
        let ts = timeout.map(|d| sys::Timespec {
            tv_sec: d.as_secs() as i64,
            tv_nsec: d.subsec_nanos() as i64,
        });
        let ts_ptr = ts
            .as_ref()
            .map_or(std::ptr::null(), |t| t as *const sys::Timespec);
        let cap = self.buf.len() as i32;
        // SAFETY: kq is the live kqueue fd owned by self; buf is a
        // live allocation of `cap` kevent slots the kernel may fill;
        // ts_ptr is null or points at a timespec alive for the call.
        let n = unsafe {
            sys::kevent(self.kq.0, std::ptr::null(), 0, self.buf.as_mut_ptr(), cap, ts_ptr)
        };
        if n < 0 {
            let err = std::io::Error::last_os_error();
            if err.kind() == std::io::ErrorKind::Interrupted {
                return Ok(());
            }
            bail!("kevent(wait): {err}");
        }
        let wake_fd = self.wake_read_fd();
        for ev in self.buf.iter().take(n as usize) {
            let fd = ev.ident as RawFd;
            if fd == wake_fd {
                self.wake.drain();
                continue;
            }
            let Some(&(token, _)) = self.tokens.get(&fd) else {
                continue;
            };
            let fail = ev.flags & (sys::EV_EOF | sys::EV_ERROR) != 0;
            out.push(PollEvent {
                token,
                readable: fail || ev.filter == sys::EVFILT_READ,
                writable: fail || ev.filter == sys::EVFILT_WRITE,
            });
        }
        Ok(())
    }

    fn waker(&self) -> Waker {
        self.wake.clone()
    }

    fn kind(&self) -> &'static str {
        "kqueue"
    }
}

// ----------------------------------------------------------------- tests

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read as _, Write as _};
    use std::net::{TcpListener, TcpStream};
    use std::time::Instant;

    fn tcp_pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        (client, server)
    }

    #[test]
    fn sleep_poller_reports_every_registration_ready() {
        let mut p = SleepPoller::new();
        p.register(41, 7, Interest::Read).unwrap();
        p.register(42, 9, Interest::ReadWrite).unwrap();
        let mut out = Vec::new();
        p.wait(&mut out, Some(Duration::from_millis(5))).unwrap();
        let mut tokens: Vec<u64> = out.iter().map(|e| e.token).collect();
        tokens.sort_unstable();
        assert_eq!(tokens, vec![7, 9]);
        assert!(out.iter().all(|e| e.readable && e.writable));
        p.deregister(41).unwrap();
        p.wait(&mut out, Some(Duration::from_millis(5))).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].token, 9);
    }

    #[test]
    fn sleep_poller_wake_skips_the_nap() {
        let mut p = SleepPoller::new();
        let w = p.waker();
        w.wake();
        let mut out = Vec::new();
        let t0 = Instant::now();
        p.wait(&mut out, Some(Duration::from_millis(250))).unwrap();
        assert!(
            t0.elapsed() < Duration::from_millis(200),
            "a pending wake must make wait return without napping"
        );
    }

    #[test]
    fn wake_token_is_rejected() {
        let mut p = SleepPoller::new();
        assert!(p.register(5, WAKE_TOKEN, Interest::Read).is_err());
        let mut p = new_poller();
        assert!(p.register(5, WAKE_TOKEN, Interest::Read).is_err());
    }

    #[cfg(any(target_os = "linux", target_os = "macos"))]
    mod readiness {
        use super::*;

        #[test]
        fn platform_poller_is_not_the_fallback() {
            let p = new_poller();
            assert_ne!(p.kind(), "sleep", "CI platforms must get real readiness");
        }

        #[test]
        fn data_arrival_reports_readable_for_the_right_token() {
            let (mut client, server) = tcp_pair();
            let mut p = new_poller();
            p.register(stream_fd(&server), 3, Interest::Read).unwrap();
            let mut out = Vec::new();
            // idle socket: nothing ready before the timeout
            p.wait(&mut out, Some(Duration::from_millis(20))).unwrap();
            assert!(out.is_empty(), "no events expected on an idle socket");
            client.write_all(b"hello\n").unwrap();
            p.wait(&mut out, Some(Duration::from_secs(5))).unwrap();
            assert!(
                out.iter().any(|e| e.token == 3 && e.readable),
                "got {out:?}"
            );
            let mut buf = [0u8; 16];
            let n = server.try_clone().unwrap().read(&mut buf).unwrap();
            assert_eq!(&buf[..n], b"hello\n");
        }

        #[test]
        fn modify_adds_writable_and_deregister_silences() {
            let (mut client, server) = tcp_pair();
            let mut p = new_poller();
            let fd = stream_fd(&server);
            p.register(fd, 4, Interest::Read).unwrap();
            p.modify(fd, 4, Interest::ReadWrite).unwrap();
            let mut out = Vec::new();
            p.wait(&mut out, Some(Duration::from_secs(5))).unwrap();
            assert!(
                out.iter().any(|e| e.token == 4 && e.writable),
                "an open socket with write interest is writable: {out:?}"
            );
            p.deregister(fd).unwrap();
            client.write_all(b"x\n").unwrap();
            p.wait(&mut out, Some(Duration::from_millis(50))).unwrap();
            assert!(
                out.iter().all(|e| e.token != 4),
                "deregistered fd must stay silent: {out:?}"
            );
        }

        #[test]
        fn waker_interrupts_a_blocked_wait() {
            let mut p = new_poller();
            // park on a quiet socket so the wait would otherwise block
            let (_client, server) = tcp_pair();
            p.register(stream_fd(&server), 8, Interest::Read).unwrap();
            let w = p.waker();
            let handle = std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(30));
                w.wake();
            });
            let mut out = Vec::new();
            let t0 = Instant::now();
            p.wait(&mut out, Some(Duration::from_secs(10))).unwrap();
            assert!(
                t0.elapsed() < Duration::from_secs(5),
                "wake must interrupt the wait well before the timeout"
            );
            assert!(out.iter().all(|e| e.token != WAKE_TOKEN));
            handle.join().unwrap();
        }

        #[test]
        fn wakes_coalesce_and_drain() {
            let mut p = new_poller();
            let w = p.waker();
            for _ in 0..100 {
                w.wake();
            }
            let mut out = Vec::new();
            p.wait(&mut out, Some(Duration::from_secs(5))).unwrap();
            assert!(out.is_empty());
            // drained: the next wait times out instead of spinning
            let t0 = Instant::now();
            p.wait(&mut out, Some(Duration::from_millis(30))).unwrap();
            assert!(out.is_empty());
            assert!(t0.elapsed() >= Duration::from_millis(20));
        }
    }
}
