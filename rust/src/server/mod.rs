//! Threaded serving layer: TCP listener + scheduler + engine loop.
//!
//! Topology (vLLM-router-like, scaled to one box):
//!   * N acceptor/connection threads parse JSON-line requests and push
//!     them onto the [`scheduler::Scheduler`] queue;
//!   * one engine thread drains batches, runs the GLASS flow
//!     (prefill → mask → fused sparse generate), and routes responses
//!     back through per-connection channels;
//!   * masks are per-slot, so heterogeneous strategies share a batch.

pub mod client;
pub mod protocol;
pub mod scheduler;

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::engine::session::pack_slot_masks;
use crate::engine::Engine;
use crate::glass::{build_mask, GlobalPrior, PriorKind, Strategy};
use crate::info;

use protocol::{Request, Response};
use scheduler::{Pending, Scheduler};

/// Server handle: bind address + shutdown flag.
pub struct Server {
    pub addr: String,
    shutdown: Arc<AtomicBool>,
    sched: Arc<Scheduler>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

struct Shared {
    engine: Engine,
    priors: HashMap<&'static str, GlobalPrior>,
    conns: Mutex<HashMap<u64, Sender<Response>>>,
}

impl Server {
    /// Start serving on `addr` (e.g. "127.0.0.1:7433"). Returns once the
    /// listener is bound; serving continues on background threads.
    pub fn start(engine: Engine, addr: &str, batch_width: usize) -> Result<Server> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding {addr}"))?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?.to_string();

        let mut priors = HashMap::new();
        for (key, kind) in [
            ("a-glass", PriorKind::ANps),
            ("i-glass", PriorKind::INps),
        ] {
            priors.insert(key, GlobalPrior::load(&engine.rt, kind)?);
        }
        // warm the executables so first requests aren't hit by compiles
        let b = engine.pick_batch(batch_width.min(4))?;
        engine.rt.executable(&format!("prefill_b{b}"))?;
        engine.rt.executable(&format!("generate_b{b}"))?;

        let shared = Arc::new(Shared {
            engine,
            priors,
            conns: Mutex::new(HashMap::new()),
        });
        let sched = Arc::new(Scheduler::new(
            batch_width,
            Duration::from_millis(4),
        ));
        let shutdown = Arc::new(AtomicBool::new(false));
        let mut threads = Vec::new();

        // engine loop
        {
            let shared = Arc::clone(&shared);
            let sched = Arc::clone(&sched);
            threads.push(std::thread::spawn(move || {
                engine_loop(&shared, &sched);
            }));
        }
        // acceptor
        {
            let shared = Arc::clone(&shared);
            let sched = Arc::clone(&sched);
            let shutdown = Arc::clone(&shutdown);
            threads.push(std::thread::spawn(move || {
                let next_conn = AtomicU64::new(1);
                loop {
                    if shutdown.load(Ordering::Relaxed) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let conn_id =
                                next_conn.fetch_add(1, Ordering::Relaxed);
                            let shared = Arc::clone(&shared);
                            let sched = Arc::clone(&sched);
                            std::thread::spawn(move || {
                                let _ = handle_conn(
                                    stream, conn_id, &shared, &sched,
                                );
                            });
                        }
                        Err(ref e)
                            if e.kind()
                                == std::io::ErrorKind::WouldBlock =>
                        {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            }));
        }
        info!("server listening on {local}");
        Ok(Server {
            addr: local,
            shutdown,
            sched,
            threads,
        })
    }

    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        self.sched.close();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    conn_id: u64,
    shared: &Arc<Shared>,
    sched: &Arc<Scheduler>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let (tx, rx) = channel::<Response>();
    shared.conns.lock().unwrap().insert(conn_id, tx);
    let mut writer = stream.try_clone()?;
    // writer thread: serialize responses back to the client
    let w = std::thread::spawn(move || {
        for resp in rx {
            if writeln!(writer, "{}", resp.to_line()).is_err() {
                break;
            }
        }
    });

    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        match Request::parse(&line) {
            Ok(request) => sched.submit(Pending {
                request,
                arrived: Instant::now(),
                conn_id,
            }),
            Err(e) => {
                // protocol error: respond immediately
                if let Some(tx) =
                    shared.conns.lock().unwrap().get(&conn_id)
                {
                    let _ = tx.send(Response::err(0, e.to_string()));
                }
            }
        }
    }
    shared.conns.lock().unwrap().remove(&conn_id);
    let _ = w.join();
    Ok(())
}

fn engine_loop(shared: &Arc<Shared>, sched: &Arc<Scheduler>) {
    while let Some(batch) = sched.next_batch() {
        let responses = match serve_batch(shared, &batch) {
            Ok(r) => r,
            Err(e) => batch
                .iter()
                .map(|p| Response::err(p.request.id, e.to_string()))
                .collect(),
        };
        let conns = shared.conns.lock().unwrap();
        for (p, resp) in batch.iter().zip(responses) {
            if let Some(tx) = conns.get(&p.conn_id) {
                let _ = tx.send(resp);
            }
        }
    }
}

/// Run one scheduled batch through the GLASS flow.
fn serve_batch(shared: &Arc<Shared>, batch: &[Pending]) -> Result<Vec<Response>> {
    let engine = &shared.engine;
    let spec = engine.spec().clone();
    let n = batch.len();
    let b = engine.pick_batch(n)?;
    let prompts: Vec<String> =
        batch.iter().map(|p| p.request.prompt.clone()).collect();

    let t0 = Instant::now();
    let pre = engine.prefill(&prompts, b)?;
    let prefill_ms = t0.elapsed().as_secs_f64() * 1e3;

    // per-slot masks from per-request strategies
    let mut masks = Vec::with_capacity(n);
    for (slot, p) in batch.iter().enumerate() {
        let req = &p.request;
        let local = engine.local_importance(&pre, slot)?;
        let k = spec.budget(req.density);
        let (strategy, prior) = match req.strategy.as_str() {
            "dense" => (Strategy::Dense, None),
            "griffin" => (Strategy::LocalOnly, None),
            "global" => (
                Strategy::GlobalOnly,
                shared.priors.get("a-glass"),
            ),
            "a-glass" => (
                Strategy::Glass { lambda: req.lambda },
                shared.priors.get("a-glass"),
            ),
            _ => (
                Strategy::Glass { lambda: req.lambda },
                shared.priors.get("i-glass"),
            ),
        };
        masks.push(build_mask(&strategy, &local, prior, k)?);
    }
    let mask_t = pack_slot_masks(&masks, n, b, &spec);

    let t1 = Instant::now();
    let gen = engine.generate(&prompts, &mask_t, b)?;
    let decode_ms = t1.elapsed().as_secs_f64() * 1e3;

    let n_gen = gen.tokens.shape[1];
    let mut out = Vec::with_capacity(n);
    for (slot, p) in batch.iter().enumerate() {
        let want = p.request.max_tokens.min(n_gen);
        let ids = &gen.tokens.data[slot * n_gen..slot * n_gen + want];
        out.push(Response::ok(
            p.request.id,
            engine.decode_text(ids),
            want,
            prefill_ms,
            decode_ms,
            masks[slot].density(),
        ));
    }
    Ok(out)
}
