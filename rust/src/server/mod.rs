//! Threaded serving layer: TCP listener + per-shard scheduler/batcher
//! pairs behind a prefix-affinity router.
//!
//! # Architecture
//!
//! ```text
//!                         ┌─▶ Scheduler 0 ──admit──▶ Batcher 0 (engine, KV,
//!  conn threads ──parse──▶│                              slots, prefix cache)
//!        ▲      route_shard└─▶ Scheduler N-1 ──admit──▶ Batcher N-1
//!        └───────────────── per-conn response channels ◀──retire──┘
//! ```
//!
//! * N acceptor/connection threads parse JSON-line requests
//!   ([`protocol`]) and **route** each one to a shard
//!   ([`route_shard`]): an FNV-1a hash of the prompt's leading
//!   [`route_window`] bytes — the first prefill frame's byte span
//!   (`prefill_len - 1`; BOS takes the frame's remaining token slot),
//!   i.e. the system-prefix window — modulo the shard count, so
//!   requests sharing a system prompt / few-shot header **colocate**
//!   on the shard whose prefix cache already holds their prefix.
//!   Routing is a pure function of the prompt text: deterministic
//!   across connections, threads, and restarts;
//! * each of the `shards` serving shards owns a full single-owner
//!   serving stack — one [`scheduler::Scheduler`] FCFS queue, one
//!   engine thread running the [`batcher::Batcher`] loop over its own
//!   `Engine`, KV state, decode slots, and shared-prefix cache. No
//!   cross-shard synchronization exists on the hot path: GLASS mask
//!   refresh, chunked admission, stats merging, and cache
//!   publish/splice all stay shard-local, preserving every
//!   single-owner invariant of the unsharded design. With the default
//!   `shards = 1` the topology (and its behavior, bit for bit) is
//!   exactly the pre-sharding server;
//! * within a shard, the batcher is the same continuous-batching loop
//!   as before: a fixed-width step-mode decode batch in which every
//!   slot is an independent request. Queued requests are admitted into
//!   free slots **mid-flight** (prefill + KV slot splice), finished
//!   slots respond and free **immediately**, so a short request is
//!   never blocked behind a long one (no head-of-line blocking);
//! * **chunked admission** — a prompt longer than the compiled prefill
//!   frame claims its slot and streams in through the `prefill_chunk`
//!   executable, at most `chunk_budget` chunks interleaved per decode
//!   step, while every other slot keeps emitting tokens (no full-batch
//!   prefill stall). Per-chunk local statistics are merged on the host
//!   (`ImportanceMap::merge`) into exactly the aggregate a monolithic
//!   prefill would produce, and the GLASS mask is built once the final
//!   chunk lands. Prompts are accepted up to `max_seq - max_tokens + 1`
//!   encoded tokens (the final token needs no KV write); anything
//!   larger is rejected with an explicit error — the server never
//!   silently truncates a prompt, and responses carry `prompt_tokens`
//!   as proof of full consumption. Admission overflow (burst wider
//!   than the free-slot count) is re-queued at the shard's scheduler
//!   front in FCFS order, never failed;
//! * masks are per-slot, so heterogeneous strategies share a batch; a
//!   request can opt into a periodic **GLASS mask refresh**
//!   (`refresh_every: R`) that re-runs the global-local rank aggregation
//!   every R decoded tokens on blended prompt + decaying-average decode
//!   statistics — the paper's aggregation applied over the generation
//!   horizon, for the long-form scenarios where prompt-only statistics
//!   drift;
//! * **shared-prefix cache** — per-shard; the server's total
//!   `cache_bytes` budget is split evenly across shards. Per cached
//!   token prefix a shard keeps the KV rows *and* the merged GLASS
//!   statistics (plus the last-position logits), both pure functions
//!   of the prefix. At admission the longest cached prefix of the
//!   prompt is spliced in: an exact full-prompt hit costs **zero**
//!   engine calls, a partial hit resumes the chunked stream after the
//!   prefix — continuing the statistics merge with the same arithmetic
//!   a cold stream would use, so a hit's prompt statistics (and
//!   therefore its GLASS mask and generated tokens) are
//!   **bit-identical** to a cold prefill. Completed-chunk prefixes and
//!   cold short prompts are published back; entries are ref-counted
//!   (a resuming stream pins its entry) and evicted LRU under the
//!   per-shard byte budget accounted through
//!   [`memsim`](crate::memsim). The scheduler clusters same-prefix
//!   requests and the batcher defers a same-prefix admission while an
//!   earlier one is still publishing; because the router colocates
//!   same-prefix traffic, a shared-system-prompt burst pays its
//!   prefill miss once **even when split across connections and
//!   shards**. Responses carry `cached_prompt_tokens` / `cache_hits` /
//!   `cache_evictions`; the `stats` protocol command serves the
//!   cross-shard **sum** of the cache counters plus one per-shard
//!   entry (queue depth, decode / prefill slot occupancy, width) so a
//!   routing imbalance is visible from the wire.
//!
//! # Knobs and trade-offs
//!
//! * `shards` ([`ServerOptions`], `glass serve --shards N`) — serving
//!   shard count; default 1 preserves the unsharded behavior exactly.
//!   More shards = more engine threads decoding in parallel and more
//!   (smaller) prefix caches; the router keeps warm traffic local, so
//!   scaling costs no cross-shard chatter. Shard counts far above the
//!   physical core count just slice the caches thinner.
//! * `batch_width` — decode slot count **per shard** (must fit a
//!   compiled `decode_b{W}`). Wider = more throughput under load,
//!   slightly more per-step work when mostly idle.
//! * scheduler `batch_window` — how long an idle shard waits for an
//!   initial burst to form before starting; admission is continuous
//!   afterwards, so this only shapes cold-start batching (latency ↔
//!   throughput).
//! * `Batcher::chunk_budget` — prefill chunks advanced per decode step
//!   for streaming (long-prompt) admissions; default 1. Higher values
//!   admit long prompts faster at the cost of more prefill work per
//!   decode step (worse inter-token latency for in-flight requests
//!   while a stream is active); 1 bounds the per-step overhead to one
//!   chunk. `overlap_steps` telemetry counts decode steps that ran
//!   concurrently with a stream — the direct no-stall observable.
//! * `refresh_every` (per request) — mask-refresh interval R. Small R
//!   tracks decode-time importance drift closely at the cost of one
//!   selection pass (pure host work, µs-scale) per R tokens; 0 keeps
//!   the prefill-time static mask.
//! * `cache_bytes` (server, [`ServerOptions`]) — **total**
//!   shared-prefix cache budget, split evenly across shards
//!   (`cache_bytes / shards` each); 0 disables caching entirely.
//!   Bigger budgets keep more distinct prefixes resident (more hits)
//!   at the cost of host memory; eviction is LRU per shard and never
//!   frees an entry a stream is resuming from. Prefix-affinity routing
//!   means splitting the budget does not split a prefix's hit rate —
//!   all of a prefix's traffic lands on the one shard that caches it.
//! * `cache` (per request) — `on` (read + publish, default),
//!   `readonly` (read, never insert — for traffic that must not
//!   displace hot prefixes), `off` (bypass — for strict cold-start
//!   measurements).
//! * `group_prefixes` (server) — same-prefix clustering/deferral so a
//!   burst of shared-prompt requests pays one miss; disable for strict
//!   FCFS admission order.
//!
//! # Request limits
//!
//! `density` ∈ (0, 1], `lambda` ∈ [0, 1], and `max_tokens` ≥ 1 are
//! enforced at protocol parse time; encoded prompt length (incl. BOS) +
//! `max_tokens` must fit the `max_seq + 1`-position serving capacity
//! (the KV window plus the final write-free token), enforced at
//! admission with an explicit "prompt too long" error.
//!
//! All executables a shard's loop can touch are warmed at startup —
//! `prefill_b{n}` for every admission size, `prefill_chunk_b1` for
//! streaming admissions, and the full-width `decode_b{W}` — so first
//! requests never pay compile latency at any batch size a scheduler
//! can form (the compiled-executable cache is shared, so warming costs
//! once, not once per shard).

pub mod batcher;
pub mod client;
pub mod protocol;
pub mod scheduler;

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::engine::prefix_cache::{
    CacheStatsSnapshot, CacheTelemetry, DEFAULT_CACHE_BYTES,
};
use crate::engine::Engine;
use crate::info;

use batcher::{Batcher, BatcherOptions, ShardGauges};
use protocol::{
    parse_client_line, stats_to_line, ClientLine, Response, ShardSnapshot,
};
use scheduler::{Pending, Scheduler};

/// Response lines are serialized before entering the per-connection
/// channel, so protocol commands (`stats`) and generation responses
/// share one ordered writer.
type Conns = Arc<Mutex<HashMap<u64, Sender<String>>>>;

/// Router window for a model: the byte span of the first cacheable
/// chunk — one prefill frame minus the BOS token slot (the byte-level
/// tokenizer maps one prompt byte per remaining token). Hashing
/// exactly this span guarantees two prompts that share their first
/// cached chunk also share a shard.
pub fn route_window(prefill_len: usize) -> usize {
    prefill_len.saturating_sub(1).max(1)
}

/// Route a prompt to a serving shard: FNV-1a over the prompt's leading
/// `window` bytes (the system-prefix span — [`route_window`] passes the
/// first prefill frame's byte span, so the hash covers exactly the
/// cacheable leading chunk), modulo the shard count. Prompts sharing at
/// least `window` leading bytes always land on the same shard, which is
/// what keeps shared-prefix cache hits local after the cache budget is
/// split. Deterministic across connections, threads, and restarts;
/// always 0 for a single shard.
pub fn route_shard(prompt: &str, n_shards: usize, window: usize) -> usize {
    if n_shards <= 1 {
        return 0;
    }
    let bytes = prompt.as_bytes();
    let take = bytes.len().min(window.max(1));
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in &bytes[..take] {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h % n_shards as u64) as usize
}

/// Construction knobs for [`Server::start_with`].
#[derive(Debug, Clone)]
pub struct ServerOptions {
    /// Decode slot count per shard (must fit a compiled `decode_b{W}`).
    pub batch_width: usize,
    /// Total shared-prefix cache byte budget, split evenly across
    /// shards; 0 disables the cache.
    pub cache_bytes: usize,
    /// Cluster same-prefix requests at each shard's scheduler and defer
    /// same-prefix admissions behind an in-flight publisher.
    pub group_prefixes: bool,
    /// Serving shard count (engine threads); 1 = the unsharded server.
    pub shards: usize,
}

impl ServerOptions {
    pub fn new(batch_width: usize) -> ServerOptions {
        ServerOptions {
            batch_width,
            cache_bytes: DEFAULT_CACHE_BYTES,
            group_prefixes: true,
            shards: 1,
        }
    }

    /// Builder-style shard count override.
    pub fn with_shards(mut self, shards: usize) -> ServerOptions {
        self.shards = shards;
        self
    }
}

/// One serving shard's handles, shared between the engine thread that
/// owns the batcher and the connection threads that submit work and
/// answer `stats`.
struct Shard {
    sched: Arc<Scheduler>,
    telemetry: Arc<CacheTelemetry>,
    gauges: Arc<ShardGauges>,
    width: usize,
}

/// Server handle: bind address + shutdown flag.
pub struct Server {
    pub addr: String,
    shutdown: Arc<AtomicBool>,
    shards: Arc<Vec<Shard>>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start serving on `addr` with default options (cache on, 1 shard).
    pub fn start(engine: Engine, addr: &str, batch_width: usize) -> Result<Server> {
        Server::start_with(engine, addr, ServerOptions::new(batch_width))
    }

    /// Start serving on `addr` (e.g. "127.0.0.1:7433"). Returns once the
    /// listener is bound; serving continues on background threads.
    pub fn start_with(
        engine: Engine,
        addr: &str,
        opts: ServerOptions,
    ) -> Result<Server> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding {addr}"))?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?.to_string();

        let n_shards = opts.shards.max(1);
        // split the cache budget evenly; with one shard this is the
        // whole budget (bit-identical to the unsharded server)
        let shard_cache_bytes = opts.cache_bytes / n_shards;
        let prefill_len = engine.spec().prefill_len;

        // build every shard's batcher up front: loads priors and warms
        // every executable an engine loop can hit (the compiled-
        // executable cache is shared across shards, so the warm-up work
        // is paid once)
        let mut batchers = Vec::with_capacity(n_shards);
        let mut shards = Vec::with_capacity(n_shards);
        for _ in 0..n_shards {
            let engine_loop = Batcher::with_options(
                engine.clone(),
                BatcherOptions {
                    batch_width: opts.batch_width,
                    cache_bytes: shard_cache_bytes,
                    chunk_budget: 1,
                    group_prefixes: opts.group_prefixes,
                },
            )?;
            let group_bytes =
                if opts.group_prefixes && shard_cache_bytes > 0 {
                    // one prefill frame of shared prompt bytes ≈ one
                    // cacheable chunk (byte-level tokenizer)
                    prefill_len
                } else {
                    0
                };
            shards.push(Shard {
                sched: Arc::new(
                    Scheduler::new(
                        opts.batch_width,
                        Duration::from_millis(4),
                    )
                    .with_prefix_grouping(group_bytes),
                ),
                telemetry: engine_loop.telemetry(),
                gauges: engine_loop.gauges(),
                width: engine_loop.width,
            });
            batchers.push(engine_loop);
        }
        let shards = Arc::new(shards);
        let conns: Conns = Arc::new(Mutex::new(HashMap::new()));
        let shutdown = Arc::new(AtomicBool::new(false));
        let mut threads = Vec::new();

        // one engine thread per shard: independent continuous-batching
        // loops, no cross-shard synchronization
        for (shard_id, mut engine_loop) in batchers.into_iter().enumerate()
        {
            let conns = Arc::clone(&conns);
            let sched = Arc::clone(&shards[shard_id].sched);
            threads.push(std::thread::spawn(move || {
                let mut sink = |conn_id: u64, resp: Response| {
                    if let Some(tx) = conns.lock().unwrap().get(&conn_id) {
                        let _ = tx.send(resp.to_line());
                    }
                };
                engine_loop.run(&sched, &mut sink);
            }));
        }
        // acceptor
        {
            let conns = Arc::clone(&conns);
            let shards = Arc::clone(&shards);
            let shutdown = Arc::clone(&shutdown);
            threads.push(std::thread::spawn(move || {
                let next_conn = AtomicU64::new(1);
                loop {
                    if shutdown.load(Ordering::Relaxed) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let conn_id =
                                next_conn.fetch_add(1, Ordering::Relaxed);
                            let conns = Arc::clone(&conns);
                            let shards = Arc::clone(&shards);
                            std::thread::spawn(move || {
                                let _ = handle_conn(
                                    stream,
                                    conn_id,
                                    &conns,
                                    &shards,
                                    route_window(prefill_len),
                                );
                            });
                        }
                        Err(ref e)
                            if e.kind()
                                == std::io::ErrorKind::WouldBlock =>
                        {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            }));
        }
        info!(
            "server listening on {local} ({n_shards} shard{})",
            if n_shards == 1 { "" } else { "s" }
        );
        Ok(Server {
            addr: local,
            shutdown,
            shards,
            threads,
        })
    }

    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        for shard in self.shards.iter() {
            shard.sched.close();
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    conn_id: u64,
    conns: &Conns,
    shards: &Arc<Vec<Shard>>,
    route_window: usize,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let (tx, rx) = channel::<String>();
    conns.lock().unwrap().insert(conn_id, tx);
    let mut writer = stream.try_clone()?;
    // writer thread: one ordered line stream back to the client
    let w = std::thread::spawn(move || {
        for line in rx {
            if writeln!(writer, "{line}").is_err() {
                break;
            }
        }
    });
    let send = |line: String| {
        if let Some(tx) = conns.lock().unwrap().get(&conn_id) {
            let _ = tx.send(line);
        }
    };

    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        match parse_client_line(&line) {
            Ok(ClientLine::Request(request)) => {
                // prefix-affinity routing: a pure function of the
                // prompt text, so same-prefix traffic colocates on the
                // shard whose cache holds (or will hold) its prefix
                let si = route_shard(
                    &request.prompt,
                    shards.len(),
                    route_window,
                );
                shards[si].sched.submit(Pending {
                    request,
                    arrived: Instant::now(),
                    conn_id,
                });
            }
            Ok(ClientLine::Stats { id }) => {
                // answered right here from the shared counters — no
                // round trip through any engine loop
                let agg = shards.iter().fold(
                    CacheStatsSnapshot::default(),
                    |acc, s| acc.merge(&s.telemetry.snapshot()),
                );
                let per: Vec<ShardSnapshot> = shards
                    .iter()
                    .enumerate()
                    .map(|(i, s)| ShardSnapshot {
                        shard: i as u64,
                        queue_depth: s.sched.len() as u64,
                        slots_active: s.gauges.active(),
                        slots_prefilling: s.gauges.prefilling(),
                        batch_width: s.width as u64,
                    })
                    .collect();
                send(stats_to_line(id, &agg, &per));
            }
            Err(e) => {
                // protocol error: respond immediately
                send(Response::err(0, e.to_string()).to_line());
            }
        }
    }
    conns.lock().unwrap().remove(&conn_id);
    let _ = w.join();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_is_deterministic_and_in_range() {
        let prompts = [
            "once there was a red fox",
            "the blue owl is",
            "every morning the wolf",
            "the grey cat is quiet and",
            "",
        ];
        for n in [1usize, 2, 3, 4, 8] {
            for p in &prompts {
                let s = route_shard(p, n, 32);
                assert!(s < n, "shard {s} out of range for {n}");
                // pure function: repeat calls agree
                for _ in 0..3 {
                    assert_eq!(route_shard(p, n, 32), s);
                }
            }
        }
        // a single shard never hashes
        assert_eq!(route_shard("anything", 1, 32), 0);
        assert_eq!(route_shard("anything", 0, 32), 0);
    }

    #[test]
    fn route_window_is_the_first_frame_minus_bos() {
        assert_eq!(route_window(32), 31);
        assert_eq!(route_window(2), 1);
        // degenerate frames still hash at least one byte
        assert_eq!(route_window(1), 1);
        assert_eq!(route_window(0), 1);
    }

    #[test]
    fn shared_prefix_window_colocates() {
        // prompts sharing at least `window` leading bytes must land on
        // the same shard — the property that keeps warm hits local
        let sys = "SYSTEM: you are a terse assistant. ".repeat(2);
        assert!(sys.len() >= 32);
        for n in [2usize, 3, 4, 7] {
            let home = route_shard(&format!("{sys}alpha"), n, 32);
            for suffix in ["beta", "gamma", "a much longer user turn"] {
                assert_eq!(
                    route_shard(&format!("{sys}{suffix}"), n, 32),
                    home,
                    "suffix {suffix:?} broke colocation at {n} shards"
                );
            }
        }
    }

    #[test]
    fn distinct_prefixes_spread_across_shards() {
        // not a strict uniformity claim — just that the hash actually
        // disperses: 32 distinct prefixes must touch ≥ 2 of 4 shards
        let hit: std::collections::HashSet<usize> = (0..32)
            .map(|i| route_shard(&format!("prompt number {i} says"), 4, 32))
            .collect();
        assert!(hit.len() >= 2, "router sent everything to one shard");
    }

    #[test]
    fn short_prompts_hash_their_whole_text() {
        // prompts shorter than the window differ within it → may spread
        let a = route_shard("a", 4, 32);
        let same = (0..8u8).all(|i| {
            route_shard(&((b'a' + i) as char).to_string(), 4, 32) == a
        });
        assert!(!same, "window-clamped hash ignored short-prompt bytes");
    }

    #[test]
    fn options_default_to_one_shard() {
        let o = ServerOptions::new(4);
        assert_eq!(o.shards, 1, "default must preserve the unsharded server");
        assert_eq!(o.with_shards(4).shards, 4);
    }
}
