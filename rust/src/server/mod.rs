//! Serving layer: a **readiness-driven nonblocking reactor** front
//! (epoll on Linux, kqueue on macOS, a sleep-tick fallback elsewhere —
//! see [`poller`]) carrying framed, multiplexed protocol-v2 sessions
//! (and the legacy v1 protocol, auto-detected) over per-shard
//! scheduler/batcher pairs behind a prefix-affinity router.
//!
//! # Architecture
//!
//! ```text
//!             accept            round-robin handoff
//!  listener ────────▶ acceptor ─────────────────────┐
//!  (registered with                                 ▼
//!   its own poller)   ┌─ reactor thread 0 ──────┐   ┌─ reactor R-1 ─┐
//!                     │ poller (epoll/kqueue):  │   │      ...      │
//!                     │ conn fds by readiness + │   └───────────────┘
//!                     │ engine-event self-wake; │
//!                     │ bounded r/w buffers,    │
//!                     │ per-conn protocol state │
//!                     └───┬──────────────▲──────┘
//!                  submit │ / control    │ per-conn event channels
//!                         ▼              │ + dirty-list doorbell
//!  ┌─▶ Scheduler 0 ──admit──▶ Batcher 0 (engine thread: KV, slots,
//!  │                              prefix cache, event emission)
//!  └─▶ Scheduler N-1 ─admit──▶ Batcher N-1
//!     (route_shard: FNV-1a over the prompt's leading bytes)
//! ```
//!
//! # Transport: readiness, backpressure, zero-copy ingestion
//!
//! * **Readiness, not sweeps.** Each reactor thread (one per shard)
//!   owns a [`poller::Poller`]: every connection's nonblocking socket
//!   is registered under its connection id, with the interest set kept
//!   in sync with what the connection can actually use (read interest
//!   while the protocol allows input, write interest only while
//!   outbound bytes are pending, deregistered entirely once neither
//!   applies). The loop parks in [`poller::Poller::wait`] and services
//!   exactly the connections the kernel reports — an **idle connection
//!   costs a registered fd and a table entry, not a per-tick `read`
//!   poll**. Engine-side event arrival rides a second path: the
//!   batcher's sink marks the connection id dirty and fires the
//!   poller's [`poller::Waker`] (eventfd on Linux, self-pipe on
//!   macOS), so the reactor drains exactly the dirty connections'
//!   event channels instead of `try_recv`-polling all of them. On
//!   targets without epoll/kqueue (or if their syscalls fail at
//!   startup) the same loop runs unchanged over the honest
//!   [`poller::SleepPoller`], which restores the old
//!   O(connections)-per-tick sweep cost (~0.5 ms ticks) — correct
//!   everywhere, cheap where the real pollers exist.
//! * **Per-connection buffers are bounded; slow consumers are parked,
//!   not dropped.** The read buffer rejects any frame larger than
//!   `max_frame_bytes` (a client that never sends a newline, or sends
//!   one gigantic line, gets a protocol error and a closed connection
//!   instead of growing server memory without limit). The write
//!   buffer is watermarked: when a consumer's backlog crosses the
//!   **high-water mark** (`high_water_bytes`, default
//!   `conn_buffer_bytes`), the reactor sends a
//!   [`scheduler::Control::Park`] for every live session on that
//!   connection — their decode slots keep KV, emitter state, and FCFS
//!   position but take no steps — and when the backlog drains below
//!   the **low-water mark** (default high/4) an `Unpark` resumes them
//!   **byte-identically** (deterministic decode; see
//!   [`batcher`]'s backpressure section). Only a connection whose
//!   backlog still grows past a hard safety valve (8× the cap —
//!   frames already emitted before the park landed) is disconnected.
//! * **Zero-copy frame ingestion.** Inbound line splitting — the
//!   per-token hot path for v2 delta-ack/cancel/control traffic —
//!   borrows frames straight out of the connection's read buffer via
//!   [`scanner::FrameScanner`]: no intermediate `String`/`Vec` per
//!   line, one front-drain per readiness burst, and no byte is
//!   newline-scanned twice (equivalence with the old allocating
//!   splitter is pinned by a fuzz test in [`scanner`]).
//! * **Protocol negotiation** happens on the first parsed line of each
//!   connection ([`protocol`]): `"v":2` locks the connection to the
//!   framed multiplexed protocol (interleaved `accepted` / `delta` /
//!   `refresh` / `done` / `error` event frames per session id, plus
//!   client `cancel` and mid-stream `set` control frames); anything
//!   else locks it to v1, which the compatibility shim serves
//!   **bit-identically** to the pre-reactor server — non-terminal
//!   events are suppressed and the terminal event is serialized as the
//!   classic one-line response.
//! * **Routing** is per-request and unchanged from the sharded server:
//!   [`route_shard`] hashes the prompt's leading [`route_window`]
//!   bytes (the first prefill frame's byte span — the system-prefix
//!   window) with FNV-1a, modulo the shard count, so requests sharing
//!   a system prompt colocate on the shard whose prefix cache already
//!   holds their prefix. A pure function of the prompt text:
//!   deterministic across connections, reactors, and restarts.
//!   v2 `cancel`/`set` frames are routed to the shard recorded for
//!   their session at submission (the connection tracks live session
//!   ids); controls ride the shard scheduler's control queue and are
//!   drained by the batcher at the top of every loop iteration, so a
//!   cancel frees its decode slot within one decode step.
//! * Each of the `shards` serving shards owns a full single-owner
//!   serving stack — one [`scheduler::Scheduler`] FCFS queue (+ its
//!   control queue), one engine thread running the
//!   [`batcher::Batcher`] loop over its own `Engine`, KV state, decode
//!   slots, and shared-prefix cache. No cross-shard synchronization
//!   exists on the hot path: GLASS mask refresh, chunked admission,
//!   stats merging, and cache publish/splice all stay shard-local,
//!   preserving every single-owner invariant of the unsharded design.
//!   With the default `shards = 1` the topology (and its behavior, bit
//!   for bit) is exactly the pre-sharding server;
//! * within a shard, the batcher is the same continuous-batching loop
//!   as before: a fixed-width step-mode decode batch in which every
//!   slot is an independent request, queued requests admitted into
//!   free slots **mid-flight**, finished slots retired **immediately**
//!   (no head-of-line blocking), **chunked admission** for prompts
//!   longer than the prefill frame (at most `chunk_budget` chunks per
//!   decode step, other slots keep emitting), per-slot masks with
//!   optional periodic **GLASS mask refresh** (`refresh_every`, now
//!   adjustable mid-stream via v2 `set`), and the per-shard
//!   **shared-prefix cache** (total `cache_bytes` split evenly; exact
//!   hits skip prefill, partial hits resume the chunked stream
//!   bit-identically; ref-counted, LRU under the byte budget). The
//!   cache is indexed by an **edge-compressed radix trie** over token
//!   ids, so `lookup`/`peek_longest`/`insert` walk O(prompt-length)
//!   edges regardless of how many entries are resident — hundreds of
//!   cached prefixes cost a lookup no more than one does.
//! * **Cache persistence** (`--cache-dir`, [`ServerConfig::cache_dir`]):
//!   when set, [`Server::stop`] snapshots each shard's resident prefix
//!   entries to `<cache-dir>/prefix-shard-<i>.gpxs` *after* its engine
//!   loop drains (format documented in
//!   [`prefix_store`](crate::engine::prefix_store); version
//!   [`SNAPSHOT_VERSION`](crate::engine::prefix_store::SNAPSHOT_VERSION),
//!   length-prefixed + FNV-1a-checksummed, written via temp file +
//!   rename). The next startup warm-starts each shard's cache from its
//!   file before serving — [`route_shard`] is deterministic, so every
//!   snapshot lands back on the shard that will serve its prefixes,
//!   and a previously-cached prompt is answered with **zero** engine
//!   prefill calls (`warm_start_hits` in `stats` counts these). A
//!   corrupt, truncated, or model-mismatched snapshot is skipped with
//!   a warning — startup never fails on cache damage, it just serves
//!   cold.
//! * **Resumable sessions** (protocol v2 `resume` frame): a client
//!   whose connection died mid-stream reconnects and replays its
//!   prompt plus the number of deltas already received; the server
//!   re-admits the session like a generate (the prefix cache supplies
//!   the prompt work it already did), re-runs the deterministic
//!   decode, and suppresses the deltas the client already has — the
//!   continued stream carries the original indices and its
//!   concatenation is byte-identical to the uninterrupted stream. See
//!   [`protocol`] for the frame grammar and ordering guarantees.
//! * **Graceful shutdown** ([`Server::stop`]): the acceptor stops
//!   accepting and late frames are refused; every in-flight session
//!   drains to its natural `done`; queued-but-unadmitted requests get
//!   an `error` frame with `retryable: true` (resubmit verbatim
//!   elsewhere); reactors then flush every connection's pending bytes
//!   before exiting.
//!
//! # Knobs and trade-offs
//!
//! All construction knobs live in one typed builder —
//! [`crate::config::ServerConfig`] — constructed once from
//! CLI/TOML/[`crate::config::RunConfig`] and handed down
//! ([`Server::start_with_config`]). The legacy [`ServerOptions`] /
//! [`batcher::BatcherOptions`] structs survive only as thin
//! compatibility views in [`crate::config::compat`], re-exported at
//! their historical paths.
//!
//! * `shards` (`glass serve --shards N`) — serving shard count (engine
//!   threads AND reactor threads); default 1 preserves the unsharded
//!   behavior exactly. More shards = more engine threads decoding in
//!   parallel and more (smaller) prefix caches; the router keeps warm
//!   traffic local.
//! * `batch_width` — decode slot count **per shard** (must fit a
//!   compiled `decode_b{W}`).
//! * `max_frame_bytes` (`--max-frame-bytes`) — largest accepted wire
//!   frame; the per-connection read-buffer bound. Default 1 MiB.
//! * `conn_buffer_bytes` (`--conn-buffer-bytes`) — outbound buffer cap
//!   per connection; crossing it parks the connection's sessions
//!   (backpressure) rather than disconnecting. Default 8 MiB.
//! * `high_water_bytes` / `low_water_bytes` (`--high-water-bytes`,
//!   `--low-water-bytes`) — explicit backpressure watermarks; 0 (the
//!   default) derives them (`conn_buffer_bytes` and a quarter of the
//!   high mark respectively).
//! * `Batcher::chunk_budget` — prefill chunks advanced per decode step
//!   for streaming (long-prompt) admissions; default 1.
//! * `refresh_every` (per request, adjustable mid-stream with a v2
//!   `set` frame) — mask-refresh interval R; 0 keeps the prefill-time
//!   static mask.
//! * `cache_bytes` (server) — **total** shared-prefix cache budget,
//!   split evenly across shards; 0 disables caching entirely.
//! * `cache_dir` (`--cache-dir`) — directory for persistent prefix
//!   snapshots (one file per shard); unset disables persistence.
//! * `cache` (per request) — `on` (read + publish, default),
//!   `readonly`, `off`.
//! * `group_prefixes` (server) — same-prefix clustering/deferral so a
//!   burst of shared-prompt requests pays one miss.
//! * `governor` (`--governor on|off`) — the overload governor +
//!   work-stealing (see "Load governance" below); default off.
//! * `governor_floors` (`--governor-floor-interactive/-standard/
//!   -batch`) — per-tier effective-density floors the governor never
//!   degrades past.
//! * `steal_threshold` (`--steal-threshold`) — home-shard pressure
//!   (outstanding work / width) at which an idle sibling may steal an
//!   admission.
//!
//! # Load governance
//!
//! GLASS gives every request a quality/compute dial (`density`,
//! `refresh_every`); the overload governor ([`governor`]) turns that
//! dial under pressure instead of letting the queue grow until
//! requests shed. Each request carries an SLO **tier** (`interactive`
//! / `standard` / `batch`, wire key `tier`, default `standard`). Each
//! shard's engine loop feeds its queue depth, occupancy, and oldest
//! queue age into the shared [`Governor`], which maintains a per-shard
//! **degradation level** (0–3, hysteresis in both directions so a
//! steady plateau never oscillates). At admission the batcher maps the
//! request's knobs through the level for its tier — batch degrades
//! first and deepest, interactive last and least, never below the
//! configured per-tier density floor — and marks the request
//! `degraded`. The rewrite happens once, before any engine work, so a
//! degraded request is **bit-identical** to the same request sent
//! explicitly with the degraded values, and it is fully reversible:
//! when pressure drains the level returns to 0 in one observation and
//! new admissions serve at full requested density. `done` frames
//! report `degraded` + `effective_density`; `stats` reports
//! `governor_level`, `degraded_requests`, and `stolen_requests` per
//! shard.
//!
//! The governor also unlocks **hot-prefix work-stealing** ([`steal`]):
//! when the router's target shard is past the steal threshold and a
//! sibling could start the request immediately, the sibling steals the
//! admission, and the home shard's longest matching cached prefix is
//! replicated into the thief's cache first so the stolen request still
//! warm-hits. This is the one deliberate, bounded exception to the
//! shards-never-share invariant above — admission-time only, copy-only,
//! locks taken sequentially and never nested (see [`steal`]'s module
//! docs). Everything is off by default (`--governor on` enables it);
//! disabled, the governor is an identity and routing is untouched.
//!
//! # Request limits
//!
//! `density` ∈ (0, 1], `lambda` ∈ [0, 1], and `max_tokens` ≥ 1 are
//! enforced at protocol parse time; encoded prompt length (incl. BOS) +
//! `max_tokens` must fit the `max_seq + 1`-position serving capacity
//! (the KV window plus the final write-free token), enforced at
//! admission with an explicit "prompt too long" error.
//!
//! # Invariants & enforcement
//!
//! The concurrency invariants this layer leans on are machine-checked
//! by the workspace linter (`cargo run -p glass-lint -- --check`),
//! which CI runs on every push:
//!
//! * **No `.unwrap()`/`.expect(` on serving paths.** Reactor and
//!   engine threads degrade — error frame, reaped connection,
//!   recovered lock — instead of dying; [`lock_conns`] is the
//!   poison-recovery pattern for the shared connection table.
//! * **Every non-`SeqCst` atomic ordering carries a justification
//!   comment** saying why the weaker ordering is sound.
//! * **`thread::sleep` only at annotated parking sites** — after the
//!   readiness rewrite exactly two remain: the fallback
//!   [`poller::SleepPoller`]'s sweep tick (the reactor's parking site
//!   on targets without epoll/kqueue) and the client-side reconnect
//!   backoff. Anywhere else a sleep stalls a whole shard; the real
//!   pollers park in the kernel instead.
//! * **No `MutexGuard` held across socket I/O or sleeps** — lock
//!   scopes stay small and never span blocking calls.
//! * **`unsafe` requires an adjacent `// SAFETY:` comment**, and every
//!   wire key written or read here must appear in [`protocol`]'s
//!   wire-key registry (drift between serializer, client, and docs is
//!   a lint error).
//!
//! Justified deviations are annotated in place —
//! `// lint: allow(no-sleep-outside-reactor) -- reason the invariant
//! holds here` — one rule per annotation; the `-- <reason>` clause is
//! mandatory, and a reasonless or unknown-rule annotation is itself a
//! lint violation (and suppresses nothing). Run Miri and TSan over
//! this module's concurrency tests as described in CONTRIBUTING.md.
//!
//! All executables a shard's loop can touch are warmed at startup, so
//! first requests never pay compile latency (the compiled-executable
//! cache is shared, so warming costs once, not once per shard).

pub mod batcher;
pub mod client;
pub mod governor;
pub mod poller;
pub mod protocol;
pub mod scanner;
pub mod scheduler;
pub mod steal;

use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::ServerConfig;
use crate::engine::prefix_cache::{
    CacheStatsSnapshot, CacheTelemetry, PrefixCache,
};
use crate::engine::Engine;
use crate::info;
use crate::model::Tokenizer;
use crate::util::json::Json;

use batcher::{Batcher, ShardGauges};
use governor::{Governor, GovernorConfig};
use poller::{
    listener_fd, new_poller, stream_fd, Interest, PollEvent, Poller,
    Waker, WAKE_TOKEN,
};
use protocol::{
    client_line_from_json, frame_version, stats_to_line,
    v2_frame_from_json, ClientLine, Event, ShardSnapshot, V2Frame,
    PROTOCOL_V2,
};
use scanner::FrameScanner;
use scheduler::{Control, Pending, Scheduler};
use steal::ShardLoad;

/// Default cap on a single wire frame (and the per-connection read
/// buffer): a client that never terminates a line cannot grow server
/// memory past this.
pub const DEFAULT_MAX_FRAME_BYTES: usize = 1 << 20;
/// Default cap on a connection's outbound buffer, which doubles as the
/// derived backpressure high-water mark: a consumer that cannot keep
/// up with its own event stream is parked, not disconnected.
pub const DEFAULT_CONN_BUFFER_BYTES: usize = 8 << 20;

/// Engine→reactor doorbell: one per reactor thread, shared with every
/// engine thread through the connection table. The batcher's sink
/// pushes the target connection id onto the dirty list and fires the
/// reactor's [`Waker`], so the reactor drains exactly the connections
/// that have fresh events — event delivery costs one list push and one
/// wake, not a `try_recv` poll of every connection per tick.
struct ReactorNotify {
    /// Connection ids with undrained events (deduplicated on push; the
    /// list stays at most table-sized).
    dirty: Mutex<Vec<u64>>,
    /// Wakes the owning reactor out of [`Poller::wait`].
    waker: Waker,
}

impl ReactorNotify {
    fn new(waker: Waker) -> ReactorNotify {
        ReactorNotify {
            dirty: Mutex::new(Vec::new()),
            waker,
        }
    }

    /// Lock the dirty list, recovering from poisoning (same policy as
    /// [`lock_conns`]: the list's invariant is re-establishable — a
    /// torn entry costs one redundant or missed drain pass, and missed
    /// ones are retried on the next event).
    fn lock_dirty(&self) -> std::sync::MutexGuard<'_, Vec<u64>> {
        self.dirty.lock().unwrap_or_else(|poisoned| {
            crate::warn_!("dirty-list mutex poisoned; recovering");
            poisoned.into_inner()
        })
    }

    /// Mark `conn_id` dirty and wake the reactor. Always wakes, even
    /// when already marked: the reactor may have taken the list but
    /// not yet parked, and wakes coalesce at the poller anyway.
    fn notify(&self, conn_id: u64) {
        {
            let mut d = self.lock_dirty();
            if !d.contains(&conn_id) {
                d.push(conn_id);
            }
        }
        self.waker.wake();
    }

    /// Drain the dirty list (reactor side).
    fn take_dirty(&self) -> Vec<u64> {
        std::mem::take(&mut *self.lock_dirty())
    }
}

/// One connection's entry in the shared table: the sender the batcher
/// threads push [`Event`]s through, plus the owning reactor's doorbell
/// so those pushes actually wake it.
#[derive(Clone)]
struct ConnHandle {
    tx: Sender<Event>,
    notify: Arc<ReactorNotify>,
}

impl ConnHandle {
    /// Deliver one event and ring the reactor's doorbell. Returns
    /// false if the receiving connection was reaped (sender
    /// disconnected).
    fn send(&self, conn_id: u64, ev: Event) -> bool {
        if self.tx.send(ev).is_err() {
            return false;
        }
        self.notify.notify(conn_id);
        true
    }
}

/// Per-connection event channels: the batcher threads push [`Event`]s,
/// the owning reactor drains and serializes them in the connection's
/// negotiated protocol.
type Conns = Arc<Mutex<HashMap<u64, ConnHandle>>>;

/// Lock the shared connection table, recovering from poisoning.
///
/// A thread that panics while holding this mutex poisons it; treating
/// that as fatal (`.unwrap()`) would take down every reactor and
/// engine thread that routes events through the table, turning one
/// shard's bug into a whole-server outage. The table's invariant is
/// re-establishable (a torn entry at worst strands one connection,
/// which the reaper collects), so degrade loudly and keep serving.
fn lock_conns(
    conns: &Mutex<HashMap<u64, ConnHandle>>,
) -> std::sync::MutexGuard<'_, HashMap<u64, ConnHandle>> {
    conns.lock().unwrap_or_else(|poisoned| {
        crate::warn_!(
            "connection-table mutex poisoned; recovering the table"
        );
        poisoned.into_inner()
    })
}

/// Reactor I/O counters, shared across all reactor threads and read
/// through [`Server::io_stats`]. The pair of readiness observables the
/// bench and the idle-fleet tests gate on: `reads` proves idle
/// connections cost no syscalls between events, `sweeps` counts poller
/// wakeups, and the backpressure pair counts park/resume transitions.
#[derive(Default)]
pub struct IoStats {
    reads: AtomicU64,
    sweeps: AtomicU64,
    backpressure_pauses: AtomicU64,
    backpressure_resumes: AtomicU64,
}

impl IoStats {
    /// Point-in-time copy of every counter. Relaxed loads: the
    /// counters are independent monotonic telemetry, never used to
    /// order other memory.
    pub fn snapshot(&self) -> IoStatsSnapshot {
        IoStatsSnapshot {
            reads: self.reads.load(Ordering::Relaxed),
            sweeps: self.sweeps.load(Ordering::Relaxed),
            backpressure_pauses: self
                .backpressure_pauses
                .load(Ordering::Relaxed),
            backpressure_resumes: self
                .backpressure_resumes
                .load(Ordering::Relaxed),
        }
    }
}

/// One consistent-enough copy of [`IoStats`] (each field is exact; the
/// set is racy across fields, which telemetry tolerates).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct IoStatsSnapshot {
    /// `read()` syscalls issued across all connections.
    pub reads: u64,
    /// Poller wakeups (readiness batches serviced) across reactors.
    pub sweeps: u64,
    /// Connections that crossed the high-water mark and parked their
    /// sessions.
    pub backpressure_pauses: u64,
    /// Connections that drained below the low-water mark and resumed.
    pub backpressure_resumes: u64,
}

/// Router window for a model: the byte span of the first cacheable
/// chunk — one prefill frame minus the BOS token slot (the byte-level
/// tokenizer maps one prompt byte per remaining token). Hashing
/// exactly this span guarantees two prompts that share their first
/// cached chunk also share a shard.
pub fn route_window(prefill_len: usize) -> usize {
    prefill_len.saturating_sub(1).max(1)
}

/// Route a prompt to a serving shard: FNV-1a over the prompt's leading
/// `window` bytes (the system-prefix span — [`route_window`] passes the
/// first prefill frame's byte span, so the hash covers exactly the
/// cacheable leading chunk), modulo the shard count. Prompts sharing at
/// least `window` leading bytes always land on the same shard, which is
/// what keeps shared-prefix cache hits local after the cache budget is
/// split. Deterministic across connections, threads, and restarts;
/// always 0 for a single shard.
pub fn route_shard(prompt: &str, n_shards: usize, window: usize) -> usize {
    if n_shards <= 1 {
        return 0;
    }
    let bytes = prompt.as_bytes();
    let take = bytes.len().min(window.max(1));
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in &bytes[..take] {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h % n_shards as u64) as usize
}

pub use crate::config::compat::ServerOptions;

/// One serving shard's handles, shared between the engine thread that
/// owns the batcher and the reactor threads that submit work, push
/// controls, and answer `stats`.
struct Shard {
    sched: Arc<Scheduler>,
    telemetry: Arc<CacheTelemetry>,
    gauges: Arc<ShardGauges>,
    width: usize,
    /// The shard's prefix cache, shared with its engine loop solely so
    /// the admission-time steal path can replicate a hot prefix into a
    /// sibling ([`steal::replicate_prefix`]); `None` when caching is
    /// disabled.
    cache: Option<Arc<Mutex<PrefixCache>>>,
}

impl Shard {
    /// One consistent stats row: the occupancy pair comes from a
    /// single atomic load ([`ShardGauges::snapshot`]), so a stats call
    /// racing heavy admission can never report `slots_active +
    /// slots_prefilling` above the batch width.
    fn snapshot_row(&self, shard: u64, gov: &Governor) -> ShardSnapshot {
        let (slots_active, slots_prefilling) = self.gauges.snapshot();
        let si = shard as usize;
        ShardSnapshot {
            shard,
            queue_depth: self.sched.len() as u64,
            slots_active,
            slots_prefilling,
            batch_width: self.width as u64,
            governor_level: gov.level(si) as u64,
            degraded_requests: gov.degraded_requests(si),
            stolen_requests: gov.stolen_requests(si),
        }
    }

    /// The reactor-side load sample the steal planner consumes.
    fn load(&self) -> ShardLoad {
        let (active, prefilling) = self.gauges.snapshot();
        ShardLoad {
            queued: self.sched.len(),
            active: active as usize,
            prefilling: prefilling as usize,
            width: self.width,
        }
    }
}

/// The `stats` response line: aggregate cache counters plus one
/// consistent per-shard row, assembled through one snapshot path for
/// both protocol versions.
fn stats_line(shards: &[Shard], gov: &Governor, id: u64) -> String {
    let agg = shards.iter().fold(
        CacheStatsSnapshot::default(),
        |acc, s| acc.merge(&s.telemetry.snapshot()),
    );
    let per: Vec<ShardSnapshot> = shards
        .iter()
        .enumerate()
        .map(|(i, s)| s.snapshot_row(i as u64, gov))
        .collect();
    stats_to_line(id, &agg, &per)
}

/// Server handle: bind address + shutdown machinery.
pub struct Server {
    /// The actually-bound address (resolves a `:0` request).
    pub addr: String,
    /// Stops the acceptor and makes reactors refuse new sessions.
    shutdown: Arc<AtomicBool>,
    /// Tells reactors to flush and exit (set after engines drain).
    reactor_stop: Arc<AtomicBool>,
    shards: Arc<Vec<Shard>>,
    conns: Conns,
    /// Shared reactor I/O counters ([`Server::io_stats`]).
    io: Arc<IoStats>,
    /// Reactor poller backend ([`Server::poller_kind`]).
    poller_kind: &'static str,
    /// Poller wakers for the acceptor and every reactor: shutdown must
    /// kick threads parked in [`Poller::wait`], not wait out their
    /// safety-net timeouts.
    wakers: Vec<Waker>,
    engine_threads: Vec<std::thread::JoinHandle<()>>,
    io_threads: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start serving on `addr` with default options (cache on, 1 shard).
    pub fn start(engine: Engine, addr: &str, batch_width: usize) -> Result<Server> {
        Server::start_with(engine, addr, ServerOptions::new(batch_width))
    }

    /// Start serving on `addr` (e.g. "127.0.0.1:7433"). Returns once the
    /// listener is bound; serving continues on background threads.
    ///
    /// Compatibility shim over [`Server::start_with_config`] — new
    /// code should build a [`ServerConfig`] directly.
    pub fn start_with(
        engine: Engine,
        addr: &str,
        opts: ServerOptions,
    ) -> Result<Server> {
        let mut cfg = ServerConfig::from(opts);
        cfg.bind = addr.to_string();
        Server::start_with_config(engine, &cfg)
    }

    /// Start serving from one unified [`ServerConfig`] (the config
    /// builder covering shards, batch width, cache, chunk budget,
    /// frame/buffer caps, backpressure watermarks, and the expected
    /// execution backend). Returns once the listener is bound; serving
    /// continues on background threads.
    pub fn start_with_config(
        engine: Engine,
        cfg: &ServerConfig,
    ) -> Result<Server> {
        // fail fast on a backend mismatch: the engine is built before
        // the server, so a concrete `cfg.backend` is an expectation to
        // check, not a knob to apply
        crate::runtime::validate_backend_name(&cfg.backend)?;
        if cfg.backend != "auto"
            && cfg.backend != engine.rt.backend_name()
        {
            bail!(
                "server config requests backend '{}' but the engine \
                 was loaded with '{}'",
                cfg.backend,
                engine.rt.backend_name()
            );
        }
        let addr = cfg.bind.as_str();
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding {addr}"))?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?.to_string();

        let n_shards = cfg.shards.max(1);
        // the per-shard cache split lives in BatcherOptions::for_shard;
        // recompute it here only for the prefix-grouping byte window
        let shard_cache_bytes = cfg.cache_bytes / n_shards;
        let prefill_len = engine.spec().prefill_len;
        // always constructed (disabled it is a frozen level-0 identity)
        // so stats rows and the steal gate read one object either way
        let governor = Arc::new(Governor::new(
            GovernorConfig {
                enabled: cfg.governor,
                floors: cfg.governor_floors,
                steal_threshold: cfg.steal_threshold,
            },
            n_shards,
        ));

        // build every shard's batcher up front: loads priors and warms
        // every executable an engine loop can hit (the compiled-
        // executable cache is shared across shards, so the warm-up work
        // is paid once)
        let mut batchers = Vec::with_capacity(n_shards);
        let mut shards = Vec::with_capacity(n_shards);
        for shard_id in 0..n_shards {
            let mut engine_loop =
                Batcher::from_config(engine.clone(), cfg, shard_id)?;
            engine_loop.attach_governor(Arc::clone(&governor), shard_id);
            let group_bytes =
                if cfg.group_prefixes && shard_cache_bytes > 0 {
                    // one prefill frame of shared prompt bytes ≈ one
                    // cacheable chunk (byte-level tokenizer)
                    prefill_len
                } else {
                    0
                };
            shards.push(Shard {
                sched: Arc::new(
                    Scheduler::new(
                        cfg.batch_width,
                        Duration::from_millis(4),
                    )
                    .with_prefix_grouping(group_bytes),
                ),
                telemetry: engine_loop.telemetry(),
                gauges: engine_loop.gauges(),
                width: engine_loop.width,
                cache: engine_loop.cache_handle(),
            });
            batchers.push(engine_loop);
        }
        let shards = Arc::new(shards);
        let conns: Conns = Arc::new(Mutex::new(HashMap::new()));
        let shutdown = Arc::new(AtomicBool::new(false));
        let reactor_stop = Arc::new(AtomicBool::new(false));
        let io = Arc::new(IoStats::default());
        let mut wakers = Vec::new();
        let mut engine_threads = Vec::new();
        let mut io_threads = Vec::new();

        // one engine thread per shard: independent continuous-batching
        // loops, no cross-shard synchronization; per-slot events flow
        // to the owning reactor through the per-conn channels
        for (shard_id, mut engine_loop) in batchers.into_iter().enumerate()
        {
            let conns = Arc::clone(&conns);
            let sched = Arc::clone(&shards[shard_id].sched);
            engine_threads.push(std::thread::spawn(move || {
                // per-conn handle cache: events are emitted per TOKEN,
                // so the shared conns map must not be locked on the
                // per-token hot path — one lock per (conn, shard)
                // pairing, lock-free sends afterwards. conn ids are
                // never reused, so a cached handle whose receiver was
                // reaped just fails its send and is evicted.
                let mut locals: HashMap<u64, ConnHandle> =
                    HashMap::new();
                let mut sink = move |conn_id: u64, ev: Event| {
                    if let Some(h) = locals.get(&conn_id) {
                        if !h.send(conn_id, ev) {
                            locals.remove(&conn_id);
                        }
                        return;
                    }
                    if locals.len() > 4096 {
                        // bound the cache across a long-lived server's
                        // conn churn; re-warms on the next event
                        locals.clear();
                    }
                    let h = lock_conns(&conns).get(&conn_id).cloned();
                    if let Some(h) = h {
                        if h.send(conn_id, ev) {
                            locals.insert(conn_id, h);
                        }
                    }
                };
                engine_loop.run(&sched, &mut sink);
                // run() returns only after Server::stop drains every
                // in-flight slot, so the snapshot captures the final
                // hot set (no-op unless --cache-dir is configured)
                engine_loop.snapshot_hot();
            }));
        }
        // reactor threads (one per shard): readiness loops over
        // registered nonblocking sockets
        let high_water = cfg.resolved_high_water();
        let low_water = cfg.resolved_low_water();
        let mut reactor_txs: Vec<Sender<(u64, TcpStream)>> = Vec::new();
        let mut reactor_notifies: Vec<Arc<ReactorNotify>> = Vec::new();
        let mut poller_kind = "";
        for _ in 0..n_shards {
            let (tx, rx) = channel::<(u64, TcpStream)>();
            reactor_txs.push(tx);
            let poller = new_poller();
            poller_kind = poller.kind();
            let notify = Arc::new(ReactorNotify::new(poller.waker()));
            reactor_notifies.push(Arc::clone(&notify));
            wakers.push(poller.waker());
            let ctx = ReactorCtx {
                shards: Arc::clone(&shards),
                governor: Arc::clone(&governor),
                tok: engine.tok.clone(),
                route_window: route_window(prefill_len),
                max_frame_bytes: cfg.max_frame_bytes.max(64),
                conn_buffer_bytes: cfg.conn_buffer_bytes.max(1 << 16),
                high_water_bytes: high_water.max(1 << 12),
                low_water_bytes: low_water,
                io: Arc::clone(&io),
                shutdown: Arc::clone(&shutdown),
            };
            let conns = Arc::clone(&conns);
            let stop = Arc::clone(&reactor_stop);
            io_threads.push(std::thread::spawn(move || {
                reactor_loop(rx, conns, ctx, stop, notify, poller)
            }));
        }
        // acceptor: its own poller watches the listener fd, so a fresh
        // connection is accepted on kernel readiness — no accept-backoff
        // sleep — and handed to a reactor round-robin (with a doorbell
        // ring so the reactor adopts it promptly)
        {
            let shutdown = Arc::clone(&shutdown);
            let notifies = reactor_notifies;
            let mut poller = new_poller();
            wakers.push(poller.waker());
            io_threads.push(std::thread::spawn(move || {
                // any non-WAKE token works: the listener is the only
                // registered fd
                let registered = poller
                    .register(listener_fd(&listener), 1, Interest::Read)
                    .is_ok();
                let mut events: Vec<PollEvent> = Vec::new();
                let mut next_conn: u64 = 1;
                loop {
                    // Relaxed: the flag is a pure quit signal checked
                    // every iteration; no data is published under it
                    if shutdown.load(Ordering::Relaxed) {
                        break;
                    }
                    // drain the accept queue completely, then park
                    loop {
                        match listener.accept() {
                            Ok((stream, _)) => {
                                let conn_id = next_conn;
                                next_conn += 1;
                                let target = (conn_id as usize)
                                    % reactor_txs.len();
                                let _ = reactor_txs[target]
                                    .send((conn_id, stream));
                                // ring the reactor so the handoff is
                                // adopted without waiting for traffic
                                notifies[target].waker.wake();
                            }
                            Err(ref e)
                                if e.kind() == ErrorKind::WouldBlock =>
                            {
                                break;
                            }
                            Err(ref e)
                                if e.kind() == ErrorKind::Interrupted => {}
                            Err(_) => return,
                        }
                    }
                    // park until the listener is readable or shutdown
                    // wakes us; the timeout is a safety net against a
                    // registration that silently stopped reporting
                    let timeout = if registered {
                        Duration::from_millis(500)
                    } else {
                        // unregistered (register failed): degrade to a
                        // paced accept poll
                        Duration::from_millis(5)
                    };
                    let _ = poller.wait(&mut events, Some(timeout));
                }
            }));
        }
        info!(
            "server listening on {local} ({n_shards} shard{} + reactor{}, \
             {poller_kind} poller)",
            if n_shards == 1 { "" } else { "s" },
            if n_shards == 1 { "" } else { "s" }
        );
        Ok(Server {
            addr: local,
            shutdown,
            reactor_stop,
            shards,
            conns,
            io,
            poller_kind,
            wakers,
            engine_threads,
            io_threads,
        })
    }

    /// Which poller backend the reactors run on: `"epoll"`, `"kqueue"`,
    /// or `"sleep"` (the portable fallback). Tests that assert
    /// zero-syscall idling gate on this — the fallback necessarily
    /// sweeps every registered fd per tick.
    pub fn poller_kind(&self) -> &'static str {
        self.poller_kind
    }

    /// Point-in-time reactor I/O counters (reads, poller sweeps,
    /// backpressure park/resume transitions) — the observables the
    /// idle-fleet and slow-consumer tests and the bench gate on.
    pub fn io_stats(&self) -> IoStatsSnapshot {
        self.io.snapshot()
    }

    /// Graceful shutdown: stop accepting, fail queued-but-unadmitted
    /// requests with a retryable error, drain every in-flight session
    /// to its natural terminal event, then flush and join the reactors.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        // close each shard's queue; whatever had not been admitted yet
        // is failed RETRYABLY (in-flight slots keep decoding to done)
        let fail_queued = |shards: &[Shard], conns: &Conns| {
            for shard in shards {
                for p in shard.sched.drain_close() {
                    let h =
                        lock_conns(conns).get(&p.conn_id).cloned();
                    if let Some(h) = h {
                        h.send(
                            p.conn_id,
                            Event::Error {
                                id: p.request.id,
                                error: "server shutting down before \
                                        admission; retry on another \
                                        server"
                                    .to_string(),
                                retryable: true,
                            },
                        );
                    }
                }
            }
        };
        fail_queued(&self.shards, &self.conns);
        // (a reactor racing the shutdown flag cannot strand a session:
        // drain_close marks the queue closed under the same mutex
        // Scheduler::submit checks, so any later submit is refused and
        // the reactor fails it retryably itself)
        // kick every poller out of its wait so the acceptor sees the
        // flag now, not at its safety-net timeout
        for w in &self.wakers {
            w.wake();
        }
        // engine loops exit once their slots drain and queues are
        // empty (a closed scheduler also lifts every backpressure
        // park, so a stalled consumer cannot wedge the drain)
        for t in self.engine_threads.drain(..) {
            let _ = t.join();
        }
        // reactors flush remaining events/bytes, then exit
        self.reactor_stop.store(true, Ordering::Relaxed);
        for w in &self.wakers {
            w.wake();
        }
        for t in self.io_threads.drain(..) {
            let _ = t.join();
        }
    }
}

// ------------------------------------------------------------ reactor

/// Immutable per-reactor context.
struct ReactorCtx {
    shards: Arc<Vec<Shard>>,
    /// Shared overload governor (level/counter source for stats, steal
    /// gate for routing); a frozen identity when `--governor off`.
    governor: Arc<Governor>,
    /// Tokenizer clone for the steal path: replicating a prefix needs
    /// the prompt's token encoding, computed reactor-side (cheap:
    /// byte-level) so no engine round-trip happens at admission.
    tok: Tokenizer,
    route_window: usize,
    max_frame_bytes: usize,
    conn_buffer_bytes: usize,
    /// Backpressure high-water mark: an outbound backlog above this
    /// parks the connection's sessions.
    high_water_bytes: usize,
    /// Backpressure low-water mark: a parked connection resumes once
    /// its backlog drains to (or below) this.
    low_water_bytes: usize,
    /// Shared I/O counters (reads / sweeps / park transitions).
    io: Arc<IoStats>,
    /// Set during shutdown: refuse new sessions retryably.
    shutdown: Arc<AtomicBool>,
}

impl ReactorCtx {
    /// Hard disconnect threshold: a parked connection's backlog can
    /// still grow by frames that were already emitted before the park
    /// landed (plus `queue` updates), so the kill line sits far above
    /// the high-water mark — reaching it means the consumer is gone,
    /// not merely slow. The operator's `conn_buffer_bytes` allowance
    /// is always honored before disconnecting.
    fn kill_water_bytes(&self) -> usize {
        kill_water(self.high_water_bytes).max(self.conn_buffer_bytes)
    }
}

/// See [`ReactorCtx::kill_water_bytes`]: 8× the high-water mark with a
/// 1 MiB floor.
fn kill_water(high_water_bytes: usize) -> usize {
    high_water_bytes.saturating_mul(8).max(1 << 20)
}

/// Pick the shard for one admission: prefix-affinity routing first
/// ([`route_shard`]), then — governor enabled, multiple shards — the
/// work-stealing override: if the home shard is past the steal
/// threshold and a sibling could start the request immediately, the
/// sibling takes it, after the home shard's longest cached prefix of
/// the prompt is replicated into its cache ([`steal::replicate_prefix`])
/// so the stolen request still warm-hits. A failed or empty
/// replication still steals: the thief serving the prompt cold beats
/// the home shard queueing it.
fn pick_shard(ctx: &ReactorCtx, prompt: &str) -> usize {
    let home = route_shard(prompt, ctx.shards.len(), ctx.route_window);
    if !ctx.governor.enabled() || ctx.shards.len() < 2 {
        return home;
    }
    let loads: Vec<ShardLoad> =
        ctx.shards.iter().map(Shard::load).collect();
    let threshold = ctx.governor.config().steal_threshold;
    let Some(thief) = steal::plan_steal(home, &loads, threshold) else {
        return home;
    };
    if let (Some(hc), Some(tc)) =
        (&ctx.shards[home].cache, &ctx.shards[thief].cache)
    {
        let tokens = ctx.tok.encode_with_bos(prompt);
        steal::replicate_prefix(hc, tc, &tokens);
    }
    ctx.governor.note_stolen(thief);
    thief
}

/// Protocol state of one connection (locked by its first parsed line).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Mode {
    Detect,
    V1,
    V2,
}

/// One connection owned by a reactor thread.
struct ConnState {
    conn_id: u64,
    stream: TcpStream,
    rx: Receiver<Event>,
    mode: Mode,
    /// Unparsed inbound bytes (bounded by `max_frame_bytes`).
    rbuf: Vec<u8>,
    /// Zero-copy line scanner over `rbuf` (no byte is newline-scanned
    /// twice; frames are borrowed, never copied out).
    scanner: FrameScanner,
    /// Outbound bytes not yet written (watermarked by the backpressure
    /// marks); `wpos` is the flush cursor.
    wbuf: Vec<u8>,
    wpos: usize,
    /// v2: live session id → owning shard (for control routing).
    live: HashMap<u64, usize>,
    /// Backpressure state: true while this connection's sessions are
    /// parked (backlog crossed the high-water mark and has not yet
    /// drained below the low-water mark).
    paused: bool,
    /// Interest set currently registered with the reactor's poller
    /// (None = not registered).
    interest: Option<Interest>,
    read_closed: bool,
    /// Protocol violation: stop reading, flush, then close.
    closing: bool,
    dead: bool,
}

impl ConnState {
    fn new(conn_id: u64, stream: TcpStream, rx: Receiver<Event>) -> ConnState {
        stream.set_nodelay(true).ok();
        stream.set_nonblocking(true).ok();
        ConnState {
            conn_id,
            stream,
            rx,
            mode: Mode::Detect,
            rbuf: Vec::new(),
            scanner: FrameScanner::new(),
            wbuf: Vec::new(),
            wpos: 0,
            live: HashMap::new(),
            paused: false,
            interest: None,
            read_closed: false,
            closing: false,
            dead: false,
        }
    }

    fn push_line(&mut self, line: &str) {
        self.wbuf.extend_from_slice(line.as_bytes());
        self.wbuf.push(b'\n');
    }

    /// Serialize one SESSION event (from the batcher channel) in the
    /// connection's negotiated protocol: v2 gets every event as its
    /// own frame; v1 (and a connection that never spoke) gets the
    /// compatibility shim — terminal events as the classic response
    /// line, the rest suppressed. A terminal event releases the
    /// session id for reuse. Reactor-originated errors (protocol
    /// violations, duplicate ids, unknown-id controls) must NOT go
    /// through here — they are not session terminals and must not
    /// release a live session's id; use [`ConnState::push_error_frame`].
    fn push_event(&mut self, ev: Event) {
        if ev.is_terminal() {
            self.live.remove(&ev.id());
        }
        self.serialize_event(ev);
    }

    /// Serialize a reactor-originated error frame WITHOUT touching the
    /// live-session map (it is not a session terminal — e.g. the error
    /// rejecting a duplicate id must not release the original live
    /// session's id).
    fn push_error_frame(&mut self, id: u64, error: &str, retryable: bool) {
        self.serialize_event(Event::Error {
            id,
            error: error.to_string(),
            retryable,
        });
    }

    /// Mode-specific wire form of one event: v2 gets every event as
    /// its own frame; v1 (and a connection that never spoke) gets the
    /// compatibility shim — terminal events as the classic response
    /// line, the rest suppressed.
    fn serialize_event(&mut self, ev: Event) {
        match self.mode {
            Mode::V2 => {
                let frame = ev.to_frame();
                self.push_line(&frame);
            }
            Mode::V1 | Mode::Detect => {
                if let Some(resp) = ev.into_response() {
                    let line = resp.to_line();
                    self.push_line(&line);
                }
            }
        }
    }

    /// Nonblocking read + line processing. Returns true if any bytes
    /// or frames moved. Called only when the poller reported this
    /// connection readable (or on adoption), so an idle connection
    /// issues **zero** read syscalls between events.
    fn tick_read(&mut self, ctx: &ReactorCtx) -> bool {
        if self.read_closed || self.closing || self.dead {
            return false;
        }
        let mut work = false;
        let mut buf = [0u8; 4096];
        loop {
            // Relaxed: independent monotonic telemetry counter, never
            // used to order other memory
            ctx.io.reads.fetch_add(1, Ordering::Relaxed);
            match self.stream.read(&mut buf) {
                Ok(0) => {
                    self.read_closed = true;
                    break;
                }
                Ok(n) => {
                    work = true;
                    self.rbuf.extend_from_slice(&buf[..n]);
                    // the read buffer must stay bounded even while the
                    // socket keeps delivering: stop ingesting once the
                    // cap is reached and let line processing below
                    // either consume complete frames or reject the
                    // oversized one — a client streaming a newline-free
                    // line can never outrun the cap check, and one
                    // connection cannot monopolize its reactor's tick
                    if self.rbuf.len() > ctx.max_frame_bytes {
                        break;
                    }
                }
                Err(ref e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => {
                    self.dead = true;
                    return work;
                }
            }
        }
        // complete lines, zero-copy: take the buffer so the scanner
        // can lend out `&[u8]` frames borrowed straight from it while
        // `handle_line` borrows `self` — no per-line Vec, no rescans,
        // ONE front-drain after the loop (a pipelined burst costs
        // O(bytes), not O(lines × bytes))
        let rbuf = std::mem::take(&mut self.rbuf);
        while let Some(line) = self.scanner.next_line(&rbuf) {
            if line.len() > ctx.max_frame_bytes {
                // frame_too_big resets the scan; the taken buffer is
                // dropped — unprocessed bytes die with the connection
                self.frame_too_big(ctx, line.len());
                return true;
            }
            match std::str::from_utf8(line) {
                Ok(text) => self.handle_line(ctx, text),
                Err(_) => {
                    // undecodable input: the pre-reactor server's
                    // BufReader::lines() errored and closed with no
                    // response — v1/Detect keep that bit-identically;
                    // a v2 connection gets an error frame first
                    if self.mode == Mode::V2 {
                        self.protocol_error(
                            0,
                            "frame is not valid UTF-8",
                        );
                    }
                    self.scanner.reset();
                    self.closing = true;
                }
            }
            work = true;
            if self.closing || self.dead {
                // unprocessed bytes die with the connection
                return work;
            }
        }
        // restore the buffer and drop the fully-processed prefix
        self.rbuf = rbuf;
        if self.scanner.consumed() > 0 {
            self.rbuf.drain(..self.scanner.consumed());
            self.scanner.on_drain();
        }
        // a partial line may not outgrow the frame cap
        if self.scanner.pending(self.rbuf.len()) > ctx.max_frame_bytes {
            self.frame_too_big(ctx, self.rbuf.len());
            work = true;
        }
        work
    }

    fn frame_too_big(&mut self, ctx: &ReactorCtx, got: usize) {
        self.protocol_error(
            0,
            &format!(
                "frame of {got} bytes exceeds max_frame_bytes \
                 ({}); closing connection",
                ctx.max_frame_bytes
            ),
        );
        self.rbuf.clear();
        self.scanner.reset();
        self.closing = true;
    }

    /// Emit a protocol-level error in the connection's current mode.
    fn protocol_error(&mut self, id: u64, msg: &str) {
        self.push_error_frame(id, msg, false);
    }

    fn handle_line(&mut self, ctx: &ReactorCtx, line: &str) {
        let trimmed = line.trim();
        if trimmed.is_empty() {
            return;
        }
        let j = match Json::parse(trimmed) {
            Ok(j) => j,
            Err(e) => {
                self.protocol_error(0, &e.to_string());
                return;
            }
        };
        if self.mode == Mode::Detect {
            // the first parsed line locks the connection's protocol
            match frame_version(&j) {
                Ok(Some(PROTOCOL_V2)) => self.mode = Mode::V2,
                Ok(None) => self.mode = Mode::V1,
                Ok(Some(v)) => {
                    self.protocol_error(
                        0,
                        &format!(
                            "unsupported protocol version {v} (this \
                             server speaks v1 and v2)"
                        ),
                    );
                    return;
                }
                Err(e) => {
                    self.protocol_error(0, &e.to_string());
                    return;
                }
            }
        }
        match self.mode {
            Mode::V1 => self.handle_v1(ctx, &j),
            Mode::V2 => self.handle_v2(ctx, &j),
            Mode::Detect => unreachable!("mode locked above"),
        }
    }

    fn handle_v1(&mut self, ctx: &ReactorCtx, j: &Json) {
        match client_line_from_json(j) {
            Ok(ClientLine::Request(request)) => {
                // Relaxed: advisory fast-path refusal — a submit that
                // races the flag is still refused at the scheduler,
                // which closes its queue under a mutex
                if ctx.shutdown.load(Ordering::Relaxed) {
                    self.push_error_frame(
                        request.id,
                        "server shutting down",
                        true,
                    );
                    return;
                }
                // prefix-affinity routing (a pure function of the
                // prompt text, so same-prefix traffic colocates on the
                // shard whose cache holds its prefix), with the
                // governor's work-stealing override under overload
                let si = pick_shard(ctx, &request.prompt);
                let id = request.id;
                let accepted = ctx.shards[si].sched.submit(Pending {
                    request,
                    arrived: Instant::now(),
                    conn_id: self.conn_id,
                    stream: false,
                    resume_from: 0,
                    degraded: false,
                    reported_floor: usize::MAX,
                });
                if accepted.is_none() {
                    // queue already closed (shutdown won the race)
                    self.push_error_frame(
                        id,
                        "server shutting down",
                        true,
                    );
                    return;
                }
                // best-effort in-flight tracking (v1 ids may repeat on
                // one connection — last wins): lets the reactor cancel
                // a disconnected client's work instead of letting it
                // decode to completion for nobody
                self.live.insert(id, si);
                if self.paused {
                    // the connection is already over its high-water
                    // mark: park the newcomer too, so its output joins
                    // the backlog only after the client drains
                    ctx.shards[si].sched.control(Control::Park {
                        conn_id: self.conn_id,
                        id,
                    });
                }
            }
            Ok(ClientLine::Stats { id }) => {
                // answered right here from the shared counters — no
                // round trip through any engine loop
                let line = stats_line(&ctx.shards, &ctx.governor, id);
                self.push_line(&line);
            }
            Err(e) => self.protocol_error(0, &e.to_string()),
        }
    }

    /// Admit one v2 session (fresh `generate`, or `resume` with a
    /// nonzero delta offset): validate the session id, refuse during
    /// shutdown (retryably), route by prompt prefix, enqueue, and
    /// answer with `accepted`.
    fn submit_session(
        &mut self,
        ctx: &ReactorCtx,
        request: protocol::Request,
        resume_from: u64,
    ) {
        let id = request.id;
        if id == 0 {
            // id 0 is the correlation id of connection-level
            // protocol errors; a session using it could read a
            // reactor-originated error as its terminal frame
            self.push_error_frame(
                0,
                "session id must be >= 1 (0 is reserved for \
                 connection-level errors)",
                false,
            );
            return;
        }
        if self.live.contains_key(&id) {
            // reactor-originated rejection, reported on the
            // RESERVED correlation id 0: using the session's
            // own id would read as the ORIGINAL live session's
            // terminal error frame
            self.push_error_frame(
                0,
                &format!(
                    "duplicate session id {id} (still live on \
                     this connection)"
                ),
                false,
            );
            return;
        }
        // Relaxed: advisory fast-path refusal — a submit that races
        // the flag is still refused at the scheduler, which closes
        // its queue under a mutex
        if ctx.shutdown.load(Ordering::Relaxed) {
            self.push_error_frame(id, "server shutting down", true);
            return;
        }
        let si = pick_shard(ctx, &request.prompt);
        let submitted = ctx.shards[si].sched.submit(Pending {
            request,
            arrived: Instant::now(),
            conn_id: self.conn_id,
            stream: true,
            resume_from,
            degraded: false,
            reported_floor: usize::MAX,
        });
        let Some(pos) = submitted else {
            // queue already closed (shutdown won the race):
            // refuse retryably instead of stranding a session
            // nothing will ever drain
            self.push_error_frame(id, "server shutting down", true);
            return;
        };
        self.live.insert(id, si);
        if self.paused {
            // see handle_v1: a submission on an already-parked
            // connection starts parked
            ctx.shards[si].sched.control(Control::Park {
                conn_id: self.conn_id,
                id,
            });
        }
        self.push_event(Event::Accepted {
            id,
            queue_pos: pos as u64,
        });
    }

    fn handle_v2(&mut self, ctx: &ReactorCtx, j: &Json) {
        let frame = match v2_frame_from_json(j) {
            Ok(f) => f,
            Err(e) => {
                // best-effort id so the client can correlate the error
                // — UNLESS that id names a live session, whose terminal
                // this error must not impersonate (then it goes to the
                // reserved connection-level id 0)
                let id = j
                    .get("id")
                    .and_then(|v| v.as_usize().ok())
                    .unwrap_or(0) as u64;
                let id =
                    if self.live.contains_key(&id) { 0 } else { id };
                self.protocol_error(id, &e.to_string());
                return;
            }
        };
        match frame {
            V2Frame::Generate(request) => {
                self.submit_session(ctx, request, 0);
            }
            V2Frame::Resume { req, received } => {
                // a resumed session is admitted exactly like a fresh
                // generate (same validation, routing, queueing); the
                // batcher re-runs the deterministic decode and
                // suppresses the `received` deltas the client already
                // consumed, so the stream continues byte-identically
                self.submit_session(ctx, req, received);
            }
            V2Frame::Cancel { id } => match self.live.get(&id).copied() {
                Some(si) => ctx.shards[si].sched.control(
                    Control::Cancel {
                        conn_id: self.conn_id,
                        id,
                    },
                ),
                None => self.push_error_frame(
                    id,
                    &format!("cancel: no live session with id {id}"),
                    false,
                ),
            },
            V2Frame::Set { id, refresh_every } => {
                match self.live.get(&id).copied() {
                    Some(si) => ctx.shards[si].sched.control(
                        Control::SetRefresh {
                            conn_id: self.conn_id,
                            id,
                            refresh_every,
                        },
                    ),
                    None => self.push_error_frame(
                        id,
                        &format!("set: no live session with id {id}"),
                        false,
                    ),
                }
            }
            V2Frame::Stats { id } => {
                let line = stats_line(&ctx.shards, &ctx.governor, id);
                self.push_line(&line);
            }
        }
    }

    /// Drain this connection's event channel into the write buffer.
    fn drain_events(&mut self) -> bool {
        let mut work = false;
        while let Ok(ev) = self.rx.try_recv() {
            work = true;
            self.push_event(ev);
        }
        work
    }

    /// Nonblocking flush of pending outbound bytes.
    fn tick_write(&mut self, ctx: &ReactorCtx) -> bool {
        let mut work = false;
        while self.wpos < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => {
                    self.dead = true;
                    break;
                }
                Ok(n) => {
                    self.wpos += n;
                    work = true;
                }
                Err(ref e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => {
                    self.dead = true;
                    break;
                }
            }
        }
        if self.wpos == self.wbuf.len() {
            self.wbuf.clear();
            self.wpos = 0;
        } else if self.wpos > (1 << 16) {
            self.wbuf.drain(..self.wpos);
            self.wpos = 0;
        }
        // backpressure watermarks: a consumer that cannot drain its
        // own event stream gets its sessions PARKED (decode pauses,
        // nothing more is emitted) instead of being disconnected, and
        // resumes byte-identically once it drains below the low mark
        let backlog = self.wbuf.len() - self.wpos;
        if !self.paused && backlog > ctx.high_water_bytes {
            self.paused = true;
            // Relaxed: independent monotonic telemetry counter, never
            // used to order other memory
            ctx.io
                .backpressure_pauses
                .fetch_add(1, Ordering::Relaxed);
            for (&id, &si) in &self.live {
                ctx.shards[si].sched.control(Control::Park {
                    conn_id: self.conn_id,
                    id,
                });
            }
        } else if self.paused && backlog <= ctx.low_water_bytes {
            self.paused = false;
            // Relaxed: same telemetry-only counter policy as above
            ctx.io
                .backpressure_resumes
                .fetch_add(1, Ordering::Relaxed);
            for (&id, &si) in &self.live {
                ctx.shards[si].sched.control(Control::Unpark {
                    conn_id: self.conn_id,
                    id,
                });
            }
        }
        // safety valve far above the watermark: frames already emitted
        // before the park landed still arrive, but a backlog this deep
        // means the consumer is gone, not slow
        if backlog > ctx.kill_water_bytes() {
            self.dead = true;
        }
        work
    }

    fn flushed(&self) -> bool {
        self.wpos == self.wbuf.len()
    }

    /// Should this connection be dropped from the table?
    fn reapable(&self) -> bool {
        self.dead
            || (self.closing && self.flushed())
            || (self.read_closed && self.live.is_empty() && self.flushed())
    }

    /// The interest set this connection currently needs from the
    /// poller: read while the protocol still accepts input, write only
    /// while outbound bytes are pending, nothing once neither applies
    /// (events still arrive via the dirty-list doorbell).
    fn desired_interest(&self) -> Option<Interest> {
        if self.dead {
            return None;
        }
        let want_read =
            !(self.read_closed || self.closing);
        let want_write = !self.flushed();
        match (want_read, want_write) {
            (true, true) => Some(Interest::ReadWrite),
            (true, false) => Some(Interest::Read),
            (false, true) => Some(Interest::Write),
            (false, false) => None,
        }
    }

    /// Reconcile the poller registration with
    /// [`ConnState::desired_interest`]. Deregistering when no interest
    /// remains is what keeps a level-triggered poller from spinning on
    /// a hung-up fd the connection no longer cares about.
    fn sync_interest(&mut self, poller: &mut dyn Poller) {
        let want = self.desired_interest();
        if want == self.interest {
            return;
        }
        let fd = stream_fd(&self.stream);
        let r = match (self.interest, want) {
            (None, Some(i)) => poller.register(fd, self.conn_id, i),
            (Some(_), Some(i)) => poller.modify(fd, self.conn_id, i),
            (Some(_), None) => poller.deregister(fd),
            (None, None) => Ok(()),
        };
        match r {
            Ok(()) => self.interest = want,
            Err(e) => {
                // a socket the poller cannot track cannot be served;
                // treat a failed DEregistration as done (the fd is on
                // its way out anyway)
                if want.is_some() {
                    crate::warn_!(
                        "conn {}: poller registration failed ({e}); \
                         dropping connection",
                        self.conn_id
                    );
                    self.dead = true;
                }
                self.interest = None;
            }
        }
    }
}

/// Service one connection after a readiness or doorbell signal:
/// optionally read (only when the poller reported readable — idle
/// connections must cost zero read syscalls), then drain the event
/// channel and flush, and finally reconcile the poller registration.
fn service_conn(
    c: &mut ConnState,
    ctx: &ReactorCtx,
    poller: &mut dyn Poller,
    readable: bool,
) {
    if readable {
        c.tick_read(ctx);
    }
    c.drain_events();
    c.tick_write(ctx);
    c.sync_interest(poller);
}

/// One reactor's readiness loop: park in the poller until a socket is
/// ready or the engine's doorbell rings, then service exactly the
/// reported connections. Exits after `stop` is set, once every
/// connection's pending bytes are flushed (bounded by a deadline).
fn reactor_loop(
    handoff: Receiver<(u64, TcpStream)>,
    conns: Conns,
    ctx: ReactorCtx,
    stop: Arc<AtomicBool>,
    notify: Arc<ReactorNotify>,
    mut poller: Box<dyn Poller>,
) {
    let mut table: HashMap<u64, ConnState> = HashMap::new();
    let mut events: Vec<PollEvent> = Vec::new();
    let mut stop_deadline: Option<Instant> = None;
    loop {
        // adopt freshly accepted connections: service immediately (the
        // client's first frame may already be queued in the kernel —
        // readable-edge information from before registration would
        // otherwise be lost on a level-triggered poller only if the
        // bytes were already drained, which they are not; reading here
        // simply avoids one wait round-trip) and register
        while let Ok((conn_id, stream)) = handoff.try_recv() {
            let (tx, rx) = channel::<Event>();
            lock_conns(&conns).insert(
                conn_id,
                ConnHandle {
                    tx,
                    notify: Arc::clone(&notify),
                },
            );
            let mut c = ConnState::new(conn_id, stream, rx);
            service_conn(&mut c, &ctx, &mut *poller, true);
            table.insert(conn_id, c);
        }
        // engine doorbell: drain exactly the connections with fresh
        // events (no per-connection try_recv sweep)
        for conn_id in notify.take_dirty() {
            if let Some(c) = table.get_mut(&conn_id) {
                service_conn(c, &ctx, &mut *poller, false);
            }
        }
        // socket readiness from the previous wait
        for ev in events.drain(..) {
            if ev.token == WAKE_TOKEN {
                continue; // doorbell/handoff wake, handled above
            }
            if let Some(c) = table.get_mut(&ev.token) {
                service_conn(c, &ctx, &mut *poller, ev.readable);
            }
        }
        // reap finished/dead connections; a dead connection's live
        // sessions are cancelled so their slots free up instead of
        // decoding for nobody
        let reap: Vec<u64> = table
            .iter()
            .filter(|(_, c)| c.reapable())
            .map(|(&id, _)| id)
            .collect();
        for conn_id in reap {
            if let Some(mut c) = table.remove(&conn_id) {
                c.dead = true;
                // drop the poller registration BEFORE the fd closes
                // (the fallback poller has no close-time cleanup)
                c.sync_interest(&mut *poller);
                lock_conns(&conns).remove(&conn_id);
                for (id, si) in c.live.drain() {
                    ctx.shards[si].sched.control(Control::Cancel {
                        conn_id,
                        id,
                    });
                }
            }
        }
        // Relaxed: stop is a latch set once by Server::stop; the
        // deadline below bounds how late a reactor may observe it
        if stop.load(Ordering::Relaxed) {
            let deadline = *stop_deadline.get_or_insert_with(|| {
                Instant::now() + Duration::from_secs(2)
            });
            let drained = table.values().all(|c| c.flushed());
            if drained || Instant::now() > deadline {
                break;
            }
        }
        // park until readiness, a doorbell, or the safety-net timeout
        // (bounds how stale a missed wake can get; it is NOT the
        // service cadence — events and readiness wake immediately)
        let timeout = Duration::from_millis(500);
        if poller.wait(&mut events, Some(timeout)).is_err() {
            // a broken poller cannot drive readiness; keep the server
            // alive by degrading to the doorbell + timeout path
            events.clear();
        }
        // Relaxed: independent monotonic telemetry counter, never
        // used to order other memory
        ctx.io.sweeps.fetch_add(1, Ordering::Relaxed);
    }
    // drop the table: sockets close, channels disconnect
    let mut conns = lock_conns(&conns);
    for conn_id in table.keys() {
        conns.remove(conn_id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_is_deterministic_and_in_range() {
        let prompts = [
            "once there was a red fox",
            "the blue owl is",
            "every morning the wolf",
            "the grey cat is quiet and",
            "",
        ];
        for n in [1usize, 2, 3, 4, 8] {
            for p in &prompts {
                let s = route_shard(p, n, 32);
                assert!(s < n, "shard {s} out of range for {n}");
                // pure function: repeat calls agree
                for _ in 0..3 {
                    assert_eq!(route_shard(p, n, 32), s);
                }
            }
        }
        // a single shard never hashes
        assert_eq!(route_shard("anything", 1, 32), 0);
        assert_eq!(route_shard("anything", 0, 32), 0);
    }

    #[test]
    fn route_window_is_the_first_frame_minus_bos() {
        assert_eq!(route_window(32), 31);
        assert_eq!(route_window(2), 1);
        // degenerate frames still hash at least one byte
        assert_eq!(route_window(1), 1);
        assert_eq!(route_window(0), 1);
    }

    #[test]
    fn shared_prefix_window_colocates() {
        // prompts sharing at least `window` leading bytes must land on
        // the same shard — the property that keeps warm hits local
        let sys = "SYSTEM: you are a terse assistant. ".repeat(2);
        assert!(sys.len() >= 32);
        for n in [2usize, 3, 4, 7] {
            let home = route_shard(&format!("{sys}alpha"), n, 32);
            for suffix in ["beta", "gamma", "a much longer user turn"] {
                assert_eq!(
                    route_shard(&format!("{sys}{suffix}"), n, 32),
                    home,
                    "suffix {suffix:?} broke colocation at {n} shards"
                );
            }
        }
    }

    #[test]
    fn distinct_prefixes_spread_across_shards() {
        // not a strict uniformity claim — just that the hash actually
        // disperses: 32 distinct prefixes must touch ≥ 2 of 4 shards
        let hit: std::collections::HashSet<usize> = (0..32)
            .map(|i| route_shard(&format!("prompt number {i} says"), 4, 32))
            .collect();
        assert!(hit.len() >= 2, "router sent everything to one shard");
    }

    #[test]
    fn short_prompts_hash_their_whole_text() {
        // prompts shorter than the window differ within it → may spread
        let a = route_shard("a", 4, 32);
        let same = (0..8u8).all(|i| {
            route_shard(&((b'a' + i) as char).to_string(), 4, 32) == a
        });
        assert!(!same, "window-clamped hash ignored short-prompt bytes");
    }

    #[test]
    fn kill_water_sits_far_above_the_high_mark() {
        // the safety valve must never fire at backlogs the watermark
        // logic is meant to handle
        assert_eq!(kill_water(8 << 20), 64 << 20);
        // tiny test-sized watermarks still get the 1 MiB floor, so a
        // park cannot be mistaken for a dead consumer mid-test
        assert_eq!(kill_water(4096), 1 << 20);
        assert_eq!(kill_water(0), 1 << 20);
        // saturation, not overflow, at absurd configs
        assert_eq!(kill_water(usize::MAX), usize::MAX);
    }

    /// A ConnState over a real (loopback) socket pair, for unit tests
    /// that need interest/watermark transitions without a server.
    fn test_conn() -> (ConnState, TcpStream, Sender<Event>) {
        let listener = TcpListener::bind("127.0.0.1:0")
            .expect("bind test listener");
        let addr = listener.local_addr().expect("local addr");
        let client = TcpStream::connect(addr).expect("connect");
        let (server, _) = listener.accept().expect("accept");
        let (tx, rx) = channel::<Event>();
        (ConnState::new(7, server, rx), client, tx)
    }

    fn test_shard() -> Shard {
        Shard {
            sched: Arc::new(Scheduler::new(4, Duration::from_millis(4))),
            telemetry: Arc::new(CacheTelemetry::default()),
            gauges: Arc::new(ShardGauges::default()),
            width: 4,
            cache: None,
        }
    }

    fn test_tok() -> Tokenizer {
        Tokenizer {
            vocab: 260,
            bos_id: 256,
            pad_id: 257,
        }
    }

    /// A one-shard ReactorCtx with explicit watermarks and no engine
    /// behind it (controls land in the scheduler and stay there).
    fn test_ctx(high: usize, low: usize) -> ReactorCtx {
        ReactorCtx {
            shards: Arc::new(vec![test_shard()]),
            governor: Arc::new(Governor::new(
                GovernorConfig::default(),
                1,
            )),
            tok: test_tok(),
            route_window: 64,
            max_frame_bytes: 1 << 20,
            conn_buffer_bytes: 1 << 20,
            high_water_bytes: high,
            low_water_bytes: low,
            io: Arc::new(IoStats::default()),
            shutdown: Arc::new(AtomicBool::new(false)),
        }
    }

    #[test]
    fn pick_shard_steals_only_under_an_enabled_governor() {
        // two shards; make shard 0 (everyone's home here is computed
        // by route_shard, so find a prompt homing on the loaded shard)
        let mk_ctx = |enabled: bool| {
            let shards = vec![test_shard(), test_shard()];
            ReactorCtx {
                shards: Arc::new(shards),
                governor: Arc::new(Governor::new(
                    GovernorConfig {
                        enabled,
                        ..GovernorConfig::default()
                    },
                    2,
                )),
                tok: test_tok(),
                route_window: 64,
                max_frame_bytes: 1 << 20,
                conn_buffer_bytes: 1 << 20,
                high_water_bytes: 1 << 20,
                low_water_bytes: 1 << 18,
                io: Arc::new(IoStats::default()),
                shutdown: Arc::new(AtomicBool::new(false)),
            }
        };
        let filler = |id: u64| Pending {
            request: protocol::Request {
                id,
                prompt: "filler".to_string(),
                strategy: "dense".into(),
                lambda: 0.5,
                density: 0.5,
                max_tokens: 4,
                refresh_every: 0,
                cache: crate::engine::prefix_cache::CacheMode::On,
                tier: protocol::Tier::Standard,
            },
            arrived: Instant::now(),
            conn_id: 1,
            stream: false,
            resume_from: 0,
            degraded: false,
            reported_floor: usize::MAX,
        };
        let ctx = mk_ctx(true);
        let prompt = "steal me a shard please";
        let home = route_shard(prompt, 2, ctx.route_window);
        // saturate the home shard's queue well past the default
        // threshold (pressure = 12/4 = 3.0 ≥ 2.0)
        for i in 0..12u64 {
            let _ = ctx.shards[home].sched.submit(filler(i + 1));
        }
        let picked = pick_shard(&ctx, prompt);
        assert_eq!(picked, 1 - home, "idle sibling steals the request");
        assert_eq!(ctx.governor.stolen_requests(1 - home), 1);
        assert_eq!(ctx.governor.stolen_requests(home), 0);

        // disabled governor: the router's choice stands no matter what
        let ctx = mk_ctx(false);
        for i in 0..12u64 {
            let _ = ctx.shards[home].sched.submit(filler(i + 1));
        }
        assert_eq!(pick_shard(&ctx, prompt), home);
        assert_eq!(ctx.governor.stolen_requests(1 - home), 0);
    }

    #[test]
    fn desired_interest_tracks_buffer_and_protocol_state() {
        let (mut c, _client, _tx) = test_conn();
        assert_eq!(c.desired_interest(), Some(Interest::Read));
        c.wbuf.extend_from_slice(b"pending");
        assert_eq!(c.desired_interest(), Some(Interest::ReadWrite));
        c.read_closed = true;
        assert_eq!(c.desired_interest(), Some(Interest::Write));
        c.wpos = c.wbuf.len(); // flushed
        assert_eq!(
            c.desired_interest(),
            None,
            "drained half-closed conn needs no registration \
             (doorbell covers engine events)"
        );
        c.dead = true;
        assert_eq!(c.desired_interest(), None);
    }

    #[test]
    fn sync_interest_registers_modifies_and_deregisters() {
        let (mut c, _client, _tx) = test_conn();
        let mut poller = new_poller();
        c.sync_interest(&mut *poller);
        assert_eq!(c.interest, Some(Interest::Read));
        c.wbuf.extend_from_slice(b"x");
        c.sync_interest(&mut *poller);
        assert_eq!(c.interest, Some(Interest::ReadWrite));
        // no-op when nothing changed
        c.sync_interest(&mut *poller);
        assert_eq!(c.interest, Some(Interest::ReadWrite));
        c.dead = true;
        c.sync_interest(&mut *poller);
        assert_eq!(c.interest, None, "dead conn is deregistered");
    }

    #[test]
    fn watermarks_park_then_resume_byte_identical() {
        let (mut c, mut peer, tx) = test_conn();
        c.mode = Mode::V2;
        c.live.insert(7, 0); // session 7 lives on shard 0
        // high: 256 KiB → kill line 2 MiB; the 1.5 MiB of frames below
        // beats any loopback kernel buffering (≲ a few hundred KiB with
        // a stalled peer) without ever reaching the kill line
        let ctx = test_ctx(256 << 10, 64 << 10);
        let payload = "x".repeat(2048);
        let mut expected: Vec<u8> = Vec::new();
        for i in 0..768u64 {
            let ev = Event::Delta {
                id: 7,
                index: i,
                text: payload.clone(),
            };
            expected.extend_from_slice(ev.to_frame().as_bytes());
            expected.push(b'\n');
            tx.send(ev).expect("enqueue event");
        }
        c.drain_events();
        c.tick_write(&ctx);
        assert!(c.paused, "backlog past the high mark must park");
        assert!(!c.dead, "a slow consumer is parked, never disconnected");
        let controls = ctx.shards[0].sched.take_controls();
        assert!(
            controls.iter().any(|ctl| matches!(
                ctl,
                Control::Park { conn_id: 7, id: 7 }
            )),
            "park control for the live session, got {controls:?}"
        );

        // the stalled peer wakes up and drains: the connection resumes
        // and the stream is byte-identical to what was emitted
        peer.set_read_timeout(Some(Duration::from_secs(10)))
            .expect("read timeout");
        let mut got: Vec<u8> = Vec::new();
        let mut buf = [0u8; 1 << 16];
        while got.len() < expected.len() {
            match peer.read(&mut buf) {
                Ok(0) => panic!("peer saw EOF after {} bytes", got.len()),
                Ok(n) => got.extend_from_slice(&buf[..n]),
                Err(ref e) if e.kind() == ErrorKind::WouldBlock => {}
                Err(e) => panic!("peer read failed: {e}"),
            }
            c.tick_write(&ctx);
        }
        assert!(
            got == expected,
            "resumed stream must be byte-identical ({} vs {} bytes)",
            got.len(),
            expected.len()
        );
        assert!(!c.paused, "draining below the low mark must resume");
        assert!(!c.dead);
        let controls = ctx.shards[0].sched.take_controls();
        assert!(
            controls.iter().any(|ctl| matches!(
                ctl,
                Control::Unpark { conn_id: 7, id: 7 }
            )),
            "unpark control on resume, got {controls:?}"
        );
        let io = ctx.io.snapshot();
        assert_eq!(io.backpressure_pauses, 1, "exactly one park transition");
        assert_eq!(io.backpressure_resumes, 1, "exactly one resume");
    }
}
