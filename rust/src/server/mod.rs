//! Threaded serving layer: TCP listener + scheduler + continuous batcher.
//!
//! # Architecture
//!
//! ```text
//!  conn threads ──parse──▶ Scheduler (FCFS queue) ──admit──▶ Batcher
//!       ▲                                                     │
//!       └───────────── per-conn response channels ◀──retire───┘
//! ```
//!
//! * N acceptor/connection threads parse JSON-line requests
//!   ([`protocol`]) and push them onto the [`scheduler::Scheduler`]
//!   queue;
//! * one engine thread runs the [`batcher::Batcher`] loop: a fixed-width
//!   step-mode decode batch in which every slot is an independent
//!   request. Queued requests are admitted into free slots **mid-flight**
//!   (prefill + KV slot splice), finished slots respond and free
//!   **immediately**, so a short request is never blocked behind a long
//!   one (no head-of-line blocking, unlike the old fused-generate drain
//!   loop that ran every batch to the compiled max length);
//! * **chunked admission** — a prompt longer than the compiled prefill
//!   frame claims its slot and streams in through the `prefill_chunk`
//!   executable, at most `chunk_budget` chunks interleaved per decode
//!   step, while every other slot keeps emitting tokens (no full-batch
//!   prefill stall). Per-chunk local statistics are merged on the host
//!   (`ImportanceMap::merge`) into exactly the aggregate a monolithic
//!   prefill would produce, and the GLASS mask is built once the final
//!   chunk lands. Prompts are accepted up to `max_seq - max_tokens + 1`
//!   encoded tokens (the final token needs no KV write); anything
//!   larger is rejected with an explicit
//!   error — the server never silently truncates a prompt (the old
//!   `prefill_len - 1` silent-tail-truncation ceiling is gone), and
//!   responses carry `prompt_tokens` as proof of full consumption.
//!   Admission overflow (burst wider than the free-slot count) is
//!   re-queued at the scheduler front in FCFS order, never failed;
//! * masks are per-slot, so heterogeneous strategies share a batch; a
//!   request can opt into a periodic **GLASS mask refresh**
//!   (`refresh_every: R`) that re-runs the global-local rank aggregation
//!   every R decoded tokens on blended prompt + decaying-average decode
//!   statistics — the paper's aggregation applied over the generation
//!   horizon, for the long-form scenarios where prompt-only statistics
//!   drift;
//! * **shared-prefix cache** — per cached token prefix the batcher
//!   keeps the KV rows *and* the merged GLASS statistics (plus the
//!   last-position logits), both pure functions of the prefix. At
//!   admission the longest cached prefix of the prompt is spliced in:
//!   an exact full-prompt hit costs **zero** engine calls, a partial
//!   hit resumes the chunked stream after the prefix — continuing the
//!   statistics merge with the same arithmetic a cold stream would
//!   use, so a hit's prompt statistics (and therefore its GLASS mask
//!   and generated tokens) are **bit-identical** to a cold prefill.
//!   Completed-chunk prefixes and cold short prompts are published
//!   back; entries are ref-counted (a resuming stream pins its entry)
//!   and evicted LRU under a byte budget accounted through
//!   [`memsim`](crate::memsim). The scheduler clusters same-prefix
//!   requests and the batcher defers a same-prefix admission while an
//!   earlier one is still publishing, so a shared-system-prompt burst
//!   pays its prefill miss once. Responses carry
//!   `cached_prompt_tokens` / `cache_hits` / `cache_evictions`;
//!   server-level aggregates (hits, misses, inserts, evictions, bytes
//!   resident, entries) are served by the `stats` protocol command.
//!
//! # Knobs and trade-offs
//!
//! * `batch_width` — decode slot count (must fit a compiled
//!   `decode_b{W}`). Wider = more throughput under load, slightly more
//!   per-step work when mostly idle.
//! * scheduler `batch_window` — how long an idle engine waits for an
//!   initial burst to form before starting; admission is continuous
//!   afterwards, so this only shapes cold-start batching (latency ↔
//!   throughput).
//! * `Batcher::chunk_budget` — prefill chunks advanced per decode step
//!   for streaming (long-prompt) admissions; default 1. Higher values
//!   admit long prompts faster at the cost of more prefill work per
//!   decode step (worse inter-token latency for in-flight requests
//!   while a stream is active); 1 bounds the per-step overhead to one
//!   chunk. `overlap_steps` telemetry counts decode steps that ran
//!   concurrently with a stream — the direct no-stall observable.
//! * `refresh_every` (per request) — mask-refresh interval R. Small R
//!   tracks decode-time importance drift closely at the cost of one
//!   selection pass (pure host work, µs-scale) per R tokens; 0 keeps
//!   the prefill-time static mask.
//! * `cache_bytes` (server, [`ServerOptions`]) — shared-prefix cache
//!   budget; 0 disables caching entirely. Bigger budgets keep more
//!   distinct prefixes resident (more hits) at the cost of host
//!   memory; eviction is LRU and never frees an entry a stream is
//!   resuming from.
//! * `cache` (per request) — `on` (read + publish, default),
//!   `readonly` (read, never insert — for traffic that must not
//!   displace hot prefixes), `off` (bypass — for strict cold-start
//!   measurements).
//! * `group_prefixes` (server) — same-prefix clustering/deferral so a
//!   burst of shared-prompt requests pays one miss; disable for strict
//!   FCFS admission order.
//!
//! # Request limits
//!
//! `density` ∈ (0, 1], `lambda` ∈ [0, 1], and `max_tokens` ≥ 1 are
//! enforced at protocol parse time; encoded prompt length (incl. BOS) +
//! `max_tokens` must fit the `max_seq + 1`-position serving capacity
//! (the KV window plus the final write-free token), enforced at
//! admission with an explicit "prompt too long" error.
//!
//! All executables the loop can touch are warmed at startup —
//! `prefill_b{n}` for every admission size, `prefill_chunk_b1` for
//! streaming admissions, and the full-width `decode_b{W}` — so first
//! requests never pay compile latency at any batch size the scheduler
//! can form.

pub mod batcher;
pub mod client;
pub mod protocol;
pub mod scheduler;

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::engine::prefix_cache::{CacheTelemetry, DEFAULT_CACHE_BYTES};
use crate::engine::Engine;
use crate::info;

use batcher::{Batcher, BatcherOptions};
use protocol::{parse_client_line, stats_to_line, ClientLine, Response};
use scheduler::{Pending, Scheduler};

/// Response lines are serialized before entering the per-connection
/// channel, so protocol commands (`stats`) and generation responses
/// share one ordered writer.
type Conns = Arc<Mutex<HashMap<u64, Sender<String>>>>;

/// Construction knobs for [`Server::start_with`].
#[derive(Debug, Clone)]
pub struct ServerOptions {
    /// Decode slot count (must fit a compiled `decode_b{W}`).
    pub batch_width: usize,
    /// Shared-prefix cache byte budget; 0 disables the cache.
    pub cache_bytes: usize,
    /// Cluster same-prefix requests at the scheduler and defer
    /// same-prefix admissions behind an in-flight publisher.
    pub group_prefixes: bool,
}

impl ServerOptions {
    pub fn new(batch_width: usize) -> ServerOptions {
        ServerOptions {
            batch_width,
            cache_bytes: DEFAULT_CACHE_BYTES,
            group_prefixes: true,
        }
    }
}

/// Server handle: bind address + shutdown flag.
pub struct Server {
    pub addr: String,
    shutdown: Arc<AtomicBool>,
    sched: Arc<Scheduler>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start serving on `addr` with default options (cache on).
    pub fn start(engine: Engine, addr: &str, batch_width: usize) -> Result<Server> {
        Server::start_with(engine, addr, ServerOptions::new(batch_width))
    }

    /// Start serving on `addr` (e.g. "127.0.0.1:7433"). Returns once the
    /// listener is bound; serving continues on background threads.
    pub fn start_with(
        engine: Engine,
        addr: &str,
        opts: ServerOptions,
    ) -> Result<Server> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding {addr}"))?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?.to_string();

        // build the batcher up front: loads priors and warms every
        // executable the engine loop can hit (all admission prefill
        // sizes + the full-width decode step)
        let prefill_len = engine.spec().prefill_len;
        let mut engine_loop = Batcher::with_options(
            engine,
            BatcherOptions {
                batch_width: opts.batch_width,
                cache_bytes: opts.cache_bytes,
                chunk_budget: 1,
                group_prefixes: opts.group_prefixes,
            },
        )?;
        let telemetry = engine_loop.telemetry();

        let conns: Conns = Arc::new(Mutex::new(HashMap::new()));
        let group_bytes = if opts.group_prefixes && opts.cache_bytes > 0
        {
            // one prefill frame of shared prompt bytes ≈ one cacheable
            // chunk (byte-level tokenizer)
            prefill_len
        } else {
            0
        };
        let sched = Arc::new(
            Scheduler::new(opts.batch_width, Duration::from_millis(4))
                .with_prefix_grouping(group_bytes),
        );
        let shutdown = Arc::new(AtomicBool::new(false));
        let mut threads = Vec::new();

        // engine thread: continuous batching loop
        {
            let conns = Arc::clone(&conns);
            let sched = Arc::clone(&sched);
            threads.push(std::thread::spawn(move || {
                let mut sink = |conn_id: u64, resp: Response| {
                    if let Some(tx) = conns.lock().unwrap().get(&conn_id) {
                        let _ = tx.send(resp.to_line());
                    }
                };
                engine_loop.run(&sched, &mut sink);
            }));
        }
        // acceptor
        {
            let conns = Arc::clone(&conns);
            let sched = Arc::clone(&sched);
            let shutdown = Arc::clone(&shutdown);
            threads.push(std::thread::spawn(move || {
                let next_conn = AtomicU64::new(1);
                loop {
                    if shutdown.load(Ordering::Relaxed) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let conn_id =
                                next_conn.fetch_add(1, Ordering::Relaxed);
                            let conns = Arc::clone(&conns);
                            let sched = Arc::clone(&sched);
                            let telemetry = Arc::clone(&telemetry);
                            std::thread::spawn(move || {
                                let _ = handle_conn(
                                    stream, conn_id, &conns, &sched,
                                    &telemetry,
                                );
                            });
                        }
                        Err(ref e)
                            if e.kind()
                                == std::io::ErrorKind::WouldBlock =>
                        {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            }));
        }
        info!("server listening on {local}");
        Ok(Server {
            addr: local,
            shutdown,
            sched,
            threads,
        })
    }

    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        self.sched.close();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    conn_id: u64,
    conns: &Conns,
    sched: &Arc<Scheduler>,
    telemetry: &Arc<CacheTelemetry>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let (tx, rx) = channel::<String>();
    conns.lock().unwrap().insert(conn_id, tx);
    let mut writer = stream.try_clone()?;
    // writer thread: one ordered line stream back to the client
    let w = std::thread::spawn(move || {
        for line in rx {
            if writeln!(writer, "{line}").is_err() {
                break;
            }
        }
    });
    let send = |line: String| {
        if let Some(tx) = conns.lock().unwrap().get(&conn_id) {
            let _ = tx.send(line);
        }
    };

    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        match parse_client_line(&line) {
            Ok(ClientLine::Request(request)) => sched.submit(Pending {
                request,
                arrived: Instant::now(),
                conn_id,
            }),
            Ok(ClientLine::Stats { id }) => {
                // answered right here from the shared counters — no
                // round trip through the engine loop
                send(stats_to_line(id, &telemetry.snapshot()));
            }
            Err(e) => {
                // protocol error: respond immediately
                send(Response::err(0, e.to_string()).to_line());
            }
        }
    }
    conns.lock().unwrap().remove(&conn_id);
    let _ = w.join();
    Ok(())
}
