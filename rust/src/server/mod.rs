//! Serving layer: a poll-based **nonblocking reactor** front carrying
//! framed, multiplexed protocol-v2 sessions (and the legacy v1
//! protocol, auto-detected) over per-shard scheduler/batcher pairs
//! behind a prefix-affinity router.
//!
//! # Architecture
//!
//! ```text
//!             accept            round-robin handoff
//!  listener ────────▶ acceptor ─────────────────────┐
//!                                                   ▼
//!  ┌─ reactor thread 0 ──────────────┐   ┌─ reactor thread R-1 ─┐
//!  │ conn table: nonblocking reads,  │   │        ...           │
//!  │ bounded r/w buffers, per-conn   │   └──────────────────────┘
//!  │ protocol state machine (v1|v2)  │
//!  └──────┬───────────────▲──────────┘
//!   submit│/control       │ per-conn event channels
//!         ▼               │
//!  ┌─▶ Scheduler 0 ──admit──▶ Batcher 0 (engine thread: KV, slots,
//!  │                              prefix cache, event emission)
//!  └─▶ Scheduler N-1 ─admit──▶ Batcher N-1
//!     (route_shard: FNV-1a over the prompt's leading bytes)
//! ```
//!
//! * **Reactor threads** (one per shard) own connection state
//!   machines instead of parking one thread per connection: every
//!   socket is `set_nonblocking`, and each reactor's readiness loop
//!   polls its connections for reads, drains each connection's event
//!   channel, and flushes pending writes — sleeping only when a full
//!   pass found no work. An idle connection therefore costs a table
//!   entry, a buffer, and one nonblocking `read` poll per sweep — not
//!   a thread or a stack. The sweep is O(connections) per tick (≥
//!   ~0.5 ms apart when idle), which is cheap into the thousands of
//!   connections; true readiness registration (epoll/kqueue) that
//!   makes idle connections cost nothing per tick is the remaining
//!   ROADMAP item.
//! * **Per-connection buffers are bounded.** The read buffer rejects
//!   any frame larger than `max_frame_bytes` (a client that never
//!   sends a newline, or sends one gigantic line, gets a protocol
//!   error and a closed connection instead of growing server memory
//!   without limit). The write buffer is capped at
//!   `conn_buffer_bytes`: a consumer too slow to drain its own event
//!   stream is disconnected rather than buffered forever.
//! * **Protocol negotiation** happens on the first parsed line of each
//!   connection ([`protocol`]): `"v":2` locks the connection to the
//!   framed multiplexed protocol (interleaved `accepted` / `delta` /
//!   `refresh` / `done` / `error` event frames per session id, plus
//!   client `cancel` and mid-stream `set` control frames); anything
//!   else locks it to v1, which the compatibility shim serves
//!   **bit-identically** to the pre-reactor server — non-terminal
//!   events are suppressed and the terminal event is serialized as the
//!   classic one-line response.
//! * **Routing** is per-request and unchanged from the sharded server:
//!   [`route_shard`] hashes the prompt's leading [`route_window`]
//!   bytes (the first prefill frame's byte span — the system-prefix
//!   window) with FNV-1a, modulo the shard count, so requests sharing
//!   a system prompt colocate on the shard whose prefix cache already
//!   holds their prefix. A pure function of the prompt text:
//!   deterministic across connections, reactors, and restarts.
//!   v2 `cancel`/`set` frames are routed to the shard recorded for
//!   their session at submission (the connection tracks live session
//!   ids); controls ride the shard scheduler's control queue and are
//!   drained by the batcher at the top of every loop iteration, so a
//!   cancel frees its decode slot within one decode step.
//! * Each of the `shards` serving shards owns a full single-owner
//!   serving stack — one [`scheduler::Scheduler`] FCFS queue (+ its
//!   control queue), one engine thread running the
//!   [`batcher::Batcher`] loop over its own `Engine`, KV state, decode
//!   slots, and shared-prefix cache. No cross-shard synchronization
//!   exists on the hot path: GLASS mask refresh, chunked admission,
//!   stats merging, and cache publish/splice all stay shard-local,
//!   preserving every single-owner invariant of the unsharded design.
//!   With the default `shards = 1` the topology (and its behavior, bit
//!   for bit) is exactly the pre-sharding server;
//! * within a shard, the batcher is the same continuous-batching loop
//!   as before: a fixed-width step-mode decode batch in which every
//!   slot is an independent request, queued requests admitted into
//!   free slots **mid-flight**, finished slots retired **immediately**
//!   (no head-of-line blocking), **chunked admission** for prompts
//!   longer than the prefill frame (at most `chunk_budget` chunks per
//!   decode step, other slots keep emitting), per-slot masks with
//!   optional periodic **GLASS mask refresh** (`refresh_every`, now
//!   adjustable mid-stream via v2 `set`), and the per-shard
//!   **shared-prefix cache** (total `cache_bytes` split evenly; exact
//!   hits skip prefill, partial hits resume the chunked stream
//!   bit-identically; ref-counted, LRU under the byte budget). The
//!   cache is indexed by an **edge-compressed radix trie** over token
//!   ids, so `lookup`/`peek_longest`/`insert` walk O(prompt-length)
//!   edges regardless of how many entries are resident — hundreds of
//!   cached prefixes cost a lookup no more than one does.
//! * **Cache persistence** (`--cache-dir`, [`ServerOptions::cache_dir`]):
//!   when set, [`Server::stop`] snapshots each shard's resident prefix
//!   entries to `<cache-dir>/prefix-shard-<i>.gpxs` *after* its engine
//!   loop drains (format documented in
//!   [`prefix_store`](crate::engine::prefix_store); version
//!   [`SNAPSHOT_VERSION`](crate::engine::prefix_store::SNAPSHOT_VERSION),
//!   length-prefixed + FNV-1a-checksummed, written via temp file +
//!   rename). The next startup warm-starts each shard's cache from its
//!   file before serving — [`route_shard`] is deterministic, so every
//!   snapshot lands back on the shard that will serve its prefixes,
//!   and a previously-cached prompt is answered with **zero** engine
//!   prefill calls (`warm_start_hits` in `stats` counts these). A
//!   corrupt, truncated, or model-mismatched snapshot is skipped with
//!   a warning — startup never fails on cache damage, it just serves
//!   cold.
//! * **Resumable sessions** (protocol v2 `resume` frame): a client
//!   whose connection died mid-stream reconnects and replays its
//!   prompt plus the number of deltas already received; the server
//!   re-admits the session like a generate (the prefix cache supplies
//!   the prompt work it already did), re-runs the deterministic
//!   decode, and suppresses the deltas the client already has — the
//!   continued stream carries the original indices and its
//!   concatenation is byte-identical to the uninterrupted stream. See
//!   [`protocol`] for the frame grammar and ordering guarantees.
//! * **Graceful shutdown** ([`Server::stop`]): the acceptor stops
//!   accepting and late frames are refused; every in-flight session
//!   drains to its natural `done`; queued-but-unadmitted requests get
//!   an `error` frame with `retryable: true` (resubmit verbatim
//!   elsewhere); reactors then flush every connection's pending bytes
//!   before exiting.
//!
//! # Knobs and trade-offs
//!
//! * `shards` ([`ServerOptions`], `glass serve --shards N`) — serving
//!   shard count (engine threads AND reactor threads); default 1
//!   preserves the unsharded behavior exactly. More shards = more
//!   engine threads decoding in parallel and more (smaller) prefix
//!   caches; the router keeps warm traffic local.
//! * `batch_width` — decode slot count **per shard** (must fit a
//!   compiled `decode_b{W}`).
//! * `max_frame_bytes` (`--max-frame-bytes`) — largest accepted wire
//!   frame; the per-connection read-buffer bound. Default 1 MiB.
//! * `conn_buffer_bytes` (`--conn-buffer-bytes`) — outbound buffer cap
//!   per connection; a slower consumer is disconnected. Default 8 MiB.
//! * `Batcher::chunk_budget` — prefill chunks advanced per decode step
//!   for streaming (long-prompt) admissions; default 1.
//! * `refresh_every` (per request, adjustable mid-stream with a v2
//!   `set` frame) — mask-refresh interval R; 0 keeps the prefill-time
//!   static mask.
//! * `cache_bytes` (server) — **total** shared-prefix cache budget,
//!   split evenly across shards; 0 disables caching entirely.
//! * `cache_dir` (`--cache-dir`) — directory for persistent prefix
//!   snapshots (one file per shard); unset disables persistence.
//! * `cache` (per request) — `on` (read + publish, default),
//!   `readonly`, `off`.
//! * `group_prefixes` (server) — same-prefix clustering/deferral so a
//!   burst of shared-prompt requests pays one miss.
//!
//! # Request limits
//!
//! `density` ∈ (0, 1], `lambda` ∈ [0, 1], and `max_tokens` ≥ 1 are
//! enforced at protocol parse time; encoded prompt length (incl. BOS) +
//! `max_tokens` must fit the `max_seq + 1`-position serving capacity
//! (the KV window plus the final write-free token), enforced at
//! admission with an explicit "prompt too long" error.
//!
//! # Invariants & enforcement
//!
//! The concurrency invariants this layer leans on are machine-checked
//! by the workspace linter (`cargo run -p glass-lint -- --check`),
//! which CI runs on every push:
//!
//! * **No `.unwrap()`/`.expect(` on serving paths.** Reactor and
//!   engine threads degrade — error frame, reaped connection,
//!   recovered lock — instead of dying; [`lock_conns`] is the
//!   poison-recovery pattern for the shared connection table.
//! * **Every non-`SeqCst` atomic ordering carries a justification
//!   comment** saying why the weaker ordering is sound.
//! * **`thread::sleep` only at annotated parking sites** (the reactor
//!   idle tick, the acceptor's accept backoff, client-side reconnect
//!   backoff) — anywhere else a sleep stalls a whole shard.
//! * **No `MutexGuard` held across socket I/O or sleeps** — lock
//!   scopes stay small and never span blocking calls.
//! * **`unsafe` requires an adjacent `// SAFETY:` comment**, and every
//!   wire key written or read here must appear in [`protocol`]'s
//!   wire-key registry (drift between serializer, client, and docs is
//!   a lint error).
//!
//! Justified deviations are annotated in place —
//! `// lint: allow(no-sleep-outside-reactor) -- reason the invariant
//! holds here` — one rule per annotation; the `-- <reason>` clause is
//! mandatory, and a reasonless or unknown-rule annotation is itself a
//! lint violation (and suppresses nothing). Run Miri and TSan over
//! this module's concurrency tests as described in CONTRIBUTING.md.
//!
//! All executables a shard's loop can touch are warmed at startup, so
//! first requests never pay compile latency (the compiled-executable
//! cache is shared, so warming costs once, not once per shard).

pub mod batcher;
pub mod client;
pub mod protocol;
pub mod scheduler;

use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::engine::prefix_cache::{
    CacheStatsSnapshot, CacheTelemetry, DEFAULT_CACHE_BYTES,
};
use crate::engine::Engine;
use crate::info;
use crate::util::json::Json;

use batcher::{Batcher, BatcherOptions, ShardGauges};
use protocol::{
    client_line_from_json, frame_version, stats_to_line,
    v2_frame_from_json, ClientLine, Event, ShardSnapshot, V2Frame,
    PROTOCOL_V2,
};
use scheduler::{Control, Pending, Scheduler};

/// Default cap on a single wire frame (and the per-connection read
/// buffer): a client that never terminates a line cannot grow server
/// memory past this.
pub const DEFAULT_MAX_FRAME_BYTES: usize = 1 << 20;
/// Default cap on a connection's outbound buffer: a consumer that
/// cannot keep up with its own event stream is disconnected.
pub const DEFAULT_CONN_BUFFER_BYTES: usize = 8 << 20;

/// Per-connection event channels: the batcher threads push [`Event`]s,
/// the owning reactor drains and serializes them in the connection's
/// negotiated protocol.
type Conns = Arc<Mutex<HashMap<u64, Sender<Event>>>>;

/// Lock the shared connection table, recovering from poisoning.
///
/// A thread that panics while holding this mutex poisons it; treating
/// that as fatal (`.unwrap()`) would take down every reactor and
/// engine thread that routes events through the table, turning one
/// shard's bug into a whole-server outage. The table's invariant is
/// re-establishable (a torn entry at worst strands one connection,
/// which the reaper collects), so degrade loudly and keep serving.
fn lock_conns(
    conns: &Mutex<HashMap<u64, Sender<Event>>>,
) -> std::sync::MutexGuard<'_, HashMap<u64, Sender<Event>>> {
    conns.lock().unwrap_or_else(|poisoned| {
        crate::warn_!(
            "connection-table mutex poisoned; recovering the table"
        );
        poisoned.into_inner()
    })
}

/// Router window for a model: the byte span of the first cacheable
/// chunk — one prefill frame minus the BOS token slot (the byte-level
/// tokenizer maps one prompt byte per remaining token). Hashing
/// exactly this span guarantees two prompts that share their first
/// cached chunk also share a shard.
pub fn route_window(prefill_len: usize) -> usize {
    prefill_len.saturating_sub(1).max(1)
}

/// Route a prompt to a serving shard: FNV-1a over the prompt's leading
/// `window` bytes (the system-prefix span — [`route_window`] passes the
/// first prefill frame's byte span, so the hash covers exactly the
/// cacheable leading chunk), modulo the shard count. Prompts sharing at
/// least `window` leading bytes always land on the same shard, which is
/// what keeps shared-prefix cache hits local after the cache budget is
/// split. Deterministic across connections, threads, and restarts;
/// always 0 for a single shard.
pub fn route_shard(prompt: &str, n_shards: usize, window: usize) -> usize {
    if n_shards <= 1 {
        return 0;
    }
    let bytes = prompt.as_bytes();
    let take = bytes.len().min(window.max(1));
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in &bytes[..take] {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h % n_shards as u64) as usize
}

/// Construction knobs for [`Server::start_with`].
#[derive(Debug, Clone)]
pub struct ServerOptions {
    /// Decode slot count per shard (must fit a compiled `decode_b{W}`).
    pub batch_width: usize,
    /// Total shared-prefix cache byte budget, split evenly across
    /// shards; 0 disables the cache.
    pub cache_bytes: usize,
    /// Cluster same-prefix requests at each shard's scheduler and defer
    /// same-prefix admissions behind an in-flight publisher.
    pub group_prefixes: bool,
    /// Serving shard count (engine + reactor threads); 1 = unsharded.
    pub shards: usize,
    /// Largest accepted wire frame; bounds the per-connection read
    /// buffer. Oversized frames are a protocol error that closes the
    /// connection.
    pub max_frame_bytes: usize,
    /// Outbound buffer cap per connection; a consumer that falls this
    /// far behind is disconnected.
    pub conn_buffer_bytes: usize,
    /// Directory for persistent prefix-cache snapshots (`--cache-dir`):
    /// each shard warm-starts from `prefix-shard-<i>.gpxs` here and
    /// [`Server::stop`] rewrites the files after drain. None (default)
    /// disables persistence.
    pub cache_dir: Option<PathBuf>,
}

impl ServerOptions {
    /// Defaults for everything except the batch width.
    pub fn new(batch_width: usize) -> ServerOptions {
        ServerOptions {
            batch_width,
            cache_bytes: DEFAULT_CACHE_BYTES,
            group_prefixes: true,
            shards: 1,
            max_frame_bytes: DEFAULT_MAX_FRAME_BYTES,
            conn_buffer_bytes: DEFAULT_CONN_BUFFER_BYTES,
            cache_dir: None,
        }
    }

    /// Builder-style shard count override.
    pub fn with_shards(mut self, shards: usize) -> ServerOptions {
        self.shards = shards;
        self
    }

    /// Builder-style frame-size cap override.
    pub fn with_max_frame_bytes(mut self, n: usize) -> ServerOptions {
        self.max_frame_bytes = n;
        self
    }

    /// Builder-style persistent-cache directory override.
    pub fn with_cache_dir(
        mut self,
        dir: Option<PathBuf>,
    ) -> ServerOptions {
        self.cache_dir = dir;
        self
    }
}

/// One serving shard's handles, shared between the engine thread that
/// owns the batcher and the reactor threads that submit work, push
/// controls, and answer `stats`.
struct Shard {
    sched: Arc<Scheduler>,
    telemetry: Arc<CacheTelemetry>,
    gauges: Arc<ShardGauges>,
    width: usize,
}

impl Shard {
    /// One consistent stats row: the occupancy pair comes from a
    /// single atomic load ([`ShardGauges::snapshot`]), so a stats call
    /// racing heavy admission can never report `slots_active +
    /// slots_prefilling` above the batch width.
    fn snapshot_row(&self, shard: u64) -> ShardSnapshot {
        let (slots_active, slots_prefilling) = self.gauges.snapshot();
        ShardSnapshot {
            shard,
            queue_depth: self.sched.len() as u64,
            slots_active,
            slots_prefilling,
            batch_width: self.width as u64,
        }
    }
}

/// The `stats` response line: aggregate cache counters plus one
/// consistent per-shard row, assembled through one snapshot path for
/// both protocol versions.
fn stats_line(shards: &[Shard], id: u64) -> String {
    let agg = shards.iter().fold(
        CacheStatsSnapshot::default(),
        |acc, s| acc.merge(&s.telemetry.snapshot()),
    );
    let per: Vec<ShardSnapshot> = shards
        .iter()
        .enumerate()
        .map(|(i, s)| s.snapshot_row(i as u64))
        .collect();
    stats_to_line(id, &agg, &per)
}

/// Server handle: bind address + shutdown machinery.
pub struct Server {
    /// The actually-bound address (resolves a `:0` request).
    pub addr: String,
    /// Stops the acceptor and makes reactors refuse new sessions.
    shutdown: Arc<AtomicBool>,
    /// Tells reactors to flush and exit (set after engines drain).
    reactor_stop: Arc<AtomicBool>,
    shards: Arc<Vec<Shard>>,
    conns: Conns,
    engine_threads: Vec<std::thread::JoinHandle<()>>,
    io_threads: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start serving on `addr` with default options (cache on, 1 shard).
    pub fn start(engine: Engine, addr: &str, batch_width: usize) -> Result<Server> {
        Server::start_with(engine, addr, ServerOptions::new(batch_width))
    }

    /// Start serving on `addr` (e.g. "127.0.0.1:7433"). Returns once the
    /// listener is bound; serving continues on background threads.
    pub fn start_with(
        engine: Engine,
        addr: &str,
        opts: ServerOptions,
    ) -> Result<Server> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding {addr}"))?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?.to_string();

        let n_shards = opts.shards.max(1);
        // split the cache budget evenly; with one shard this is the
        // whole budget (bit-identical to the unsharded server)
        let shard_cache_bytes = opts.cache_bytes / n_shards;
        let prefill_len = engine.spec().prefill_len;

        // build every shard's batcher up front: loads priors and warms
        // every executable an engine loop can hit (the compiled-
        // executable cache is shared across shards, so the warm-up work
        // is paid once)
        let mut batchers = Vec::with_capacity(n_shards);
        let mut shards = Vec::with_capacity(n_shards);
        for shard_id in 0..n_shards {
            // per-shard persistent snapshot: route_shard is
            // deterministic across restarts, so shard i's file always
            // warms the shard that will serve its prefixes
            let snapshot = opts.cache_dir.as_deref().map(|dir| {
                crate::engine::prefix_store::snapshot_path(
                    dir, shard_id,
                )
            });
            let engine_loop = Batcher::with_options(
                engine.clone(),
                BatcherOptions {
                    batch_width: opts.batch_width,
                    cache_bytes: shard_cache_bytes,
                    chunk_budget: 1,
                    group_prefixes: opts.group_prefixes,
                    snapshot_path: snapshot,
                },
            )?;
            let group_bytes =
                if opts.group_prefixes && shard_cache_bytes > 0 {
                    // one prefill frame of shared prompt bytes ≈ one
                    // cacheable chunk (byte-level tokenizer)
                    prefill_len
                } else {
                    0
                };
            shards.push(Shard {
                sched: Arc::new(
                    Scheduler::new(
                        opts.batch_width,
                        Duration::from_millis(4),
                    )
                    .with_prefix_grouping(group_bytes),
                ),
                telemetry: engine_loop.telemetry(),
                gauges: engine_loop.gauges(),
                width: engine_loop.width,
            });
            batchers.push(engine_loop);
        }
        let shards = Arc::new(shards);
        let conns: Conns = Arc::new(Mutex::new(HashMap::new()));
        let shutdown = Arc::new(AtomicBool::new(false));
        let reactor_stop = Arc::new(AtomicBool::new(false));
        let mut engine_threads = Vec::new();
        let mut io_threads = Vec::new();

        // one engine thread per shard: independent continuous-batching
        // loops, no cross-shard synchronization; per-slot events flow
        // to the owning reactor through the per-conn channels
        for (shard_id, mut engine_loop) in batchers.into_iter().enumerate()
        {
            let conns = Arc::clone(&conns);
            let sched = Arc::clone(&shards[shard_id].sched);
            engine_threads.push(std::thread::spawn(move || {
                // per-conn Sender cache: events are emitted per TOKEN,
                // so the shared conns map must not be locked on the
                // per-token hot path — one lock per (conn, shard)
                // pairing, lock-free sends afterwards. conn ids are
                // never reused, so a cached Sender whose receiver was
                // reaped just fails its send and is evicted.
                let mut locals: HashMap<u64, Sender<Event>> =
                    HashMap::new();
                let mut sink = move |conn_id: u64, ev: Event| {
                    if let Some(tx) = locals.get(&conn_id) {
                        if tx.send(ev).is_ok() {
                            return;
                        }
                        locals.remove(&conn_id);
                        return;
                    }
                    if locals.len() > 4096 {
                        // bound the cache across a long-lived server's
                        // conn churn; re-warms on the next event
                        locals.clear();
                    }
                    let tx = lock_conns(&conns).get(&conn_id).cloned();
                    if let Some(tx) = tx {
                        if tx.send(ev).is_ok() {
                            locals.insert(conn_id, tx);
                        }
                    }
                };
                engine_loop.run(&sched, &mut sink);
                // run() returns only after Server::stop drains every
                // in-flight slot, so the snapshot captures the final
                // hot set (no-op unless --cache-dir is configured)
                engine_loop.snapshot_hot();
            }));
        }
        // reactor threads (one per shard): connection state machines
        // over nonblocking sockets
        let mut reactor_txs: Vec<Sender<(u64, TcpStream)>> = Vec::new();
        for _ in 0..n_shards {
            let (tx, rx) = channel::<(u64, TcpStream)>();
            reactor_txs.push(tx);
            let ctx = ReactorCtx {
                shards: Arc::clone(&shards),
                route_window: route_window(prefill_len),
                max_frame_bytes: opts.max_frame_bytes.max(64),
                conn_buffer_bytes: opts.conn_buffer_bytes.max(1 << 16),
                shutdown: Arc::clone(&shutdown),
            };
            let conns = Arc::clone(&conns);
            let stop = Arc::clone(&reactor_stop);
            io_threads.push(std::thread::spawn(move || {
                reactor_loop(rx, conns, ctx, stop)
            }));
        }
        // acceptor: hands fresh sockets to the reactors round-robin
        {
            let shutdown = Arc::clone(&shutdown);
            io_threads.push(std::thread::spawn(move || {
                let next_conn = AtomicU64::new(1);
                loop {
                    // Relaxed: the flag is a pure quit signal checked
                    // every iteration; no data is published under it
                    if shutdown.load(Ordering::Relaxed) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            // Relaxed: only uniqueness of the id
                            // matters, never ordering against other
                            // memory
                            let conn_id =
                                next_conn.fetch_add(1, Ordering::Relaxed);
                            let target =
                                (conn_id as usize) % reactor_txs.len();
                            let _ = reactor_txs[target]
                                .send((conn_id, stream));
                        }
                        Err(ref e)
                            if e.kind() == ErrorKind::WouldBlock =>
                        {
                            // lint: allow(no-sleep-outside-reactor) -- accept
                            // backoff; nothing is held while parked
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            }));
        }
        info!(
            "server listening on {local} ({n_shards} shard{} + reactor{})",
            if n_shards == 1 { "" } else { "s" },
            if n_shards == 1 { "" } else { "s" }
        );
        Ok(Server {
            addr: local,
            shutdown,
            reactor_stop,
            shards,
            conns,
            engine_threads,
            io_threads,
        })
    }

    /// Graceful shutdown: stop accepting, fail queued-but-unadmitted
    /// requests with a retryable error, drain every in-flight session
    /// to its natural terminal event, then flush and join the reactors.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        // close each shard's queue; whatever had not been admitted yet
        // is failed RETRYABLY (in-flight slots keep decoding to done)
        let fail_queued = |shards: &[Shard], conns: &Conns| {
            for shard in shards {
                for p in shard.sched.drain_close() {
                    if let Some(tx) =
                        lock_conns(conns).get(&p.conn_id)
                    {
                        let _ = tx.send(Event::Error {
                            id: p.request.id,
                            error: "server shutting down before \
                                    admission; retry on another server"
                                .to_string(),
                            retryable: true,
                        });
                    }
                }
            }
        };
        fail_queued(&self.shards, &self.conns);
        // (a reactor racing the shutdown flag cannot strand a session:
        // drain_close marks the queue closed under the same mutex
        // Scheduler::submit checks, so any later submit is refused and
        // the reactor fails it retryably itself)
        // engine loops exit once their slots drain and queues are empty
        for t in self.engine_threads.drain(..) {
            let _ = t.join();
        }
        // reactors flush remaining events/bytes, then exit
        self.reactor_stop.store(true, Ordering::Relaxed);
        for t in self.io_threads.drain(..) {
            let _ = t.join();
        }
    }
}

// ------------------------------------------------------------ reactor

/// Immutable per-reactor context.
struct ReactorCtx {
    shards: Arc<Vec<Shard>>,
    route_window: usize,
    max_frame_bytes: usize,
    conn_buffer_bytes: usize,
    /// Set during shutdown: refuse new sessions retryably.
    shutdown: Arc<AtomicBool>,
}

/// Protocol state of one connection (locked by its first parsed line).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Mode {
    Detect,
    V1,
    V2,
}

/// One connection owned by a reactor thread.
struct ConnState {
    conn_id: u64,
    stream: TcpStream,
    rx: Receiver<Event>,
    mode: Mode,
    /// Unparsed inbound bytes (bounded by `max_frame_bytes`).
    rbuf: Vec<u8>,
    /// Bytes of `rbuf` already scanned for a newline (no rescans: a
    /// large frame trickling in over many ticks is scanned once).
    scanned: usize,
    /// Outbound bytes not yet written (bounded by
    /// `conn_buffer_bytes`); `wpos` is the flush cursor.
    wbuf: Vec<u8>,
    wpos: usize,
    /// v2: live session id → owning shard (for control routing).
    live: HashMap<u64, usize>,
    read_closed: bool,
    /// Protocol violation: stop reading, flush, then close.
    closing: bool,
    dead: bool,
}

impl ConnState {
    fn new(conn_id: u64, stream: TcpStream, rx: Receiver<Event>) -> ConnState {
        stream.set_nodelay(true).ok();
        stream.set_nonblocking(true).ok();
        ConnState {
            conn_id,
            stream,
            rx,
            mode: Mode::Detect,
            rbuf: Vec::new(),
            scanned: 0,
            wbuf: Vec::new(),
            wpos: 0,
            live: HashMap::new(),
            read_closed: false,
            closing: false,
            dead: false,
        }
    }

    fn push_line(&mut self, line: &str) {
        self.wbuf.extend_from_slice(line.as_bytes());
        self.wbuf.push(b'\n');
    }

    /// Serialize one SESSION event (from the batcher channel) in the
    /// connection's negotiated protocol: v2 gets every event as its
    /// own frame; v1 (and a connection that never spoke) gets the
    /// compatibility shim — terminal events as the classic response
    /// line, the rest suppressed. A terminal event releases the
    /// session id for reuse. Reactor-originated errors (protocol
    /// violations, duplicate ids, unknown-id controls) must NOT go
    /// through here — they are not session terminals and must not
    /// release a live session's id; use [`ConnState::push_error_frame`].
    fn push_event(&mut self, ev: Event) {
        if ev.is_terminal() {
            self.live.remove(&ev.id());
        }
        self.serialize_event(ev);
    }

    /// Serialize a reactor-originated error frame WITHOUT touching the
    /// live-session map (it is not a session terminal — e.g. the error
    /// rejecting a duplicate id must not release the original live
    /// session's id).
    fn push_error_frame(&mut self, id: u64, error: &str, retryable: bool) {
        self.serialize_event(Event::Error {
            id,
            error: error.to_string(),
            retryable,
        });
    }

    /// Mode-specific wire form of one event: v2 gets every event as
    /// its own frame; v1 (and a connection that never spoke) gets the
    /// compatibility shim — terminal events as the classic response
    /// line, the rest suppressed.
    fn serialize_event(&mut self, ev: Event) {
        match self.mode {
            Mode::V2 => {
                let frame = ev.to_frame();
                self.push_line(&frame);
            }
            Mode::V1 | Mode::Detect => {
                if let Some(resp) = ev.into_response() {
                    let line = resp.to_line();
                    self.push_line(&line);
                }
            }
        }
    }

    /// Nonblocking read + line processing. Returns true if any bytes
    /// or frames moved.
    fn tick_read(&mut self, ctx: &ReactorCtx) -> bool {
        if self.read_closed || self.closing || self.dead {
            return false;
        }
        let mut work = false;
        let mut buf = [0u8; 4096];
        loop {
            match self.stream.read(&mut buf) {
                Ok(0) => {
                    self.read_closed = true;
                    break;
                }
                Ok(n) => {
                    work = true;
                    self.rbuf.extend_from_slice(&buf[..n]);
                    // the read buffer must stay bounded even while the
                    // socket keeps delivering: stop ingesting once the
                    // cap is reached and let line processing below
                    // either consume complete frames or reject the
                    // oversized one — a client streaming a newline-free
                    // line can never outrun the cap check, and one
                    // connection cannot monopolize its reactor's tick
                    if self.rbuf.len() > ctx.max_frame_bytes {
                        break;
                    }
                }
                Err(ref e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => {
                    self.dead = true;
                    return work;
                }
            }
        }
        // complete lines — resume the newline scan where the last tick
        // left off (every buffered byte is examined exactly once), and
        // consume processed lines with ONE front-drain after the loop
        // instead of one O(remaining) memmove per line, so a pipelined
        // burst costs O(bytes), not O(lines × bytes)
        let mut consumed = 0usize;
        while let Some(at) = self.rbuf[self.scanned..]
            .iter()
            .position(|&b| b == b'\n')
        {
            let nl = self.scanned + at;
            let line: Vec<u8> = self.rbuf[consumed..nl].to_vec();
            self.scanned = nl + 1;
            consumed = nl + 1;
            if line.len() > ctx.max_frame_bytes {
                // frame_too_big discards the whole buffer
                self.frame_too_big(ctx, line.len());
                return true;
            }
            match std::str::from_utf8(&line) {
                Ok(text) => self.handle_line(ctx, text),
                Err(_) => {
                    // undecodable input: the pre-reactor server's
                    // BufReader::lines() errored and closed with no
                    // response — v1/Detect keep that bit-identically;
                    // a v2 connection gets an error frame first
                    if self.mode == Mode::V2 {
                        self.protocol_error(
                            0,
                            "frame is not valid UTF-8",
                        );
                    }
                    self.rbuf.clear();
                    self.scanned = 0;
                    self.closing = true;
                }
            }
            work = true;
            if self.closing || self.dead {
                // unprocessed bytes die with the connection
                return work;
            }
        }
        if consumed > 0 {
            self.rbuf.drain(..consumed);
        }
        // everything left was searched and holds no newline
        self.scanned = self.rbuf.len();
        // a partial line may not outgrow the frame cap
        if self.rbuf.len() > ctx.max_frame_bytes {
            self.frame_too_big(ctx, self.rbuf.len());
            work = true;
        }
        work
    }

    fn frame_too_big(&mut self, ctx: &ReactorCtx, got: usize) {
        self.protocol_error(
            0,
            &format!(
                "frame of {got} bytes exceeds max_frame_bytes \
                 ({}); closing connection",
                ctx.max_frame_bytes
            ),
        );
        self.rbuf.clear();
        self.scanned = 0;
        self.closing = true;
    }

    /// Emit a protocol-level error in the connection's current mode.
    fn protocol_error(&mut self, id: u64, msg: &str) {
        self.push_error_frame(id, msg, false);
    }

    fn handle_line(&mut self, ctx: &ReactorCtx, line: &str) {
        let trimmed = line.trim();
        if trimmed.is_empty() {
            return;
        }
        let j = match Json::parse(trimmed) {
            Ok(j) => j,
            Err(e) => {
                self.protocol_error(0, &e.to_string());
                return;
            }
        };
        if self.mode == Mode::Detect {
            // the first parsed line locks the connection's protocol
            match frame_version(&j) {
                Ok(Some(PROTOCOL_V2)) => self.mode = Mode::V2,
                Ok(None) => self.mode = Mode::V1,
                Ok(Some(v)) => {
                    self.protocol_error(
                        0,
                        &format!(
                            "unsupported protocol version {v} (this \
                             server speaks v1 and v2)"
                        ),
                    );
                    return;
                }
                Err(e) => {
                    self.protocol_error(0, &e.to_string());
                    return;
                }
            }
        }
        match self.mode {
            Mode::V1 => self.handle_v1(ctx, &j),
            Mode::V2 => self.handle_v2(ctx, &j),
            Mode::Detect => unreachable!("mode locked above"),
        }
    }

    fn handle_v1(&mut self, ctx: &ReactorCtx, j: &Json) {
        match client_line_from_json(j) {
            Ok(ClientLine::Request(request)) => {
                // Relaxed: advisory fast-path refusal — a submit that
                // races the flag is still refused at the scheduler,
                // which closes its queue under a mutex
                if ctx.shutdown.load(Ordering::Relaxed) {
                    self.push_error_frame(
                        request.id,
                        "server shutting down",
                        true,
                    );
                    return;
                }
                // prefix-affinity routing: a pure function of the
                // prompt text, so same-prefix traffic colocates on the
                // shard whose cache holds (or will hold) its prefix
                let si = route_shard(
                    &request.prompt,
                    ctx.shards.len(),
                    ctx.route_window,
                );
                let id = request.id;
                let accepted = ctx.shards[si].sched.submit(Pending {
                    request,
                    arrived: Instant::now(),
                    conn_id: self.conn_id,
                    stream: false,
                    resume_from: 0,
                });
                if accepted.is_none() {
                    // queue already closed (shutdown won the race)
                    self.push_error_frame(
                        id,
                        "server shutting down",
                        true,
                    );
                    return;
                }
                // best-effort in-flight tracking (v1 ids may repeat on
                // one connection — last wins): lets the reactor cancel
                // a disconnected client's work instead of letting it
                // decode to completion for nobody
                self.live.insert(id, si);
            }
            Ok(ClientLine::Stats { id }) => {
                // answered right here from the shared counters — no
                // round trip through any engine loop
                let line = stats_line(&ctx.shards, id);
                self.push_line(&line);
            }
            Err(e) => self.protocol_error(0, &e.to_string()),
        }
    }

    /// Admit one v2 session (fresh `generate`, or `resume` with a
    /// nonzero delta offset): validate the session id, refuse during
    /// shutdown (retryably), route by prompt prefix, enqueue, and
    /// answer with `accepted`.
    fn submit_session(
        &mut self,
        ctx: &ReactorCtx,
        request: protocol::Request,
        resume_from: u64,
    ) {
        let id = request.id;
        if id == 0 {
            // id 0 is the correlation id of connection-level
            // protocol errors; a session using it could read a
            // reactor-originated error as its terminal frame
            self.push_error_frame(
                0,
                "session id must be >= 1 (0 is reserved for \
                 connection-level errors)",
                false,
            );
            return;
        }
        if self.live.contains_key(&id) {
            // reactor-originated rejection, reported on the
            // RESERVED correlation id 0: using the session's
            // own id would read as the ORIGINAL live session's
            // terminal error frame
            self.push_error_frame(
                0,
                &format!(
                    "duplicate session id {id} (still live on \
                     this connection)"
                ),
                false,
            );
            return;
        }
        // Relaxed: advisory fast-path refusal — a submit that races
        // the flag is still refused at the scheduler, which closes
        // its queue under a mutex
        if ctx.shutdown.load(Ordering::Relaxed) {
            self.push_error_frame(id, "server shutting down", true);
            return;
        }
        let si = route_shard(
            &request.prompt,
            ctx.shards.len(),
            ctx.route_window,
        );
        let submitted = ctx.shards[si].sched.submit(Pending {
            request,
            arrived: Instant::now(),
            conn_id: self.conn_id,
            stream: true,
            resume_from,
        });
        let Some(pos) = submitted else {
            // queue already closed (shutdown won the race):
            // refuse retryably instead of stranding a session
            // nothing will ever drain
            self.push_error_frame(id, "server shutting down", true);
            return;
        };
        self.live.insert(id, si);
        self.push_event(Event::Accepted {
            id,
            queue_pos: pos as u64,
        });
    }

    fn handle_v2(&mut self, ctx: &ReactorCtx, j: &Json) {
        let frame = match v2_frame_from_json(j) {
            Ok(f) => f,
            Err(e) => {
                // best-effort id so the client can correlate the error
                // — UNLESS that id names a live session, whose terminal
                // this error must not impersonate (then it goes to the
                // reserved connection-level id 0)
                let id = j
                    .get("id")
                    .and_then(|v| v.as_usize().ok())
                    .unwrap_or(0) as u64;
                let id =
                    if self.live.contains_key(&id) { 0 } else { id };
                self.protocol_error(id, &e.to_string());
                return;
            }
        };
        match frame {
            V2Frame::Generate(request) => {
                self.submit_session(ctx, request, 0);
            }
            V2Frame::Resume { req, received } => {
                // a resumed session is admitted exactly like a fresh
                // generate (same validation, routing, queueing); the
                // batcher re-runs the deterministic decode and
                // suppresses the `received` deltas the client already
                // consumed, so the stream continues byte-identically
                self.submit_session(ctx, req, received);
            }
            V2Frame::Cancel { id } => match self.live.get(&id).copied() {
                Some(si) => ctx.shards[si].sched.control(
                    Control::Cancel {
                        conn_id: self.conn_id,
                        id,
                    },
                ),
                None => self.push_error_frame(
                    id,
                    &format!("cancel: no live session with id {id}"),
                    false,
                ),
            },
            V2Frame::Set { id, refresh_every } => {
                match self.live.get(&id).copied() {
                    Some(si) => ctx.shards[si].sched.control(
                        Control::SetRefresh {
                            conn_id: self.conn_id,
                            id,
                            refresh_every,
                        },
                    ),
                    None => self.push_error_frame(
                        id,
                        &format!("set: no live session with id {id}"),
                        false,
                    ),
                }
            }
            V2Frame::Stats { id } => {
                let line = stats_line(&ctx.shards, id);
                self.push_line(&line);
            }
        }
    }

    /// Drain this connection's event channel into the write buffer.
    fn drain_events(&mut self) -> bool {
        let mut work = false;
        while let Ok(ev) = self.rx.try_recv() {
            work = true;
            self.push_event(ev);
        }
        work
    }

    /// Nonblocking flush of pending outbound bytes.
    fn tick_write(&mut self, ctx: &ReactorCtx) -> bool {
        let mut work = false;
        while self.wpos < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => {
                    self.dead = true;
                    break;
                }
                Ok(n) => {
                    self.wpos += n;
                    work = true;
                }
                Err(ref e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => {
                    self.dead = true;
                    break;
                }
            }
        }
        if self.wpos == self.wbuf.len() {
            self.wbuf.clear();
            self.wpos = 0;
        } else if self.wpos > (1 << 16) {
            self.wbuf.drain(..self.wpos);
            self.wpos = 0;
        }
        // bounded write buffer: a consumer that cannot drain its own
        // event stream is disconnected, not buffered without limit
        if self.wbuf.len() - self.wpos > ctx.conn_buffer_bytes {
            self.dead = true;
        }
        work
    }

    fn flushed(&self) -> bool {
        self.wpos == self.wbuf.len()
    }

    /// Should this connection be dropped from the table?
    fn reapable(&self) -> bool {
        self.dead
            || (self.closing && self.flushed())
            || (self.read_closed && self.live.is_empty() && self.flushed())
    }
}

/// One reactor's readiness loop: poll nonblocking sockets for frames,
/// drain event channels, flush writes; sleep only when a full pass
/// found nothing to do. Exits after `stop` is set, once every
/// connection's pending bytes are flushed (bounded by a deadline).
fn reactor_loop(
    handoff: Receiver<(u64, TcpStream)>,
    conns: Conns,
    ctx: ReactorCtx,
    stop: Arc<AtomicBool>,
) {
    let mut table: Vec<ConnState> = Vec::new();
    let mut stop_deadline: Option<Instant> = None;
    loop {
        let mut work = false;
        // adopt freshly accepted connections
        while let Ok((conn_id, stream)) = handoff.try_recv() {
            let (tx, rx) = channel::<Event>();
            lock_conns(&conns).insert(conn_id, tx);
            table.push(ConnState::new(conn_id, stream, rx));
            work = true;
        }
        for c in table.iter_mut() {
            work |= c.tick_read(&ctx);
            work |= c.drain_events();
            work |= c.tick_write(&ctx);
        }
        // reap finished/dead connections; a dead connection's live
        // sessions are cancelled so their slots free up instead of
        // decoding for nobody
        let mut i = 0;
        while i < table.len() {
            if table[i].reapable() {
                let c = table.swap_remove(i);
                lock_conns(&conns).remove(&c.conn_id);
                for (id, si) in c.live {
                    ctx.shards[si].sched.control(Control::Cancel {
                        conn_id: c.conn_id,
                        id,
                    });
                }
                work = true;
            } else {
                i += 1;
            }
        }
        // Relaxed: stop is a latch set once by Server::stop; the
        // deadline below bounds how late a reactor may observe it
        if stop.load(Ordering::Relaxed) {
            let deadline = *stop_deadline.get_or_insert_with(|| {
                Instant::now() + Duration::from_secs(2)
            });
            let drained = table.iter().all(|c| c.flushed());
            if drained || Instant::now() > deadline {
                break;
            }
        }
        if !work {
            // lint: allow(no-sleep-outside-reactor) -- the reactor's
            // own idle tick: a full pass found no work, no lock held
            std::thread::sleep(Duration::from_micros(500));
        }
    }
    // drop the table: sockets close, channels disconnect
    let mut conns = lock_conns(&conns);
    for c in &table {
        conns.remove(&c.conn_id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_is_deterministic_and_in_range() {
        let prompts = [
            "once there was a red fox",
            "the blue owl is",
            "every morning the wolf",
            "the grey cat is quiet and",
            "",
        ];
        for n in [1usize, 2, 3, 4, 8] {
            for p in &prompts {
                let s = route_shard(p, n, 32);
                assert!(s < n, "shard {s} out of range for {n}");
                // pure function: repeat calls agree
                for _ in 0..3 {
                    assert_eq!(route_shard(p, n, 32), s);
                }
            }
        }
        // a single shard never hashes
        assert_eq!(route_shard("anything", 1, 32), 0);
        assert_eq!(route_shard("anything", 0, 32), 0);
    }

    #[test]
    fn route_window_is_the_first_frame_minus_bos() {
        assert_eq!(route_window(32), 31);
        assert_eq!(route_window(2), 1);
        // degenerate frames still hash at least one byte
        assert_eq!(route_window(1), 1);
        assert_eq!(route_window(0), 1);
    }

    #[test]
    fn shared_prefix_window_colocates() {
        // prompts sharing at least `window` leading bytes must land on
        // the same shard — the property that keeps warm hits local
        let sys = "SYSTEM: you are a terse assistant. ".repeat(2);
        assert!(sys.len() >= 32);
        for n in [2usize, 3, 4, 7] {
            let home = route_shard(&format!("{sys}alpha"), n, 32);
            for suffix in ["beta", "gamma", "a much longer user turn"] {
                assert_eq!(
                    route_shard(&format!("{sys}{suffix}"), n, 32),
                    home,
                    "suffix {suffix:?} broke colocation at {n} shards"
                );
            }
        }
    }

    #[test]
    fn distinct_prefixes_spread_across_shards() {
        // not a strict uniformity claim — just that the hash actually
        // disperses: 32 distinct prefixes must touch ≥ 2 of 4 shards
        let hit: std::collections::HashSet<usize> = (0..32)
            .map(|i| route_shard(&format!("prompt number {i} says"), 4, 32))
            .collect();
        assert!(hit.len() >= 2, "router sent everything to one shard");
    }

    #[test]
    fn short_prompts_hash_their_whole_text() {
        // prompts shorter than the window differ within it → may spread
        let a = route_shard("a", 4, 32);
        let same = (0..8u8).all(|i| {
            route_shard(&((b'a' + i) as char).to_string(), 4, 32) == a
        });
        assert!(!same, "window-clamped hash ignored short-prompt bytes");
    }

    #[test]
    fn options_default_to_one_shard_with_bounded_buffers() {
        let o = ServerOptions::new(4);
        assert_eq!(o.shards, 1, "default must preserve the unsharded server");
        assert_eq!(o.max_frame_bytes, DEFAULT_MAX_FRAME_BYTES);
        assert_eq!(o.conn_buffer_bytes, DEFAULT_CONN_BUFFER_BYTES);
        let o = o.with_shards(4).with_max_frame_bytes(4096);
        assert_eq!(o.shards, 4);
        assert_eq!(o.max_frame_bytes, 4096);
    }
}
