//! Client for the serving wire protocol (used by examples, the
//! integration tests, and the serving benchmark).
//!
//! One [`Client`] speaks either protocol: [`Client::connect`] opens a
//! legacy v1 (one-shot blocking) connection, [`Client::connect_v2`] a
//! framed multiplexed v2 connection. On v2 the primitive is
//! [`Client::generate_stream`] — start a session and consume its
//! `accepted`/`queue`/`delta`/`refresh` events incrementally with
//! [`Client::next_event`] (`queue` frames report the session's
//! admission-queue position while a saturated server holds it; they
//! carry no text and every blocking collector skips them) — and the
//! old blocking methods
//! ([`Client::call`], [`Client::call_many`], [`Client::recv`]) are
//! reimplemented on top of the event stream: they simply discard
//! non-terminal events and return the `done` frame's response, so the
//! same test/bench code runs against both protocols.
//! [`Client::next_event`] and [`Client::stats_full`] buffer other
//! sessions' frames per-session rather than dropping them (a consumed
//! terminal clears its session's buffer); the blocking collectors
//! ([`Client::recv`]/[`Client::call_many`]) discard non-terminal
//! frames they read, so don't interleave them with a
//! [`Client::generate_stream`] whose deltas you still want.
//!
//! For interruption-tolerant streaming there is
//! [`Client::call_resuming`]: where [`Client::call`] treats every
//! `error` frame as terminal (even `retryable: true` shutdown drains)
//! and dies with its socket, `call_resuming` reconnects with bounded
//! exponential backoff and continues the session via the v2 `resume`
//! frame ([`Client::resume`]) — the assembled delta text is
//! byte-identical to an uninterrupted stream.

use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::protocol::{
    cancel_frame, parse_stats_line, set_frame, stats_frame, Event,
    Request, Response, ShardSnapshot, Tier,
};
use crate::engine::prefix_cache::{CacheMode, CacheStatsSnapshot};
use crate::util::json::Json;

/// One wire-protocol connection (v1 one-shot or v2 streaming); see
/// the module docs for which methods fit which protocol.
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
    /// Connect target, kept for reconnect-and-resume
    /// ([`Client::call_resuming`]).
    addr: String,
    next_id: u64,
    /// Speak framed v2 instead of one-shot v1.
    v2: bool,
    /// v2: buffered events for sessions other than the one currently
    /// being waited on.
    inbox: HashMap<u64, VecDeque<Event>>,
}

impl Client {
    /// Connect speaking the legacy v1 protocol (one request line → one
    /// response line).
    pub fn connect(addr: &str) -> Result<Client> {
        Client::connect_proto(addr, false)
    }

    /// Connect speaking framed protocol v2 (multiplexed streaming
    /// sessions; see [`super::protocol`] for the frame grammar).
    pub fn connect_v2(addr: &str) -> Result<Client> {
        Client::connect_proto(addr, true)
    }

    fn connect_proto(addr: &str, v2: bool) -> Result<Client> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to {addr}"))?;
        stream.set_nodelay(true).ok();
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client {
            stream,
            reader,
            addr: addr.to_string(),
            next_id: 1,
            v2,
            inbox: HashMap::new(),
        })
    }

    /// Drop the current socket and open a fresh connection to the same
    /// address with the same protocol. The inbox is cleared — buffered
    /// events belong to sessions of the dead connection.
    pub fn reconnect(&mut self) -> Result<()> {
        let fresh = Client::connect_proto(&self.addr, self.v2)?;
        self.stream = fresh.stream;
        self.reader = fresh.reader;
        self.inbox.clear();
        Ok(())
    }

    /// Is this a v2 (streaming) connection?
    pub fn is_v2(&self) -> bool {
        self.v2
    }

    fn fresh_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    fn read_line(&mut self) -> Result<String> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        if n == 0 {
            bail!("server closed connection");
        }
        Ok(line)
    }

    /// Send one request (non-blocking with respect to the response).
    /// On a v2 connection this starts a streaming session; consume its
    /// events with [`Client::next_event`] or collapse them with
    /// [`Client::recv`].
    pub fn send(&mut self, mut req: Request) -> Result<u64> {
        if req.id == 0 {
            req.id = self.fresh_id();
        }
        let line = if self.v2 {
            req.to_v2_frame()
        } else {
            req.to_line()
        };
        writeln!(self.stream, "{line}")?;
        Ok(req.id)
    }

    /// Start a streaming session (v2 only): returns the session id to
    /// pass to [`Client::next_event`].
    pub fn generate_stream(&mut self, req: Request) -> Result<u64> {
        if !self.v2 {
            bail!("generate_stream requires a v2 connection");
        }
        self.send(req)
    }

    /// Resume an interrupted streaming session (v2 only, typically
    /// after [`Client::reconnect`]): replays the original request plus
    /// the number of delta frames already consumed. The server
    /// re-admits the session (the prefix cache supplies the prompt
    /// work it already did) and continues the delta stream at index
    /// `received`, so the concatenation of pre-interruption and
    /// post-resume deltas is byte-identical to the uninterrupted
    /// stream. Returns the session id for [`Client::next_event`].
    pub fn resume(
        &mut self,
        mut req: Request,
        received: u64,
    ) -> Result<u64> {
        if !self.v2 {
            bail!("resume requires a v2 connection");
        }
        if req.id == 0 {
            req.id = self.fresh_id();
        }
        writeln!(self.stream, "{}", req.to_v2_resume_frame(received))?;
        Ok(req.id)
    }

    /// Round-trip one streaming request, surviving interruptions (v2
    /// only). [`Client::call`] treats EVERY `error` frame as terminal
    /// — including the `retryable: true` errors a draining server
    /// sends for not-yet-admitted work — and an io failure kills it
    /// outright. This collector instead reconnects with exponential
    /// backoff (10 ms doubling, capped at 500 ms) and sends a `resume`
    /// frame carrying the delta count already consumed, so the
    /// assembled text stays byte-identical to an uninterrupted
    /// stream. At most `max_reconnects` reconnect attempts; a
    /// non-retryable error frame fails immediately. Returns the
    /// delta-assembled text (what a streaming consumer displayed)
    /// alongside the terminal response.
    pub fn call_resuming(
        &mut self,
        mut req: Request,
        max_reconnects: usize,
    ) -> Result<(String, Response)> {
        if !self.v2 {
            bail!("call_resuming requires a v2 connection");
        }
        if req.id == 0 {
            req.id = self.fresh_id();
        }
        let id = req.id;
        let mut received: u64 = 0;
        let mut text = String::new();
        let mut attempts = 0usize;
        let mut delay = Duration::from_millis(10);
        self.send(req.clone())?;
        loop {
            let failed = match self.next_event(id) {
                Ok(Event::Delta { index, text: t, .. }) => {
                    if index != received {
                        bail!(
                            "session {id}: delta index {index}, \
                             expected {received}"
                        );
                    }
                    text.push_str(&t);
                    received += 1;
                    continue;
                }
                Ok(Event::Done(resp)) => return Ok((text, resp)),
                Ok(Event::Error {
                    error,
                    retryable: false,
                    ..
                }) => bail!("session {id} failed: {error}"),
                // retryable error (shutdown drain, engine hiccup) —
                // reconnect and resume
                Ok(Event::Error { error, .. }) => error,
                // accepted / queue / refresh frames carry no text
                Ok(_) => continue,
                // io failure: dropped connection, closed socket
                Err(e) => e.to_string(),
            };
            loop {
                attempts += 1;
                if attempts > max_reconnects {
                    bail!(
                        "session {id}: gave up after {max_reconnects} \
                         reconnect attempts (last error: {failed})"
                    );
                }
                // lint: allow(no-sleep-outside-reactor) -- client-side
                // reconnect backoff; no server resource is held
                std::thread::sleep(delay);
                delay = (delay * 2).min(Duration::from_millis(500));
                if self.reconnect().is_err() {
                    continue;
                }
                let frame = req.to_v2_resume_frame(received);
                if writeln!(self.stream, "{frame}").is_ok() {
                    break;
                }
            }
        }
    }

    /// Cancel a live session (v2 only). The session's terminal frame —
    /// a `done` with finish "cancel", or a no-op `error` if the id is
    /// unknown/finished — still arrives through the event stream.
    pub fn cancel(&mut self, id: u64) -> Result<()> {
        if !self.v2 {
            bail!("cancel requires a v2 connection");
        }
        writeln!(self.stream, "{}", cancel_frame(id))?;
        Ok(())
    }

    /// Adjust `refresh_every` for a live session mid-stream (v2 only).
    pub fn set_refresh(&mut self, id: u64, refresh_every: usize) -> Result<()> {
        if !self.v2 {
            bail!("set requires a v2 connection");
        }
        writeln!(self.stream, "{}", set_frame(id, refresh_every))?;
        Ok(())
    }

    /// Read the next event frame off the wire (v2).
    fn read_event(&mut self) -> Result<Event> {
        let line = self.read_line()?;
        let j = Json::parse(line.trim())?;
        Event::parse_frame(&j)
    }

    /// Next event for session `id` (v2): drains the per-session buffer
    /// first, then reads frames off the wire — buffering other
    /// sessions' frames rather than dropping them. Consuming a
    /// session's terminal clears its buffer slot, so a reused id never
    /// sees a previous session's stale events.
    pub fn next_event(&mut self, id: u64) -> Result<Event> {
        if let Some(q) = self.inbox.get_mut(&id) {
            if let Some(ev) = q.pop_front() {
                if q.is_empty() {
                    self.inbox.remove(&id);
                }
                return Ok(ev);
            }
            self.inbox.remove(&id);
        }
        loop {
            let ev = self.read_event()?;
            if ev.id() == id {
                return Ok(ev);
            }
            self.inbox.entry(ev.id()).or_default().push_back(ev);
        }
    }

    /// Read the next COMPLETED response: on v1 the next response line;
    /// on v2 the next terminal event of any session (non-terminal
    /// events are discarded — use [`Client::next_event`] to observe
    /// them).
    pub fn recv(&mut self) -> Result<Response> {
        if !self.v2 {
            let line = self.read_line()?;
            return Response::parse(line.trim());
        }
        // drain any buffered terminal first (sessions observed while
        // waiting on another id), dropping that session's preceding
        // non-terminal events with it — otherwise they would sit in
        // the inbox forever and leak into a later session reusing the
        // same id
        let buffered = self.inbox.iter_mut().find_map(|(&id, q)| {
            let at = q.iter().position(|ev| ev.is_terminal())?;
            // dropping the non-terminals first leaves the terminal at
            // the front, so no position is ever out of date
            q.drain(..at);
            q.pop_front().map(|ev| (id, ev))
        });
        if let Some((id, ev)) = buffered {
            if self.inbox.get(&id).is_some_and(|q| q.is_empty()) {
                self.inbox.remove(&id);
            }
            if let Some(resp) = ev.into_response() {
                return Ok(resp);
            }
        }
        loop {
            if let Some(resp) = self.read_event()?.into_response() {
                return Ok(resp);
            }
        }
    }

    /// Round-trip a single request (blocking, either protocol).
    pub fn call(&mut self, req: Request) -> Result<Response> {
        let id = self.send(req)?;
        if self.v2 {
            loop {
                if let Some(resp) =
                    self.next_event(id)?.into_response()
                {
                    return Ok(resp);
                }
            }
        }
        let resp = self.recv()?;
        if resp.id != id && resp.id != 0 {
            bail!("response id {} != request id {id}", resp.id);
        }
        Ok(resp)
    }

    /// Round-trip the `stats` command: server-level cache counters
    /// (summed across shards). See [`Client::stats_full`] for the
    /// per-shard breakdown.
    pub fn stats(&mut self) -> Result<CacheStatsSnapshot> {
        Ok(self.stats_full()?.0)
    }

    /// Round-trip the `stats` command, keeping the per-shard counters
    /// (queue depth, slot occupancy) alongside the aggregate cache
    /// snapshot. Works on both protocols (the stats response line is
    /// identical); on v2, event frames of in-flight sessions arriving
    /// first are buffered, not lost.
    pub fn stats_full(
        &mut self,
    ) -> Result<(CacheStatsSnapshot, Vec<ShardSnapshot>)> {
        let id = self.fresh_id();
        if self.v2 {
            writeln!(self.stream, "{}", stats_frame(id))?;
        } else {
            writeln!(self.stream, "{{\"cmd\":\"stats\",\"id\":{id}}}")?;
        }
        loop {
            let line = self.read_line()?;
            let trimmed = line.trim();
            if self.v2 {
                // an in-flight session's event may interleave before
                // the stats line: buffer it and keep reading
                let j = Json::parse(trimmed)?;
                if j.get("ev").is_some() {
                    let ev = Event::parse_frame(&j)?;
                    self.inbox.entry(ev.id()).or_default().push_back(ev);
                    continue;
                }
            }
            let (resp_id, snap, shards) = parse_stats_line(trimmed)?;
            if resp_id != id {
                bail!("stats response id {resp_id} != request id {id}");
            }
            return Ok((snap, shards));
        }
    }

    /// Pipeline many requests, returning responses keyed by id with
    /// per-request wall-clock latency measured from send to receive
    /// completion of that id. Works on both protocols.
    pub fn call_many(
        &mut self,
        reqs: Vec<Request>,
    ) -> Result<Vec<(Response, Duration)>> {
        let t0 = Instant::now();
        let mut sent = HashMap::new();
        for r in reqs {
            let id = self.send(r)?;
            sent.insert(id, t0.elapsed());
        }
        let mut out = Vec::with_capacity(sent.len());
        for _ in 0..sent.len() {
            let resp = self.recv()?;
            let sent_at = sent.get(&resp.id).copied().unwrap_or_default();
            out.push((resp, t0.elapsed() - sent_at));
        }
        Ok(out)
    }
}

/// Convenience request builder. Defaults to the `standard` SLO tier;
/// use [`request_tiered`] to pick one explicitly.
pub fn request(prompt: &str, strategy: &str, density: f64) -> Request {
    request_tiered(prompt, strategy, density, Tier::Standard)
}

/// [`request`] with an explicit SLO tier (see
/// [`super::protocol::Tier`] for the governor semantics).
pub fn request_tiered(
    prompt: &str,
    strategy: &str,
    density: f64,
    tier: Tier,
) -> Request {
    Request {
        id: 0,
        prompt: prompt.to_string(),
        strategy: strategy.to_string(),
        lambda: 0.5,
        density,
        max_tokens: 64,
        refresh_every: 0,
        cache: CacheMode::On,
        tier,
    }
}
