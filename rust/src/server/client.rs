//! Blocking client for the JSON-line protocol (used by examples, the
//! integration tests, and the serving benchmark).

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::protocol::{
    parse_stats_line, Request, Response, ShardSnapshot,
};
use crate::engine::prefix_cache::{CacheMode, CacheStatsSnapshot};

pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
    next_id: u64,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to {addr}"))?;
        stream.set_nodelay(true).ok();
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client {
            stream,
            reader,
            next_id: 1,
        })
    }

    fn fresh_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Send one request (non-blocking with respect to the response).
    pub fn send(&mut self, mut req: Request) -> Result<u64> {
        if req.id == 0 {
            req.id = self.fresh_id();
        }
        writeln!(self.stream, "{}", req.to_line())?;
        Ok(req.id)
    }

    /// Read the next response line.
    pub fn recv(&mut self) -> Result<Response> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        if n == 0 {
            bail!("server closed connection");
        }
        Response::parse(line.trim())
    }

    /// Round-trip a single request.
    pub fn call(&mut self, req: Request) -> Result<Response> {
        let id = self.send(req)?;
        let resp = self.recv()?;
        if resp.id != id && resp.id != 0 {
            bail!("response id {} != request id {id}", resp.id);
        }
        Ok(resp)
    }

    /// Round-trip the `stats` command: server-level cache counters
    /// (summed across shards). See [`Client::stats_full`] for the
    /// per-shard breakdown.
    pub fn stats(&mut self) -> Result<CacheStatsSnapshot> {
        Ok(self.stats_full()?.0)
    }

    /// Round-trip the `stats` command, keeping the per-shard counters
    /// (queue depth, slot occupancy) alongside the aggregate cache
    /// snapshot.
    pub fn stats_full(
        &mut self,
    ) -> Result<(CacheStatsSnapshot, Vec<ShardSnapshot>)> {
        let id = self.fresh_id();
        writeln!(self.stream, "{{\"cmd\":\"stats\",\"id\":{id}}}")?;
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        if n == 0 {
            bail!("server closed connection");
        }
        let (resp_id, snap, shards) = parse_stats_line(line.trim())?;
        if resp_id != id {
            bail!("stats response id {resp_id} != request id {id}");
        }
        Ok((snap, shards))
    }

    /// Pipeline many requests, returning responses keyed by id with
    /// per-request wall-clock latency measured from send to receive
    /// completion of that id.
    pub fn call_many(
        &mut self,
        reqs: Vec<Request>,
    ) -> Result<Vec<(Response, Duration)>> {
        let t0 = Instant::now();
        let mut sent = HashMap::new();
        for r in reqs {
            let id = self.send(r)?;
            sent.insert(id, t0.elapsed());
        }
        let mut out = Vec::with_capacity(sent.len());
        for _ in 0..sent.len() {
            let resp = self.recv()?;
            let sent_at = sent.get(&resp.id).copied().unwrap_or_default();
            out.push((resp, t0.elapsed() - sent_at));
        }
        Ok(out)
    }
}

/// Convenience request builder.
pub fn request(prompt: &str, strategy: &str, density: f64) -> Request {
    Request {
        id: 0,
        prompt: prompt.to_string(),
        strategy: strategy.to_string(),
        lambda: 0.5,
        density,
        max_tokens: 64,
        refresh_every: 0,
        cache: CacheMode::On,
    }
}
